#!/usr/bin/env bash
# Crash/resume smoke: run the same seeded dynamic workload twice —
# once uninterrupted as the reference, once SIGKILLed mid-run and then
# resumed from its newest RVCK checkpoint — and require the resumed
# run to land on the reference's quality:
#
#   |local_edges(resumed) - local_edges(reference)| <= 3% (relative)
#   mnl(resumed) <= 1.10 x mnl(reference)
#
# kill -9 is deliberate: no atexit, no flush, no graceful shutdown —
# durability must come entirely from the atomic tmp+rename checkpoint
# writes. Mid-run progress is read from the live /healthz endpoint
# (the PR-8 telemetry plane), not from buffered stdout. Requires
# cargo, curl, python3.
#
#   scripts/ci_crash_smoke.sh [--vertices N] [--epochs N]
set -euo pipefail

cd "$(dirname "$0")/.."

VERTICES=16384
EPOCHS=20
while [ $# -gt 0 ]; do
    case "$1" in
        --vertices) VERTICES="$2"; shift ;;
        --epochs) EPOCHS="$2"; shift ;;
        *) echo "unknown flag: $1" >&2; exit 2 ;;
    esac
    shift
done

WORK="$(mktemp -d)"
RUN_PID=""
cleanup() {
    [ -n "$RUN_PID" ] && kill "$RUN_PID" 2>/dev/null || true
    rm -rf "$WORK"
}
trap cleanup EXIT

# All three runs share the exact same workload: seeded churn over the
# same surrogate graph, so batches replay bit-for-bit on resume.
run_dynamic() {
    (cd rust && cargo run --release --quiet -- dynamic \
        --graph so --vertices "$VERTICES" --parts 8 --seed 42 \
        --churn uniform:0.05 --epochs "$EPOCHS" --repair-steps 8 \
        "$@")
}

(cd rust && cargo build --release --quiet)

echo "== reference: uninterrupted run ==" >&2
run_dynamic >"$WORK/ref.txt" 2>"$WORK/ref.err"
grep '^epoch ' "$WORK/ref.txt" | tail -n 1 >&2

echo "== victim: same run, checkpointed, killed -9 mid-flight ==" >&2
run_dynamic --checkpoint "$WORK/ckpt" --checkpoint-every 2 \
    --metrics-addr 127.0.0.1:0 \
    --obs-log "$WORK/victim.jsonl" --diag \
    >"$WORK/victim.txt" 2>"$WORK/victim.err" &
RUN_PID=$!

# The kernel-assigned telemetry port is echoed on stderr once bound.
BASE=""
for _ in $(seq 1 600); do
    BASE="$(sed -n 's#^metrics: serving \(http://[^/]*\)/metrics$#\1#p' \
        "$WORK/victim.err" | head -n 1)"
    [ -n "$BASE" ] && break
    if ! kill -0 "$RUN_PID" 2>/dev/null; then
        echo "error: victim exited before announcing the metrics address" >&2
        cat "$WORK/victim.err" >&2
        exit 1
    fi
    sleep 0.05
done
[ -n "$BASE" ] || { echo "error: no 'metrics: serving' line after 30s" >&2; exit 1; }

# Poll live /healthz progress until mid-run (epoch >= 5) AND at least
# one epoch-cadence snapshot is durable, then yank with SIGKILL.
MID_SEEN=0
for _ in $(seq 1 600); do
    EPOCH="$(curl -fsS --max-time 5 "$BASE/healthz" 2>/dev/null \
        | python3 -c 'import json,sys; print(json.load(sys.stdin).get("epoch", 0))' \
        2>/dev/null || echo 0)"
    if [ "${EPOCH:-0}" -ge 5 ] && ls "$WORK/ckpt"/ckpt-*.rvck >/dev/null 2>&1; then
        MID_SEEN=1
        break
    fi
    if ! kill -0 "$RUN_PID" 2>/dev/null; then
        echo "error: victim run finished before it could be killed;" \
             "raise --epochs or --vertices" >&2
        cat "$WORK/victim.err" >&2
        exit 1
    fi
    sleep 0.05
done
if [ "$MID_SEEN" != 1 ]; then
    echo "error: victim never reached epoch 5 with a durable checkpoint in 30s" >&2
    cat "$WORK/victim.err" >&2
    exit 1
fi
echo "== /healthz reports epoch $EPOCH; killing -9 ==" >&2
kill -9 "$RUN_PID"
wait "$RUN_PID" 2>/dev/null || true
RUN_PID=""

ls "$WORK/ckpt"/ckpt-*.rvck >/dev/null 2>&1 || {
    echo "error: no checkpoint files survived the kill" >&2
    exit 1
}
echo "== checkpoints on disk: $(ls "$WORK/ckpt" | tr '\n' ' ')==" >&2

echo "== resume: finishing the victim's run from its checkpoint ==" >&2
run_dynamic --checkpoint "$WORK/ckpt" --checkpoint-every 2 --resume \
    --obs-log "$WORK/resumed.jsonl" --diag \
    >"$WORK/resumed.txt" 2>"$WORK/resumed.err"
grep -q 'resumed from checkpoint' "$WORK/resumed.txt" || {
    echo "error: resumed run did not pick up the checkpoint" >&2
    cat "$WORK/resumed.txt" "$WORK/resumed.err" >&2
    exit 1
}
grep '^epoch ' "$WORK/resumed.txt" | tail -n 1 >&2

python3 - "$WORK/ref.txt" "$WORK/resumed.txt" <<'PY'
import re, sys

def final_quality(path):
    """(local_edges, mnl) from the last per-epoch progress line."""
    last = None
    for line in open(path, encoding="utf-8"):
        m = re.match(r"epoch\s+\d+: local=([0-9.]+) mnl=([0-9.]+)", line)
        if m:
            last = (float(m.group(1)), float(m.group(2)))
    if last is None:
        sys.exit(f"no epoch lines in {path}")
    return last

ref_local, ref_mnl = final_quality(sys.argv[1])
res_local, res_mnl = final_quality(sys.argv[2])
print(f"reference: local={ref_local:.4f} mnl={ref_mnl:.4f}")
print(f"resumed:   local={res_local:.4f} mnl={res_mnl:.4f}")

# 3% relative band on locality (floor the denominator so a degenerate
# reference can't make the band vanish), 1.10x ceiling on imbalance.
band = 0.03 * max(ref_local, 0.1)
assert abs(res_local - ref_local) <= band, (
    f"resumed local_edges {res_local:.4f} deviates from reference "
    f"{ref_local:.4f} by more than 3%"
)
assert res_mnl <= 1.10 * ref_mnl, (
    f"resumed mnl {res_mnl:.4f} exceeds 1.10x reference {ref_mnl:.4f}"
)
print("ok: resumed run converged to the reference quality")
PY

echo "ok: kill -9 + --resume round trip preserved run quality" >&2

# Post-mortem reporting: the killed run's obs log is a prefix (torn
# final line possible — the kill is mid-write by design), the resumed
# run's is complete. Both must render, with the observatory's flow
# matrix and the halt attribution present.
echo "== report: rendering the interrupted and resumed obs logs ==" >&2
(cd rust && cargo run --release --quiet -- report \
    --obs-log "$WORK/victim.jsonl" --partial) >"$WORK/victim.report"
(cd rust && cargo run --release --quiet -- report \
    --obs-log "$WORK/resumed.jsonl") >"$WORK/resumed.report"
for rpt in victim resumed; do
    grep -qi 'flow matrix' "$WORK/$rpt.report" || {
        echo "error: $rpt report is missing its flow matrix section" >&2
        cat "$WORK/$rpt.report" >&2
        exit 1
    }
    grep -qi 'halt reason' "$WORK/$rpt.report" || {
        echo "error: $rpt report is missing its halt attribution" >&2
        cat "$WORK/$rpt.report" >&2
        exit 1
    }
done
grep -i 'halt reason' "$WORK/victim.report" "$WORK/resumed.report" >&2
echo "ok: post-mortem reports rendered for both runs" >&2
