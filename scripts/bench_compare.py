#!/usr/bin/env python3
"""Diff the last two recorded runs in BENCH_hotpath.json.

Rows from the two runs are matched by identity — the `bench` section
tag plus every non-measurement field (string tags and structural
numeric keys like threads/vertices/parts). For each matched pair the
primary timing metric (median_ns, else mean_ns, else repair_ns) is
compared and the delta reported; regressions beyond --threshold PCT
(default 10%) fail the script. Rows present in only one run are listed
as added/removed but never fail.

CI runs this as an advisory step: a regression prints a loud table and
a non-zero exit, but the workflow marks the step continue-on-error —
bench noise on shared runners must not block merges. Locally:

    scripts/bench_hotpath.sh            # record a run
    scripts/bench_compare.py            # diff the last two

Usage: bench_compare.py [--file PATH] [--threshold PCT] [--self-test]
Stdlib only.
"""

import json
import sys

DEFAULT_FILE = "BENCH_hotpath.json"
DEFAULT_THRESHOLD = 10.0

# Measurement keys never take part in row identity; everything else
# (strings + structural numerics) does.
MEASUREMENT_KEYS = {
    "median_ns",
    "mean_ns",
    "min_ns",
    "repair_ns",
    "iters",
    "evaluated",
    "evaluations_saved",
    "local_edges",
    "max_normalized_load",
    "mean_communication_volume",
    "stamp_reads",
    "scan_steps",
    "worklist_steps",
    "chunk_reuses",
    "placed",
    "seeds",
}

# Primary timing metric, in preference order.
TIMING_KEYS = ("median_ns", "mean_ns", "repair_ns")


def fail(msg):
    print(f"bench_compare: {msg}", file=sys.stderr)
    sys.exit(2)


def row_identity(row):
    return tuple(
        sorted((k, v) for k, v in row.items() if k not in MEASUREMENT_KEYS)
    )


def timing(row):
    for key in TIMING_KEYS:
        v = row.get(key)
        if isinstance(v, (int, float)) and v > 0:
            return key, float(v)
    return None, None


def human_ns(ns):
    for unit, scale in (("s", 1e9), ("ms", 1e6), ("us", 1e3)):
        if ns >= scale:
            return f"{ns / scale:.2f}{unit}"
    return f"{ns:.0f}ns"


def identity_label(ident):
    parts = []
    for k, v in ident:
        if k == "bench":
            parts.insert(0, str(v))
        else:
            parts.append(f"{k}={v}")
    return " ".join(parts)


def compare(old_run, new_run, threshold):
    """Return (report_lines, regressions) comparing two run objects."""
    old_rows = {row_identity(r): r for r in old_run.get("rows", [])}
    new_rows = {row_identity(r): r for r in new_run.get("rows", [])}

    lines = []
    regressions = []
    shared = [i for i in old_rows if i in new_rows]
    for ident in sorted(shared, key=identity_label):
        key_o, old_ns = timing(old_rows[ident])
        key_n, new_ns = timing(new_rows[ident])
        label = identity_label(ident)
        if old_ns is None or new_ns is None or key_o != key_n:
            lines.append(f"  ?          {label}  (no comparable timing metric)")
            continue
        delta = (new_ns - old_ns) / old_ns * 100.0
        mark = " "
        if delta > threshold:
            mark = "!"
            regressions.append((label, key_n, old_ns, new_ns, delta))
        elif delta < -threshold:
            mark = "+"
        lines.append(
            f"  {mark} {delta:+7.1f}%  {label}  "
            f"[{key_n} {human_ns(old_ns)} -> {human_ns(new_ns)}]"
        )
    for ident in sorted(set(old_rows) - set(new_rows), key=identity_label):
        lines.append(f"  - removed   {identity_label(ident)}")
    for ident in sorted(set(new_rows) - set(old_rows), key=identity_label):
        lines.append(f"  + added     {identity_label(ident)}")
    return lines, regressions


def run_note(run):
    commit = str(run.get("git_commit", "?"))[:12]
    note = run.get("note") or ""
    stamp = run.get("recorded_at", "?")
    suffix = f" ({note})" if note else ""
    return f"{stamp} @{commit}{suffix}"


def main_compare(path, threshold):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")
    runs = doc.get("runs", [])
    if len(runs) < 2:
        print(
            f"bench_compare: nothing to compare ({len(runs)} run(s) in {path}; "
            "need 2 — record with scripts/bench_hotpath.sh)"
        )
        return 0
    old_run, new_run = runs[-2], runs[-1]
    print(f"bench_compare: {path}, threshold {threshold:.1f}%")
    print(f"  old: {run_note(old_run)}")
    print(f"  new: {run_note(new_run)}")
    lines, regressions = compare(old_run, new_run, threshold)
    for line in lines:
        print(line)
    if regressions:
        print(f"bench_compare: {len(regressions)} regression(s) over {threshold:.1f}%:")
        for label, key, old_ns, new_ns, delta in regressions:
            print(
                f"  ! {label}: {key} {human_ns(old_ns)} -> {human_ns(new_ns)} "
                f"({delta:+.1f}%)"
            )
        return 1
    print("bench_compare: OK (no regressions)")
    return 0


def self_test():
    def row(bench, median, **tags):
        return {"bench": bench, "median_ns": median, "mean_ns": median, **tags}

    old_run = {
        "recorded_at": "2026-01-01T00:00:00Z",
        "git_commit": "aaaaaaaaaaaa",
        "rows": [
            row("schedule_rmat", 1000, threads=1, vertices=4096),
            row("schedule_rmat", 1000, threads=4, vertices=4096),
            row("hotpath_micro", 500, mode="f32"),
            row("stream_rmat", 2000, parts=8),  # removed in new
        ],
    }
    new_run = {
        "recorded_at": "2026-01-02T00:00:00Z",
        "git_commit": "bbbbbbbbbbbb",
        "note": "after change",
        "rows": [
            row("schedule_rmat", 1500, threads=1, vertices=4096),  # +50% regression
            row("schedule_rmat", 700, threads=4, vertices=4096),  # -30% improvement
            row("hotpath_micro", 505, mode="f32"),  # +1% within threshold
            row("dynamic_rmat", 3000, parts=8),  # added
        ],
    }
    lines, regressions = compare(old_run, new_run, 10.0)
    assert len(regressions) == 1, regressions
    label, key, old_ns, new_ns, delta = regressions[0]
    assert "threads=1" in label and key == "median_ns", regressions
    assert abs(delta - 50.0) < 1e-9, delta
    text = "\n".join(lines)
    assert "+   -30.0%" in text, text
    assert "+1.0%" in text and "!   +1.0%" not in text, text
    assert "- removed   stream_rmat parts=8" in text, text
    assert "+ added     dynamic_rmat parts=8" in text, text

    # A looser threshold clears the regression.
    _, none = compare(old_run, new_run, 60.0)
    assert none == [], none

    # repair_ns rows (dynamic section has no median/mean) still compare.
    o = {"rows": [{"bench": "dynamic_rmat", "epoch": 1, "repair_ns": 100}]}
    n = {"rows": [{"bench": "dynamic_rmat", "epoch": 1, "repair_ns": 150}]}
    _, regs = compare(o, n, 10.0)
    assert len(regs) == 1 and regs[0][1] == "repair_ns", regs

    # Identity uses structural keys: same bench, different vertices ->
    # no match, reported as removed+added, never compared.
    o = {"rows": [{"bench": "stream_rmat", "vertices": 1024, "median_ns": 100}]}
    n = {"rows": [{"bench": "stream_rmat", "vertices": 2048, "median_ns": 900}]}
    lines, regs = compare(o, n, 10.0)
    assert regs == [] and any("removed" in l for l in lines), lines

    assert human_ns(950) == "950ns" and human_ns(1500) == "1.50us"
    assert human_ns(2.5e6) == "2.50ms" and human_ns(3e9) == "3.00s"
    print("bench_compare: self-test OK")


def main():
    argv = sys.argv[1:]
    if "--self-test" in argv:
        self_test()
        return 0
    path = DEFAULT_FILE
    threshold = DEFAULT_THRESHOLD
    i = 0
    while i < len(argv):
        if argv[i] == "--file" and i + 1 < len(argv):
            path = argv[i + 1]
            i += 2
        elif argv[i] == "--threshold" and i + 1 < len(argv):
            try:
                threshold = float(argv[i + 1])
            except ValueError:
                fail(f"bad --threshold {argv[i + 1]!r}")
            i += 2
        else:
            fail("usage: bench_compare.py [--file PATH] [--threshold PCT] [--self-test]")
    return main_compare(path, threshold)


if __name__ == "__main__":
    sys.exit(main())
