#!/usr/bin/env bash
# Live telemetry smoke: launch a release-mode `dynamic` run with
# `--metrics-addr 127.0.0.1:0`, scrape all four HTTP endpoints while
# epochs are still executing, and validate every payload with the
# stdlib checkers. Exercises the whole plane end to end:
#
#   stderr   `metrics: serving http://127.0.0.1:PORT/metrics` (port 0
#            resolution — this line is the only place the port appears)
#   /healthz JSON liveness: ok=true + phase/step/epoch progress
#   /metrics Prometheus text, validated by scripts/check_prom.py
#   /profile live span tree
#   /events  NDJSON ring tail, validated by check_obs_log.py --partial
#            (mid-run prefix: schema + ordering, no run_end yet)
#
# The run then finishes normally and its --obs-log file must pass the
# strict (full-run) validator. Requires cargo, curl, python3.
#
#   scripts/ci_http_smoke.sh [--vertices N] [--epochs N]
set -euo pipefail

cd "$(dirname "$0")/.."

VERTICES=32768
EPOCHS=24
while [ $# -gt 0 ]; do
    case "$1" in
        --vertices) VERTICES="$2"; shift ;;
        --epochs) EPOCHS="$2"; shift ;;
        *) echo "unknown flag: $1" >&2; exit 2 ;;
    esac
    shift
done

WORK="$(mktemp -d)"
RUN_PID=""
cleanup() {
    [ -n "$RUN_PID" ] && kill "$RUN_PID" 2>/dev/null || true
    rm -rf "$WORK"
}
trap cleanup EXIT

# Build first so the serving line isn't delayed behind compilation.
(cd rust && cargo build --release --quiet)

echo "== launching dynamic run with --metrics-addr 127.0.0.1:0 ==" >&2
(cd rust && exec cargo run --release --quiet -- dynamic \
    --graph so --vertices "$VERTICES" --parts 8 \
    --churn uniform:0.05 --epochs "$EPOCHS" --repair-steps 8 \
    --obs-log "$WORK/run.jsonl" \
    --metrics-addr 127.0.0.1:0) >"$WORK/stdout.txt" 2>"$WORK/stderr.txt" &
RUN_PID=$!

# The kernel-assigned port is echoed on stderr once the listener binds.
BASE=""
for _ in $(seq 1 300); do
    BASE="$(sed -n 's#^metrics: serving \(http://[^/]*\)/metrics$#\1#p' \
        "$WORK/stderr.txt" | head -n 1)"
    [ -n "$BASE" ] && break
    if ! kill -0 "$RUN_PID" 2>/dev/null; then
        echo "error: run exited before announcing the metrics address" >&2
        cat "$WORK/stderr.txt" >&2
        exit 1
    fi
    sleep 0.1
done
if [ -z "$BASE" ]; then
    echo "error: no 'metrics: serving' line on stderr after 30s" >&2
    cat "$WORK/stderr.txt" >&2
    exit 1
fi
echo "== serving at $BASE ==" >&2

# The server answers from the moment it binds — before the first span
# lands in the registry. Poll /metrics until real engine output shows
# up, then hit the remaining endpoints in the same breath (mid-run).
SEEN=0
for _ in $(seq 1 300); do
    curl -fsS --max-time 10 "$BASE/metrics" >"$WORK/metrics.txt"
    if grep -q 'span_seconds_total{path=' "$WORK/metrics.txt"; then
        SEEN=1
        break
    fi
    sleep 0.1
done
if [ "$SEEN" != 1 ]; then
    echo "error: no spans appeared in /metrics after 30s of scraping" >&2
    exit 1
fi
curl -fsS --max-time 10 "$BASE/healthz" >"$WORK/healthz.json"
curl -fsS --max-time 10 "$BASE/profile" >"$WORK/profile.txt"
curl -fsS --max-time 10 "$BASE/events?since=0" >"$WORK/events.jsonl"

kill -0 "$RUN_PID" 2>/dev/null || {
    echo "error: run was already finished when the endpoints answered" >&2
    exit 1
}

python3 - "$WORK/healthz.json" <<'PY'
import json, sys
h = json.load(open(sys.argv[1]))
assert h["ok"] is True, h
assert isinstance(h["phase"], str) and h["phase"], h
for key in ("uptime_s", "step", "epoch", "events"):
    assert isinstance(h[key], (int, float)), (key, h)
print(f"healthz: ok phase={h['phase']} step={h['step']} epoch={h['epoch']}")
PY

python3 scripts/check_prom.py --require span_seconds_total \
    --require span_calls_total "$WORK/metrics.txt"
grep -q "top-level spans:" "$WORK/profile.txt"
python3 scripts/check_obs_log.py --partial "$WORK/events.jsonl"
head -n 1 "$WORK/events.jsonl" | grep -q '"ev":"run_start"'

wait "$RUN_PID"
RUN_PID=""

# After a clean exit the full --obs-log must satisfy the strict
# validator (run_start .. run_end, steps present, t_s monotone).
python3 scripts/check_obs_log.py "$WORK/run.jsonl"
echo "ok: live telemetry plane answered all endpoints mid-run" >&2
