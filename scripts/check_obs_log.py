#!/usr/bin/env python3
"""Validate a --obs-log JSONL file (CI smoke gate).

Mirrors rust/src/obs/events.rs EVENT_SPEC: every line is a flat JSON
object with an "ev" kind from the spec, a finite t_s >= 0, the kind's
required numeric fields, and only string/number values. Event times
must be non-decreasing, and run_end — when present — must be the final
event (the recorder emits it exactly once, at the very end).
Additionally enforces run shape: non-empty, starts with run_start,
contains at least one step, ends with run_end.

Usage: check_obs_log.py <file.jsonl>
       check_obs_log.py --partial <file.jsonl>   # killed-run prefix:
           per-line schema + ordering only, no run-shape requirements
           (the line-buffered sink contract guarantees complete lines)
       check_obs_log.py --self-test
Exits non-zero with a message on the first violation.

Stdlib only.
"""

import json
import math
import sys

EVENT_SPEC = {
    "run_start": [],
    "step": ["step", "frontier", "evaluated", "migrations"],
    "stream_pass": ["pass", "edges"],
    "ml_level": ["level", "vertices"],
    "epoch": ["epoch", "placed", "seeds", "evaluated", "repair_s"],
    "fault": ["step"],
    "checkpoint": ["step", "epoch"],
    # Learning-dynamics observatory (--diag); extras like maxp_mean /
    # entropy_mean / frontier / halt / epoch ride as optional fields.
    "flow": ["step", "from", "to", "moves", "mass"],
    "partition": ["step", "part", "load", "boundary", "local_frac"],
    "diag": ["step", "oscillating"],
    "run_end": ["wall_s"],
}


def fail(msg):
    print(f"check_obs_log: {msg}", file=sys.stderr)
    sys.exit(1)


def is_finite_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v)


def validate(lines, partial=False):
    """Return (kinds, step_count) or raise ValueError on violation."""
    kinds = []
    prev_t = None
    for i, line in enumerate(lines, 1):
        if not line.strip():
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"line {i}: invalid JSON: {e}")
        if not isinstance(ev, dict):
            raise ValueError(f"line {i}: not an object")
        kind = ev.get("ev")
        if not isinstance(kind, str):
            raise ValueError(f"line {i}: missing string \"ev\"")
        if kind not in EVENT_SPEC:
            raise ValueError(f"line {i}: unknown event kind {kind!r}")
        t_s = ev.get("t_s")
        if not is_finite_number(t_s) or t_s < 0:
            raise ValueError(
                f"line {i} ({kind}): t_s must be a finite number >= 0, got {t_s!r}"
            )
        if prev_t is not None and t_s < prev_t:
            raise ValueError(
                f"line {i} ({kind}): t_s went backwards ({t_s} after {prev_t})"
            )
        prev_t = t_s
        for key in EVENT_SPEC[kind]:
            if not is_finite_number(ev.get(key)):
                raise ValueError(
                    f"line {i} ({kind}): missing/non-finite required field {key!r}"
                )
        for key, val in ev.items():
            if not (isinstance(val, str) or is_finite_number(val)):
                raise ValueError(
                    f"line {i} ({kind}): field {key!r} must be string/finite number"
                )
        # run_end is terminal whenever it appears at all — even in a
        # partial (killed-run) log, nothing may follow it.
        if kinds and kinds[-1] == "run_end":
            raise ValueError(f"line {i} ({kind}): events after run_end")
        kinds.append(kind)

    if not partial:
        if not kinds:
            raise ValueError("no events")
        if kinds[0] != "run_start":
            raise ValueError(f"first event must be run_start, got {kinds[0]!r}")
        if kinds[-1] != "run_end":
            raise ValueError(f"last event must be run_end, got {kinds[-1]!r}")
        if "step" not in kinds:
            raise ValueError("no step events recorded")
    return kinds, kinds.count("step")


def self_test():
    step = '{"ev":"step","t_s":0.5,"step":0,"frontier":9,"evaluated":9,"migrations":2}'
    good = [
        '{"ev":"run_start","t_s":0.0}',
        step,
        "",  # blank lines are permitted
        '{"ev":"flow","t_s":0.6,"step":0,"from":0,"to":1,"moves":2,"mass":17}',
        '{"ev":"partition","t_s":0.6,"step":0,"part":1,"load":40,'
        '"boundary":3,"local_frac":0.9}',
        '{"ev":"diag","t_s":0.7,"step":0,"oscillating":1,"maxp_mean":0.8,"halt":3}',
        '{"ev":"run_end","t_s":1.0,"wall_s":1.0}',
    ]
    kinds, steps = validate(good)
    assert kinds == ["run_start", "step", "flow", "partition", "diag", "run_end"], kinds
    assert steps == 1, steps

    # Partial mode: a killed-run prefix without run_end passes, and an
    # empty log is fine.
    validate(good[:2], partial=True)
    validate([], partial=True)

    bad_cases = [
        ("invalid JSON", ["not json"]),
        ("not an object", ["[1,2]"]),
        ('missing string "ev"', ['{"t_s":0.0}']),
        ("unknown event kind", ['{"ev":"mystery","t_s":0.0}']),
        ("t_s must be", ['{"ev":"run_start"}']),
        ("t_s must be", ['{"ev":"run_start","t_s":-1.0}']),
        ("required field", ['{"ev":"run_end","t_s":0.0}']),
        ("string/finite number", ['{"ev":"run_start","t_s":0.0,"x":{"y":1}}']),
        (
            "t_s went backwards",
            ['{"ev":"run_start","t_s":2.0}', '{"ev":"run_end","t_s":1.0,"wall_s":1.0}'],
        ),
        (
            "events after run_end",
            [
                '{"ev":"run_start","t_s":0.0}',
                step,
                '{"ev":"run_end","t_s":1.0,"wall_s":1.0}',
                '{"ev":"run_start","t_s":2.0}',
            ],
        ),
        ("no events", []),
        ("first event must be run_start", [step]),
        ("last event must be run_end", ['{"ev":"run_start","t_s":0.0}', step]),
        (
            "no step events",
            ['{"ev":"run_start","t_s":0.0}', '{"ev":"run_end","t_s":1.0,"wall_s":1.0}'],
        ),
        # Observatory kinds: each rejects a missing required field.
        (
            "required field",
            ['{"ev":"flow","t_s":0.0,"step":0,"from":1,"to":2,"moves":3}'],
        ),
        (
            "required field",
            ['{"ev":"partition","t_s":0.0,"step":0,"part":1,"load":5,"boundary":2}'],
        ),
        ("required field", ['{"ev":"diag","t_s":0.0,"step":0}']),
    ]
    for expect, lines in bad_cases:
        try:
            validate(lines)
        except ValueError as e:
            assert expect in str(e), f"expected {expect!r} in {e!r}"
        else:
            raise AssertionError(f"case {expect!r} did not fail: {lines}")

    # Ordering violations are caught even in partial mode.
    for expect, lines in bad_cases[:10]:
        if not lines:
            continue
        try:
            validate(lines, partial=True)
        except ValueError:
            pass
        else:
            raise AssertionError(f"partial mode missed {expect!r}: {lines}")
    print("check_obs_log: self-test OK")


def main():
    argv = sys.argv[1:]
    if argv == ["--self-test"]:
        self_test()
        return
    partial = False
    if argv and argv[0] == "--partial":
        partial = True
        argv = argv[1:]
    if len(argv) != 1:
        fail("usage: check_obs_log.py [--partial] <file.jsonl> | --self-test")
    path = argv[0]
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.read().splitlines()
    except OSError as e:
        fail(f"cannot read {path}: {e}")

    try:
        kinds, steps = validate(lines, partial=partial)
    except ValueError as e:
        fail(str(e))
    mode = " (partial)" if partial else ""
    print(f"check_obs_log: OK{mode} ({len(kinds)} events, {steps} steps)")


if __name__ == "__main__":
    main()
