#!/usr/bin/env python3
"""Validate a --obs-log JSONL file (CI smoke gate).

Mirrors rust/src/obs/events.rs EVENT_SPEC: every line is a flat JSON
object with an "ev" kind from the spec, a finite t_s >= 0, the kind's
required numeric fields, and only string/number values. Additionally
enforces run shape: non-empty, starts with run_start, contains at least
one step, ends with run_end.

Usage: check_obs_log.py <file.jsonl>
Exits non-zero with a message on the first violation.

Stdlib only.
"""

import json
import math
import sys

EVENT_SPEC = {
    "run_start": [],
    "step": ["step", "frontier", "evaluated", "migrations"],
    "stream_pass": ["pass", "edges"],
    "ml_level": ["level", "vertices"],
    "epoch": ["epoch", "placed", "seeds", "evaluated", "repair_s"],
    "run_end": ["wall_s"],
}


def fail(msg):
    print(f"check_obs_log: {msg}", file=sys.stderr)
    sys.exit(1)


def is_finite_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v)


def main():
    if len(sys.argv) != 2:
        fail("usage: check_obs_log.py <file.jsonl>")
    path = sys.argv[1]
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.read().splitlines()
    except OSError as e:
        fail(f"cannot read {path}: {e}")

    kinds = []
    for i, line in enumerate(lines, 1):
        if not line.strip():
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"line {i}: invalid JSON: {e}")
        if not isinstance(ev, dict):
            fail(f"line {i}: not an object")
        kind = ev.get("ev")
        if not isinstance(kind, str):
            fail(f"line {i}: missing string \"ev\"")
        if kind not in EVENT_SPEC:
            fail(f"line {i}: unknown event kind {kind!r}")
        t_s = ev.get("t_s")
        if not is_finite_number(t_s) or t_s < 0:
            fail(f"line {i} ({kind}): t_s must be a finite number >= 0, got {t_s!r}")
        for key in EVENT_SPEC[kind]:
            if not is_finite_number(ev.get(key)):
                fail(f"line {i} ({kind}): missing/non-finite required field {key!r}")
        for key, val in ev.items():
            if not (isinstance(val, str) or is_finite_number(val)):
                fail(f"line {i} ({kind}): field {key!r} must be string/finite number")
        kinds.append(kind)

    if not kinds:
        fail(f"{path}: no events")
    if kinds[0] != "run_start":
        fail(f"first event must be run_start, got {kinds[0]!r}")
    if kinds[-1] != "run_end":
        fail(f"last event must be run_end, got {kinds[-1]!r}")
    if "step" not in kinds:
        fail("no step events recorded")
    print(f"check_obs_log: OK ({len(kinds)} events, {kinds.count('step')} steps)")


if __name__ == "__main__":
    main()
