#!/usr/bin/env python3
"""Validate a Prometheus text-format scrape (/metrics smoke gate).

Checks the exposition the obs HTTP server emits (rust/src/obs/expose.rs):
 - every non-comment line is `name[{labels}] value` with a finite value,
 - every sample family has a preceding `# TYPE family <counter|gauge|histogram>`,
 - histograms carry `_bucket`/`_sum`/`_count` series, bucket counts are
   cumulative non-decreasing in `le` order, the last bucket is
   `le="+Inf"`, and its count equals `_count` (the live-scrape
   invariant: count is derived from the buckets, see registry.rs).

Usage: check_prom.py <file>          # or `-` for stdin
       check_prom.py --require NAME  # additionally assert NAME present
       check_prom.py --self-test
Exits non-zero with a message on the first violation. Stdlib only.
"""

import math
import re
import sys

SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?P<labels>\{[^}]*\})?'
    r' (?P<value>\S+)$'
)
LE_RE = re.compile(r'le="([^"]*)"')
TYPES = {"counter", "gauge", "histogram"}


def fail(msg):
    print(f"check_prom: {msg}", file=sys.stderr)
    sys.exit(1)


def parse_value(text):
    if text == "+Inf":
        return math.inf
    if text == "-Inf":
        return -math.inf
    try:
        return float(text)
    except ValueError:
        return None


def family_of(name):
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def validate(text):
    """Return {family: type} of validated samples; raise ValueError."""
    types = {}
    samples = []  # (line_no, name, labels_text, value)
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in TYPES:
                raise ValueError(f"line {i}: malformed TYPE comment: {line!r}")
            if parts[2] in types:
                raise ValueError(f"line {i}: duplicate TYPE for {parts[2]!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue  # HELP or free comment
        m = SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {i}: malformed sample line: {line!r}")
        value = parse_value(m.group("value"))
        if value is None or math.isnan(value):
            raise ValueError(f"line {i}: bad value {m.group('value')!r}")
        samples.append((i, m.group("name"), m.group("labels") or "", value))

    hist = {}  # family -> {"buckets": [(le, v)], "sum": v, "count": v}
    for i, name, labels, value in samples:
        family = family_of(name)
        ftype = types.get(family) or types.get(name)
        if ftype is None:
            raise ValueError(f"line {i}: sample {name!r} has no TYPE comment")
        if ftype != "histogram":
            if family != name:
                # e.g. a counter literally named foo_count: fine, but
                # only if declared under its own full name.
                if name not in types:
                    raise ValueError(f"line {i}: sample {name!r} has no TYPE comment")
            continue
        h = hist.setdefault(family, {"buckets": [], "sum": None, "count": None})
        if name.endswith("_bucket"):
            le = LE_RE.search(labels)
            if not le:
                raise ValueError(f"line {i}: {name} without le label")
            h["buckets"].append((le.group(1), value))
        elif name.endswith("_sum"):
            h["sum"] = value
        elif name.endswith("_count"):
            h["count"] = value
        else:
            raise ValueError(f"line {i}: bare sample {name!r} for histogram family")

    for family, ftype in types.items():
        if ftype != "histogram" or family not in hist:
            continue
        h = hist[family]
        if not h["buckets"]:
            raise ValueError(f"histogram {family}: no _bucket series")
        if h["sum"] is None or h["count"] is None:
            raise ValueError(f"histogram {family}: missing _sum or _count")
        prev = -1.0
        for le, v in h["buckets"]:
            if v < prev:
                raise ValueError(
                    f"histogram {family}: bucket le={le} count {v:g} < previous {prev:g}"
                )
            prev = v
        last_le, last_v = h["buckets"][-1]
        if last_le != "+Inf":
            raise ValueError(f"histogram {family}: last bucket le={last_le!r}, not +Inf")
        if last_v != h["count"]:
            raise ValueError(
                f"histogram {family}: +Inf bucket {last_v:g} != _count {h['count']:g}"
            )
    return types


GOOD = """\
# TYPE engine_runs counter
engine_runs 1
# TYPE engine_mean_score gauge
engine_mean_score 0.5
# TYPE engine_frontier_size histogram
engine_frontier_size_bucket{le="0"} 1
engine_frontier_size_bucket{le="1"} 1
engine_frontier_size_bucket{le="3"} 3
engine_frontier_size_bucket{le="+Inf"} 3
engine_frontier_size_sum 5
engine_frontier_size_count 3
# TYPE span_seconds_total counter
span_seconds_total{path="engine"} 1.5
"""


def self_test():
    types = validate(GOOD)
    assert types["engine_frontier_size"] == "histogram", types
    assert types["engine_runs"] == "counter", types
    validate("")  # an empty scrape is structurally valid

    bad_cases = [
        ("malformed TYPE", "# TYPE engine_runs\nengine_runs 1\n"),
        ("malformed TYPE", "# TYPE engine_runs summary\nengine_runs 1\n"),
        ("duplicate TYPE", "# TYPE x counter\n# TYPE x counter\nx 1\n"),
        ("no TYPE comment", "engine_runs 1\n"),
        ("malformed sample", "# TYPE x counter\nx 1 2 3\n"),
        ("bad value", "# TYPE x counter\nx abc\n"),
        ("bad value", "# TYPE x counter\nx NaN\n"),
        (
            "no _bucket series",
            "# TYPE h histogram\nh_sum 1\nh_count 1\n",
        ),
        (
            "missing _sum or _count",
            '# TYPE h histogram\nh_bucket{le="+Inf"} 1\nh_count 1\n',
        ),
        (
            "bucket le=2 count",
            '# TYPE h histogram\nh_bucket{le="1"} 5\nh_bucket{le="2"} 3\n'
            'h_bucket{le="+Inf"} 5\nh_sum 9\nh_count 5\n',
        ),
        (
            "not +Inf",
            '# TYPE h histogram\nh_bucket{le="1"} 5\nh_sum 9\nh_count 5\n',
        ),
        (
            "+Inf bucket 4 != _count",
            '# TYPE h histogram\nh_bucket{le="+Inf"} 4\nh_sum 9\nh_count 5\n',
        ),
        ("without le label", "# TYPE h histogram\nh_bucket 4\n"),
        ("bare sample", "# TYPE h histogram\nh 4\n"),
    ]
    for expect, text in bad_cases:
        try:
            validate(text)
        except ValueError as e:
            assert expect in str(e), f"expected {expect!r} in {e!r}"
        else:
            raise AssertionError(f"case {expect!r} did not fail")
    print("check_prom: self-test OK")


def main():
    argv = sys.argv[1:]
    if argv == ["--self-test"]:
        self_test()
        return
    required = []
    while len(argv) >= 2 and argv[0] == "--require":
        required.append(argv[1])
        argv = argv[2:]
    if len(argv) != 1:
        fail("usage: check_prom.py [--require NAME ...] <file|-> | --self-test")
    try:
        if argv[0] == "-":
            text = sys.stdin.read()
        else:
            with open(argv[0], encoding="utf-8") as f:
                text = f.read()
    except OSError as e:
        fail(f"cannot read {argv[0]}: {e}")
    try:
        types = validate(text)
    except ValueError as e:
        fail(str(e))
    for name in required:
        if name not in types:
            fail(f"required family {name!r} not present in scrape")
    print(f"check_prom: OK ({len(types)} families)")


if __name__ == "__main__":
    main()
