#!/usr/bin/env bash
# Run the hot-path bench suite and record its BENCH_JSON rows as one
# dated entry in BENCH_hotpath.json (repo root) — the bench trajectory
# DESIGN.md §Hot paths documents.
#
#   scripts/bench_hotpath.sh                # quick mode, append a run
#   scripts/bench_hotpath.sh --full         # REVOLVER_BENCH_SCALE=full
#   scripts/bench_hotpath.sh --check        # run + validate, append nothing
#   scripts/bench_hotpath.sh --note "text"  # free-form provenance note
#
# The bench binary validates every row against its section schema
# in-process (util::bench::validate_rows) and panics on drift, so a
# harvested line is already schema-clean; this script only extracts it
# and merges it with machine metadata. Requires python3 for the JSON
# merge (stdlib only).
set -euo pipefail

cd "$(dirname "$0")/.."
OUT="BENCH_hotpath.json"
MODE="quick"
CHECK=0
NOTE=""
while [ $# -gt 0 ]; do
    case "$1" in
        --full) MODE="full" ;;
        --check) CHECK=1 ;;
        --note) NOTE="$2"; shift ;;
        --out) OUT="$2"; shift ;;
        *) echo "unknown flag: $1" >&2; exit 2 ;;
    esac
    shift
done

LOG="$(mktemp)"
trap 'rm -f "$LOG"' EXIT

echo "== cargo bench --bench hotpath (mode=$MODE) ==" >&2
if [ "$MODE" = "full" ]; then
    (cd rust && REVOLVER_BENCH_SCALE=full cargo bench --bench hotpath) | tee "$LOG"
else
    (cd rust && cargo bench --bench hotpath) | tee "$LOG"
fi

ROWS_LINE="$(grep '^BENCH_JSON \[' "$LOG" | tail -n 1 | sed 's/^BENCH_JSON //')"
if [ -z "$ROWS_LINE" ]; then
    echo "error: no BENCH_JSON line in bench output" >&2
    exit 1
fi
grep -q 'BENCH_JSON rows validated' "$LOG" || {
    echo "error: bench did not report in-process row validation" >&2
    exit 1
}

if [ "$CHECK" = 1 ]; then
    echo "ok: BENCH_JSON line present and validated (check mode, nothing written)" >&2
    exit 0
fi

ROWS_LINE="$ROWS_LINE" OUT="$OUT" MODE="$MODE" NOTE="$NOTE" python3 - <<'PY'
import json, os, platform, subprocess, sys
from datetime import datetime, timezone

out = os.environ["OUT"]
rows = json.loads(os.environ["ROWS_LINE"])
with open(out) as f:
    doc = json.load(f)

def git(*args):
    try:
        return subprocess.check_output(["git", *args], text=True).strip()
    except Exception:
        return "unknown"

run = {
    "recorded_at": datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ"),
    "git_commit": git("rev-parse", "--short", "HEAD"),
    "git_dirty": bool(git("status", "--porcelain")),
    "scale": os.environ["MODE"],
    "host": {
        "machine": platform.machine(),
        "system": platform.system(),
        "cpus": os.cpu_count(),
    },
    "note": os.environ.get("NOTE", ""),
    "rows": rows,
}
doc["runs"].append(run)
with open(out, "w") as f:
    json.dump(doc, f, indent=1)
    f.write("\n")
print(f"appended run with {len(rows)} rows to {out}", file=sys.stderr)
PY
