"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the published xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Emits, per (B, k) configuration:
    artifacts/step_b{B}_k{k}.hlo.txt        fused score+signal+LA update
    artifacts/la_update_b{B}_k{k}.hlo.txt   signal+LA update only
    artifacts/score_b{B}_k{k}.hlo.txt       normalized LP scoring only
and a ``manifest.json`` describing shapes/params so the Rust runtime can
select and validate an artifact without re-deriving conventions.

Usage: python -m compile.aot --out ../artifacts [--batch 256] [--parts 8,32]
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Paper settings (Sec. V-F): alpha = 1, beta = 0.1.
ALPHA = 1.0
BETA = 0.1


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def emit(out_dir: str, batch: int, parts: list[int]) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "alpha": ALPHA,
        "beta": BETA,
        "batch": batch,
        "entries": [],
    }

    for k in parts:
        f32 = jnp.float32
        hist = jax.ShapeDtypeStruct((batch, k), f32)
        wsum = jax.ShapeDtypeStruct((batch,), f32)
        loads = jax.ShapeDtypeStruct((k,), f32)
        cap = jax.ShapeDtypeStruct((), f32)
        p = jax.ShapeDtypeStruct((batch, k), f32)
        raw_w = jax.ShapeDtypeStruct((batch, k), f32)

        entries = {
            f"step_b{batch}_k{k}": (
                functools.partial(model.batched_step, alpha=ALPHA, beta=BETA),
                (hist, wsum, loads, cap, p, raw_w),
                ["hist", "wsum", "loads", "capacity", "p", "raw_w"],
                ["scores", "p_next"],
            ),
            f"la_update_b{batch}_k{k}": (
                functools.partial(model.batched_la_update, alpha=ALPHA, beta=BETA),
                (p, raw_w),
                ["p", "raw_w"],
                ["p_next"],
            ),
            f"score_b{batch}_k{k}": (
                model.batched_score,
                (hist, wsum, loads, cap),
                ["hist", "wsum", "loads", "capacity"],
                ["scores"],
            ),
        }

        for name, (fn, args, in_names, out_names) in entries.items():
            text = lower_entry(fn, args)
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(
                {
                    "name": name,
                    "file": f"{name}.hlo.txt",
                    "batch": batch,
                    "k": k,
                    "inputs": [
                        {"name": n, "shape": list(a.shape), "dtype": "f32"}
                        for n, a in zip(in_names, args)
                    ],
                    "outputs": out_names,
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument(
        "--parts",
        default="8,32",
        help="comma-separated k values to emit artifacts for",
    )
    args = ap.parse_args()
    parts = [int(x) for x in args.parts.split(",") if x]
    emit(args.out, args.batch, parts)


if __name__ == "__main__":
    main()
