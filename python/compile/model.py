"""L2: the batched Revolver numeric step as a JAX computation.

This is the dense half of one Revolver step for a B-vertex batch,
composed from the L1 Pallas kernels:

    scores  = score(hist, wsum, loads, C)        # eqs. (10)-(12), Pallas
    w, r    = signal(raw_w)                      # Sec. IV-D.6, jnp
    p_next  = la_update(p, w, r, alpha, beta)    # eqs. (8)-(9),  Pallas

The irregular half (CSR neighbour gather, roulette-wheel action draws,
migration) stays in the Rust coordinator; this graph is lowered once by
``aot.py`` to HLO text and executed from Rust via PJRT.

All functions are shape-polymorphic in Python but are lowered at fixed
example shapes — one artifact per (B, k, alpha, beta) configuration.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.la_update import la_update
from .kernels.score import score

__all__ = ["signal", "batched_step", "batched_la_update", "batched_score"]


def signal(raw_w):
    """Reinforcement signal construction (Sec. IV-D.6), pure jnp.

    Mean-split the accumulated weight vector into reward/penalty halves;
    each entry's weight is its deviation |w - mean| and each half is
    normalized to sum 1 (so sum(W) = 2, as eqs. 8-9 require). Degenerate
    halves fall back to uniform. Mirrors `ref.signal_ref` and the Rust
    `la::signal::build_signals` exactly.

    Args:
        raw_w: (B, k) raw weights accumulated by eq. (13) on the host.

    Returns:
        (w_norm, r): (B, k) float32 each; r is 0 = reward, 1 = penalty.
    """
    raw_w = jnp.asarray(raw_w, jnp.float32)
    mean = jnp.mean(raw_w, axis=1, keepdims=True)
    r = jnp.where(raw_w > mean, 0.0, 1.0)
    dev = jnp.abs(raw_w - mean)

    def half_norm(mask):
        cnt = jnp.sum(mask, axis=1, keepdims=True)
        s = jnp.sum(dev * mask, axis=1, keepdims=True)
        uniform = mask / jnp.maximum(cnt, 1.0)
        scaled = dev * mask / jnp.where(s > 0.0, s, 1.0)
        return jnp.where(s > 0.0, scaled, uniform)

    w_norm = half_norm(1.0 - r) + half_norm(r)
    return w_norm, r


def batched_step(hist, wsum, loads, capacity, p, raw_w, *, alpha, beta):
    """Fused dense Revolver step for one vertex batch.

    Args:
        hist: (B, k) neighbour label-weight histogram.
        wsum: (B,) total neighbour weight per vertex.
        loads: (k,) partition loads b(l).
        capacity: scalar C.
        p: (B, k) LA probability vectors.
        raw_w: (B, k) raw eq.-(13) weights.
        alpha, beta: python scalars, baked at lowering time.

    Returns:
        (scores, p_next): (B, k) float32 each.
    """
    scores = score(hist, wsum, loads, capacity)
    w_norm, r = signal(raw_w)
    p_next = la_update(p, w_norm, r, alpha, beta)
    return scores, p_next


def batched_la_update(p, raw_w, *, alpha, beta):
    """Signal construction + weighted-LA update only (no scoring)."""
    w_norm, r = signal(raw_w)
    return la_update(p, w_norm, r, alpha, beta)


def batched_score(hist, wsum, loads, capacity):
    """Normalized LP scoring only."""
    return score(hist, wsum, loads, capacity)
