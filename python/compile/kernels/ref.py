"""Pure-jnp reference oracle for the Pallas kernels.

Every function here is the mathematically-literal transcription of the
paper's equations, written with no regard for performance. The Pallas
kernels in `la_update.py` / `score.py` and the fused L2 step in
`model.py` are asserted allclose against these by `python/tests/`.

Shapes (batch-of-vertices convention):
    B — number of vertices in the batch
    k — number of partitions (= LA actions, m in the paper)

Equations implemented (paper numbering):
    (8)/(9)  weighted-LA probability update      -> ``la_update_ref``
    (10)-(12) normalized LP score                 -> ``score_ref``
    (13)+Sec IV-D.6  weight vector & signal split -> ``signal_ref``
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "la_update_ref",
    "score_ref",
    "signal_ref",
    "step_ref",
]


def la_update_ref(p, w, r, alpha, beta):
    """Weighted learning-automaton update, eqs. (8) and (9).

    The paper applies the update once per reinforcement signal ``r_i``
    (m passes over an m-vector, m^2 scalar work).  Pass ``i`` uses
    weight ``w_i`` and signal ``r_i``:

      reward  (r_i = 0):  p_i += alpha*w_i*(1-p_i);  p_j *= (1-alpha*w_i)
      penalty (r_i = 1):  p_i *= (1-beta*w_i);
                          p_j  = p_j*(1-beta*w_i) + beta/(m-1)

    The penalty redistribution term is weighted by the *receiving*
    element's weight w_j (``beta*w_j/(m-1)``) — eq. (9) as printed
    subscripts the weight with j; the unweighted beta/(m-1) variant
    hands probability mass back to known-bad actions every pass and
    freezes the automaton at a high noise floor (DESIGN.md F4). A
    renormalization closes the sweep to keep P a distribution under
    float arithmetic.

    Args:
        p: (B, k) probability vectors.
        w: (B, k) weights, each half (reward/penalty) summing to 1.
        r: (B, k) reinforcement signals, 0 = reward, 1 = penalty.
        alpha, beta: scalar learning parameters.

    Returns:
        (B, k) updated probability vectors (rows sum to 1).
    """
    p = jnp.asarray(p, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    r = jnp.asarray(r, jnp.float32)
    B, k = p.shape

    # Sequential sweep over the k signals, exactly as the paper's m^2
    # formulation prescribes.
    for i in range(k):
        wi = w[:, i : i + 1]  # (B, 1)
        ri = r[:, i : i + 1]  # (B, 1)
        onehot = jnp.zeros((B, k), jnp.float32).at[:, i].set(1.0)

        # Reward branch, eq. (8).
        p_rew_i = p + alpha * wi * (1.0 - p)
        p_rew_j = p * (1.0 - alpha * wi)
        p_rew = onehot * p_rew_i + (1.0 - onehot) * p_rew_j

        # Penalty branch, eq. (9) — additive term weighted by w_j.
        p_pen_i = p * (1.0 - beta * wi)
        p_pen_j = p * (1.0 - beta * wi) + beta * w / (k - 1)
        p_pen = onehot * p_pen_i + (1.0 - onehot) * p_pen_j

        p = jnp.where(ri > 0.5, p_pen, p_rew)

    # Float-arithmetic renormalization (see docstring).
    p = jnp.clip(p, 1e-12, None)
    return p / jnp.sum(p, axis=1, keepdims=True)


def score_ref(hist, wsum, loads, capacity):
    """Normalized LP score, eqs. (10)-(12).

    score(v, l) = (tau(v, l) + pi(l)) / 2
      tau(v, l) = (sum_{u in N(v)} w(u,v) * delta(psi(u), l)) / sum w(u,v)
      pi(l)     = (1 - b(l)/C) / sum_i (1 - b(l_i)/C)

    The neighbour gather is done host-side; the kernel consumes the
    per-vertex label-weight histogram ``hist[v, l] = sum_{u in N(v)}
    w(u,v) * delta(psi(u), l)`` and the per-vertex total weight ``wsum``.

    Footnote 1: if any penalty term is negative (overloaded partition,
    b(l) > C), all penalties are shifted by the minimum negative value
    before normalization.

    Args:
        hist: (B, k) neighbour label-weight histogram.
        wsum: (B,) or (B, 1) total neighbour weight per vertex.
        loads: (k,) current partition loads b(l).
        capacity: scalar C.

    Returns:
        (B, k) scores.
    """
    hist = jnp.asarray(hist, jnp.float32)
    wsum = jnp.asarray(wsum, jnp.float32).reshape(-1, 1)
    loads = jnp.asarray(loads, jnp.float32)

    tau = hist / jnp.maximum(wsum, 1e-12)

    pen = 1.0 - loads / capacity  # (k,)
    # Footnote 1: augment with respect to the minimum negative value.
    min_pen = jnp.min(pen)
    pen = jnp.where(min_pen < 0.0, pen - min_pen, pen)
    denom = jnp.sum(pen)
    pi = pen / jnp.maximum(denom, 1e-12)  # (k,)

    return (tau + pi[None, :]) / 2.0


def signal_ref(weights):
    """Reinforcement-signal construction, Sec. IV-D.6.

    Split the raw weight vector at its mean: w_i > mean -> reward
    (r_i = 0), else penalty (r_i = 1). Each entry's weight is its
    deviation |w_i - mean| (an entry at the mean carries no signal —
    DESIGN.md F3); each half is normalized independently so each sums to
    1 (and the whole vector sums to 2). Degenerate halves (empty, or
    all-at-mean) get a uniform distribution over their members so the LA
    update stays well-defined.

    Args:
        weights: (B, k) raw accumulated weights (eq. 13 output).

    Returns:
        (w_norm, r): both (B, k); r is 0.0 for reward, 1.0 for penalty.
    """
    weights = jnp.asarray(weights, jnp.float32)
    mean = jnp.mean(weights, axis=1, keepdims=True)
    r = jnp.where(weights > mean, 0.0, 1.0)  # (B, k)
    dev = jnp.abs(weights - mean)

    def half_norm(mask):
        cnt = jnp.sum(mask, axis=1, keepdims=True)
        s = jnp.sum(dev * mask, axis=1, keepdims=True)
        # If the half's deviations sum to 0 (or the half is empty
        # elsewhere), fall back to uniform over the half's members.
        uniform = mask / jnp.maximum(cnt, 1.0)
        scaled = dev * mask / jnp.where(s > 0.0, s, 1.0)
        return jnp.where(s > 0.0, scaled, uniform)

    rew_mask = 1.0 - r
    pen_mask = r
    w_norm = half_norm(rew_mask) + half_norm(pen_mask)
    return w_norm, r


def step_ref(hist, wsum, loads, capacity, p, raw_w, alpha, beta):
    """Fused per-batch Revolver numeric step (the L2 computation).

    score -> (returned for the host's argmax/lambda bookkeeping), then
    signal construction from the host-accumulated raw weights (eq. 13),
    then the weighted-LA update.

    Returns:
        (scores, p_next): (B, k) each.
    """
    scores = score_ref(hist, wsum, loads, capacity)
    w_norm, r = signal_ref(raw_w)
    p_next = la_update_ref(p, w_norm, r, alpha, beta)
    return scores, p_next
