"""L1 Pallas kernel: batched weighted learning-automaton update.

Implements eqs. (8)/(9) of the paper — the m^2 inner loop of Revolver —
for a (B, k) batch of probability vectors in one VMEM-resident block.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the paper runs this
loop per-vertex on Xeon cores; on a TPU we tile the batch dimension into
``block_b``-row blocks, keep P/W/R resident in VMEM for the whole k-pass
sweep (one HBM round-trip per block instead of k), and let the VPU
vectorize the k-wide elementwise update. ``interpret=True`` is mandatory
on this CPU-only image — real TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["la_update", "DEFAULT_BLOCK_B"]

# 256 rows x k<=256 cols x 4 bytes x 3 live operands ~= 0.75 MiB VMEM:
# comfortably inside a TPU core's ~16 MiB VMEM with double-buffering room.
DEFAULT_BLOCK_B = 256


def _la_update_kernel(p_ref, w_ref, r_ref, out_ref, *, alpha, beta, k):
    """One (block_b, k) tile: sequential sweep over the k signals."""
    p0 = p_ref[...]
    w = w_ref[...]
    r = r_ref[...]

    col = jax.lax.broadcasted_iota(jnp.int32, p0.shape, dimension=1)

    def body(i, p):
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=1)  # (B, 1)
        ri = jax.lax.dynamic_slice_in_dim(r, i, 1, axis=1)  # (B, 1)
        onehot = (col == i).astype(jnp.float32)

        # Reward branch, eq. (8).
        p_rew = onehot * (p + alpha * wi * (1.0 - p)) + (1.0 - onehot) * (
            p * (1.0 - alpha * wi)
        )
        # Penalty branch, eq. (9) — additive term weighted by the
        # receiving element's weight w_j (see ref.la_update_ref).
        scaled = p * (1.0 - beta * wi)
        p_pen = scaled + (1.0 - onehot) * (beta * w / (k - 1))

        return jnp.where(ri > 0.5, p_pen, p_rew)

    p = jax.lax.fori_loop(0, k, body, p0)

    # Renormalize (float drift over the k-pass sweep).
    p = jnp.clip(p, 1e-12, None)
    out_ref[...] = p / jnp.sum(p, axis=1, keepdims=True)


def la_update(p, w, r, alpha, beta, *, block_b: int = DEFAULT_BLOCK_B):
    """Batched weighted-LA probability update (eqs. 8-9).

    Args:
        p: (B, k) float32 probability vectors.
        w: (B, k) float32 half-normalized weights (reward half sums to 1,
           penalty half sums to 1 — see ``ref.signal_ref``).
        r: (B, k) float32 reinforcement signals (0 reward / 1 penalty).
        alpha, beta: python-scalar learning parameters (baked into the
           kernel — one compiled artifact per (alpha, beta) setting).
        block_b: batch tile height.

    Returns:
        (B, k) float32 updated probability vectors, rows summing to 1.
    """
    B, k = p.shape
    if k < 2:
        raise ValueError(f"weighted LA needs k >= 2 actions, got k={k}")
    block_b = min(block_b, B)
    if B % block_b != 0:
        # Pad the batch to a block multiple; padded rows are discarded.
        pad = block_b - (B % block_b)
        p = jnp.concatenate([p, jnp.full((pad, k), 1.0 / k, p.dtype)], axis=0)
        w = jnp.concatenate([w, jnp.zeros((pad, k), w.dtype)], axis=0)
        r = jnp.concatenate([r, jnp.ones((pad, k), r.dtype)], axis=0)
        out = la_update(p, w, r, alpha, beta, block_b=block_b)
        return out[:B]

    kernel = functools.partial(
        _la_update_kernel, alpha=float(alpha), beta=float(beta), k=k
    )
    grid = (p.shape[0] // block_b,)
    spec = pl.BlockSpec((block_b, k), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(p.shape, jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(p.astype(jnp.float32), w.astype(jnp.float32), r.astype(jnp.float32))
