"""L1 Pallas kernel: batched normalized label-propagation scoring.

Implements eqs. (10)-(12): ``score(v,l) = (tau(v,l) + pi(l)) / 2`` for a
(B, k) batch. The neighbour gather (irregular, CSR-driven) stays on the
host — the kernel consumes the dense per-vertex label-weight histogram,
which is the part worth vectorizing. The partition-penalty vector pi is
computed once per call from the (k,) load vector, including footnote 1's
negative-penalty augmentation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["score", "DEFAULT_BLOCK_B"]

DEFAULT_BLOCK_B = 256


def _score_kernel(hist_ref, wsum_ref, pi_ref, out_ref):
    """One (block_b, k) tile: tau from the histogram, add precomputed pi."""
    hist = hist_ref[...]
    wsum = wsum_ref[...]  # (block_b, 1)
    pi = pi_ref[...]  # (1, k)
    tau = hist / jnp.maximum(wsum, 1e-12)
    out_ref[...] = (tau + pi) / 2.0


def _penalty(loads, capacity):
    """Eq. (12) + footnote 1, as plain jnp (k is tiny; fuses into HLO)."""
    pen = 1.0 - loads / capacity
    min_pen = jnp.min(pen)
    pen = jnp.where(min_pen < 0.0, pen - min_pen, pen)
    return pen / jnp.maximum(jnp.sum(pen), 1e-12)


def score(hist, wsum, loads, capacity, *, block_b: int = DEFAULT_BLOCK_B):
    """Batched normalized LP score (eqs. 10-12).

    Args:
        hist: (B, k) float32 neighbour label-weight histogram
              ``hist[v,l] = sum_{u in N(v)} w(u,v) * delta(psi(u), l)``.
        wsum: (B,) float32 total neighbour weight per vertex.
        loads: (k,) float32 current partition loads b(l).
        capacity: scalar C = (1 + eps) * |E| / k.
        block_b: batch tile height.

    Returns:
        (B, k) float32 scores.
    """
    B, k = hist.shape
    hist = hist.astype(jnp.float32)
    wsum = jnp.asarray(wsum, jnp.float32).reshape(B, 1)
    pi = _penalty(jnp.asarray(loads, jnp.float32), jnp.float32(capacity))
    pi = pi.reshape(1, k)

    block_b = min(block_b, B)
    if B % block_b != 0:
        pad = block_b - (B % block_b)
        hist = jnp.concatenate([hist, jnp.zeros((pad, k), hist.dtype)], axis=0)
        wsum = jnp.concatenate([wsum, jnp.ones((pad, 1), wsum.dtype)], axis=0)
        out = _call(hist, wsum, pi, block_b, k)
        return out[:B]
    return _call(hist, wsum, pi, block_b, k)


def _call(hist, wsum, pi, block_b, k):
    grid = (hist.shape[0] // block_b,)
    return pl.pallas_call(
        functools.partial(_score_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(hist.shape, jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(hist, wsum, pi)
