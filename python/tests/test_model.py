"""L2 fused step vs the oracle, plus signal-construction invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import signal_ref, step_ref


def make_inputs(b, k, seed=0):
    rng = np.random.default_rng(seed)
    hist = rng.random((b, k)).astype(np.float32) * 5.0
    wsum = hist.sum(axis=1) + 0.1
    cap = 50.0
    loads = rng.random(k).astype(np.float32) * cap
    p = rng.random((b, k)).astype(np.float32) + 1e-3
    p /= p.sum(axis=1, keepdims=True)
    raw_w = rng.random((b, k)).astype(np.float32)
    return tuple(jnp.asarray(x) for x in (hist, wsum, loads)) + (
        cap,
        jnp.asarray(p),
        jnp.asarray(raw_w),
    )


@pytest.mark.parametrize("b,k", [(8, 4), (256, 32), (100, 8)])
def test_step_matches_ref(b, k):
    hist, wsum, loads, cap, p, raw_w = make_inputs(b, k)
    scores, p_next = model.batched_step(
        hist, wsum, loads, cap, p, raw_w, alpha=1.0, beta=0.1
    )
    scores_ref, p_next_ref = step_ref(hist, wsum, loads, cap, p, raw_w, 1.0, 0.1)
    np.testing.assert_allclose(scores, scores_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(p_next, p_next_ref, rtol=1e-4, atol=1e-5)


def test_signal_matches_ref():
    rng = np.random.default_rng(1)
    raw_w = jnp.asarray(rng.random((32, 16)).astype(np.float32))
    w_got, r_got = model.signal(raw_w)
    w_want, r_want = signal_ref(raw_w)
    np.testing.assert_allclose(w_got, w_want, rtol=1e-6)
    np.testing.assert_allclose(r_got, r_want)


def test_signal_halves_sum_to_one():
    rng = np.random.default_rng(2)
    raw_w = jnp.asarray(rng.random((16, 9)).astype(np.float32))
    w, r = model.signal(raw_w)
    w, r = np.asarray(w), np.asarray(r)
    rew = (w * (1 - r)).sum(axis=1)
    pen = (w * r).sum(axis=1)
    np.testing.assert_allclose(rew, 1.0, atol=1e-5)
    np.testing.assert_allclose(pen, 1.0, atol=1e-5)
    np.testing.assert_allclose(w.sum(axis=1), 2.0, atol=1e-5)


def test_signal_all_equal_weights():
    """All-equal weights: nothing is > mean, so everything is penalty;
    the empty reward half must fall back to something finite."""
    raw_w = jnp.full((4, 8), 0.5, jnp.float32)
    w, r = model.signal(raw_w)
    assert np.isfinite(np.asarray(w)).all()
    np.testing.assert_allclose(np.asarray(r), 1.0)  # all penalties


def test_signal_all_zero_weights():
    raw_w = jnp.zeros((4, 8), jnp.float32)
    w, r = model.signal(raw_w)
    assert np.isfinite(np.asarray(w)).all()


def test_la_update_entry_matches_composition():
    hist, wsum, loads, cap, p, raw_w = make_inputs(64, 8, seed=3)
    got = model.batched_la_update(p, raw_w, alpha=1.0, beta=0.1)
    _, want = step_ref(hist, wsum, loads, cap, p, raw_w, 1.0, 0.1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 24), k=st.integers(2, 16), seed=st.integers(0, 2**31 - 1))
def test_step_hypothesis(b, k, seed):
    hist, wsum, loads, cap, p, raw_w = make_inputs(b, k, seed=seed)
    scores, p_next = model.batched_step(
        hist, wsum, loads, cap, p, raw_w, alpha=1.0, beta=0.1
    )
    np.testing.assert_allclose(np.asarray(p_next).sum(axis=1), 1.0, atol=1e-4)
    assert np.isfinite(np.asarray(scores)).all()
