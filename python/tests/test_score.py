"""Pallas normalized-LP score kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import score_ref
from compile.kernels.score import score


def make_inputs(b, k, seed=0, overload=False):
    rng = np.random.default_rng(seed)
    hist = rng.random((b, k)).astype(np.float32) * 10.0
    wsum = hist.sum(axis=1) + rng.random(b).astype(np.float32)
    cap = 100.0
    loads = rng.random(k).astype(np.float32) * (cap * (1.5 if overload else 0.9))
    return jnp.asarray(hist), jnp.asarray(wsum), jnp.asarray(loads), cap


@pytest.mark.parametrize("b,k", [(1, 2), (16, 8), (256, 32), (100, 7)])
def test_matches_ref(b, k):
    hist, wsum, loads, cap = make_inputs(b, k)
    got = score(hist, wsum, loads, cap)
    want = score_ref(hist, wsum, loads, cap)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_overloaded_partition_footnote1():
    """Negative penalties (b(l) > C) take the augmentation path."""
    hist, wsum, loads, cap = make_inputs(32, 8, seed=1, overload=True)
    assert (np.asarray(loads) > cap).any()
    got = score(hist, wsum, loads, cap)
    want = score_ref(hist, wsum, loads, cap)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert np.isfinite(np.asarray(got)).all()


def test_score_bounded():
    """tau in [0,1] and pi sums to 1 => scores in [0, 1]."""
    hist, wsum, loads, cap = make_inputs(64, 16, seed=2)
    got = np.asarray(score(hist, wsum, loads, cap))
    assert (got >= 0).all() and (got <= 1.0 + 1e-6).all()


def test_empty_neighbourhood_is_safe():
    """wsum = 0 (isolated vertex) must not produce NaN/inf."""
    hist = jnp.zeros((4, 8), jnp.float32)
    wsum = jnp.zeros((4,), jnp.float32)
    loads = jnp.ones((8,), jnp.float32)
    got = np.asarray(score(hist, wsum, loads, 10.0))
    assert np.isfinite(got).all()


def test_uniform_loads_give_uniform_penalty():
    """Equal loads => pi uniform => score differences come from tau only."""
    k = 8
    hist = jnp.zeros((1, k), jnp.float32).at[0, 3].set(5.0)
    wsum = jnp.full((1,), 5.0, jnp.float32)
    loads = jnp.full((k,), 2.0, jnp.float32)
    got = np.asarray(score(hist, wsum, loads, 10.0))
    # partition 3 has tau=1 + pi=1/k; others tau=0 + pi=1/k.
    np.testing.assert_allclose(got[0, 3], (1.0 + 1.0 / k) / 2.0, rtol=1e-5)
    np.testing.assert_allclose(got[0, 0], (0.0 + 1.0 / k) / 2.0, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 50),
    k=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
    overload=st.booleans(),
)
def test_hypothesis_sweep(b, k, seed, overload):
    hist, wsum, loads, cap = make_inputs(b, k, seed=seed, overload=overload)
    got = score(hist, wsum, loads, cap, block_b=16)
    want = score_ref(hist, wsum, loads, cap)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
