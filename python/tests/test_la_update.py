"""Pallas weighted-LA update kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.la_update import la_update
from compile.kernels.ref import la_update_ref, signal_ref

RNG = np.random.default_rng(0)


def make_inputs(b, k, seed=0):
    """Random probability vectors + half-normalized weights + signals."""
    rng = np.random.default_rng(seed)
    p = rng.random((b, k)).astype(np.float32) + 1e-3
    p /= p.sum(axis=1, keepdims=True)
    raw_w = rng.random((b, k)).astype(np.float32)
    w, r = signal_ref(raw_w)
    return jnp.asarray(p), jnp.asarray(w), jnp.asarray(r)


@pytest.mark.parametrize("b,k", [(1, 2), (4, 8), (256, 32), (300, 7), (32, 256)])
def test_matches_ref(b, k):
    p, w, r = make_inputs(b, k)
    got = la_update(p, w, r, 1.0, 0.1)
    want = la_update_ref(p, w, r, 1.0, 0.1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,k", [(8, 4), (64, 16)])
def test_rows_sum_to_one(b, k):
    p, w, r = make_inputs(b, k, seed=1)
    got = la_update(p, w, r, 1.0, 0.1)
    np.testing.assert_allclose(np.asarray(got).sum(axis=1), 1.0, atol=1e-5)


def test_probabilities_stay_positive():
    p, w, r = make_inputs(16, 8, seed=2)
    got = np.asarray(la_update(p, w, r, 1.0, 0.1))
    assert (got > 0).all()


def test_reward_increases_rewarded_action():
    """A pure-reward signal on action 0 must increase p_0."""
    k = 4
    p = jnp.full((1, k), 1.0 / k, jnp.float32)
    w = jnp.zeros((1, k), jnp.float32).at[0, 0].set(1.0)
    # r: action 0 reward, others penalty with uniform penalty weights.
    r = jnp.ones((1, k), jnp.float32).at[0, 0].set(0.0)
    w = w.at[0, 1:].set(1.0 / (k - 1))
    got = np.asarray(la_update(p, w, r, 0.5, 0.1))
    assert got[0, 0] > 1.0 / k


def test_zero_alpha_beta_is_identity_up_to_renorm():
    p, w, r = make_inputs(8, 8, seed=3)
    got = np.asarray(la_update(p, w, r, 0.0, 0.0))
    np.testing.assert_allclose(got, np.asarray(p), rtol=1e-5, atol=1e-6)


def test_block_padding_consistency():
    """Non-multiple batch sizes must agree with the exact-block result."""
    p, w, r = make_inputs(300, 8, seed=4)
    full = np.asarray(la_update(p, w, r, 1.0, 0.1, block_b=256))
    small = np.asarray(la_update(p, w, r, 1.0, 0.1, block_b=300))
    np.testing.assert_allclose(full, small, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 40),
    k=st.integers(2, 24),
    alpha=st.floats(0.0, 1.0),
    beta=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(b, k, alpha, beta, seed):
    p, w, r = make_inputs(b, k, seed=seed)
    got = la_update(p, w, r, alpha, beta, block_b=16)
    want = la_update_ref(p, w, r, alpha, beta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got).sum(axis=1), 1.0, atol=1e-4)


def test_k1_rejected():
    p = jnp.ones((2, 1), jnp.float32)
    with pytest.raises(ValueError):
        la_update(p, p, p, 1.0, 0.1)
