//! Quickstart: partition a social-network surrogate with Revolver and
//! print the paper's two quality metrics.
//!
//!     cargo run --release --example quickstart

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::metrics::quality;
use revolver::partitioners::{revolver::Revolver, Partitioner};

fn main() -> anyhow::Result<()> {
    // 1. A LiveJournal-shaped graph (right-skewed social network).
    let graph = generate_dataset(Dataset::Lj, 1 << 13, /*seed=*/ 7)?;
    println!(
        "graph: |V|={}, |E|={} (LiveJournal surrogate)",
        graph.num_vertices(),
        graph.num_edges()
    );

    // 2. Paper settings (§V-F) with k=8 partitions.
    let cfg = RevolverConfig { parts: 8, seed: 42, ..Default::default() };
    let k = cfg.parts;

    // 3. Partition.
    let out = Revolver::new(cfg).partition(&graph);

    // 4. Evaluate (§V-E metrics).
    let q = quality::evaluate(&graph, &out.labels, k);
    println!("steps executed:       {}", out.trace.steps());
    println!("converged at:         {:?}", out.trace.converged_at);
    println!("local edges:          {:.4}  (higher = less communication)", q.local_edges);
    println!("max normalized load:  {:.4}  (1.0 = perfect balance)", q.max_normalized_load);
    println!("wall time:            {:.2}s", out.trace.wall_time_s);

    // Partition sizes.
    let loads = quality::partition_loads(&graph, &out.labels, k);
    println!("partition loads (out-edges): {loads:?}");
    Ok(())
}
