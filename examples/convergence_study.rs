//! Figure-4 reproduction: per-step convergence of local edges and max
//! normalized load for Revolver vs Spinner on the LiveJournal surrogate.
//!
//! Writes the CSV traces and renders an ASCII sketch of the figure.
//!
//!     cargo run --release --example convergence_study

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::metrics::trace::RunTrace;
use revolver::partitioners::by_name;

fn main() -> anyhow::Result<()> {
    let graph = generate_dataset(Dataset::Lj, 1 << 13, 7)?;
    println!(
        "LJ surrogate: |V|={}, |E|={}; k=32, 120 steps, no early halt\n",
        graph.num_vertices(),
        graph.num_edges()
    );

    let mut traces: Vec<(String, RunTrace)> = Vec::new();
    for algo in ["revolver", "spinner"] {
        let cfg = RevolverConfig {
            parts: 32,
            max_steps: 120,
            halt_window: u32::MAX, // run the full budget, like Figure 4
            trace_every: 1,
            seed: 5,
            ..Default::default()
        };
        let out = by_name(algo, cfg)?.partition(&graph);
        std::fs::create_dir_all("results")?;
        let path = format!("results/fig4_{algo}_lj_k32.csv");
        std::fs::write(&path, out.trace.to_csv())?;
        println!("wrote {path}");
        traces.push((algo.to_string(), out.trace));
    }

    // ASCII sketch: local edges over steps.
    println!("\nlocal edges over steps ('r' = revolver, 's' = spinner):");
    plot(&traces, |p| p.local_edges);
    println!("\nmax normalized load over steps:");
    plot(&traces, |p| p.max_normalized_load);

    // The paper's Figure-4 observations, checked on this run:
    let rev = &traces[0].1;
    let spi = &traces[1].1;
    let rev_final = rev.points.last().unwrap();
    let spi_final = spi.points.last().unwrap();
    println!("\nfinal: revolver le={:.4} mnl={:.4} | spinner le={:.4} mnl={:.4}",
        rev_final.local_edges, rev_final.max_normalized_load,
        spi_final.local_edges, spi_final.max_normalized_load);
    println!(
        "Revolver stays within ~2% extra capacity while Spinner rides the ε cap: {}",
        if rev_final.max_normalized_load < spi_final.max_normalized_load {
            "reproduced"
        } else {
            "NOT reproduced"
        }
    );
    Ok(())
}

fn plot(traces: &[(String, RunTrace)], f: impl Fn(&revolver::metrics::trace::TracePoint) -> f64) {
    const W: usize = 80;
    const H: usize = 16;
    let all: Vec<f64> = traces.iter().flat_map(|(_, t)| t.points.iter().map(&f)).collect();
    let (lo, hi) = all
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| (lo.min(x), hi.max(x)));
    let span = (hi - lo).max(1e-9);
    let mut grid = vec![vec![b' '; W]; H];
    for (name, t) in traces {
        let c = name.as_bytes()[0];
        let n = t.points.len().max(2);
        for (i, p) in t.points.iter().enumerate() {
            let x = i * (W - 1) / (n - 1);
            let y = ((f(p) - lo) / span * (H - 1) as f64).round() as usize;
            grid[H - 1 - y.min(H - 1)][x] = c;
        }
    }
    println!("  {hi:8.4} ┐");
    for row in &grid {
        println!("           │{}", String::from_utf8_lossy(row));
    }
    println!("  {lo:8.4} └{}", "─".repeat(W));
}
