//! Release-mode frontier smoke check (run by CI): tiny R-MAT, Revolver
//! with the frontier on vs off at the same seed and superstep budget.
//! Asserts the active-set run (a) skips a nonzero number of vertex
//! evaluations, and (b) stays inside the same quality envelope as the
//! full-sweep run. Exits nonzero (assert panic) on violation.
//!
//!     cargo run --release --example frontier_smoke

use revolver::config::{Frontier, RevolverConfig};
use revolver::metrics::quality;
use revolver::partitioners::revolver::Revolver;
use revolver::partitioners::Partitioner;
use revolver::util::bench::bench_rmat;

fn main() {
    let g = bench_rmat(13); // the shared hotpath-bench R-MAT recipe
    let n = g.num_vertices();
    let k = 8usize;
    let steps = 15u32;
    let base = RevolverConfig {
        parts: k,
        max_steps: steps,
        halt_window: u32::MAX,
        threads: 1, // deterministic smoke: no scheduler luck in the margins
        seed: 3,
        ..Default::default()
    };

    let run = |frontier: Frontier| {
        let cfg = RevolverConfig { frontier, ..base.clone() };
        let out = Revolver::new(cfg).partition(&g);
        let q = quality::evaluate(&g, &out.labels, k);
        (out.trace.total_evaluated, q)
    };
    let (evals_off, q_off) = run(Frontier::Off);
    let (evals_on, q_on) = run(Frontier::On);

    let full = steps as u64 * n as u64;
    let saved = full.saturating_sub(evals_on);
    println!("frontier off: evals={evals_off} local={:.4} mnl={:.4}", q_off.local_edges, q_off.max_normalized_load);
    println!("frontier on:  evals={evals_on} local={:.4} mnl={:.4}", q_on.local_edges, q_on.max_normalized_load);
    println!("evaluations saved: {saved} ({:.1}%)", 100.0 * saved as f64 / full as f64);

    assert_eq!(evals_off, full, "full sweeps must evaluate steps × |V|");
    assert!(saved > 0, "frontier execution must skip a nonzero number of evaluations");
    assert!(
        q_on.local_edges >= q_off.local_edges - 0.03,
        "frontier quality out of envelope: on={} off={}",
        q_on.local_edges,
        q_off.local_edges
    );
    assert!(
        q_on.max_normalized_load <= 1.1 && q_off.max_normalized_load <= 1.1,
        "balance envelope violated: on={} off={}",
        q_on.max_normalized_load,
        q_off.max_normalized_load
    );
    println!("frontier smoke: OK");
}
