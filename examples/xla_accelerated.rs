//! End-to-end three-layer driver — the full-system validation run
//! (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer on a real workload:
//!   L1/L2: the Pallas LP-score and weighted-LA kernels, AOT-lowered to
//!          HLO by `make artifacts`, executed through PJRT;
//!   L3:    the Rust coordinator running the full Revolver loop.
//!
//! Partitions an LJ-shaped graph with the `xla` engine and the `native`
//! engine, checks they agree statistically, and reports quality +
//! throughput for both.
//!
//!     make artifacts && cargo run --release --example xla_accelerated

use revolver::config::{Engine, RevolverConfig};
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::metrics::quality;
use revolver::partitioners::{revolver::Revolver, Partitioner};
use revolver::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // Artifact diagnostics first (fail early with a clear message).
    let rt = Runtime::open("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts: {:?}\n", rt.manifest().names());

    let graph = generate_dataset(Dataset::Lj, 1 << 12, 7)?;
    let k = 8usize;
    println!("workload: LJ surrogate |V|={} |E|={} k={k}", graph.num_vertices(), graph.num_edges());

    let mut results = Vec::new();
    for engine in [Engine::Native, Engine::Xla] {
        let cfg = RevolverConfig {
            parts: k,
            engine,
            max_steps: 40,
            halt_window: u32::MAX,
            threads: 1,
            seed: 9,
            ..Default::default()
        };
        let out = Revolver::new(cfg).partition(&graph);
        let q = quality::evaluate(&graph, &out.labels, k);
        let steps = out.trace.steps();
        let edges_per_s =
            steps as f64 * graph.num_edges() as f64 / out.trace.wall_time_s.max(1e-9);
        println!(
            "{engine:?}: local edges {:.4}, max norm load {:.4}, {} steps in {:.2}s ({:.2}M edge-visits/s)",
            q.local_edges,
            q.max_normalized_load,
            steps,
            out.trace.wall_time_s,
            edges_per_s / 1e6
        );
        results.push(q);
    }

    // The two engines run the same algorithm through different numeric
    // stacks (pure Rust vs Pallas-in-XLA); RNG consumption differs only
    // through f32 reduction order, so quality must agree statistically.
    let d_le = (results[0].local_edges - results[1].local_edges).abs();
    let d_mnl = (results[0].max_normalized_load - results[1].max_normalized_load).abs();
    println!("\nengine agreement: Δlocal_edges={d_le:.4}, Δmax_norm_load={d_mnl:.4}");
    anyhow::ensure!(d_le < 0.05, "native and xla engines diverged on local edges");
    anyhow::ensure!(d_mnl < 0.10, "native and xla engines diverged on load");
    println!("native and XLA paths agree — three-layer stack validated ✓");
    Ok(())
}
