//! Release-mode dynamic-subsystem smoke check (run by CI): R-MAT under
//! uniform edge churn, incremental frontier-seeded repair vs a cold
//! restart per epoch at the same per-epoch superstep budget. Asserts
//! the repair path (a) spends strictly fewer evaluated vertex-steps
//! than restarting, and (b) ends with locality within the acceptance
//! envelope of the restart (and balanced). Exits nonzero (assert
//! panic) on violation.
//!
//!     cargo run --release --example dynamic_churn

use revolver::config::RevolverConfig;
use revolver::dynamic::{ChurnRecipe, IncrementalPartitioner};
use revolver::metrics::quality;
use revolver::multilevel::Refiner;
use revolver::partitioners::by_name;
use revolver::util::bench::bench_rmat;

fn main() {
    let g = bench_rmat(13); // the shared hotpath-bench R-MAT recipe
    let k = 8usize;
    let repair = 5u32;
    let epochs = 4u64;
    let cfg = RevolverConfig {
        parts: k,
        max_steps: 40,
        threads: 1, // deterministic smoke: no scheduler luck in the margins
        seed: 3,
        repair_steps: repair,
        ..Default::default()
    };

    let mut inc = IncrementalPartitioner::new(g, cfg.clone(), Refiner::Spinner);
    let recipe = ChurnRecipe::Uniform { frac: 0.02 };

    let mut cold_evaluated = 0u64;
    let mut cold_le = 0.0f64;
    for e in 0..epochs {
        let batch = recipe.generate(inc.current(), 500 + e);
        let stats = inc.epoch(&batch);

        let mut rc = cfg.clone();
        rc.max_steps = repair;
        rc.halt_window = u32::MAX;
        let cold = by_name("spinner", rc).unwrap().partition(inc.current());
        cold_evaluated += cold.trace.total_evaluated;
        cold_le = quality::local_edges(inc.current(), &cold.labels);

        let q = quality::evaluate(inc.current(), inc.labels(), k);
        println!(
            "epoch {e}: local={:.4} mnl={:.4} seeds={} evaluated={} (cold local={:.4})",
            q.local_edges, q.max_normalized_load, stats.seeds, stats.evaluated, cold_le
        );
    }

    let q = quality::evaluate(inc.current(), inc.labels(), k);
    let (inc_ev, cold_ev) = (inc.total_evaluated(), cold_evaluated);
    println!(
        "totals: repair evaluated={inc_ev} vs restart evaluated={cold_ev} ({:.1}% saved)",
        100.0 * (cold_ev.saturating_sub(inc_ev)) as f64 / cold_ev.max(1) as f64
    );

    assert!(
        inc_ev < cold_ev,
        "repair must beat per-epoch restarts on evaluated vertex-steps: {inc_ev} vs {cold_ev}"
    );
    assert!(
        q.local_edges >= cold_le - 0.03 * cold_le,
        "repair quality out of envelope: inc={} cold={cold_le}",
        q.local_edges
    );
    assert!(
        q.max_normalized_load <= 1.10,
        "balance envelope violated: {}",
        q.max_normalized_load
    );
    println!("dynamic churn smoke: OK");
}
