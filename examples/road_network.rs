//! Road-network partitioning — the paper's §V-G.4 case study: on a
//! left-skewed planar graph with strong id locality (USA-road class),
//! Range partitioning is the one baseline that beats LP methods on
//! local edges, while Revolver still wins on balance.
//!
//!     cargo run --release --example road_network

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::graph::stats;
use revolver::metrics::quality;
use revolver::partitioners::by_name;

fn main() -> anyhow::Result<()> {
    let graph = generate_dataset(Dataset::Usa, 1 << 13, 7)?;
    let s = stats::compute(&graph);
    anyhow::ensure!(
        s.skewness < 0.0,
        "surrogate lost its left skew: {:.3}",
        s.skewness
    );
    println!(
        "USA-road surrogate: |V|={}, |E|={}, skew={:.3} ({:?}, negative like the real USA-road), density={:.3}e-5",
        s.vertices,
        s.edges,
        s.skewness,
        stats::classify_skew(s.skewness),
        s.density * 1e5
    );

    println!("\n{:<10} {:>6} {:>12} {:>18}", "algorithm", "k", "local edges", "max norm load");
    let mut range_le = 0.0;
    let mut revolver_le = 0.0;
    let mut revolver_mnl = 0.0;
    for algo in ["revolver", "spinner", "hash", "range"] {
        for k in [8usize, 32] {
            let cfg = RevolverConfig { parts: k, seed: 3, ..Default::default() };
            let out = by_name(algo, cfg)?.partition(&graph);
            let q = quality::evaluate(&graph, &out.labels, k);
            println!(
                "{algo:<10} {k:>6} {:>12.4} {:>18.4}",
                q.local_edges, q.max_normalized_load
            );
            if k == 8 {
                match algo {
                    "range" => range_le = q.local_edges,
                    "revolver" => {
                        revolver_le = q.local_edges;
                        revolver_mnl = q.max_normalized_load;
                    }
                    _ => {}
                }
            }
        }
    }

    println!("\npaper §V-G.4 expectations on this graph class:");
    println!(
        "  Range beats LP methods on local edges here: range={range_le:.3} vs revolver={revolver_le:.3} -> {}",
        if range_le > revolver_le { "reproduced" } else { "NOT reproduced" }
    );
    println!(
        "  Revolver keeps near-perfect balance: mnl={revolver_mnl:.3} -> {}",
        if revolver_mnl < 1.10 { "reproduced" } else { "NOT reproduced" }
    );
    Ok(())
}
