//! Streaming baselines and the streaming→Revolver warm start.
//!
//! Runs the streaming family (LDG / Fennel / prioritized restreaming)
//! against the hash floor on a power-law R-MAT graph, then shows the
//! warm-start bridge: Revolver seeded from a Fennel pass
//! (`--init stream:fennel` on the CLI) reaches its convergence
//! threshold in a fraction of the steps of a uniform-random start.
//!
//!     cargo run --release --example streaming_warmstart

use revolver::config::{Init, RevolverConfig, StreamAlgo};
use revolver::graph::gen::rmat;
use revolver::metrics::quality;
use revolver::partitioners::{by_name, revolver::Revolver, Partitioner};
use revolver::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let n = 1 << 13;
    let g = rmat::rmat(n, 16 * n, 0.57, 0.19, 0.19, 7);
    let k = 8;
    println!(
        "graph: |V|={}, |E|={} (R-MAT, power-law)  k={k}\n",
        g.num_vertices(),
        g.num_edges()
    );

    // 1. The streaming family vs the hash floor: one cheap pass each.
    println!("{:>9}  {:>11} {:>8} {:>9} {:>10}", "algorithm", "local edges", "mnl", "edge mnl", "wall");
    for algo in ["hash", "ldg", "fennel", "restream"] {
        let cfg = RevolverConfig { parts: k, seed: 42, ..Default::default() };
        let p = by_name(algo, cfg)?;
        let sw = Stopwatch::start();
        let out = p.partition(&g);
        let q = quality::evaluate(&g, &out.labels, k);
        println!(
            "{algo:>9}  {:>11.4} {:>8.4} {:>9.4} {:>9.3}s",
            q.local_edges,
            q.max_normalized_load,
            q.max_normalized_edge_load,
            sw.elapsed_s()
        );
    }

    // 2. Warm start: uniform-random vs stream:fennel init, same seed.
    println!("\nRevolver convergence, cold vs warm start:");
    for (name, init) in [
        ("random (paper)", Init::Random),
        ("stream:fennel", Init::Stream(StreamAlgo::Fennel)),
    ] {
        let cfg = RevolverConfig {
            parts: k,
            seed: 42,
            threads: 1,
            max_steps: 150,
            init,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let out = Revolver::new(cfg).partition(&g);
        let q = quality::evaluate(&g, &out.labels, k);
        println!(
            "  init {name:>15}: {:>3} steps (converged at {:?}), local edges {:.4}, mnl {:.4}, {:.2}s",
            out.trace.steps(),
            out.trace.converged_at,
            q.local_edges,
            q.max_normalized_load,
            sw.elapsed_s()
        );
    }
    Ok(())
}
