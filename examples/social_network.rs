//! Social-network partitioning study — the paper intro's motivating
//! workload: place a power-law friendship graph (LiveJournal/Orkut
//! class) across cloud machines so PageRank-style analytics minimize
//! communication without hot-spotting any one machine.
//!
//! Compares all four §V-D algorithms on LJ- and OK-shaped surrogates and
//! prints a Figure-3-style mini-table.
//!
//!     cargo run --release --example social_network

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::metrics::quality;
use revolver::metrics::report::{Report, ResultRow};
use revolver::partitioners::by_name;

fn main() -> anyhow::Result<()> {
    let mut report = Report::new();

    for ds in [Dataset::Lj, Dataset::Ok] {
        let graph = generate_dataset(ds, 1 << 12, 7)?;
        println!(
            "=== {} surrogate: |V|={}, |E|={} ===",
            ds.paper_stats().full_name,
            graph.num_vertices(),
            graph.num_edges()
        );
        for algo in ["revolver", "spinner", "hash", "range"] {
            for k in [4usize, 16] {
                let cfg = RevolverConfig { parts: k, seed: 1, ..Default::default() };
                let out = by_name(algo, cfg)?.partition(&graph);
                let q = quality::evaluate(&graph, &out.labels, k);
                println!(
                    "  {algo:>9} k={k:<3} local edges {:.4}   max norm load {:.4}",
                    q.local_edges, q.max_normalized_load
                );
                report.push(ResultRow {
                    graph: ds.name().to_string(),
                    algorithm: algo.to_string(),
                    parts: k as u32,
                    local_edges: q.local_edges,
                    max_normalized_load: q.max_normalized_load,
                    steps: out.trace.steps(),
                    wall_time_s: out.trace.wall_time_s,
                    runs: 1,
                });
            }
        }
    }

    // The paper's headline checks (§V-G.1, §V-H.1) on this run:
    let rows = report.rows();
    let rev_mnl_worst = rows
        .iter()
        .filter(|r| r.algorithm == "revolver")
        .map(|r| r.max_normalized_load)
        .fold(0.0f64, f64::max);
    println!("\nworst Revolver max-normalized-load across runs: {rev_mnl_worst:.4}");
    println!("(the paper's claim: Revolver never sacrifices balance — expect ≈1.0,");
    println!(" while Range on skewed graphs blows up and Hash wastes local edges)");

    report.write_files(std::path::Path::new("results"), "social_network")?;
    println!("\nwrote results/social_network.csv and .json");
    Ok(())
}
