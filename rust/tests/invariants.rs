//! Randomized property tests (proptest is unavailable offline, so these
//! drive a seeded case generator through the same check/shrink-free
//! harness style: many random cases per property, failures print the
//! seed needed to reproduce).

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::graph::GraphBuilder;
use revolver::la::signal::build_signals;
use revolver::la::weighted::WeightedLa;
use revolver::la::Signal;
use revolver::lp::{neighbor_histogram, normalized, spinner};
use revolver::metrics::quality;
use revolver::partition::{InitialAssignment, PartitionState};
use revolver::partitioners::by_name;
use revolver::util::json::Json;
use revolver::util::rng::Rng;

/// Run `prop` for `cases` random seeds, reporting the failing seed.
fn forall(cases: u64, prop: impl Fn(u64)) {
    for seed in 0..cases {
        // Panics inside `prop` bubble up; wrap with seed context.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(seed)));
        if let Err(e) = result {
            panic!("property failed at seed={seed}: {e:?}");
        }
    }
}

fn random_distribution(rng: &mut Rng, k: usize) -> Vec<f32> {
    let mut p: Vec<f32> = (0..k).map(|_| rng.next_f32() + 1e-4).collect();
    let sum: f32 = p.iter().sum();
    p.iter_mut().for_each(|x| *x /= sum);
    p
}

#[test]
fn prop_weighted_la_preserves_distribution() {
    forall(200, |seed| {
        let mut rng = Rng::new(seed);
        let k = 2 + rng.below_usize(30);
        let mut p = random_distribution(&mut rng, k);
        let raw: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let (w, s) = build_signals(&raw);
        let alpha = rng.next_f32();
        let beta = rng.next_f32() * 0.5;
        WeightedLa::update(&mut p, &w, &s, alpha, beta);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "sum={sum} k={k}");
        assert!(p.iter().all(|&x| x > 0.0 && x.is_finite()));
    });
}

#[test]
fn prop_signal_halves_normalized() {
    forall(300, |seed| {
        let mut rng = Rng::new(seed);
        let k = 2 + rng.below_usize(60);
        let raw: Vec<f32> = (0..k).map(|_| rng.next_f32() * 10.0).collect();
        let (w, s) = build_signals(&raw);
        let rew: f32 = w.iter().zip(&s).filter(|(_, s)| s.is_reward()).map(|(w, _)| w).sum();
        let pen: f32 = w.iter().zip(&s).filter(|(_, s)| !s.is_reward()).map(|(w, _)| w).sum();
        // Non-degenerate raw vectors: both halves sum to 1.
        if s.iter().any(|x| x.is_reward()) {
            assert!((rew - 1.0).abs() < 1e-4, "rew={rew}");
        }
        assert!((pen - 1.0).abs() < 1e-4, "pen={pen}");
        assert!(w.iter().all(|&x| (0.0..=1.0 + 1e-5).contains(&x)));
    });
}

#[test]
fn prop_normalized_penalty_is_distribution() {
    forall(300, |seed| {
        let mut rng = Rng::new(seed);
        let k = 2 + rng.below_usize(40);
        let cap = 1.0 + rng.next_f32() * 1000.0;
        // Loads may exceed capacity (footnote-1 path).
        let loads: Vec<f32> = (0..k).map(|_| rng.next_f32() * cap * 1.5).collect();
        let mut pi = vec![0.0f32; k];
        normalized::penalty_into(&loads, cap, &mut pi);
        let sum: f32 = pi.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");
        assert!(pi.iter().all(|&x| x >= 0.0));
    });
}

#[test]
fn prop_scores_bounded_and_argmax_correct() {
    forall(200, |seed| {
        let mut rng = Rng::new(seed);
        let k = 2 + rng.below_usize(20);
        let hist: Vec<f32> = (0..k).map(|_| rng.next_f32() * 5.0).collect();
        let wsum: f32 = hist.iter().sum::<f32>() + rng.next_f32();
        let mut pi = vec![0.0f32; k];
        let loads: Vec<f32> = (0..k).map(|_| rng.next_f32() * 100.0).collect();
        normalized::penalty_into(&loads, 120.0, &mut pi);
        let mut scores = vec![0.0f32; k];
        let best = normalized::score_into(&hist, wsum, &pi, &mut scores);
        assert!(scores.iter().all(|&s| (0.0..=1.0 + 1e-5).contains(&s)));
        let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(scores[best], max);
    });
}

#[test]
fn prop_spinner_migration_probability_in_unit_range() {
    forall(300, |seed| {
        let mut rng = Rng::new(seed);
        let p = spinner::migration_probability(
            rng.next_f32() * 100.0,
            rng.next_f32() * 150.0,
            rng.next_f32() * 100.0 - 1.0,
        );
        assert!((0.0..=1.0).contains(&p), "p={p}");
    });
}

#[test]
fn prop_partition_loads_sum_to_edges() {
    // After any partitioning run, Σ_l b(l) == |E| and labels < k.
    forall(12, |seed| {
        let mut rng = Rng::new(seed);
        let ds = Dataset::ALL[rng.below_usize(9)];
        let k = 2 + rng.below_usize(14);
        let g = generate_dataset(ds, 256 + rng.below_usize(512), seed).unwrap();
        let algo = ["revolver", "spinner", "hash", "range"][rng.below_usize(4)];
        let cfg = RevolverConfig {
            parts: k,
            max_steps: 8,
            threads: 1 + rng.below_usize(3),
            seed,
            ..Default::default()
        };
        let out = by_name(algo, cfg).unwrap().partition(&g);
        let loads = quality::partition_loads(&g, &out.labels, k);
        assert_eq!(loads.iter().sum::<u64>(), g.num_edges() as u64, "{algo} {}", ds.name());
    });
}

#[test]
fn prop_migrate_keeps_state_invariant() {
    forall(50, |seed| {
        let mut rng = Rng::new(seed);
        let n = 64 + rng.below_usize(128);
        let mut b = GraphBuilder::new(n);
        for _ in 0..4 * n {
            b.edge(rng.below(n as u64) as u32, rng.below(n as u64) as u32);
        }
        let g = b.build();
        let k = 2 + rng.below_usize(6);
        let st = PartitionState::new(&g, k, 0.05, InitialAssignment::Random(seed));
        for _ in 0..500 {
            let v = rng.below(n as u64) as u32;
            st.migrate(v, rng.below(k as u64) as u32, g.out_degree(v));
        }
        st.check_load_invariant().unwrap();
    });
}

#[test]
fn prop_histogram_total_equals_weight_sum() {
    forall(100, |seed| {
        let mut rng = Rng::new(seed);
        let g = generate_dataset(Dataset::Wiki, 512, seed).unwrap();
        let k = 2 + rng.below_usize(8);
        let labels: Vec<u32> = (0..512).map(|_| rng.below(k as u64) as u32).collect();
        let mut hist = vec![0.0f32; k];
        let v = rng.below(512) as u32;
        let wsum = neighbor_histogram(
            g.neighbors(v),
            g.neighbor_weights(v),
            |u| labels[u as usize],
            &mut hist,
        );
        let total: f32 = hist.iter().sum();
        assert!((total - wsum).abs() < 1e-3 * wsum.max(1.0), "{total} vs {wsum}");
    });
}

#[test]
fn prop_classic_la_update_preserves_distribution() {
    use revolver::la::classic::ClassicLa;
    forall(200, |seed| {
        let mut rng = Rng::new(seed);
        let k = 2 + rng.below_usize(20);
        let mut la = ClassicLa::new(k);
        for _ in 0..30 {
            let i = rng.below_usize(k);
            let sig = if rng.chance(0.5) { Signal::Reward } else { Signal::Penalty };
            la.update(i, sig, rng.next_f32() * 0.9, rng.next_f32() * 0.5);
        }
        let sum: f32 = la.probabilities().iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");
    });
}

#[test]
fn prop_json_roundtrip_random_structures() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.next_f64() * 1e6).round()),
            3 => Json::Str(format!("s{}", rng.next_u64() % 1000)),
            4 => Json::Arr((0..rng.below_usize(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below_usize(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(200, |seed| {
        let mut rng = Rng::new(seed);
        let j = random_json(&mut rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j, "roundtrip failed for {text}");
    });
}

#[test]
fn prop_generators_always_valid() {
    forall(30, |seed| {
        let mut rng = Rng::new(seed);
        let ds = Dataset::ALL[rng.below_usize(9)];
        let n = 100 + rng.below_usize(900);
        let g = generate_dataset(ds, n, seed).unwrap();
        g.validate().unwrap_or_else(|e| panic!("{} n={n}: {e}", ds.name()));
    });
}

// ───────────── multilevel coarsening invariants (BA + R-MAT) ─────────────

/// One power-law graph per generator family, per seed — the matching /
/// contraction properties must hold on both hub-heavy regimes.
fn coarsening_graphs(seed: u64) -> Vec<(&'static str, revolver::graph::Graph)> {
    use revolver::graph::gen::{ba, rmat};
    vec![
        ("ba", ba::barabasi_albert(512, 8, seed)),
        ("rmat", rmat::rmat(512, 8 * 512, 0.57, 0.19, 0.19, seed)),
    ]
}

#[test]
fn prop_matching_pairs_disjoint_and_adjacent() {
    use revolver::multilevel::heavy_edge_matching;
    forall(4, |seed| {
        for (name, g) in coarsening_graphs(seed) {
            let mate = heavy_edge_matching(&g, seed, u64::MAX);
            assert_eq!(mate.len(), g.num_vertices());
            for v in 0..g.num_vertices() {
                let m = mate[v] as usize;
                // Involution ⇒ every vertex is in at most one pair.
                assert_eq!(mate[m] as usize, v, "{name}: mate not symmetric at {v}");
                if m != v {
                    assert!(
                        g.neighbors(v as u32).binary_search(&(m as u32)).is_ok(),
                        "{name}: matched pair ({v},{m}) must be adjacent"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_coarse_vertex_weights_sum_to_fine_vertices() {
    use revolver::multilevel::{contract, heavy_edge_matching};
    forall(4, |seed| {
        for (name, g) in coarsening_graphs(seed) {
            let mate = heavy_edge_matching(&g, seed ^ 0x5150, u64::MAX);
            let (cg, map) = contract(&g, &mate);
            assert_eq!(
                cg.graph().total_vertex_weight(),
                g.num_vertices() as u64,
                "{name}: coarse vertex weights must sum to |V|"
            );
            // The fine→coarse map is onto 0..cn (no empty clusters).
            let mut hit = vec![false; cg.num_vertices()];
            for &c in &map {
                hit[c as usize] = true;
            }
            assert!(hit.iter().all(|&h| h), "{name}: every coarse vertex non-empty");
            cg.graph().validate().unwrap();
        }
    });
}

#[test]
fn prop_coarse_edge_weight_conservation() {
    use revolver::multilevel::{contract, heavy_edge_matching, matched_weight};
    forall(4, |seed| {
        for (name, g) in coarsening_graphs(seed) {
            let mate = heavy_edge_matching(&g, seed ^ 0x434F, u64::MAX);
            let (cg, _) = contract(&g, &mate);
            let fine = g.total_neighbor_weight() / 2.0;
            let removed = matched_weight(&g, &mate);
            let coarse = cg.total_edge_weight();
            assert!(
                (coarse - (fine - removed)).abs() <= 1e-6 * fine.max(1.0),
                "{name}: coarse {coarse} != fine {fine} - matched {removed}"
            );
        }
    });
}

#[test]
fn prop_hierarchy_invariants_hold_at_every_level() {
    use revolver::multilevel::Hierarchy;
    forall(3, |seed| {
        for (name, g) in coarsening_graphs(seed) {
            let h = Hierarchy::build(&g, 64, seed, u64::MAX);
            assert!(h.levels() >= 1, "{name}: 512 vertices must coarsen at least once");
            let total = g.num_vertices() as u64;
            let mut prev_n = g.num_vertices();
            for (i, cg) in h.graphs.iter().enumerate() {
                assert!(cg.num_vertices() < prev_n, "{name}: level {i} must shrink");
                assert_eq!(cg.graph().total_vertex_weight(), total, "{name}: level {i}");
                assert_eq!(h.maps[i].len(), prev_n, "{name}: map {i} covers its level");
                assert!(
                    h.maps[i].iter().all(|&c| (c as usize) < cg.num_vertices()),
                    "{name}: map {i} in range"
                );
                cg.graph().validate().unwrap_or_else(|e| panic!("{name} level {i}: {e}"));
                prev_n = cg.num_vertices();
            }
        }
    });
}

#[test]
fn prop_rebalance_always_lands_inside_envelope() {
    use revolver::multilevel::rebalance;
    forall(6, |seed| {
        for (name, g) in coarsening_graphs(seed) {
            // Adversarial start: all mass piled into partition 0.
            let k = 4;
            let mut labels = vec![0u32; g.num_vertices()];
            rebalance(&g, &mut labels, k, 0.05);
            let mnl = quality::max_normalized_load(&g, &labels, k);
            assert!(mnl <= 1.05 + 1e-9, "{name}: mnl={mnl}");
        }
    });
}

#[test]
fn prop_rebalance_drains_concentrated_start_at_large_k() {
    // With every vertex in partition 0 all target histograms tie, so
    // every candidate prefers the same lightest partition — the case
    // that forces the apply-time fallback target. k well above the
    // sweep bound proves one sweep can fan out across many partitions.
    // BA's near-uniform out-degrees keep the instance feasible by
    // construction (any partition with ≥ m_attach room accepts any
    // vertex).
    use revolver::graph::gen::ba;
    use revolver::multilevel::rebalance;
    forall(3, |seed| {
        let g = ba::barabasi_albert(512, 8, seed);
        for k in [8usize, 32] {
            let mut labels = vec![0u32; g.num_vertices()];
            rebalance(&g, &mut labels, k, 0.05);
            let mnl = quality::max_normalized_load(&g, &labels, k);
            assert!(mnl <= 1.05 + 1e-9, "k={k}: mnl={mnl}");
        }
    });
}

#[test]
fn prop_frontier_chunks_cover_exactly_the_frontier() {
    // Active-set scheduling (ISSUE 4): subset-aware degree-balanced
    // chunks must cover exactly the frontier, emit no empty chunks, and
    // handle the empty- and single-vertex-frontier edges — on both BA
    // and R-MAT degree sequences, across seeds and thread counts.
    use revolver::coordinator::Chunks;
    use revolver::graph::gen::{ba, rmat};
    forall(8, |seed| {
        let graphs = [
            ("ba", ba::barabasi_albert(1024, 8, seed)),
            ("rmat", rmat::rmat(1024, 8 * 1024, 0.57, 0.19, 0.19, seed)),
        ];
        for (name, g) in graphs {
            let mut rng = Rng::new(seed ^ 0xF407);
            // Random frontier: each vertex active with ~1/3 probability.
            let frontier: Vec<u32> =
                (0..g.num_vertices() as u32).filter(|_| rng.below(3) == 0).collect();
            for threads in [1usize, 2, 3, 4, 8] {
                let c = Chunks::by_weight_subset(&frontier, threads, |v| {
                    1 + g.out_degree(v) as u64
                });
                if frontier.is_empty() {
                    assert!(c.is_empty(), "{name}: empty frontier ⇒ zero chunks");
                    continue;
                }
                assert_eq!(c.len(), threads.min(frontier.len()), "{name}");
                assert_eq!(c.total(), frontier.len(), "{name}");
                // Cover exactly, in order, with no empty chunk.
                let mut covered = Vec::new();
                for i in 0..c.len() {
                    let r = c.range(i);
                    assert!(!r.is_empty(), "{name}: chunk {i} empty (t={threads})");
                    covered.extend_from_slice(&frontier[r]);
                }
                assert_eq!(covered, frontier, "{name}: chunks must cover the frontier");
            }
        }
        // Edge cases independent of the random draw.
        let one = [7u32];
        let c = Chunks::by_weight_subset(&one, 8, |_| 1);
        assert_eq!(c.len(), 1);
        assert_eq!(c.range(0), 0..1);
        assert!(Chunks::by_weight_subset(&[], 4, |_| 1).is_empty());
    });
}

// ── Dynamic-graph overlay properties (ISSUE 5) ──────────────────────

/// Shadow model of [`revolver::dynamic::DynamicGraph`]: a plain
/// directed edge set + tombstones with the same update semantics,
/// rebuilt into a CSR from scratch for every comparison.
struct ShadowGraph {
    n: usize,
    edges: std::collections::BTreeSet<(u32, u32)>,
    alive: Vec<bool>,
}

impl ShadowGraph {
    fn new(g: &revolver::graph::Graph) -> Self {
        ShadowGraph {
            n: g.num_vertices(),
            edges: g.edges().collect(),
            alive: vec![true; g.num_vertices()],
        }
    }

    fn ensure(&mut self, v: u32) {
        if v as usize >= self.n {
            self.n = v as usize + 1;
            self.alive.resize(self.n, true);
        }
    }

    fn apply(&mut self, up: &revolver::dynamic::Update) {
        use revolver::dynamic::Update::*;
        match *up {
            AddEdge(u, v) => {
                if u != v {
                    self.ensure(u.max(v));
                    self.edges.insert((u, v));
                    self.alive[u as usize] = true;
                    self.alive[v as usize] = true;
                }
            }
            RemoveEdge(u, v) => {
                self.edges.remove(&(u, v));
            }
            AddVertex(v) => {
                self.ensure(v);
                self.alive[v as usize] = true;
            }
            RemoveVertex(v) => {
                if (v as usize) < self.n && self.alive[v as usize] {
                    self.edges.retain(|&(a, b)| a != v && b != v);
                    self.alive[v as usize] = false;
                }
            }
        }
    }

    fn rebuild(&self) -> revolver::graph::Graph {
        let mut b = GraphBuilder::with_capacity(self.n.max(1), self.edges.len());
        for &(u, v) in &self.edges {
            b.edge(u, v);
        }
        b.build()
    }
}

/// The overlay after arbitrary batches must be observation-equivalent
/// to a CSR rebuilt from scratch: vertex/edge counts, per-vertex
/// out/und degrees and neighbour sets, load-mass totals, and a valid
/// materialization.
fn assert_observation_equivalent(
    tag: &str,
    d: &revolver::dynamic::DynamicGraph,
    shadow: &ShadowGraph,
) {
    let fresh = shadow.rebuild();
    assert_eq!(d.num_vertices(), fresh.num_vertices(), "{tag}: |V|");
    assert_eq!(d.num_edges(), fresh.num_edges(), "{tag}: |E|");
    let mut mass = 0u64;
    for v in 0..fresh.num_vertices() as u32 {
        assert_eq!(d.out_degree(v), fresh.out_degree(v), "{tag}: out_degree({v})");
        assert_eq!(d.und_degree(v), fresh.und_degree(v), "{tag}: und_degree({v})");
        assert_eq!(d.load_mass(v), fresh.load_mass(v), "{tag}: load_mass({v})");
        assert_eq!(
            d.out_neighbors(v).collect::<Vec<_>>(),
            fresh.out_neighbors(v),
            "{tag}: out({v})"
        );
        assert_eq!(
            d.und_neighbors(v).collect::<Vec<_>>(),
            fresh.neighbors(v),
            "{tag}: und({v})"
        );
        assert_eq!(d.is_alive(v), shadow.alive[v as usize], "{tag}: alive({v})");
        mass += d.load_mass(v) as u64;
    }
    assert_eq!(mass, fresh.total_load_mass(), "{tag}: Σ load_mass");
    let mat = d.to_graph();
    mat.validate().unwrap();
    assert_eq!(
        mat.edges().collect::<Vec<_>>(),
        fresh.edges().collect::<Vec<_>>(),
        "{tag}: materialized edge set"
    );
    d.check_invariants().unwrap();
}

fn random_update(rng: &mut Rng, shadow: &ShadowGraph) -> revolver::dynamic::Update {
    use revolver::dynamic::Update::*;
    let n = shadow.n as u64;
    match rng.below(10) {
        // Adds dominate so the graph never collapses.
        0..=3 => AddEdge(rng.below(n) as u32, rng.below(n) as u32),
        4..=6 => {
            // Remove an existing edge when possible (else a random
            // probably-absent pair — exercising the no-op path).
            if shadow.edges.is_empty() {
                RemoveEdge(rng.below(n) as u32, rng.below(n) as u32)
            } else {
                let i = rng.below_usize(shadow.edges.len());
                let &(u, v) = shadow.edges.iter().nth(i).unwrap();
                RemoveEdge(u, v)
            }
        }
        7 => AddVertex(rng.below(n + 4) as u32),
        8 => RemoveVertex(rng.below(n) as u32),
        // Edge to a brand-new id: implicit arrival.
        _ => AddEdge(rng.below(n) as u32, n as u32),
    }
}

#[test]
fn prop_dynamic_overlay_equals_rebuilt_csr() {
    use revolver::dynamic::{DynamicGraph, UpdateBatch};
    use revolver::graph::gen::{ba, rmat};
    forall(5, |seed| {
        let graphs = [
            ("ba", ba::barabasi_albert(256, 4, seed)),
            ("rmat", rmat::rmat(256, 4 * 256, 0.57, 0.19, 0.19, seed)),
        ];
        for (name, g) in graphs {
            let mut rng = Rng::new(seed ^ 0xD1CE);
            // Tiny compact ratio on odd seeds: auto-compaction fires
            // mid-run and must stay invisible.
            let ratio = if seed % 2 == 1 { 0.01 } else { 1000.0 };
            let mut d = DynamicGraph::new(g.clone(), ratio);
            let mut shadow = ShadowGraph::new(&g);
            for round in 0..4 {
                let updates: Vec<_> =
                    (0..48).map(|_| random_update(&mut rng, &shadow)).collect();
                for up in &updates {
                    shadow.apply(up);
                }
                let mut touched = Vec::new();
                d.apply(&UpdateBatch { updates }, &mut touched);
                assert_observation_equivalent(
                    &format!("{name} seed={seed} round={round}"),
                    &d,
                    &shadow,
                );
            }
            if seed % 2 == 1 {
                assert!(d.compactions() > 0, "{name}: tiny ratio must trigger compaction");
            }
        }
    });
}

#[test]
fn prop_dynamic_compact_is_quality_noop() {
    use revolver::dynamic::{ChurnRecipe, DynamicGraph, UpdateBatch};
    use revolver::graph::gen::{ba, rmat};
    forall(5, |seed| {
        let graphs = [
            ("ba", ba::barabasi_albert(512, 6, seed)),
            ("rmat", rmat::rmat(512, 6 * 512, 0.57, 0.19, 0.19, seed)),
        ];
        for (name, g) in graphs {
            let mut d = DynamicGraph::new(g.clone(), 1000.0);
            // Recipe-generated churn (the workload the CLI applies).
            let batch = ChurnRecipe::Uniform { frac: 0.05 }.generate(&g, seed);
            let mut touched = Vec::new();
            d.apply(&batch, &mut touched);
            // A couple of manual vertex ops on top.
            let extra = UpdateBatch {
                updates: vec![
                    revolver::dynamic::Update::RemoveVertex(3),
                    revolver::dynamic::Update::AddVertex(g.num_vertices() as u32),
                ],
            };
            d.apply(&extra, &mut touched);

            let k = 4;
            let mut rng = Rng::new(seed ^ 0x9A9A);
            let labels: Vec<u32> =
                (0..d.num_vertices()).map(|_| rng.below(k as u64) as u32).collect();
            let before = quality::evaluate(&d.to_graph(), &labels, k);
            assert!(d.is_dirty());
            d.compact();
            assert!(!d.is_dirty());
            let after = quality::evaluate(d.base(), &labels, k);
            assert_eq!(before.local_edges, after.local_edges, "{name} seed={seed}");
            assert_eq!(
                before.max_normalized_load, after.max_normalized_load,
                "{name} seed={seed}"
            );
            assert_eq!(
                before.mean_communication_volume, after.mean_communication_volume,
                "{name} seed={seed}"
            );
            d.check_invariants().unwrap();
        }
    });
}
