//! Learning-dynamics observatory integration (`--diag`): the flow
//! matrix's exactness contract — row sums equal the engine's migration
//! counters, cell for cell sourced from the same `StepCtx::migrate`
//! calls — and the `report` renderer's agreement with the run's own
//! CSV trace on both a complete log and a killed-run prefix.
//!
//! These tests install into the process-global recorder slot, so they
//! serialize behind one mutex (same pattern as `tests/obs.rs`).

use std::io::Write;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use revolver::config::{Frontier, ProbFormat, RevolverConfig};
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::metrics::quality;
use revolver::obs::{self, events, report, RunRecorder};
use revolver::partitioners::revolver::Revolver;
use revolver::partitioners::Partitioner;
use revolver::util::json::Json;

fn serialize() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(data);
        Ok(data.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn diag_cfg(k: usize, steps: u32, seed: u64) -> RevolverConfig {
    RevolverConfig {
        parts: k,
        max_steps: steps,
        threads: 1,
        seed,
        frontier: Frontier::Off,
        prob_format: ProbFormat::F32,
        trace_every: 1,
        diag: true,
        ..Default::default()
    }
}

/// Run one recorded `--diag` partition; returns (labels CSV-side trace
/// output, the JSONL text, the recorder).
fn recorded_diag_run(
    k: usize,
    steps: u32,
    seed: u64,
) -> (revolver::partitioners::PartitionOutput, String, Arc<RunRecorder>) {
    let g = generate_dataset(Dataset::So, 512, 4).unwrap();
    let buf = Arc::new(Mutex::new(Vec::<u8>::new()));
    let rec = Arc::new(RunRecorder::with_sink(Box::new(SharedBuf(buf.clone()))));
    obs::install(rec.clone());
    obs::event("run_start", &[]);
    let out = Revolver::new(diag_cfg(k, steps, seed)).partition(&g);
    obs::event("run_end", &[("wall_s", rec.elapsed_s())]);
    obs::uninstall();
    rec.flush();
    let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    (out, text, rec)
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or_else(|| panic!("missing {key}: {j:?}"))
}

/// The acceptance contract: with diag enabled, the flow matrix's cells
/// sum to the engine's migration counters *exactly* — the JSONL flow
/// events, the accumulated `DiagStore`, the `engine_migrations`
/// counter, and the CSV trace's per-step migrations all agree.
#[test]
fn flow_matrix_row_sums_equal_engine_migration_counters() {
    let _serial = serialize();
    let k = 4;
    let (out, text, rec) = recorded_diag_run(k, 8, 11);
    events::validate_events(&text).expect("diag log must be schema-valid");

    // Σ over JSONL flow events (cell granularity, nonzero cells only).
    let mut event_moves = 0u64;
    let mut per_step_moves: std::collections::BTreeMap<u64, u64> = Default::default();
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        if j.get("ev").and_then(Json::as_str) == Some("flow") {
            let moves = num(&j, "moves") as u64;
            event_moves += moves;
            *per_step_moves.entry(num(&j, "step") as u64).or_insert(0) += moves;
        }
    }
    assert!(event_moves > 0, "an 8-step revolver run must migrate: {text}");

    // The engine's own counter (one fetch_add per executed migrate).
    let counters = rec.registry().counters();
    let engine_migrations =
        counters.iter().find(|(n, _)| n == "engine_migrations").map(|(_, v)| *v).unwrap();
    assert_eq!(event_moves, engine_migrations, "flow cells must sum to the counter");

    // The accumulated store behind /state and /metrics.
    let snap = rec.diag().snapshot();
    assert_eq!(snap.k, k);
    assert_eq!(snap.flow_moves.iter().sum::<u64>(), engine_migrations);

    // The CSV trace (trace_every = 1: every step sampled once).
    let trace_migrations: u64 = out.trace.points.iter().map(|p| p.migrations).sum();
    assert_eq!(trace_migrations, engine_migrations);

    // And per step: each step's flow cells sum to that step's trace
    // migrations (the swap-to-zero drain makes steps disjoint).
    for p in &out.trace.points {
        let step_flow = per_step_moves.get(&(p.step as u64)).copied().unwrap_or(0);
        assert_eq!(step_flow, p.migrations, "step {} flow vs trace", p.step);
    }
}

/// `report` renders a complete run without error and its summary
/// numbers match the run's own CSV trace: total migrations and the
/// final per-partition loads.
#[test]
fn report_matches_the_runs_csv_trace() {
    let _serial = serialize();
    let k = 4;
    let (out, text, _rec) = recorded_diag_run(k, 8, 11);
    let g = generate_dataset(Dataset::So, 512, 4).unwrap();

    let rendered = report::render_report(&text, false).expect("complete log must render");
    assert!(rendered.contains("flow matrix"), "{rendered}");
    assert!(rendered.contains("halt reason"), "{rendered}");
    assert!(rendered.contains("per-partition trajectories"), "{rendered}");

    let trace_migrations: u64 = out.trace.points.iter().map(|p| p.migrations).sum();
    assert!(
        rendered.contains(&format!("total migrations: {trace_migrations}")),
        "report total must match the CSV trace ({trace_migrations}):\n{rendered}"
    );

    let want_loads = quality::partition_loads(&g, &out.labels, k);
    let loads_line = rendered
        .lines()
        .find(|l| l.starts_with("final loads:"))
        .unwrap_or_else(|| panic!("no final loads line:\n{rendered}"));
    let got_loads: Vec<u64> = loads_line["final loads:".len()..]
        .split_whitespace()
        .map(|t| t.parse().unwrap())
        .collect();
    assert_eq!(got_loads, want_loads, "report loads vs quality::partition_loads");
}

/// `--partial` accepts the prefix a killed run leaves behind: a torn
/// final line plus no `run_end`, attributed as an interrupted run.
#[test]
fn report_renders_a_killed_run_prefix() {
    let _serial = serialize();
    let (_out, text, _rec) = recorded_diag_run(4, 8, 11);
    // Simulate a mid-write kill: drop run_end, tear the last line.
    let mut lines: Vec<&str> = text.lines().collect();
    assert!(lines.pop().unwrap().contains("run_end"));
    let torn_tail = &lines.pop().unwrap()[..10];
    let prefix = format!("{}\n{}", lines.join("\n"), torn_tail);

    let rendered = report::render_report(&prefix, true).expect("--partial must accept a prefix");
    assert!(rendered.contains("flow matrix"), "{rendered}");
    assert!(rendered.contains("halt reason: run interrupted"), "{rendered}");
    assert!(rendered.contains("partial log (torn final line dropped)"), "{rendered}");
    // Without --partial the same prefix is an error (torn JSON).
    assert!(report::render_report(&prefix, false).is_err());
}
