//! Observability integration: the process-global recorder slot end to
//! end — a real partition run streams schema-valid JSONL, the engine's
//! barrier segments tile its span, counters/histograms land in the
//! registry — and the overhead contract: an installed recorder must
//! never change the labels a run produces.
//!
//! These tests install into the global slot, so they serialize behind
//! one mutex (unit tests elsewhere use `RunRecorder` directly and never
//! install).

use std::io::Write;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use revolver::config::{Frontier, ProbFormat, RevolverConfig};
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::obs::{self, events, Recorder as _, RunRecorder};
use revolver::partitioners::revolver::Revolver;
use revolver::partitioners::Partitioner;

fn serialize() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(data);
        Ok(data.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn run_cfg(k: usize, steps: u32, seed: u64) -> RevolverConfig {
    RevolverConfig {
        parts: k,
        max_steps: steps,
        threads: 1,
        seed,
        frontier: Frontier::Off,
        prob_format: ProbFormat::F32,
        ..Default::default()
    }
}

#[test]
fn recorded_run_emits_valid_events_spans_and_metrics() {
    let _serial = serialize();
    let g = generate_dataset(Dataset::So, 512, 4).unwrap();
    let steps = 5u32;
    let buf = Arc::new(Mutex::new(Vec::<u8>::new()));
    let rec = Arc::new(RunRecorder::with_sink(Box::new(SharedBuf(buf.clone()))));
    obs::install(rec.clone());
    obs::event("run_start", &[]);
    let out = Revolver::new(run_cfg(4, steps, 7)).partition(&g);
    obs::event("run_end", &[("wall_s", rec.elapsed_s())]);
    obs::uninstall();
    rec.flush();
    assert_eq!(out.labels.len(), 512);

    // JSONL: run_start + one step event per executed step + run_end,
    // every line schema-valid.
    let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    let n = events::validate_events(&text).expect("event log must be schema-valid");
    assert_eq!(n as u32, out.trace.steps() + 2, "{text}");
    assert!(text.lines().next().unwrap().contains("\"run_start\""), "{text}");
    assert!(text.lines().last().unwrap().contains("\"run_end\""), "{text}");

    // Spans: the engine's guard plus its barrier-crossing segments,
    // which tile the run — their sum accounts for the engine span.
    let spans = rec.spans();
    let get = |p: &str| spans.iter().find(|(q, _)| q == p).map(|(_, s)| s.total_ns);
    let engine_ns = get("engine").expect("engine span recorded");
    for seg in [
        "engine/init",
        "engine/collect",
        "engine/phase_a",
        "engine/phase_b_prep",
        "engine/phase_b",
        "engine/reduce",
        "engine/finish",
    ] {
        assert!(get(seg).is_some(), "missing segment {seg} in {spans:?}");
    }
    let child_ns: u64 = spans
        .iter()
        .filter(|(p, _)| p.starts_with("engine/"))
        .map(|(_, s)| s.total_ns)
        .sum();
    assert!(
        child_ns <= engine_ns && child_ns as f64 >= engine_ns as f64 * 0.90,
        "segments must tile the engine span: {child_ns} of {engine_ns}"
    );

    // Registry: run counters and worker histograms.
    let counters = rec.registry().counters();
    let counter = |n: &str| counters.iter().find(|(k, _)| k == n).map(|(_, v)| *v);
    assert_eq!(counter("engine_runs"), Some(1));
    assert_eq!(counter("engine_steps"), Some(out.trace.steps() as u64));
    assert_eq!(counter("engine_evaluated"), Some(out.trace.total_evaluated));
    assert_eq!(counter("revolver_spins"), Some(out.trace.total_evaluated));
    let hists = rec.registry().histograms();
    let frontier = &hists.iter().find(|(k, _)| k == "engine_frontier_size").unwrap().1;
    assert_eq!(frontier.count, out.trace.steps() as u64);

    // Exports render from the same snapshots.
    let prom = rec.prometheus();
    assert!(prom.contains("# TYPE engine_steps counter"), "{prom}");
    assert!(prom.contains("span_seconds_total{path=\"engine\"}"), "{prom}");
    let tree = rec.profile_report();
    assert!(tree.contains("engine"), "{tree}");
    assert!(tree.contains("top-level spans:"), "{tree}");
}

#[test]
fn installed_recorder_never_changes_labels() {
    let _serial = serialize();
    let g = generate_dataset(Dataset::Lj, 1024, 4).unwrap();
    let cfg = run_cfg(4, 15, 42);
    let plain = Revolver::new(cfg.clone()).partition(&g).labels;

    let rec = Arc::new(RunRecorder::new());
    obs::install(rec.clone());
    let recorded = Revolver::new(cfg.clone()).partition(&g).labels;
    obs::uninstall();
    assert_eq!(plain, recorded, "full recorder must not perturb the run");
    assert!(!rec.spans().is_empty(), "the recorded run must actually record");

    // The no-op recorder exercises dispatch without retention.
    obs::install(Arc::new(obs::NoopRecorder));
    let noop = Revolver::new(cfg.clone()).partition(&g).labels;
    obs::uninstall();
    assert_eq!(plain, noop, "no-op recorder must not perturb the run");

    // The learning-dynamics observatory (`--diag`) adds flow recording
    // inside `StepCtx::migrate`, decisiveness reads over the ProbSlab,
    // an oscillation scan, and partition sampling — none of which may
    // perturb the trajectory either.
    let mut diag_cfg = cfg;
    diag_cfg.diag = true;
    let rec = Arc::new(RunRecorder::new());
    obs::install(rec.clone());
    let diag = Revolver::new(diag_cfg).partition(&g).labels;
    obs::uninstall();
    assert_eq!(plain, diag, "diag probes must not perturb the run");
    let snap = rec.diag().snapshot();
    assert!(snap.k > 0 && !snap.flow_moves.is_empty(), "diag probes must actually record");
}

/// Install/uninstall racing metric writers and progress readers must
/// never panic, tear a step/epoch pair, or leave a recorder installed.
/// Writers racing an uninstall may lose samples (the slot is an
/// `RwLock<Option<_>>`, not a queue) — that's the documented contract;
/// what this pins is memory safety plus the terminal state.
#[test]
fn install_uninstall_races_are_safe_and_end_uninstalled() {
    let _serial = serialize();
    let rec = Arc::new(RunRecorder::new());
    std::thread::scope(|s| {
        // Churn the global slot.
        s.spawn(|| {
            for _ in 0..500 {
                obs::install(rec.clone());
                obs::uninstall();
            }
        });
        // Hammer metrics + events through whatever is installed.
        s.spawn(|| {
            for i in 0..2_000u64 {
                obs::counter_add("race_total", 1);
                obs::observe("race_hist", i % 64);
                obs::event("run_start", &[]);
            }
        });
        // Progress writes (step always advanced before epoch)...
        s.spawn(|| {
            for j in 0..2_000u64 {
                obs::progress().set_phase("engine");
                obs::progress().set_step(j);
                obs::progress().set_epoch(j);
            }
        });
        // ...racing snapshot reads: the /healthz invariant.
        s.spawn(|| {
            for _ in 0..2_000 {
                let p = obs::progress().snapshot();
                assert!(p.epoch <= p.step, "torn pair: step={} epoch={}", p.step, p.epoch);
            }
        });
    });
    obs::uninstall();
    assert!(!obs::enabled(), "slot must end uninstalled");
    // With no run active the readout resets to a stable idle state.
    obs::progress().reset();
    let p = obs::progress().snapshot();
    assert_eq!((p.phase, p.step, p.epoch), ("idle", 0, 0));
}

/// Registry contention property: N threads hammering the *same*
/// counter and histogram names through the global `obs::` entry points
/// must sum exactly — creation-on-first-use races, `Arc` handle
/// sharing, and relaxed `fetch_add`s lose nothing.
#[test]
fn concurrent_hammering_of_shared_names_sums_exactly() {
    let _serial = serialize();
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 10_000;
    let rec = Arc::new(RunRecorder::new());
    obs::install(rec.clone());
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for i in 0..PER_THREAD {
                    obs::counter_add("hammer_total", 1);
                    obs::counter_add("hammer_weighted", i % 7 + 1);
                    obs::observe("hammer_hist", i % 1000);
                }
            });
        }
    });
    obs::uninstall();

    let counters = rec.registry().counters();
    let counter = |n: &str| counters.iter().find(|(k, _)| k == n).map(|(_, v)| *v).unwrap();
    assert_eq!(counter("hammer_total"), THREADS * PER_THREAD);
    let weighted_per_thread: u64 = (0..PER_THREAD).map(|i| i % 7 + 1).sum();
    assert_eq!(counter("hammer_weighted"), THREADS * weighted_per_thread);

    let hists = rec.registry().histograms();
    let h = &hists.iter().find(|(k, _)| k == "hammer_hist").unwrap().1;
    assert_eq!(h.count, THREADS * PER_THREAD);
    let sum_per_thread: u64 = (0..PER_THREAD).map(|i| i % 1000).sum();
    assert_eq!(h.sum, THREADS * sum_per_thread);
    // The live-scrape invariant: count ≡ Σ buckets (S1 consistency).
    assert_eq!(h.count, h.buckets.iter().sum::<u64>());
}

#[test]
fn dynamic_epochs_emit_epoch_events() {
    let _serial = serialize();
    use revolver::dynamic::{ChurnRecipe, IncrementalPartitioner};
    use revolver::metrics::trace::RunTrace;
    use revolver::multilevel::Refiner;

    let g = generate_dataset(Dataset::So, 512, 4).unwrap();
    let mut cfg = run_cfg(4, 10, 7);
    cfg.repair_steps = 3;
    let buf = Arc::new(Mutex::new(Vec::<u8>::new()));
    let rec = Arc::new(RunRecorder::with_sink(Box::new(SharedBuf(buf.clone()))));
    obs::install(rec.clone());
    let recipe: ChurnRecipe = "uniform:0.05".parse().unwrap();
    let mut inc = IncrementalPartitioner::new(g, cfg, Refiner::Spinner).unwrap();
    let mut trace = RunTrace::default();
    for e in 0..2u32 {
        let batch = recipe.generate(inc.current(), 100 + e as u64);
        let stats = inc.epoch(&batch).unwrap();
        inc.record_epoch(&mut trace, e, &stats);
    }
    obs::uninstall();
    rec.flush();

    let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    events::validate_events(&text).expect("epoch events must be schema-valid");
    assert_eq!(text.matches("\"ev\":\"epoch\"").count(), 2, "{text}");
    let spans = rec.spans();
    for p in ["dynamic_epoch", "dynamic_epoch/repair", "dynamic_epoch/rebalance"] {
        assert!(spans.iter().any(|(q, _)| q == p), "missing {p} in {spans:?}");
    }
    // The CSV satellite: mean_score now carries repair wall seconds.
    let pt = trace.final_point().unwrap();
    assert!(pt.mean_score >= 0.0 && pt.elapsed_s > 0.0);
}
