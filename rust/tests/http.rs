//! Live telemetry integration: a real run scraped over HTTP while it
//! executes — the acceptance path for `--metrics-addr`. Installs into
//! the process-global recorder slot, so tests serialize behind one
//! mutex (this binary runs in its own process; it cannot race
//! `tests/obs.rs`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use revolver::config::RevolverConfig;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::obs::http::MetricsServer;
use revolver::obs::{self, events, httpd, RunRecorder};
use revolver::partitioners::revolver::Revolver;
use revolver::partitioners::Partitioner;
use revolver::util::json::Json;

fn serialize() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

const T: Duration = Duration::from_secs(5);

fn get_text(addr: std::net::SocketAddr, target: &str) -> (u16, String) {
    let (status, _, body) = httpd::get(addr, target, T).expect("request must succeed");
    (status, String::from_utf8(body).expect("utf-8 body"))
}

/// The ISSUE acceptance scenario: all four endpoints answer while
/// steps execute, and the final in-process `prometheus()` snapshot
/// equals the last scrape.
#[test]
fn live_endpoints_answer_mid_run_and_final_snapshot_matches_last_scrape() {
    let _serial = serialize();
    let g = generate_dataset(Dataset::So, 512, 4).unwrap();
    let cfg = RevolverConfig { parts: 4, max_steps: 8, threads: 2, seed: 7, ..Default::default() };

    let rec = Arc::new(RunRecorder::new());
    obs::install(rec.clone());
    let srv = MetricsServer::start("127.0.0.1:0", rec.clone()).expect("bind loopback");
    let addr = srv.local_addr();

    // Workload: back-to-back partition runs until the scrapes below are
    // done, so "mid-run" needs no timing luck.
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let stop = stop.clone();
        let cfg = cfg.clone();
        let g = g.clone();
        std::thread::spawn(move || {
            let mut runs = 0u64;
            while !stop.load(Ordering::SeqCst) {
                let out = Revolver::new(cfg.clone()).partition(&g);
                assert_eq!(out.labels.len(), 512);
                runs += 1;
            }
            runs
        })
    };

    // Wait until the run has visibly recorded, then scrape everything.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, prom) = get_text(addr, "/metrics");
        assert_eq!(status, 200);
        if prom.contains("# TYPE engine_steps counter") {
            break;
        }
        assert!(Instant::now() < deadline, "engine metrics never appeared:\n{prom}");
        std::thread::sleep(Duration::from_millis(20));
    }

    let (status, health) = get_text(addr, "/healthz");
    assert_eq!(status, 200);
    let j = Json::parse(&health).expect("healthz must be JSON");
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{health}");
    assert_eq!(j.get("phase").and_then(Json::as_str), Some("engine"), "{health}");
    assert!(j.get("step").and_then(Json::as_f64).is_some(), "{health}");
    assert!(j.get("epoch").and_then(Json::as_f64).is_some(), "{health}");
    assert!(j.get("events").and_then(Json::as_f64).unwrap() >= 1.0, "{health}");

    let (status, tree) = get_text(addr, "/profile");
    assert_eq!(status, 200);
    assert!(tree.contains("engine"), "{tree}");
    assert!(tree.contains("top-level spans:"), "{tree}");

    let (status, headers, body) = httpd::get(addr, "/events?since=0", T).unwrap();
    assert_eq!(status, 200);
    let tail = String::from_utf8(body).unwrap();
    let n = events::validate_events(&tail).expect("event tail must be schema-valid");
    assert!(n >= 1, "{tail}");
    assert!(tail.contains("\"ev\":\"step\""), "{tail}");
    let next: u64 = headers
        .iter()
        .find(|(k, _)| k == "X-Events-Next")
        .and_then(|(_, v)| v.parse().ok())
        .expect("cursor header");
    // The run keeps emitting, so the scraped cursor is somewhere
    // between the returned lines and the ring's current end.
    assert!(next >= n as u64 && next <= rec.events_end(), "next={next}");

    // Stop the workload; once it has joined, nothing records anymore,
    // so one more scrape must equal the in-process snapshot exactly.
    stop.store(true, Ordering::SeqCst);
    let runs = worker.join().expect("workload thread");
    assert!(runs >= 1);
    let (status, scrape) = get_text(addr, "/metrics");
    assert_eq!(status, 200);
    assert_eq!(scrape, rec.prometheus(), "final snapshot must equal the last scrape");
    assert!(scrape.contains(&format!("engine_runs {runs}")), "{scrape}");

    drop(srv);
    obs::uninstall();
    // After shutdown the port no longer answers.
    assert!(httpd::get(addr, "/metrics", Duration::from_millis(300)).is_err());
}

/// `--metrics-addr` without `--obs-log` still serves events (the ring
/// does not depend on a sink), and a cursor past the tail long-polls
/// until the next event instead of replying stale data.
#[test]
fn events_endpoint_works_without_a_sink_and_honours_cursors() {
    let _serial = serialize();
    let rec = Arc::new(RunRecorder::new());
    obs::install(rec.clone());
    obs::event("run_start", &[]);
    let srv = MetricsServer::start("127.0.0.1:0", rec.clone()).unwrap();
    let addr = srv.local_addr();

    let (_, tail) = get_text(addr, "/events?since=0");
    assert!(tail.contains("run_start"), "{tail}");

    // A long-poll from the current end parks until the next event.
    let end = rec.events_end();
    let poll = std::thread::spawn(move || get_text(addr, &format!("/events?since={end}")));
    std::thread::sleep(Duration::from_millis(100));
    obs::event("run_end", &[("wall_s", 0.01)]);
    let (status, tail) = poll.join().unwrap();
    assert_eq!(status, 200);
    assert!(tail.contains("run_end"), "long-poll must deliver the new event: {tail}");

    drop(srv);
    obs::uninstall();
}

/// Regression: a cursor *past* the ring end (a stale client, or a
/// typo'd `since`) must get an immediate empty 200 whose
/// `X-Events-Next` points at the real end — not park for the full 10 s
/// long-poll waiting for sequence numbers that may never come.
#[test]
fn events_cursor_past_ring_end_returns_immediately() {
    let _serial = serialize();
    let rec = Arc::new(RunRecorder::new());
    obs::install(rec.clone());
    obs::event("run_start", &[]);
    obs::event("run_end", &[("wall_s", 0.01)]);
    let srv = MetricsServer::start("127.0.0.1:0", rec.clone()).unwrap();
    let addr = srv.local_addr();

    let end = rec.events_end();
    let t0 = Instant::now();
    let (status, headers, body) =
        httpd::get(addr, &format!("/events?since={}", end + 1_000), T).unwrap();
    assert!(t0.elapsed() < Duration::from_secs(2), "must not long-poll: {:?}", t0.elapsed());
    assert_eq!(status, 200);
    assert!(body.is_empty(), "nothing newer than the end exists: {body:?}");
    let hdr = |k: &str| headers.iter().find(|(n, _)| n == k).map(|(_, v)| v.clone());
    assert_eq!(hdr("X-Events-Start").as_deref(), Some(end.to_string().as_str()));
    assert_eq!(hdr("X-Events-Next").as_deref(), Some(end.to_string().as_str()));

    drop(srv);
    obs::uninstall();
}
