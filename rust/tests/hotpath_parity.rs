//! Hot-path equivalence properties (DESIGN.md §Hot paths):
//!
//! 1. **Scheduling**: the coordinator's frontier collection is a pure
//!    implementation choice — merged per-worker worklists, the dense
//!    stamp scan, and the density-switched hybrid must produce
//!    bit-identical runs (labels *and* evaluation counts) at any
//!    thread count, while the counters prove the cheap path actually
//!    ran.
//! 2. **Quantized LA storage**: `prob_format = q16` changes the RNG
//!    consumption pattern and rounds every stored probability, so it is
//!    a *different trajectory* — but it must land inside a quality
//!    envelope of the f32 reference at equal step budget.

use revolver::config::{Frontier, ProbFormat, RevolverConfig};
use revolver::graph::gen::ba::barabasi_albert;
use revolver::graph::gen::rmat::rmat;
use revolver::graph::Graph;
use revolver::metrics::quality;
use revolver::partitioners::revolver::Revolver;
use revolver::partitioners::spinner::Spinner;
use revolver::partitioners::{PartitionOutput, Partitioner};

fn graphs(seed: u64) -> Vec<(&'static str, Graph)> {
    vec![
        ("ba", barabasi_albert(1024, 4, seed)),
        ("rmat", rmat(1024, 8 * 1024, 0.57, 0.19, 0.19, seed)),
    ]
}

fn base_cfg(k: usize, threads: usize, seed: u64) -> RevolverConfig {
    RevolverConfig {
        parts: k,
        threads,
        seed,
        max_steps: 15,
        halt_window: u32::MAX, // fixed budget: only the empty frontier halts
        frontier: Frontier::On,
        ..Default::default()
    }
}

/// Run at a given dense-scan threshold.
fn run_revolver(g: &Graph, cfg: &RevolverConfig, frac: f64) -> PartitionOutput {
    let mut cfg = cfg.clone();
    cfg.frontier_dense_frac = frac;
    Revolver::new(cfg).partition(g)
}

fn run_spinner(g: &Graph, cfg: &RevolverConfig, frac: f64) -> PartitionOutput {
    let mut cfg = cfg.clone();
    cfg.frontier_dense_frac = frac;
    Spinner::new(cfg).partition(g)
}

#[test]
fn worklist_scan_and_hybrid_runs_identical_revolver() {
    for seed in [3u64, 17, 91] {
        for (name, g) in graphs(seed) {
            let cfg = base_cfg(4, 1, seed);
            let scan = run_revolver(&g, &cfg, 0.0);
            let wl = run_revolver(&g, &cfg, 1.0);
            let hybrid = run_revolver(&g, &cfg, 0.25);
            assert_eq!(scan.labels, wl.labels, "{name} seed={seed}");
            assert_eq!(scan.labels, hybrid.labels, "{name} seed={seed}");
            assert_eq!(
                scan.trace.total_evaluated, wl.trace.total_evaluated,
                "{name} seed={seed}"
            );
            assert_eq!(
                scan.trace.total_evaluated, hybrid.trace.total_evaluated,
                "{name} seed={seed}"
            );
            // The counters prove which collector ran: scan-always never
            // merges worklists, worklist-always never reads a stamp, and
            // both saw the same number of post-step-0 collections
            // (identical trajectories ⇒ identical step counts).
            assert_eq!(scan.trace.worklist_steps, 0, "{name} seed={seed}");
            assert_eq!(wl.trace.stamp_reads, 0, "{name} seed={seed}");
            assert_eq!(wl.trace.scan_steps, 0, "{name} seed={seed}");
            assert_eq!(
                scan.trace.scan_steps, wl.trace.worklist_steps,
                "{name} seed={seed}"
            );
            assert_eq!(
                hybrid.trace.scan_steps + hybrid.trace.worklist_steps,
                scan.trace.scan_steps,
                "{name} seed={seed}"
            );
        }
    }
}

#[test]
fn worklist_scan_identical_spinner_multithreaded() {
    // Frontier collection happens on the coordinator before chunking,
    // so the equivalence must hold at any worker count — the merged
    // worklists are sorted back into the scan order the chunker (and
    // hence every per-chunk RNG stream) sees.
    for seed in [5u64, 23] {
        for (name, g) in graphs(seed) {
            let cfg = base_cfg(4, 4, seed);
            let scan = run_spinner(&g, &cfg, 0.0);
            let wl = run_spinner(&g, &cfg, 1.0);
            assert_eq!(scan.labels, wl.labels, "{name} seed={seed}");
            assert_eq!(
                scan.trace.total_evaluated, wl.trace.total_evaluated,
                "{name} seed={seed}"
            );
            assert_eq!(wl.trace.stamp_reads, 0, "{name} seed={seed}");
        }
    }
}

#[test]
fn q16_quality_within_envelope_of_f32() {
    // Equal budget, converged runs: the quantized slab must stay within
    // 1% mean local-edges (3 seeds) and 1.10× balance of the f32 rows.
    let mut le_f = 0.0f64;
    let mut le_q = 0.0f64;
    for seed in [11u64, 29, 47] {
        let g = barabasi_albert(2048, 5, seed);
        let mut cfg = RevolverConfig {
            parts: 4,
            threads: 2,
            seed,
            max_steps: 80,
            ..Default::default()
        };
        cfg.prob_format = ProbFormat::F32;
        let f = Revolver::new(cfg.clone()).partition(&g);
        cfg.prob_format = ProbFormat::Q16;
        let q = Revolver::new(cfg).partition(&g);

        le_f += quality::local_edges(&g, &f.labels);
        le_q += quality::local_edges(&g, &q.labels);
        let mnl_f = quality::max_normalized_load(&g, &f.labels, 4);
        let mnl_q = quality::max_normalized_load(&g, &q.labels, 4);
        assert!(mnl_q <= 1.10 * mnl_f, "seed={seed} mnl q16={mnl_q} f32={mnl_f}");
    }
    le_f /= 3.0;
    le_q /= 3.0;
    assert!(
        le_q >= 0.99 * le_f,
        "q16 mean local edges {le_q} fell >1% below f32's {le_f}"
    );
}

#[test]
fn q16_single_thread_deterministic() {
    let g = rmat(1024, 8 * 1024, 0.57, 0.19, 0.19, 13);
    let cfg = RevolverConfig {
        parts: 8,
        threads: 1,
        seed: 13,
        max_steps: 25,
        prob_format: ProbFormat::Q16,
        ..Default::default()
    };
    let a = Revolver::new(cfg.clone()).partition(&g);
    let b = Revolver::new(cfg).partition(&g);
    assert_eq!(a.labels, b.labels);
    assert!(a.labels.iter().all(|&l| l < 8));
}
