//! Engine ↔ seed parity: porting Revolver onto the shared execution
//! engine must not change its numerics. This test transcribes the
//! pre-engine (seed) single-threaded step loop — same RNG forks, same
//! batch granularity, same operation order — and asserts the engine
//! produces **bit-identical** labels for `threads = 1`.
//!
//! If this test fails after an engine change, the engine altered
//! execution semantics (RNG stream assignment, phase ordering, batch
//! snapshot granularity, or convergence accounting) — not just
//! performance.

use revolver::config::{Frontier, ProbFormat, RevolverConfig};
use revolver::coordinator::ConvergenceDetector;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::graph::Graph;
use revolver::la::signal::build_signals_into;
use revolver::la::weighted::WeightedLa;
use revolver::la::{roulette, Signal};
use revolver::lp::{neighbor_histogram, normalized as nlp};
use revolver::partition::{DemandTracker, InitialAssignment, PartitionState};
use revolver::partitioners::revolver::{Revolver, BATCH};
use revolver::partitioners::Partitioner;
use revolver::util::rng::Rng;

/// The seed implementation's single-threaded asynchronous step loop,
/// written sequentially (no threads, no barriers): one worker, chunk =
/// 0..n, RNG forks `2·step` (phase A) and `2·step + 1` (phase B).
fn seed_reference(g: &Graph, cfg: &RevolverConfig) -> Vec<u32> {
    assert_eq!(cfg.threads, 1);
    let k = cfg.parts;
    let n = g.num_vertices();
    let state = PartitionState::new(g, k, cfg.epsilon, InitialAssignment::Random(cfg.seed));
    let demand = DemandTracker::new(k);
    let base_rng = Rng::new(cfg.seed ^ 0x5245564F); // "REVO"

    // λ(v), initialized to the starting labels.
    let mut lambda: Vec<u32> = (0..n).map(|v| state.label(v as u32)).collect();
    let mut selected: Vec<u32> = vec![0; n];
    let mut probs = vec![0.0f32; n * k];
    for row in probs.chunks_mut(k) {
        WeightedLa::init(row);
    }

    // k-sized scratch.
    let mut hist = vec![0.0f32; k];
    let mut scores = vec![0.0f32; k];
    let mut pi = vec![0.0f32; k];
    let mut overlay = vec![0.0f32; k];
    let mut raw_w = vec![0.0f32; k];
    let mut w_norm = vec![0.0f32; k];
    let mut signals = vec![Signal::Penalty; k];
    let mut loads = vec![0.0f32; k];
    let mut headroom = vec![true; k];

    let mut detector = ConvergenceDetector::new(cfg.halt_theta, cfg.halt_window);
    for step in 0..cfg.max_steps as u64 {
        demand.reset();

        // ── Phase A: action selection + demand ──
        let mut rng = base_rng.fork(step * 2);
        for v in 0..n {
            let a = roulette::spin(&probs[v * k..(v + 1) * k], &mut rng) as u32;
            selected[v] = a;
            if a != state.label(v as u32) {
                demand.add(a as usize, g.out_degree(v as u32));
            }
        }

        // ── Phase B: score, λ, migrate, learn ──
        let mut rng = base_rng.fork(step * 2 + 1);
        let mut score_sum = 0.0f64;
        let mut batch_start = 0usize;
        while batch_start < n {
            let batch_end = (batch_start + BATCH).min(n);
            state.loads_into(&mut loads);
            nlp::penalty_into(&loads, state.system_capacity() as f32, &mut pi);
            let cap = state.capacity() as f32;
            for l in 0..k {
                headroom[l] = demand.get(l) <= 0 || loads[l] < cap;
            }
            for v in batch_start..batch_end {
                let vid = v as u32;
                let wsum = neighbor_histogram(
                    g.neighbors(vid),
                    g.neighbor_weights(vid),
                    |u| state.label(u),
                    &mut hist,
                );
                let best = nlp::score_into(&hist, wsum, &pi, &mut scores);
                lambda[v] = best as u32;

                let action = selected[v];
                let current = state.label(vid);
                if action != current
                    && (scores[action as usize] >= scores[current as usize]
                        || state.remaining(current as usize) < 0.0)
                {
                    let p = demand.migration_probability(&state, action as usize);
                    if p > 0.0 && rng.next_f64() < p {
                        state.migrate(vid, action, g.out_degree(vid));
                    }
                }
                score_sum += scores[state.label(vid) as usize] as f64;

                // Eq.-(13) raw weights in the hot path's overlay form:
                // neighbour modulation accumulates separately and the
                // score base is added per entry (`scores[l] +
                // overlay[l]` — the arithmetic
                // `build_signals_overlay_into` evaluates on the fly).
                overlay.fill(0.0);
                let wsum_inv = if wsum > 1e-12 { 1.0 / wsum } else { 0.0 };
                if wsum_inv > 0.0 {
                    for (&u, &w_uv) in g.neighbors(vid).iter().zip(g.neighbor_weights(vid)) {
                        let lu = lambda[u as usize] as usize;
                        if lu == action as usize {
                            overlay[lu] += w_uv * wsum_inv;
                        } else if headroom[lu] {
                            overlay[lu] += wsum_inv;
                        }
                    }
                }
                for l in 0..k {
                    raw_w[l] = scores[l] + overlay[l];
                }
                build_signals_into(&raw_w, &mut w_norm, &mut signals);
                WeightedLa::update(
                    &mut probs[v * k..(v + 1) * k],
                    &w_norm,
                    &signals,
                    cfg.alpha,
                    cfg.beta,
                );
            }
            batch_start = batch_end;
        }

        if detector.observe(score_sum / n as f64) {
            break;
        }
    }
    state.labels_snapshot()
}

fn parity_cfg(k: usize, steps: u32, seed: u64) -> RevolverConfig {
    RevolverConfig {
        parts: k,
        max_steps: steps,
        threads: 1,
        seed,
        // The seed loop re-evaluates every vertex every step; the
        // active-set default intentionally does not. `frontier = off`
        // is the documented bit-exact escape hatch, and this test is
        // the acceptance check that it really is bit-exact.
        frontier: Frontier::Off,
        // The seed loop keeps f32 LA rows; `prob_format = f32` is the
        // documented bit-exact setting (q16 storage rounds each row).
        prob_format: ProbFormat::F32,
        ..Default::default()
    }
}

#[test]
fn revolver_on_engine_bit_identical_to_seed_single_thread() {
    for (ds, n, seed) in [
        (Dataset::Wiki, 512, 11u64),
        (Dataset::Lj, 1024, 42),
        (Dataset::So, 512, 7),
    ] {
        let g = generate_dataset(ds, n, 4).unwrap();
        let cfg = parity_cfg(4, 20, seed);
        let engine_labels = Revolver::new(cfg.clone()).partition(&g).labels;
        let seed_labels = seed_reference(&g, &cfg);
        assert_eq!(
            engine_labels,
            seed_labels,
            "engine diverged from seed semantics on {}",
            ds.name()
        );
    }
}

#[test]
fn parity_holds_with_convergence_halting() {
    // Long budget + default halting: both must halt at the same step.
    let g = generate_dataset(Dataset::Lj, 1024, 9).unwrap();
    let cfg = parity_cfg(8, 290, 3);
    let engine_labels = Revolver::new(cfg.clone()).partition(&g).labels;
    let seed_labels = seed_reference(&g, &cfg);
    assert_eq!(engine_labels, seed_labels);
}
