//! L1/L2 ↔ L3 parity: the AOT-compiled XLA artifacts must compute the
//! same numbers as the native Rust implementations of the same
//! equations (eqs. 8-12 + signal construction).
//!
//! These tests need `make artifacts` to have run; they are skipped (with
//! a loud message) when the artifacts directory is absent so `cargo
//! test` works in a fresh checkout.

use revolver::la::signal::build_signals;
use revolver::la::weighted::WeightedLa;
use revolver::lp::normalized;
use revolver::runtime::{Runtime, XlaStepEngine};
use revolver::util::rng::Rng;

const BATCH: usize = 256;

fn artifacts_available() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
    }
    ok
}

fn random_rows(rng: &mut Rng, rows: usize, k: usize, scale: f32) -> Vec<f32> {
    (0..rows * k).map(|_| rng.next_f32() * scale).collect()
}

#[test]
fn score_artifact_matches_native() {
    if !artifacts_available() {
        return;
    }
    for k in [8usize, 32] {
        let mut eng = XlaStepEngine::load("artifacts", BATCH, k, 1.0, 0.1).unwrap();
        let mut rng = Rng::new(42 + k as u64);
        let hist = random_rows(&mut rng, BATCH, k, 5.0);
        let wsum: Vec<f32> =
            (0..BATCH).map(|i| hist[i * k..(i + 1) * k].iter().sum::<f32>() + 0.1).collect();
        let capacity = 1000.0f32;
        let loads: Vec<f32> = (0..k).map(|_| rng.next_f32() * capacity).collect();

        let got = eng.score(&hist, &wsum, &loads, capacity).unwrap();

        let mut pi = vec![0.0f32; k];
        normalized::penalty_into(&loads, capacity, &mut pi);
        let mut scores = vec![0.0f32; k];
        for i in 0..BATCH {
            normalized::score_into(&hist[i * k..(i + 1) * k], wsum[i], &pi, &mut scores);
            for l in 0..k {
                let (a, b) = (got[i * k + l], scores[l]);
                assert!(
                    (a - b).abs() < 1e-4,
                    "k={k} row={i} l={l}: xla={a} native={b}"
                );
            }
        }
    }
}

#[test]
fn score_artifact_overload_footnote1_matches() {
    if !artifacts_available() {
        return;
    }
    let k = 8;
    let mut eng = XlaStepEngine::load("artifacts", BATCH, k, 1.0, 0.1).unwrap();
    let mut rng = Rng::new(7);
    let hist = random_rows(&mut rng, BATCH, k, 3.0);
    let wsum: Vec<f32> = (0..BATCH).map(|_| 10.0).collect();
    let capacity = 100.0f32;
    // One partition overloaded -> negative raw penalty -> shift path.
    let mut loads: Vec<f32> = (0..k).map(|_| 50.0).collect();
    loads[3] = 150.0;

    let got = eng.score(&hist, &wsum, &loads, capacity).unwrap();
    let mut pi = vec![0.0f32; k];
    normalized::penalty_into(&loads, capacity, &mut pi);
    let mut scores = vec![0.0f32; k];
    for i in 0..BATCH {
        normalized::score_into(&hist[i * k..(i + 1) * k], wsum[i], &pi, &mut scores);
        for l in 0..k {
            assert!((got[i * k + l] - scores[l]).abs() < 1e-4);
        }
    }
}

#[test]
fn la_update_artifact_matches_native() {
    if !artifacts_available() {
        return;
    }
    for k in [8usize, 32] {
        let mut eng = XlaStepEngine::load("artifacts", BATCH, k, 1.0, 0.1).unwrap();
        let mut rng = Rng::new(99 + k as u64);
        let mut probs = vec![0.0f32; BATCH * k];
        for row in probs.chunks_mut(k) {
            let mut p: Vec<f32> = (0..k).map(|_| rng.next_f32() + 1e-3).collect();
            let s: f32 = p.iter().sum();
            p.iter_mut().for_each(|x| *x /= s);
            row.copy_from_slice(&p);
        }
        let raw_w = random_rows(&mut rng, BATCH, k, 1.0);

        let got = eng.la_update(&probs, &raw_w).unwrap();

        for i in 0..BATCH {
            let mut native = probs[i * k..(i + 1) * k].to_vec();
            let (w, s) = build_signals(&raw_w[i * k..(i + 1) * k]);
            WeightedLa::update(&mut native, &w, &s, 1.0, 0.1);
            for l in 0..k {
                let (a, b) = (got[i * k + l], native[l]);
                assert!(
                    (a - b).abs() < 2e-4,
                    "k={k} row={i} l={l}: xla={a} native={b}"
                );
            }
        }
    }
}

#[test]
fn la_update_artifact_rows_are_distributions() {
    if !artifacts_available() {
        return;
    }
    let k = 8;
    let mut eng = XlaStepEngine::load("artifacts", BATCH, k, 1.0, 0.1).unwrap();
    let probs = vec![1.0 / k as f32; BATCH * k];
    let mut rng = Rng::new(3);
    let raw_w = random_rows(&mut rng, BATCH, k, 2.0);
    let got = eng.la_update(&probs, &raw_w).unwrap();
    for row in got.chunks(k) {
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "row sum {sum}");
        assert!(row.iter().all(|&p| p > 0.0));
    }
}

#[test]
fn step_artifact_composes_score_and_update() {
    if !artifacts_available() {
        return;
    }
    // The fused `step` artifact = score ∘ signal ∘ la_update; cross-check
    // against the two split artifacts.
    let k = 8;
    let rt = Runtime::open("artifacts").unwrap();
    let step = rt.compile(&format!("step_b{BATCH}_k{k}")).unwrap();
    let mut eng = XlaStepEngine::load("artifacts", BATCH, k, 1.0, 0.1).unwrap();

    let mut rng = Rng::new(11);
    let hist = random_rows(&mut rng, BATCH, k, 4.0);
    let wsum: Vec<f32> = (0..BATCH).map(|_| 8.0).collect();
    let capacity = 500.0f32;
    let loads: Vec<f32> = (0..k).map(|_| rng.next_f32() * capacity).collect();
    let probs = vec![1.0 / k as f32; BATCH * k];
    let raw_w = random_rows(&mut rng, BATCH, k, 1.0);

    let outs = step
        .run_f32(&[&hist, &wsum, &loads, &[capacity], &probs, &raw_w])
        .unwrap();
    assert_eq!(outs.len(), 2, "step artifact returns (scores, p_next)");

    let scores = eng.score(&hist, &wsum, &loads, capacity).unwrap();
    let p_next = eng.la_update(&probs, &raw_w).unwrap();
    for (a, b) in outs[0].iter().zip(scores.iter()) {
        assert!((a - b).abs() < 1e-5);
    }
    for (a, b) in outs[1].iter().zip(p_next.iter()) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn runtime_rejects_wrong_shapes() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::open("artifacts").unwrap();
    let e = rt.compile("score_b256_k8").unwrap();
    // Too few inputs.
    assert!(e.run_f32(&[&[1.0f32]]).is_err());
    // Wrong element count.
    let bad = vec![0.0f32; 7];
    let wsum = vec![1.0f32; 256];
    let loads = vec![0.0f32; 8];
    assert!(e.run_f32(&[&bad, &wsum, &loads, &[1.0]]).is_err());
}

#[test]
fn manifest_lists_expected_entries() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::open("artifacts").unwrap();
    let names = rt.manifest().names();
    for k in [8, 32] {
        for stem in ["step", "la_update", "score"] {
            let want = format!("{stem}_b256_k{k}");
            assert!(names.contains(&want.as_str()), "missing {want} in {names:?}");
        }
    }
    assert_eq!(rt.manifest().available_k(), vec![8, 32]);
}
