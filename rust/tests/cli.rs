//! End-to-end CLI tests: spawn the real `revolver` binary and check the
//! launcher surface (subcommands, flags, config files, error paths).

use std::process::Command;

fn revolver() -> Command {
    Command::new(env!("CARGO_BIN_EXE_revolver"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = revolver().args(args).output().expect("spawn revolver");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

/// Like [`run`], but returns the raw exit code (the fault-tolerance
/// contract: 0 ok, 1 runtime failure, 2 usage error, 3 contained
/// worker panic).
fn run_code(args: &[&str]) -> (i32, String, String) {
    let out = revolver().args(args).output().expect("spawn revolver");
    (
        out.status.code().expect("no exit code (killed by signal?)"),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn no_args_prints_usage() {
    let (ok, stdout, _) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("usage: revolver"));
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn unknown_flag_fails() {
    let (ok, _, stderr) = run(&["stats", "--graph", "lj", "--bogus", "1"]);
    assert!(!ok, "unknown flags must be rejected");
    assert!(stderr.contains("bogus"), "{stderr}");
}

#[test]
fn partition_runs_and_reports_metrics() {
    let (ok, stdout, _) = run(&[
        "partition",
        "--graph",
        "so",
        "--vertices",
        "512",
        "--parts",
        "4",
        "--steps",
        "5",
        "--threads",
        "1",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("local edges:"));
    assert!(stdout.contains("max normalized load:"));
}

#[test]
fn partition_each_algorithm() {
    for algo in ["revolver", "spinner", "hash", "range", "ldg", "fennel", "restream"] {
        let (ok, stdout, stderr) = run(&[
            "partition",
            "--graph",
            "wiki",
            "--vertices",
            "256",
            "--parts",
            "2",
            "--steps",
            "3",
            "--algorithm",
            algo,
        ]);
        assert!(ok, "{algo}: {stderr}");
        assert!(stdout.contains(&format!("algorithm:           {algo}")));
    }
}

#[test]
fn stats_all_lists_nine_datasets() {
    let (ok, stdout, _) = run(&["stats", "--all", "--vertices", "256"]);
    assert!(ok);
    for name in ["wiki", "uk", "usa", "so", "lj", "en", "ok", "hlwd", "eu"] {
        assert!(stdout.contains(name), "missing {name} in stats output");
    }
}

#[test]
fn generate_then_partition_file() {
    let dir = std::env::temp_dir().join("revolver_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.txt");
    let (ok, stdout, _) = run(&[
        "generate",
        "--graph",
        "lj",
        "--vertices",
        "256",
        "--format",
        "txt",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}");
    assert!(path.exists());

    let (ok, stdout, stderr) = run(&[
        "partition",
        "--graph",
        path.to_str().unwrap(),
        "--parts",
        "2",
        "--steps",
        "3",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("local edges:"));
}

#[test]
fn sweep_writes_csv() {
    let dir = std::env::temp_dir().join("revolver_cli_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let (ok, _, stderr) = run(&[
        "sweep",
        "--graphs",
        "so",
        "--algorithms",
        "hash,range",
        "--parts",
        "2,4",
        "--vertices",
        "256",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    let csv = std::fs::read_to_string(dir.join("fig3_sweep.csv")).unwrap();
    assert!(csv.lines().count() >= 5, "{csv}");
    assert!(csv.contains("so,hash,2"));
    assert!(csv.contains("so,range,4"));
}

#[test]
fn convergence_writes_traces() {
    let dir = std::env::temp_dir().join("revolver_cli_conv");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (ok, _, stderr) = run(&[
        "convergence",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--parts",
        "2",
        "--steps",
        "4",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    for algo in ["revolver", "spinner"] {
        let p = dir.join(format!("fig4_{algo}_so_k2.csv"));
        let csv = std::fs::read_to_string(&p).unwrap();
        assert!(csv.starts_with("step,local_edges"), "{p:?}");
    }
}

#[test]
fn config_file_drives_run() {
    let dir = std::env::temp_dir().join("revolver_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.toml");
    std::fs::write(&cfg, "parts = 4\nmax_steps = 3\nthreads = 1\n").unwrap();
    let (ok, stdout, stderr) = run(&[
        "partition",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--config",
        cfg.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("partitions:          4"));
}

#[test]
fn schedule_flag_accepted_and_validated() {
    let (ok, stdout, _) = run(&[
        "partition",
        "--graph",
        "lj",
        "--vertices",
        "512",
        "--parts",
        "4",
        "--steps",
        "5",
        "--threads",
        "2",
        "--schedule",
        "degree",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("local edges:"));

    let (ok, _, stderr) = run(&[
        "partition",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--schedule",
        "zigzag",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown schedule"), "{stderr}");
}

#[test]
fn frontier_flag_accepted_and_validated() {
    // `--frontier off` must run (bit-exact legacy sweeps) and the
    // report must expose the evaluation counter either way.
    let (ok, stdout, _) = run(&[
        "partition",
        "--graph",
        "lj",
        "--vertices",
        "512",
        "--parts",
        "4",
        "--steps",
        "5",
        "--threads",
        "1",
        "--frontier",
        "off",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("vertex evals:"), "{stdout}");

    let (ok, _, stderr) = run(&[
        "partition",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--frontier",
        "sideways",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown frontier mode"), "{stderr}");
}

#[test]
fn partition_reports_edge_balance_metric() {
    let (ok, stdout, _) = run(&[
        "partition", "--graph", "so", "--vertices", "256", "--parts", "4", "--steps", "3",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("max norm edge load:"), "{stdout}");
}

#[test]
fn partition_with_stream_warmstart_flag() {
    let (ok, stdout, stderr) = run(&[
        "partition",
        "--graph",
        "lj",
        "--vertices",
        "512",
        "--parts",
        "4",
        "--steps",
        "5",
        "--threads",
        "1",
        "--init",
        "stream:fennel",
        "--stream-order",
        "bfs",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("local edges:"));

    let (ok, _, stderr) =
        run(&["partition", "--graph", "so", "--vertices", "256", "--init", "warm"]);
    assert!(!ok);
    assert!(stderr.contains("unknown init"), "{stderr}");
}

#[test]
fn stream_subcommand_partitions_file_without_csr() {
    let dir = std::env::temp_dir().join("revolver_cli_stream");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.txt");
    let (ok, stdout, _) = run(&[
        "generate",
        "--graph",
        "lj",
        "--vertices",
        "512",
        "--format",
        "txt",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}");

    let labels = dir.join("labels.txt");
    let (ok, stdout, stderr) = run(&[
        "stream",
        "--file",
        path.to_str().unwrap(),
        "--algorithm",
        "ldg",
        "--parts",
        "4",
        "--evaluate",
        "--out",
        labels.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("edges streamed:"), "{stdout}");
    assert!(stdout.contains("local edges:"), "{stdout}");
    let written = std::fs::read_to_string(&labels).unwrap();
    assert!(written.lines().count() > 0);
    assert!(written.lines().all(|l| l.parse::<u32>().map(|v| v < 4).unwrap_or(false)));

    // Missing --file is a clean error.
    let (ok, _, stderr) = run(&["stream", "--algorithm", "ldg"]);
    assert!(!ok);
    assert!(stderr.contains("--file"), "{stderr}");
}

#[test]
fn partition_multilevel_on_generated_graph() {
    let (ok, stdout, stderr) = run(&[
        "partition",
        "--graph",
        "lj",
        "--vertices",
        "2048",
        "--parts",
        "4",
        "--threads",
        "2",
        "--coarsen-until",
        "64",
        "--refine-steps",
        "3",
        "--algo", // the short alias
        "multilevel",
        "--evaluate",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("algorithm:           multilevel"), "{stdout}");
    assert!(stdout.contains("comm volume/vertex:"), "{stdout}");
    assert!(stdout.contains("per-partition loads"), "{stdout}");
}

#[test]
fn partition_multilevel_on_edge_list_file() {
    let dir = std::env::temp_dir().join("revolver_cli_multilevel");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.txt");
    let (ok, stdout, _) = run(&[
        "generate",
        "--graph",
        "lj",
        "--vertices",
        "1024",
        "--format",
        "txt",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}");

    let (ok, stdout, stderr) = run(&[
        "partition",
        "--graph",
        path.to_str().unwrap(),
        "--parts",
        "4",
        "--threads",
        "2",
        "--coarsen-until",
        "64",
        "--refine-steps",
        "3",
        "--coarse-algo",
        "ldg",
        "--algorithm",
        "multilevel",
        "--evaluate",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("local edges:"), "{stdout}");
    assert!(stdout.contains("per-partition loads"), "{stdout}");
}

#[test]
fn unknown_algorithm_error_lists_full_registry() {
    let (ok, _, stderr) =
        run(&["partition", "--graph", "so", "--vertices", "256", "--algorithm", "metis"]);
    assert!(!ok);
    for name in ["revolver", "spinner", "ldg", "fennel", "multilevel", "ml-revolver"] {
        assert!(stderr.contains(name), "error must list {name}: {stderr}");
    }
}

#[test]
fn recursive_coarse_algo_rejected() {
    let (ok, _, stderr) = run(&[
        "partition",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--algorithm",
        "multilevel",
        "--coarse-algo",
        "multilevel",
    ]);
    assert!(!ok);
    assert!(stderr.contains("coarse_algo"), "{stderr}");
}

#[test]
fn bad_dataset_name_fails_with_hint() {
    let (ok, _, stderr) = run(&["partition", "--graph", "nonexistent_ds"]);
    assert!(!ok);
    assert!(stderr.contains("neither a dataset name"), "{stderr}");
}

#[test]
fn info_reports_artifacts_when_present() {
    let (ok, stdout, _) = run(&["info"]);
    assert!(ok);
    assert!(stdout.contains("revolver"));
    // With artifacts built, the manifest entries are listed.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        assert!(stdout.contains("step_b256_k8"), "{stdout}");
    }
}

#[test]
fn dynamic_churn_reports_epochs_and_writes_trace() {
    let dir = std::env::temp_dir().join("revolver_cli_dynamic");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("dyn.csv");
    let (ok, stdout, stderr) = run(&[
        "dynamic",
        "--graph",
        "so",
        "--vertices",
        "512",
        "--parts",
        "4",
        "--threads",
        "1",
        "--steps",
        "10",
        "--repair-steps",
        "3",
        "--churn",
        "uniform:0.05",
        "--epochs",
        "2",
        "--out",
        csv.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}\n{stdout}");
    assert!(stdout.contains("cold partition"), "{stdout}");
    assert!(stdout.contains("epoch   0:"), "{stdout}");
    assert!(stdout.contains("epoch   1:"), "{stdout}");
    assert!(stdout.contains("evaluated="), "{stdout}");
    assert!(stdout.contains("totals:"), "{stdout}");
    let trace = std::fs::read_to_string(&csv).unwrap();
    let lines: Vec<&str> = trace.trim().lines().collect();
    assert_eq!(lines.len(), 3, "header + one row per epoch: {trace}");
    assert!(lines[0].starts_with("step,local_edges"), "{trace}");
    assert!(lines[1].starts_with("0,"), "{trace}");
    assert!(lines[2].starts_with("1,"), "{trace}");
}

#[test]
fn dynamic_update_log_drives_epochs() {
    let dir = std::env::temp_dir().join("revolver_cli_dynamic");
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("updates.log");
    // Two batches against dense ids of the generated graph.
    std::fs::write(&log, "# batch 1\nd 0 1\na 0 2\ncommit\nav 9999\na 9999 3\ncommit\n")
        .unwrap();
    let (ok, stdout, stderr) = run(&[
        "dynamic",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--parts",
        "4",
        "--threads",
        "1",
        "--steps",
        "5",
        "--update-log",
        log.to_str().unwrap(),
        "--algorithm",
        "revolver",
    ]);
    assert!(ok, "{stderr}\n{stdout}");
    assert!(stdout.contains("epoch   1:"), "two log batches = two epochs: {stdout}");
    assert!(stdout.contains("placed=1"), "the av/edge arrival must be placed: {stdout}");
}

#[test]
fn obs_flags_profile_log_and_quiet() {
    let dir = std::env::temp_dir().join("revolver_cli_obs");
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("obs.jsonl");
    let (ok, stdout, stderr) = run(&[
        "partition",
        "--graph",
        "so",
        "--vertices",
        "512",
        "--parts",
        "4",
        "--steps",
        "5",
        "--threads",
        "1",
        "--profile",
        "--obs-log",
        log.to_str().unwrap(),
        "--verbosity",
        "quiet",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("── profile ("), "--profile must print the tree: {stdout}");
    assert!(stdout.contains("top-level spans:"), "{stdout}");
    assert!(stdout.contains("engine"), "{stdout}");
    assert!(stdout.contains("local edges:"), "metrics still print: {stdout}");
    assert!(
        !stderr.contains("partitioning"),
        "--verbosity quiet must silence progress: {stderr}"
    );
    let text = std::fs::read_to_string(&log).unwrap();
    let n = revolver::obs::events::validate_events(&text).expect("obs log must validate");
    assert!(n >= 3, "run_start + steps + run_end: {text}");
    assert!(text.lines().next().unwrap().contains("\"ev\":\"run_start\""), "{text}");
    assert!(text.lines().last().unwrap().contains("\"ev\":\"run_end\""), "{text}");

    // Bad verbosity is a clean flag error.
    let (ok, _, stderr) = run(&[
        "partition", "--graph", "so", "--vertices", "256", "--verbosity", "loud",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown verbosity"), "{stderr}");
}

// ── Fault-tolerance layer: exit codes, checkpoint/resume, ingest ──

#[test]
fn exit_code_2_for_usage_errors() {
    let (code, _, stderr) = run_code(&["frobnicate"]);
    assert_eq!(code, 2, "unknown subcommand: {stderr}");

    let (code, _, stderr) =
        run_code(&["stats", "--graph", "lj", "--vertices", "256", "--bogus", "1"]);
    assert_eq!(code, 2, "unknown flag: {stderr}");

    let (code, _, stderr) = run_code(&[
        "partition", "--graph", "so", "--vertices", "256", "--faults", "explode@heap:1",
    ]);
    assert_eq!(code, 2, "bad fault spec is a config error: {stderr}");

    // --resume without --checkpoint is a config error.
    let (code, _, stderr) =
        run_code(&["partition", "--graph", "so", "--vertices", "256", "--resume"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("resume requires"), "{stderr}");
}

#[test]
fn exit_code_1_for_runtime_failures() {
    // A missing input file is an environment problem, not a usage one.
    let (code, _, stderr) = run_code(&["partition", "--graph", "no_such_edges.txt"]);
    assert_eq!(code, 1, "{stderr}");

    let (code, _, stderr) = run_code(&[
        "dynamic",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--update-log",
        "/nonexistent/updates.log",
    ]);
    assert_eq!(code, 1, "{stderr}");
    assert!(stderr.contains("open"), "{stderr}");
}

#[test]
fn exit_code_3_for_contained_worker_panic() {
    let (code, _, stderr) = run_code(&[
        "partition",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--parts",
        "2",
        "--steps",
        "5",
        "--threads",
        "2",
        "--algorithm",
        "spinner",
        "--faults",
        "panic@step:1",
    ]);
    assert_eq!(code, 3, "injected worker panic must abort with code 3: {stderr}");
    assert!(stderr.contains("panicked in phase"), "{stderr}");
    assert!(stderr.contains("injected fault"), "{stderr}");
}

#[test]
fn partition_checkpoint_then_resume() {
    let dir = std::env::temp_dir().join("revolver_cli_ckpt_partition");
    let _ = std::fs::remove_dir_all(&dir);
    let base: &[&str] = &[
        "--graph",
        "so",
        "--vertices",
        "512",
        "--parts",
        "4",
        "--steps",
        "6",
        "--threads",
        "1",
        "--algorithm",
        "revolver",
        "--checkpoint",
    ];
    let mut first: Vec<&str> = vec!["partition"];
    first.extend_from_slice(base);
    first.extend_from_slice(&[dir.to_str().unwrap(), "--checkpoint-every", "2"]);
    let (ok, stdout, stderr) = run(&first);
    assert!(ok, "{stderr}\n{stdout}");
    let snapshots = std::fs::read_dir(&dir)
        .expect("checkpoint dir created")
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().ends_with(".rvck"))
        .count();
    assert!(snapshots >= 1, "step cadence 2 over 6 steps must write snapshots");

    let mut second: Vec<&str> = vec!["partition"];
    second.extend_from_slice(base);
    second.extend_from_slice(&[dir.to_str().unwrap(), "--resume"]);
    let (ok, stdout, stderr) = run(&second);
    assert!(ok, "{stderr}\n{stdout}");
    assert!(stdout.contains("resumed from step:"), "{stdout}");
    assert!(stdout.contains("local edges:"), "{stdout}");

    // Resuming with a different seed must refuse the checkpoint.
    let mut third: Vec<&str> = vec!["partition"];
    third.extend_from_slice(base);
    third.extend_from_slice(&[dir.to_str().unwrap(), "--resume", "--seed", "7"]);
    let (code, _, stderr) = run_code(&third);
    assert_eq!(code, 1, "{stderr}");
    assert!(stderr.contains("checkpoint mismatch"), "{stderr}");
}

#[test]
fn dynamic_checkpoint_then_resume_extends_the_run() {
    let dir = std::env::temp_dir().join("revolver_cli_ckpt_dynamic");
    let _ = std::fs::remove_dir_all(&dir);
    let base: &[&str] = &[
        "--graph",
        "so",
        "--vertices",
        "512",
        "--parts",
        "4",
        "--threads",
        "1",
        "--steps",
        "10",
        "--repair-steps",
        "3",
        "--churn",
        "uniform:0.05",
        "--checkpoint",
    ];
    let mut first: Vec<&str> = vec!["dynamic"];
    first.extend_from_slice(base);
    first.extend_from_slice(&[dir.to_str().unwrap(), "--epochs", "2"]);
    let (ok, stdout, stderr) = run(&first);
    assert!(ok, "{stderr}\n{stdout}");
    assert!(stdout.contains("cold partition"), "{stdout}");

    // The final epoch is always snapshotted, so a resumed run with a
    // larger budget replays the churn stream to epoch 2 and only
    // executes epochs 2..4.
    let mut second: Vec<&str> = vec!["dynamic"];
    second.extend_from_slice(base);
    second.extend_from_slice(&[dir.to_str().unwrap(), "--epochs", "4", "--resume"]);
    let (ok, stdout, stderr) = run(&second);
    assert!(ok, "{stderr}\n{stdout}");
    assert!(stdout.contains("resumed from checkpoint"), "{stdout}");
    assert!(!stdout.contains("cold partition"), "resume must skip the cold start: {stdout}");
    assert!(!stdout.contains("epoch   1:"), "epochs before the snapshot replay: {stdout}");
    assert!(stdout.contains("epoch   2:"), "{stdout}");
    assert!(stdout.contains("epoch   3:"), "{stdout}");
    assert!(stdout.contains("totals:"), "{stdout}");
}

#[test]
fn ingest_mode_gates_dirty_edge_lists() {
    let dir = std::env::temp_dir().join("revolver_cli_ingest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("dirty.txt");
    std::fs::write(&path, "0 1\n1 2\nthis line is garbage\n2 0\n").unwrap();

    let (code, _, stderr) = run_code(&[
        "partition", "--graph", path.to_str().unwrap(), "--parts", "2", "--steps", "3",
    ]);
    assert_eq!(code, 1, "strict ingest aborts on the malformed line: {stderr}");
    assert!(stderr.contains("line 3"), "{stderr}");

    let (ok, stdout, stderr) = run(&[
        "partition",
        "--graph",
        path.to_str().unwrap(),
        "--parts",
        "2",
        "--steps",
        "3",
        "--ingest",
        "lenient",
    ]);
    assert!(ok, "lenient ingest skips the malformed line: {stderr}");
    assert!(stdout.contains("local edges:"), "{stdout}");
}

#[test]
fn dynamic_truncate_log_fault_drops_tail_batches() {
    let dir = std::env::temp_dir().join("revolver_cli_truncate");
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("updates.log");
    std::fs::write(&log, "d 0 1\ncommit\na 0 2\ncommit\nd 1 2\ncommit\na 1 3\ncommit\n")
        .unwrap();
    let (ok, stdout, stderr) = run(&[
        "dynamic",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--parts",
        "4",
        "--threads",
        "1",
        "--steps",
        "5",
        "--update-log",
        log.to_str().unwrap(),
        "--faults",
        "truncate@log:50%",
    ]);
    // 8 lines cut to 4 = two surviving commits = two epochs.
    assert!(ok, "{stderr}\n{stdout}");
    assert!(stdout.contains("epoch   1:"), "{stdout}");
    assert!(!stdout.contains("epoch   2:"), "the truncated tail must be gone: {stdout}");
}

#[test]
fn dynamic_requires_churn_or_log() {
    let (ok, _, stderr) = run(&["dynamic", "--graph", "so", "--vertices", "256"]);
    assert!(!ok);
    assert!(stderr.contains("--churn"), "{stderr}");
}

#[test]
fn dynamic_rejects_bad_recipe_and_algorithm() {
    let (ok, _, stderr) = run(&[
        "dynamic", "--graph", "so", "--vertices", "256", "--churn", "metis:1",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown churn recipe"), "{stderr}");
    let (ok, _, stderr) = run(&[
        "dynamic",
        "--graph",
        "so",
        "--vertices",
        "256",
        "--churn",
        "uniform:0.05",
        "--algorithm",
        "hash",
    ]);
    assert!(!ok);
    assert!(stderr.contains("spinner|revolver"), "{stderr}");
}
