//! Cross-module integration tests: generators → partitioners → metrics,
//! config plumbing, streaming/warm-start paths, and I/O round-trips
//! through the full pipeline.

use revolver::config::{ExecutionModel, Frontier, Init, RevolverConfig, StreamAlgo};
use revolver::graph::gen::{generate_dataset, rmat, Dataset};
use revolver::graph::{io, stats, Graph, GraphBuilder};
use revolver::metrics::quality;
use revolver::partitioners::by_name;

fn cfg(k: usize, steps: u32) -> RevolverConfig {
    RevolverConfig { parts: k, max_steps: steps, threads: 2, seed: 3, ..Default::default() }
}

#[test]
fn all_algorithms_all_datasets_smoke() {
    // Every partitioner must produce valid output on every dataset class.
    for ds in Dataset::ALL {
        let g = generate_dataset(ds, 256, 1).unwrap();
        for algo in [
            "revolver",
            "spinner",
            "hash",
            "range",
            "ldg",
            "fennel",
            "restream",
            "multilevel",
            "ml-revolver",
        ] {
            let out = by_name(algo, cfg(4, 10)).unwrap().partition(&g);
            assert_eq!(out.labels.len(), g.num_vertices(), "{algo}/{}", ds.name());
            assert!(out.labels.iter().all(|&l| l < 4), "{algo}/{}", ds.name());
            let q = quality::evaluate(&g, &out.labels, 4);
            assert!((0.0..=1.0).contains(&q.local_edges));
            assert!(q.max_normalized_load >= 1.0 - 1e-9);
            // Mean distinct remote partitions per vertex is bounded by
            // the k−1 remote partitions that exist.
            assert!(
                (0.0..=3.0).contains(&q.mean_communication_volume),
                "{algo}/{}",
                ds.name()
            );
        }
    }
}

/// The R-MAT surrogate the streaming acceptance criteria run on (k=8).
fn rmat_surrogate() -> Graph {
    let n = 1 << 13;
    rmat::rmat(n, 16 * n, 0.57, 0.19, 0.19, 5)
}

#[test]
fn streaming_beats_hash_within_balance_envelope() {
    let g = rmat_surrogate();
    let k = 8;
    let hash_le =
        quality::local_edges(&g, &by_name("hash", cfg(k, 1)).unwrap().partition(&g).labels);
    for algo in ["ldg", "fennel"] {
        let out = by_name(algo, cfg(k, 1)).unwrap().partition(&g);
        let q = quality::evaluate(&g, &out.labels, k);
        assert!(
            q.local_edges > hash_le,
            "{algo} local edges {} must beat hash {hash_le}",
            q.local_edges
        );
        assert!(
            q.max_normalized_load <= 1.1,
            "{algo} max normalized load {} exceeds 1.1",
            q.max_normalized_load
        );
    }
}

#[test]
fn restream_three_passes_no_worse_than_one() {
    let g = rmat_surrogate();
    let mut c1 = cfg(8, 1);
    c1.restream_passes = 1;
    let mut c3 = cfg(8, 1);
    c3.restream_passes = 3;
    let le1 =
        quality::local_edges(&g, &by_name("restream", c1).unwrap().partition(&g).labels);
    let le3 =
        quality::local_edges(&g, &by_name("restream", c3).unwrap().partition(&g).labels);
    assert!(le3 >= le1, "restream 3 passes ({le3}) must be no worse than pass 1 ({le1})");
}

#[test]
fn revolver_stream_warmstart_converges_no_slower() {
    // Same graph, same seed: `--init stream:fennel` must reach the
    // §IV-D.9 convergence threshold in no more steps than the paper's
    // uniform-random start.
    let g = rmat_surrogate();
    let mut c = cfg(8, 150);
    c.threads = 1;
    let cold = by_name("revolver", c.clone()).unwrap().partition(&g);
    c.init = Init::Stream(StreamAlgo::Fennel);
    let warm = by_name("revolver", c).unwrap().partition(&g);
    assert!(
        warm.trace.steps() <= cold.trace.steps(),
        "warm={} cold={}",
        warm.trace.steps(),
        cold.trace.steps()
    );
    // The warm start is a head start, not a quality trade: it must
    // still land in the same balance envelope.
    let q = quality::evaluate(&g, &warm.labels, 8);
    assert!(q.max_normalized_load < 1.15, "{q:?}");
}

#[test]
fn spinner_stream_warmstart_runs_and_keeps_quality() {
    let g = rmat_surrogate();
    let mut c = cfg(8, 30);
    c.init = Init::Stream(StreamAlgo::Ldg);
    let ldg_le =
        quality::local_edges(&g, &by_name("ldg", c.clone()).unwrap().partition(&g).labels);
    let out = by_name("spinner", c).unwrap().partition(&g);
    assert!(out.labels.iter().all(|&l| l < 8));
    let warm_le = quality::local_edges(&g, &out.labels);
    // Spinner iterating from the streamed start must not destroy it:
    // it only migrates vertices toward higher-scoring partitions.
    assert!(warm_le > ldg_le - 0.05, "spinner {warm_le} vs its ldg init {ldg_le}");
}

#[test]
fn figure3_shape_on_lj() {
    // The core Figure-3 ordering on a right-skewed graph (k=8):
    //   local edges: revolver ≳ spinner >> hash; hash ≈ 1/k
    //   balance: revolver best (≈1.0), hash decent, range poor.
    let g = generate_dataset(Dataset::Lj, 4096, 7).unwrap();
    let k = 8;
    let mut le = std::collections::HashMap::new();
    let mut mnl = std::collections::HashMap::new();
    for algo in ["revolver", "spinner", "hash", "range"] {
        let out = by_name(algo, cfg(k, 290)).unwrap().partition(&g);
        let q = quality::evaluate(&g, &out.labels, k);
        le.insert(algo, q.local_edges);
        mnl.insert(algo, q.max_normalized_load);
    }
    assert!(le["revolver"] > le["hash"] + 0.05, "{le:?}");
    assert!(le["spinner"] > le["hash"] + 0.05, "{le:?}");
    assert!(le["revolver"] > le["spinner"] - 0.02, "revolver must be ≳ spinner: {le:?}");
    assert!((le["hash"] - 1.0 / k as f64).abs() < 0.05, "{le:?}");
    assert!(mnl["revolver"] < 1.10, "{mnl:?}");
    assert!(
        mnl["revolver"] <= mnl["spinner"] + 0.02,
        "revolver balance must not lose to spinner: {mnl:?}"
    );
}

#[test]
fn async_balances_better_than_sync() {
    // §V-H.2: the asynchronous model's progressive load exchange gives
    // better (or equal) balance than the synchronous variant.
    let g = generate_dataset(Dataset::Ok, 2048, 3).unwrap();
    let k = 8;
    let mut m = std::collections::HashMap::new();
    for exec in [ExecutionModel::Asynchronous, ExecutionModel::Synchronous] {
        let mut c = cfg(k, 80);
        c.execution = exec;
        let out = by_name("revolver", c).unwrap().partition(&g);
        m.insert(format!("{exec:?}"), quality::max_normalized_load(&g, &out.labels, k));
    }
    let a = m["Asynchronous"];
    let s = m["Synchronous"];
    assert!(a <= s + 0.05, "async {a} should not balance worse than sync {s}");
}

/// The multilevel acceptance surrogate (ISSUE 3): R-MAT, 2^16 vertices,
/// k = 8, fixed seed.
fn multilevel_surrogate() -> Graph {
    let n = 1 << 16;
    rmat::rmat(n, 16 * n, 0.57, 0.19, 0.19, 5)
}

#[test]
fn multilevel_matches_spinner_at_equal_superstep_budget() {
    // The headline acceptance criterion: at the same total superstep
    // budget, the V-cycle (most of whose supersteps run on levels a
    // fraction of |V|) must reach at least flat Spinner's locality
    // while staying inside the ε = 0.05 balance envelope.
    let g = multilevel_surrogate();
    let k = 8;
    // threads = 1: the comparison margins are zero-slack, so both runs
    // must be fully deterministic (multithreaded async interleavings
    // shift quality by scheduler luck).
    let mut c = cfg(k, 290);
    c.threads = 1;
    let ml = by_name("multilevel", c.clone()).unwrap().partition(&g);
    let q_ml = quality::evaluate(&g, &ml.labels, k);
    assert!(
        q_ml.max_normalized_load <= 1.05 + 1e-9,
        "multilevel must hold the ε envelope: {q_ml:?}"
    );

    let budget = ml.trace.steps().max(1);
    let mut sc = c;
    sc.max_steps = budget;
    sc.halt_window = u32::MAX; // flat Spinner spends the whole budget
    let sp = by_name("spinner", sc).unwrap().partition(&g);
    let q_sp = quality::evaluate(&g, &sp.labels, k);
    assert!(
        q_ml.local_edges >= q_sp.local_edges,
        "multilevel local edges {} must reach flat spinner's {} at {budget} supersteps",
        q_ml.local_edges,
        q_sp.local_edges
    );
}

#[test]
fn vcycle_refinement_improves_on_coarse_projection() {
    // The coarsest-level partition projected straight down, with no
    // refinement, must be strictly beaten by the refined V-cycle —
    // otherwise the refinement levels add nothing.
    let g = multilevel_surrogate();
    let k = 8;
    // threads = 1 for the same zero-slack determinism reason as the
    // equal-budget test above.
    let mut c = cfg(k, 290);
    c.threads = 1;
    let base = revolver::multilevel::coarse_projection(&g, &c);
    let base_le = quality::local_edges(&g, &base);
    let ml = by_name("multilevel", c).unwrap().partition(&g);
    let ml_le = quality::local_edges(&g, &ml.labels);
    assert!(
        ml_le > base_le,
        "refinement must strictly improve the projected coarse cut: {ml_le} vs {base_le}"
    );
}

#[test]
fn multilevel_cuts_communication_volume_versus_hash() {
    // The new metric must show the structural win: a V-cycle cut needs
    // far fewer distinct remote replicas per vertex than a hash split.
    let g = rmat_surrogate();
    let k = 8;
    let hash = by_name("hash", cfg(k, 1)).unwrap().partition(&g);
    let ml = by_name("multilevel", cfg(k, 290)).unwrap().partition(&g);
    let cv_hash = quality::mean_communication_volume(&g, &hash.labels, k);
    let cv_ml = quality::mean_communication_volume(&g, &ml.labels, k);
    assert!(
        cv_ml < cv_hash,
        "multilevel comm volume {cv_ml} must beat hash {cv_hash}"
    );
}

#[test]
fn frontier_matches_quality_with_fewer_evaluations() {
    // The active-set acceptance criterion (ISSUE 4): same graph, same
    // seed, same superstep budget — frontier-driven execution must land
    // within 2% of full-sweep local edges, hold the ε envelope, and
    // perform measurably fewer total vertex-evaluations (compared via
    // the RunTrace counter, not wall clock).
    let g = multilevel_surrogate(); // 2^16 R-MAT, k = 8
    let k = 8;
    let mut c = cfg(k, 30);
    c.threads = 1; // deterministic: zero-slack statistical margins
    c.halt_window = u32::MAX; // fixed budget ⇒ comparable evaluation counts
    c.frontier = Frontier::Off;
    let off = by_name("revolver", c.clone()).unwrap().partition(&g);
    c.frontier = Frontier::On;
    let on = by_name("revolver", c).unwrap().partition(&g);

    let full = 30u64 * g.num_vertices() as u64;
    assert_eq!(off.trace.total_evaluated, full, "full sweeps evaluate steps × |V|");
    assert!(
        on.trace.total_evaluated < off.trace.total_evaluated,
        "frontier must skip settled vertices: on={} off={}",
        on.trace.total_evaluated,
        off.trace.total_evaluated
    );

    let q_off = quality::evaluate(&g, &off.labels, k);
    let q_on = quality::evaluate(&g, &on.labels, k);
    assert!(
        q_on.local_edges >= q_off.local_edges - 0.02 * q_off.local_edges.max(0.1),
        "frontier quality within 2%: on={} off={}",
        q_on.local_edges,
        q_off.local_edges
    );
    // Balance: skipping settled vertices must not loosen the envelope —
    // the same bound the Figure-3 acceptance holds Revolver to (a
    // mid-run cut can carry one transient hub overshoot above 1+ε,
    // which later steps drain, so the exact 1.05 line is asserted where
    // a deterministic rebalance enforces it, not on a raw async cut).
    assert!(
        q_on.max_normalized_load <= 1.10,
        "frontier must hold the balance envelope: {q_on:?}"
    );
}

/// Two reciprocal 4-cliques, one per partition: every vertex's argmax
/// is its own partition and (at ε = 0) no migration has headroom, so
/// nothing can ever change.
fn preconverged_two_cliques() -> (Graph, Vec<u32>) {
    let mut b = GraphBuilder::new(8);
    for base in [0u32, 4] {
        for i in 0..4u32 {
            for j in 0..4u32 {
                if i != j {
                    b.edge(base + i, base + j);
                }
            }
        }
    }
    (b.build(), vec![0, 0, 0, 0, 1, 1, 1, 1])
}

#[test]
fn empty_frontier_halts_preconverged_run() {
    // Pre-converged init with zero migration headroom: step 0 produces
    // no migrations, no λ changes and no unsettled vertices, so the
    // frontier is empty at step 1 and both refiners must terminate in
    // ≤ 2 supersteps — far below the 50-step budget and regardless of
    // the (disabled) score-window detector.
    let (g, init) = preconverged_two_cliques();
    let mut c = cfg(2, 50);
    c.threads = 1;
    c.epsilon = 0.0;
    c.halt_window = u32::MAX;

    let sp = revolver::partitioners::spinner::refine(&g, &c, init.clone()).unwrap();
    assert_eq!(sp.labels, init, "spinner must not disturb the converged cut");
    assert!(sp.trace.steps() <= 2, "spinner ran {} supersteps", sp.trace.steps());

    let rv = revolver::partitioners::revolver::refine(&g, &c, init.clone()).unwrap();
    assert_eq!(rv.labels, init, "revolver must not disturb the converged cut");
    assert!(rv.trace.steps() <= 2, "revolver ran {} supersteps", rv.trace.steps());
}

#[test]
fn isolated_vertices_never_migrate_or_stay_active_under_frontier() {
    // Regression (ISSUE 4 satellite): isolated vertices score by
    // penalty alone, so legacy evaluation lets them chase the emptiest
    // partition. Under the frontier they must never migrate spuriously
    // and never activate anyone — they leave the frontier after step 0.
    let mut b = GraphBuilder::new(12);
    // 0..4 form a path (both directions); 4..12 are isolated.
    for v in 0..3u32 {
        b.edge(v, v + 1);
        b.edge(v + 1, v);
    }
    let g = b.build();
    let init: Vec<u32> = (0..12).map(|v| if v < 4 { v % 2 } else { 1 }).collect();
    let steps = 20u32;
    let mut c = cfg(2, steps);
    c.threads = 1;
    c.halt_window = u32::MAX;

    for algo in ["spinner", "revolver"] {
        let out = match algo {
            "spinner" => revolver::partitioners::spinner::refine(&g, &c, init.clone()).unwrap(),
            _ => revolver::partitioners::revolver::refine(&g, &c, init.clone()).unwrap(),
        };
        for v in 4..12 {
            assert_eq!(
                out.labels[v], init[v],
                "{algo}: isolated vertex {v} migrated spuriously"
            );
        }
        // Isolated vertices are evaluated once (step 0) and never again:
        // everything after step 0 fits in the 4 connected vertices.
        let bound = 12 + (steps as u64 - 1) * 4;
        assert!(
            out.trace.total_evaluated <= bound,
            "{algo}: isolated vertices stayed active ({} > {bound} evals)",
            out.trace.total_evaluated
        );
    }
}

#[test]
fn dynamic_repair_matches_restart_quality_with_fewer_evaluations() {
    // The dynamic-subsystem acceptance criterion (ISSUE 5): 2^16 R-MAT
    // k=8 (threads=1, fixed seed), 5 epochs of 2% edge churn. The
    // incremental path (greedy arrival placement + frontier-seeded
    // repair at `repair_steps` supersteps per epoch) must reach
    // `local_edges` within 3% of a full from-scratch repartition given
    // the same per-epoch superstep budget, hold mnl ≤ 1.10, and spend
    // strictly fewer total evaluated vertex-steps than restarting each
    // epoch.
    use revolver::dynamic::{ChurnRecipe, IncrementalPartitioner};
    use revolver::multilevel::Refiner;

    let g = multilevel_surrogate(); // 2^16 R-MAT, k = 8
    let k = 8;
    let repair = 6u32;
    let mut c = cfg(k, 60);
    c.threads = 1; // deterministic: zero-slack statistical margins
    c.repair_steps = repair;

    let mut inc = IncrementalPartitioner::new(g, c.clone(), Refiner::Spinner).unwrap();
    let recipe = ChurnRecipe::Uniform { frac: 0.02 };

    let mut cold_evaluated = 0u64;
    let mut cold_final_le = 0.0f64;
    for e in 0..5u64 {
        let batch = recipe.generate(inc.current(), 1000 + e);
        let stats = inc.epoch(&batch).unwrap();
        assert!(stats.applied > 0, "epoch {e}: churn must apply");

        // Cold restart on the identical evolved graph, same per-epoch
        // superstep budget, same seed family.
        let mut rc = c.clone();
        rc.max_steps = repair;
        rc.halt_window = u32::MAX;
        let cold = by_name("spinner", rc).unwrap().partition(inc.current());
        cold_evaluated += cold.trace.total_evaluated;
        if e == 4 {
            cold_final_le = quality::local_edges(inc.current(), &cold.labels);
        }
    }

    let q = quality::evaluate(inc.current(), inc.labels(), k);
    assert!(
        q.local_edges >= cold_final_le - 0.03 * cold_final_le,
        "incremental local edges {} must be within 3% of the {}-step cold restart's {}",
        q.local_edges,
        repair,
        cold_final_le
    );
    assert!(
        q.max_normalized_load <= 1.10 + 1e-9,
        "incremental repair must hold the balance envelope: {q:?}"
    );
    assert!(
        inc.total_evaluated() < cold_evaluated,
        "repair must beat per-epoch restarts on evaluated vertex-steps: inc={} cold={}",
        inc.total_evaluated(),
        cold_evaluated
    );
    assert!(inc.total_evaluated() > 0, "repair must actually run");
}

#[test]
fn dynamic_arrivals_grow_partition_within_envelope() {
    // Vertex arrival stream: the assignment must grow with the graph,
    // keep every label valid, and stay balanced — the scenario class
    // (BA-style growth) the placement path exists for.
    use revolver::dynamic::{ChurnRecipe, IncrementalPartitioner};
    use revolver::multilevel::Refiner;

    let g = rmat_surrogate(); // 2^13 R-MAT
    let k = 8;
    let n0 = g.num_vertices();
    let mut c = cfg(k, 40);
    c.threads = 1;
    c.repair_steps = 5;
    let mut inc = IncrementalPartitioner::new(g, c, Refiner::Spinner).unwrap();
    let recipe = ChurnRecipe::Arrivals { count: 256, edges_per: 4 };
    for e in 0..3u64 {
        let batch = recipe.generate(inc.current(), 70 + e);
        let stats = inc.epoch(&batch).unwrap();
        assert_eq!(stats.placed, 256, "epoch {e}");
    }
    assert_eq!(inc.current().num_vertices(), n0 + 3 * 256);
    assert_eq!(inc.labels().len(), n0 + 3 * 256);
    assert!(inc.labels().iter().all(|&l| (l as usize) < k));
    let q = quality::evaluate(inc.current(), inc.labels(), k);
    assert!(q.max_normalized_load <= 1.10 + 1e-9, "{q:?}");
    // Placement against the full assignment keeps arrivals local:
    // the evolved cut must stay far above a hash split.
    let hash = by_name("hash", cfg(k, 1)).unwrap().partition(inc.current());
    let hash_le = quality::local_edges(inc.current(), &hash.labels);
    assert!(q.local_edges > hash_le, "evolved {} vs hash {hash_le}", q.local_edges);
}

#[test]
fn partition_after_io_roundtrip() {
    // Generate → save → load → partition must equal partitioning the
    // original (loaders preserve structure exactly).
    let g = generate_dataset(Dataset::So, 512, 9).unwrap();
    let dir = std::env::temp_dir().join("revolver_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("so.bin");
    io::save_binary(&g, &path).unwrap();
    let g2 = io::load_binary(&path).unwrap();

    let out1 = by_name("revolver", cfg(4, 15)).unwrap().partition(&g);
    let out2 = by_name("revolver", cfg(4, 15)).unwrap().partition(&g2);
    // threads=2 introduces scheduling nondeterminism in the async engine,
    // so compare quality, not labels.
    let q1 = quality::evaluate(&g, &out1.labels, 4);
    let q2 = quality::evaluate(&g2, &out2.labels, 4);
    assert!((q1.local_edges - q2.local_edges).abs() < 0.05);
}

#[test]
fn table1_surrogates_match_paper_classes() {
    // Every surrogate must land in its paper dataset's skew class
    // (DESIGN.md §4's substitution-fidelity check).
    for (ds, expect_positive) in [
        (Dataset::Wiki, true),
        (Dataset::Uk, true),
        (Dataset::Usa, false),
        (Dataset::Lj, true),
        (Dataset::En, true),
        (Dataset::Ok, true),
        (Dataset::Hlwd, true),
    ] {
        let g = generate_dataset(ds, 2048, 7).unwrap();
        let s = stats::compute(&g);
        assert_eq!(
            s.skewness > 0.0,
            expect_positive,
            "{}: skew {} has wrong sign",
            ds.name(),
            s.skewness
        );
    }
    // Skew-free classes: |skew| small.
    for ds in [Dataset::So, Dataset::Eu] {
        let g = generate_dataset(ds, 2048, 7).unwrap();
        let s = stats::compute(&g);
        assert!(s.skewness.abs() < 0.4, "{}: {}", ds.name(), s.skewness);
    }
}

#[test]
fn config_toml_to_partition() {
    // A config file drives a run end to end.
    let dir = std::env::temp_dir().join("revolver_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        "parts = 4\nmax_steps = 10\nthreads = 1\nseed = 5\nexecution = \"sync\"\n",
    )
    .unwrap();
    let cfg = RevolverConfig::from_toml_file(&path).unwrap();
    assert_eq!(cfg.execution, ExecutionModel::Synchronous);
    let g = generate_dataset(Dataset::Wiki, 256, 2).unwrap();
    let out = by_name("revolver", cfg).unwrap().partition(&g);
    assert_eq!(out.labels.len(), 256);
}

#[test]
fn convergence_traces_are_consistent() {
    // trace_every=1 must yield one point per executed step with metrics
    // matching an independent evaluation at the end.
    let g = generate_dataset(Dataset::Lj, 1024, 4).unwrap();
    let mut c = cfg(4, 25);
    c.trace_every = 1;
    c.halt_window = u32::MAX;
    // Full sweeps: the exact one-point-per-step count below assumes no
    // empty-frontier early halt.
    c.frontier = Frontier::Off;
    let out = by_name("revolver", c).unwrap().partition(&g);
    assert_eq!(out.trace.points.len(), 25);
    let last = out.trace.points.last().unwrap();
    let q = quality::evaluate(&g, &out.labels, 4);
    assert!((last.local_edges - q.local_edges).abs() < 1e-9);
    assert!((last.max_normalized_load - q.max_normalized_load).abs() < 1e-9);
}

#[test]
fn epsilon_zero_still_works() {
    // Degenerate imbalance budget: migrations nearly all blocked, but
    // the run must finish and stay valid.
    let g = generate_dataset(Dataset::So, 512, 6).unwrap();
    let mut c = cfg(4, 10);
    c.epsilon = 0.0;
    let out = by_name("revolver", c).unwrap().partition(&g);
    assert!(out.labels.iter().all(|&l| l < 4));
}

#[test]
fn large_k_exceeding_small_graph() {
    // k close to |V|: every partition nearly empty; must not panic.
    let g = generate_dataset(Dataset::So, 128, 8).unwrap();
    let out = by_name("revolver", cfg(64, 5)).unwrap().partition(&g);
    assert!(out.labels.iter().all(|&l| l < 64));
    let out = by_name("spinner", cfg(64, 5)).unwrap().partition(&g);
    assert!(out.labels.iter().all(|&l| l < 64));
}
