//! Seeded mutation fuzzing of every ingest surface (ISSUE 9 tentpole,
//! hardened-ingest leg): edge-list text, update-log text, the `RVLB`
//! binary graph format and the `RVCK` checkpoint format.
//!
//! Std-only by necessity (no fuzzer crates offline) and deterministic
//! by design: each iteration derives a mutation from the repo's own
//! xoshiro [`Rng`] seeded with the iteration index, so a failure
//! reproduces from the printed seed alone. Mutations are the classic
//! torn-input catalogue — bit flips, truncation, NUL / invalid-UTF-8
//! splices, huge integer tokens, duplicated and deleted chunks.
//!
//! The contract under test:
//!
//! * parsers only ever return structured errors — no panic (asserted
//!   via `catch_unwind`), no abort, no unbounded allocation;
//! * lenient text ingest *always* returns `Ok` (a malformed line is
//!   skipped, never fatal);
//! * parsed graphs never mint phantom vertices: every edge endpoint
//!   stays inside the id space the parser reports.

use std::panic::{catch_unwind, AssertUnwindSafe};

use revolver::config::IngestMode;
use revolver::dynamic::read_update_log_named;
use revolver::graph::io::{read_edge_list_named, save_binary};
use revolver::util::rng::Rng;

/// Mutations per corpus. The ISSUE 9 acceptance floor is 10k.
const ITERS: u64 = 10_000;

/// Apply one seeded mutation to `base`. Always changes something
/// (possibly a no-op flip on pathological inputs, which is fine — the
/// clean corpus must parse too).
fn mutate(base: &[u8], rng: &mut Rng) -> Vec<u8> {
    let mut buf = base.to_vec();
    // 1-3 stacked mutations per iteration: single-site fuzzing misses
    // interactions like "truncate, then flip a byte in the new tail".
    for _ in 0..=rng.below(3) {
        if buf.is_empty() {
            buf = base.to_vec();
        }
        match rng.below(6) {
            // Bit flips: 1-8 random bits anywhere.
            0 => {
                for _ in 0..=rng.below(8) {
                    let i = rng.below_usize(buf.len());
                    buf[i] ^= 1 << rng.below(8) as u8;
                }
            }
            // Truncation at a random offset (torn write).
            1 => {
                buf.truncate(rng.below_usize(buf.len()));
            }
            // NUL / invalid-UTF-8 splices.
            2 => {
                let garbage: &[&[u8]] =
                    &[&[0x00], &[0xC0, 0xAF], &[0xFF, 0xFE], &[0xED, 0xA0, 0x80]];
                let g = garbage[rng.below_usize(garbage.len())];
                let at = rng.below_usize(buf.len() + 1);
                buf.splice(at..at, g.iter().copied());
            }
            // Huge integer tokens (u64 overflow, count bombs).
            3 => {
                let token: &[u8] = match rng.below(3) {
                    0 => b" 99999999999999999999999999 ",
                    1 => b" 18446744073709551616 ",
                    _ => b" -1 ",
                };
                let at = rng.below_usize(buf.len() + 1);
                buf.splice(at..at, token.iter().copied());
            }
            // Duplicate a random chunk (repeated region / double write).
            4 => {
                let a = rng.below_usize(buf.len());
                let b = (a + 1 + rng.below_usize(64)).min(buf.len());
                let chunk: Vec<u8> = buf[a..b].to_vec();
                let at = rng.below_usize(buf.len() + 1);
                buf.splice(at..at, chunk);
            }
            // Delete a random chunk (lost region).
            _ => {
                let a = rng.below_usize(buf.len());
                let b = (a + 1 + rng.below_usize(64)).min(buf.len());
                buf.drain(a..b);
            }
        }
    }
    buf
}

fn mode_for(seed: u64) -> IngestMode {
    if seed % 2 == 0 {
        IngestMode::Strict
    } else {
        IngestMode::Lenient
    }
}

/// A small clean edge-list corpus: comments, blank lines, sparse ids.
fn edge_list_corpus() -> Vec<u8> {
    let mut text = String::from("# fuzz corpus\n% percent comments too\n\n");
    let mut rng = Rng::new(11);
    for i in 0..30u64 {
        let s = rng.below(50);
        let d = rng.below(50);
        match i % 3 {
            0 => text.push_str(&format!("{s} {d}\n")),
            1 => text.push_str(&format!("{s}\t{d}\n")),
            _ => text.push_str(&format!("  {s}   {d}  \n")),
        }
    }
    text.into_bytes()
}

fn update_log_corpus() -> Vec<u8> {
    let mut text = String::from("# update-log fuzz corpus\n");
    let mut rng = Rng::new(13);
    for batch in 0..6u64 {
        for _ in 0..4 {
            let u = rng.below(40);
            let v = rng.below(40);
            match rng.below(4) {
                0 => text.push_str(&format!("a {u} {v}\n")),
                1 => text.push_str(&format!("d {u} {v}\n")),
                2 => text.push_str(&format!("av {}\n", 100 + batch)),
                _ => text.push_str(&format!("dv {u}\n")),
            }
        }
        text.push_str("commit\n");
    }
    text.into_bytes()
}

#[test]
fn fuzz_edge_list_reader_never_panics() {
    let corpus = edge_list_corpus();
    for seed in 0..ITERS {
        let mut rng = Rng::new(seed);
        let input = mutate(&corpus, &mut rng);
        let mode = mode_for(seed);
        let result = catch_unwind(AssertUnwindSafe(|| {
            read_edge_list_named(std::io::Cursor::new(input.clone()), "<fuzz>", mode)
        }));
        let parsed = match result {
            Ok(r) => r,
            Err(_) => panic!("edge-list reader panicked (seed {seed}, mode {mode:?})"),
        };
        match parsed {
            Ok(g) => {
                // No phantom vertices: the CSR's id space covers every
                // edge endpoint it reports.
                let n = g.num_vertices() as u32;
                for (s, d) in g.edges() {
                    assert!(s < n && d < n, "edge ({s},{d}) outside 0..{n} (seed {seed})");
                }
            }
            Err(e) => {
                assert!(
                    mode == IngestMode::Strict,
                    "lenient ingest must skip, not fail (seed {seed}): {e:#}"
                );
            }
        }
    }
}

#[test]
fn fuzz_update_log_reader_never_panics() {
    let corpus = update_log_corpus();
    for seed in 0..ITERS {
        let mut rng = Rng::new(seed ^ 0x5EED_1062);
        let input = mutate(&corpus, &mut rng);
        let mode = mode_for(seed);
        let result = catch_unwind(AssertUnwindSafe(|| {
            read_update_log_named(std::io::Cursor::new(input.clone()), 64, "<fuzz>", mode)
        }));
        let parsed = match result {
            Ok(r) => r,
            Err(_) => panic!("update-log reader panicked (seed {seed}, mode {mode:?})"),
        };
        if let Err(e) = parsed {
            assert!(
                mode == IngestMode::Strict,
                "lenient ingest must skip, not fail (seed {seed}): {e:#}"
            );
        }
    }
}

#[test]
fn fuzz_binary_graph_loader_never_panics() {
    // Clean corpus: a real RVLB file's bytes.
    let g = revolver::graph::gen::generate_dataset(
        revolver::graph::gen::Dataset::from_name("so").unwrap(),
        128,
        7,
    )
    .unwrap();
    let dir = std::env::temp_dir().join("revolver_fuzz_rvlb");
    std::fs::create_dir_all(&dir).unwrap();
    let clean_path = dir.join("clean.bin");
    save_binary(&g, &clean_path).unwrap();
    let corpus = std::fs::read(&clean_path).unwrap();
    let path = dir.join("mutant.bin");

    for seed in 0..ITERS {
        let mut rng = Rng::new(seed ^ 0xB1AB_10AD);
        let input = mutate(&corpus, &mut rng);
        std::fs::write(&path, &input).unwrap();
        let result =
            catch_unwind(AssertUnwindSafe(|| revolver::graph::io::load_binary(&path)));
        let parsed = match result {
            Ok(r) => r,
            Err(_) => panic!("binary loader panicked (seed {seed})"),
        };
        if let Ok(g) = parsed {
            let n = g.num_vertices() as u32;
            for (s, d) in g.edges() {
                assert!(s < n && d < n, "edge ({s},{d}) outside 0..{n} (seed {seed})");
            }
        }
    }
}

#[test]
fn fuzz_checkpoint_decoder_never_panics() {
    use revolver::fault::checkpoint::{decode, encode};
    use revolver::fault::{LaSlab, Snapshot};

    // Two clean corpora: one per LA slab format (plus one slab-free).
    let base = |la: Option<LaSlab>| Snapshot {
        seed: 42,
        step: 17,
        epoch: 3,
        k: 4,
        labels: (0..96u32).map(|v| v % 4).collect(),
        loads: vec![11, 7, 5, 3],
        la,
    };
    let corpora: Vec<Vec<u8>> = vec![
        encode(&base(None)),
        encode(&base(Some(LaSlab::F32 { cols: 4, data: vec![0.25; 96 * 4] }))),
        encode(&base(Some(LaSlab::Q16 { cols: 4, data: vec![16384; 96 * 4] }))),
    ];

    for seed in 0..ITERS {
        let corpus = &corpora[(seed % corpora.len() as u64) as usize];
        let mut rng = Rng::new(seed ^ 0xC4EC_4B01);
        let input = mutate(corpus, &mut rng);
        let result = catch_unwind(AssertUnwindSafe(|| decode(&input)));
        let parsed = match result {
            Ok(r) => r,
            Err(_) => panic!("checkpoint decoder panicked (seed {seed})"),
        };
        if let Ok(snap) = parsed {
            // A surviving decode must be internally consistent: the
            // trailing checksum makes silent corruption astronomically
            // unlikely, so anything that decodes looks like a snapshot.
            assert_eq!(snap.loads.len(), snap.k as usize, "seed {seed}");
            if let Some(la) = &snap.la {
                assert_eq!(la.rows(), snap.labels.len(), "seed {seed}");
            }
        }
    }
}
