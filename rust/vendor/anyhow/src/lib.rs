//! Minimal, API-compatible shim of the `anyhow` crate for fully offline
//! builds: the subset this repository uses (`Error`, `Result`,
//! `Context`, `anyhow!` / `bail!` / `ensure!`), nothing more.
//!
//! Semantics mirror upstream anyhow where it matters here:
//! * `{}` displays the outermost message only, `{:#}` the whole
//!   context chain joined by `": "`, and `{:?}` an outermost line plus a
//!   `Caused by:` list (what `fn main() -> anyhow::Result<()>` prints).
//! * `?` converts any `std::error::Error + Send + Sync + 'static`;
//!   converting walks `source()` so the cause chain is preserved.
//! * Like upstream, [`Error`] deliberately does **not** implement
//!   `std::error::Error` — that is what makes the blanket `From` and the
//!   `Context`-on-`Result<_, Error>` impls coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` with the usual default-parameter alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus the chain of
/// causes beneath it (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

mod ext {
    use super::Error;

    /// Anything that can absorb a context frame and become an [`Error`].
    /// Implemented for std errors and for [`Error`] itself (coherent
    /// because `Error` is not a `std::error::Error`).
    pub trait IntoContextError {
        fn ext_context<C: std::fmt::Display>(self, context: C) -> Error;
    }

    impl<E> IntoContextError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: std::fmt::Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl IntoContextError for Error {
        fn ext_context<C: std::fmt::Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: ext::IntoContextError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(,)?) => {
        $crate::Error::msg(format!($fmt))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: file missing");
    }

    #[test]
    fn debug_lists_causes() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("file missing"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        let v = Some(7u32);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "file missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(50).unwrap_err()), "x too big: 50");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
        assert_eq!(e.chain().count(), 2);
    }
}
