//! Real PJRT backend (requires the `xla` binding crate; compiled only
//! under the `xla` cargo feature — see the module docs in
//! [`super`]). Enabling the feature additionally requires adding the
//! `xla` crate to `[dependencies]`.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::manifest::{Manifest, ManifestEntry};

/// A compiled artifact plus its expected I/O shapes.
pub struct CompiledEntry {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT client wrapper holding compiled executables for one artifacts
/// directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and read `manifest.json` from `dir`.
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("load manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, dir, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile the artifact named `name`.
    pub fn compile(&self, name: &str) -> Result<CompiledEntry> {
        let entry = self
            .manifest
            .find(name)
            .with_context(|| {
                format!(
                    "artifact {name:?} not in manifest (available: {:?})",
                    self.manifest.names()
                )
            })?
            .clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        Ok(CompiledEntry { entry, exe })
    }
}

impl CompiledEntry {
    /// Execute with f32 tensor inputs (shapes per the manifest entry);
    /// returns the flattened f32 outputs, one `Vec` per output, in
    /// manifest order.
    ///
    /// The AOT pipeline lowers with `return_tuple=True`, so the result
    /// is always a tuple literal, even for single outputs.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == self.entry.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.entry.name,
            self.entry.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(self.entry.inputs.iter()) {
            let expect: usize = spec.shape.iter().product::<usize>().max(1);
            anyhow::ensure!(
                data.len() == expect,
                "{}: input {} expected {} elements ({:?}), got {}",
                self.entry.name,
                spec.name,
                expect,
                spec.shape,
                data.len()
            );
            let lit = if spec.shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.entry.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.entry.name,
            self.entry.outputs.len(),
            parts.len()
        );
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }
}

/// The engine Revolver's `--engine xla` path drives: batched normalized
/// LP scoring and batched weighted-LA updates through the compiled
/// artifacts (one `score_b{B}_k{k}` + one `la_update_b{B}_k{k}` pair).
pub struct XlaStepEngine {
    batch: usize,
    k: usize,
    score: CompiledEntry,
    la_update: CompiledEntry,
}

impl XlaStepEngine {
    /// Load the engine for a given (batch, k). `alpha`/`beta` must match
    /// the values baked at lowering time (checked against the manifest).
    pub fn load<P: AsRef<Path>>(
        dir: P,
        batch: usize,
        k: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<Self> {
        let rt = Runtime::open(dir)?;
        let m = rt.manifest();
        // f32->f64 widening tolerance: 0.1f32 as f64 != 0.1.
        anyhow::ensure!(
            (m.alpha - alpha as f64).abs() < 1e-6 && (m.beta - beta as f64).abs() < 1e-6,
            "artifacts were lowered with alpha={}, beta={}; config wants alpha={alpha}, beta={beta} — regenerate with `make artifacts`",
            m.alpha,
            m.beta
        );
        let score = rt.compile(&format!("score_b{batch}_k{k}"))?;
        let la_update = rt.compile(&format!("la_update_b{batch}_k{k}"))?;
        Ok(XlaStepEngine { batch, k, score, la_update })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Batched normalized LP scores: `hist` is (B·k), `wsum` (B),
    /// `loads` (k); returns (B·k) scores.
    pub fn score(
        &mut self,
        hist: &[f32],
        wsum: &[f32],
        loads: &[f32],
        capacity: f32,
    ) -> Result<Vec<f32>> {
        let cap = [capacity];
        let outs = self.score.run_f32(&[hist, wsum, loads, &cap])?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Batched signal construction + weighted-LA update: `probs` and
    /// `raw_w` are (B·k); returns the updated (B·k) probabilities.
    pub fn la_update(&mut self, probs: &[f32], raw_w: &[f32]) -> Result<Vec<f32>> {
        let outs = self.la_update.run_f32(&[probs, raw_w])?;
        Ok(outs.into_iter().next().unwrap())
    }
}
