//! `artifacts/manifest.json` reader — the contract between
//! `python/compile/aot.py` and the Rust runtime.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One input tensor spec of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub name: String,
    /// Dims; empty = scalar.
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One compiled-artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub batch: usize,
    pub k: usize,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub alpha: f64,
    pub beta: f64,
    pub batch: usize,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parse manifest.json")?;
        let get_num = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("manifest missing numeric {key:?}"))
        };
        let alpha = get_num("alpha")?;
        let beta = get_num("beta")?;
        let batch = get_num("batch")? as usize;

        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing entries[]")?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .context("entry missing name")?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .context("entry missing file")?
                .to_string();
            let batch = e.get("batch").and_then(Json::as_usize).context("entry batch")?;
            let k = e.get("k").and_then(Json::as_usize).context("entry k")?;

            let mut inputs = Vec::new();
            for i in e.get("inputs").and_then(Json::as_arr).context("entry inputs")? {
                inputs.push(InputSpec {
                    name: i
                        .get("name")
                        .and_then(Json::as_str)
                        .context("input name")?
                        .to_string(),
                    shape: i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("input shape")?
                        .iter()
                        .map(|d| d.as_usize().context("shape dim"))
                        .collect::<Result<_>>()?,
                    dtype: i
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("f32")
                        .to_string(),
                });
            }
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .context("entry outputs")?
                .iter()
                .map(|o| Ok(o.as_str().context("output name")?.to_string()))
                .collect::<Result<_>>()?;

            entries.push(ManifestEntry { name, file, batch, k, inputs, outputs });
        }
        Ok(Manifest { alpha, beta, batch, entries })
    }

    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// All k values for which artifacts exist.
    pub fn available_k(&self) -> Vec<usize> {
        let mut ks: Vec<usize> = self.entries.iter().map(|e| e.k).collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "alpha": 1.0, "beta": 0.1, "batch": 256,
      "entries": [
        {"name": "score_b256_k8", "file": "score_b256_k8.hlo.txt",
         "batch": 256, "k": 8,
         "inputs": [
           {"name": "hist", "shape": [256, 8], "dtype": "f32"},
           {"name": "wsum", "shape": [256], "dtype": "f32"},
           {"name": "loads", "shape": [8], "dtype": "f32"},
           {"name": "capacity", "shape": [], "dtype": "f32"}],
         "outputs": ["scores"]},
        {"name": "la_update_b256_k8", "file": "la_update_b256_k8.hlo.txt",
         "batch": 256, "k": 8,
         "inputs": [
           {"name": "p", "shape": [256, 8], "dtype": "f32"},
           {"name": "raw_w", "shape": [256, 8], "dtype": "f32"}],
         "outputs": ["p_next"]}
      ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.alpha, 1.0);
        assert_eq!(m.batch, 256);
        assert_eq!(m.entries.len(), 2);
        let e = m.find("score_b256_k8").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[3].shape, Vec::<usize>::new());
        assert_eq!(e.outputs, vec!["scores".to_string()]);
        assert_eq!(m.available_k(), vec![8]);
    }

    #[test]
    fn find_missing_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("nope").is_none());
        assert_eq!(m.names().len(), 2);
    }

    #[test]
    fn real_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must parse.
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(!m.entries.is_empty());
            for e in &m.entries {
                assert!(p.parent().unwrap().join(&e.file).exists(), "{} missing", e.file);
            }
        }
    }

    #[test]
    fn garbage_rejected() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
