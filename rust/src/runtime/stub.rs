//! Default backend when the crate is built without the `xla` feature:
//! same public surface as the real backend (`runtime/pjrt.rs`), but
//! every entry point errors with a pointer at the feature flag.
//! `Runtime::open` still *reads* the manifest first, so a missing
//! artifacts directory reports the same manifest error as the real
//! backend before the unavailability error takes over.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, ManifestEntry};

const UNAVAILABLE: &str = "PJRT backend unavailable: revolver was built without the `xla` \
     cargo feature (the offline crate set does not ship the `xla` binding crate); \
     use `--engine native`, or rebuild with `--features xla` in an environment that has it";

/// A compiled artifact plus its expected I/O shapes (stub: never
/// constructed — compilation always fails first).
pub struct CompiledEntry {
    pub entry: ManifestEntry,
}

/// Manifest-only stand-in for the PJRT client wrapper.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Read `manifest.json` from `dir`, then report the backend as
    /// unavailable (keeping the same error texture as the real
    /// backend's open path: missing manifest ⇒ manifest error).
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("load manifest from {dir:?} (run `make artifacts`)"))?;
        let _ = Runtime { manifest };
        bail!(UNAVAILABLE)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (built without the `xla` feature)".to_string()
    }

    /// Load + compile the artifact named `name`.
    pub fn compile(&self, name: &str) -> Result<CompiledEntry> {
        let _ = name;
        bail!(UNAVAILABLE)
    }
}

impl CompiledEntry {
    /// Execute with f32 tensor inputs — always an error in the stub.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let _ = inputs;
        bail!(UNAVAILABLE)
    }
}

/// Stand-in for the batched scoring/LA-update engine behind
/// `--engine xla`.
pub struct XlaStepEngine {
    batch: usize,
    k: usize,
}

impl XlaStepEngine {
    pub fn load<P: AsRef<Path>>(
        dir: P,
        batch: usize,
        k: usize,
        _alpha: f32,
        _beta: f32,
    ) -> Result<Self> {
        // Surface the most actionable error: a missing manifest means
        // the artifacts were never built, which the caller must fix
        // first either way.
        Runtime::open(dir)?;
        let _ = XlaStepEngine { batch, k };
        bail!(UNAVAILABLE)
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Batched normalized LP scores — always an error in the stub.
    pub fn score(
        &mut self,
        _hist: &[f32],
        _wsum: &[f32],
        _loads: &[f32],
        _capacity: f32,
    ) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    /// Batched weighted-LA update — always an error in the stub.
    pub fn la_update(&mut self, _probs: &[f32], _raw_w: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }
}
