//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at request time: `make artifacts` lowers the L2 JAX
//! computation (which embeds the L1 Pallas kernels) to **HLO text**
//! once; this module parses the text (`HloModuleProto::from_text_file`),
//! compiles it on the PJRT CPU client, and exposes typed entry points.
//!
//! HLO *text* is the interchange format because jax ≥ 0.5 serializes
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see DESIGN.md and
//! /opt/xla-example/README.md).
//!
//! ## Backend gating
//!
//! The PJRT client comes from the `xla` binding crate, which the
//! offline vendored crate set does not ship. The real backend
//! ([`self::pjrt`]) is therefore compiled only under the `xla` cargo
//! feature (see Cargo.toml); the default build uses an API-compatible
//! stub ([`self::stub`]) whose entry points return a descriptive error,
//! so `--engine native` — the tier-1 path — is entirely unaffected and
//! `--engine xla` fails fast with an actionable message instead of a
//! link error.

pub mod manifest;

pub use manifest::{Manifest, ManifestEntry};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{CompiledEntry, Runtime, XlaStepEngine};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{CompiledEntry, Runtime, XlaStepEngine};

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need real artifacts live in
    // rust/tests/xla_parity.rs (integration) so `cargo test --lib`
    // stays artifact-free. Here: manifest-shape plumbing only.

    #[test]
    fn missing_dir_is_error() {
        match Runtime::open("/nonexistent/artifacts") {
            Ok(_) => panic!("expected error for missing artifacts dir"),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("manifest"), "{msg}");
            }
        }
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_engine_load_fails_with_hint() {
        match XlaStepEngine::load("/nonexistent/artifacts", 256, 8, 1.0, 0.1) {
            Ok(_) => panic!("stub backend must not load"),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("manifest") || msg.contains("xla"), "{msg}");
            }
        }
    }
}
