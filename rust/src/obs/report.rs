//! Post-hoc run report: `revolver report --obs-log run.jsonl`.
//!
//! Renders a self-contained text report from an `--obs-log` JSONL
//! stream (see [`super::events::EVENT_SPEC`]): the aggregated
//! migration flow matrix, per-partition trajectories, and a
//! convergence-attribution section (halt reason, oscillator count,
//! frontier decay). Stdlib-only — the input is parsed with
//! [`crate::util::json::Json`], the same parser that validates the
//! stream in-process.
//!
//! With `partial = true` the renderer accepts the prefix a killed run
//! left behind: a torn final line is dropped instead of rejected, and
//! a missing `run_end` is reported as the halt reason rather than an
//! error. Everything the report states is computed from the lines that
//! did land — the kill-safe sink contract (`obs::mod`) guarantees each
//! is complete and schema-valid.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// One partition's sampled series from `partition` events.
#[derive(Default, Clone)]
struct PartSeries {
    /// (step, load, boundary, local_frac), in stream order.
    samples: Vec<(u64, u64, u64, f64)>,
}

/// Everything the report needs, folded out of the event stream.
#[derive(Default)]
struct Digest {
    kind_counts: BTreeMap<String, usize>,
    /// (step, frontier, migrations) per `step` event.
    steps: Vec<(u64, u64, u64)>,
    /// (from, to) → (moves, mass), aggregated over all `flow` events.
    flow: BTreeMap<(usize, usize), (u64, u64)>,
    flow_k: usize,
    parts: BTreeMap<usize, PartSeries>,
    last_oscillating: Option<u64>,
    halt: Option<u64>,
    has_run_end: bool,
    torn_tail: bool,
}

fn num(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(Json::as_f64)
}

fn req(j: &Json, key: &str, lineno: usize, kind: &str) -> Result<f64, String> {
    num(j, key).ok_or_else(|| format!("line {lineno}: {kind} event missing {key:?}"))
}

fn digest(text: &str, partial: bool) -> Result<Digest, String> {
    let mut d = Digest::default();
    let nonempty: Vec<(usize, &str)> =
        text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty()).collect();
    let last = nonempty.len().saturating_sub(1);
    for (i, &(idx, line)) in nonempty.iter().enumerate() {
        let lineno = idx + 1;
        let j = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                if partial && i == last {
                    // The kill landed mid-line; every earlier line is
                    // complete by the sink's write_all-per-line contract.
                    d.torn_tail = true;
                    break;
                }
                return Err(format!("line {lineno}: {e}"));
            }
        };
        let kind = match j.get("ev").and_then(Json::as_str) {
            Some(k) => k.to_string(),
            None => return Err(format!("line {lineno}: missing \"ev\" tag")),
        };
        *d.kind_counts.entry(kind.clone()).or_insert(0) += 1;
        match kind.as_str() {
            "step" => {
                let step = req(&j, "step", lineno, "step")? as u64;
                let frontier = req(&j, "frontier", lineno, "step")? as u64;
                let migrations = req(&j, "migrations", lineno, "step")? as u64;
                d.steps.push((step, frontier, migrations));
            }
            "flow" => {
                let from = req(&j, "from", lineno, "flow")? as usize;
                let to = req(&j, "to", lineno, "flow")? as usize;
                let moves = req(&j, "moves", lineno, "flow")? as u64;
                let mass = req(&j, "mass", lineno, "flow")? as u64;
                let cell = d.flow.entry((from, to)).or_insert((0, 0));
                cell.0 += moves;
                cell.1 += mass;
                d.flow_k = d.flow_k.max(from + 1).max(to + 1);
            }
            "partition" => {
                let step = req(&j, "step", lineno, "partition")? as u64;
                let part = req(&j, "part", lineno, "partition")? as usize;
                let load = req(&j, "load", lineno, "partition")? as u64;
                let boundary = req(&j, "boundary", lineno, "partition")? as u64;
                let local_frac = req(&j, "local_frac", lineno, "partition")?;
                d.parts.entry(part).or_default().samples.push((step, load, boundary, local_frac));
            }
            "diag" => {
                d.last_oscillating = Some(req(&j, "oscillating", lineno, "diag")? as u64);
                if let Some(h) = num(&j, "halt") {
                    d.halt = Some(h as u64);
                }
            }
            "run_end" => d.has_run_end = true,
            _ => {}
        }
    }
    Ok(d)
}

fn halt_reason(d: &Digest, partial: bool) -> String {
    match d.halt {
        Some(1) => "converged (halting window)".to_string(),
        Some(2) => "converged (empty frontier)".to_string(),
        Some(3) => "step budget exhausted".to_string(),
        Some(4) => "worker panic (contained)".to_string(),
        Some(x) => format!("unknown halt code {x}"),
        None if !d.has_run_end && (partial || d.torn_tail) => {
            "run interrupted (partial log, no run_end)".to_string()
        }
        None => "not recorded (run without --diag)".to_string(),
    }
}

/// A proportional text bar, `width` columns at full scale.
fn bar(value: u64, max: u64, width: usize) -> String {
    let n = if max == 0 { 0 } else { ((value as f64 / max as f64) * width as f64).round() as usize };
    "#".repeat(n.min(width))
}

fn render_flow_section(out: &mut String, d: &Digest) {
    let _ = writeln!(out, "flow matrix (vertex moves, from -> to)");
    let _ = writeln!(out, "--------------------------------------");
    let k = d.flow_k;
    if k == 0 {
        let _ = writeln!(out, "no flow events (run without --diag, or no migrations)");
        let _ = writeln!(out);
        return;
    }
    let cell = |from: usize, to: usize| d.flow.get(&(from, to)).copied().unwrap_or((0, 0));
    let row_total = |from: usize| (0..k).map(|to| cell(from, to).0).sum::<u64>();
    let col_total = |to: usize| (0..k).map(|from| cell(from, to).0).sum::<u64>();
    let grand: u64 = (0..k).map(row_total).sum();
    let w = format!("{grand}").len().max(format!("to {}", k - 1).len()).max(5);
    let mut head = format!("{:>8}", "");
    for to in 0..k {
        let _ = write!(head, " {:>w$}", format!("to {to}"));
    }
    let _ = write!(head, " {:>w$}", "total");
    let _ = writeln!(out, "{head}");
    for from in 0..k {
        let mut row = format!("{:>8}", format!("from {from}"));
        for to in 0..k {
            let m = cell(from, to).0;
            let _ = write!(row, " {:>w$}", if m == 0 { "-".to_string() } else { m.to_string() });
        }
        let _ = write!(row, " {:>w$}", row_total(from));
        let _ = writeln!(out, "{row}");
    }
    let mut foot = format!("{:>8}", "total");
    for to in 0..k {
        let _ = write!(foot, " {:>w$}", col_total(to));
    }
    let _ = write!(foot, " {:>w$}", grand);
    let _ = writeln!(out, "{foot}");
    let churn: u64 = d.flow.iter().filter(|((f, t), _)| f != t).map(|(_, (m, _))| *m).sum();
    let _ = writeln!(out, "churn (off-diagonal moves): {churn}");
    // Net mass flow per partition: inflow - outflow; sums to zero.
    let mut net = String::from("net mass flow:");
    for p in 0..k {
        let inflow: i64 = (0..k).map(|from| cell(from, p).1 as i64).sum();
        let outflow: i64 = (0..k).map(|to| cell(p, to).1 as i64).sum();
        let _ = write!(net, " p{p} {:+}", inflow - outflow);
    }
    let _ = writeln!(out, "{net}");
    let _ = writeln!(out);
}

fn render_partition_section(out: &mut String, d: &Digest) {
    let _ = writeln!(out, "per-partition trajectories");
    let _ = writeln!(out, "--------------------------");
    if d.parts.is_empty() {
        let _ = writeln!(out, "no partition events (run without --diag)");
        let _ = writeln!(out);
        return;
    }
    let _ = writeln!(
        out,
        "{:>4} {:>21} {:>21} {:>23}",
        "part", "load first->last", "boundary first->last", "local_frac first->last"
    );
    for (p, series) in &d.parts {
        let first = series.samples.first().copied().unwrap_or_default();
        let last = series.samples.last().copied().unwrap_or_default();
        let _ = writeln!(
            out,
            "{:>4} {:>21} {:>21} {:>23}",
            p,
            format!("{} -> {}", first.1, last.1),
            format!("{} -> {}", first.2, last.2),
            format!("{:.3} -> {:.3}", first.3, last.3),
        );
    }
    let mut loads = String::from("final loads:");
    for series in d.parts.values() {
        let _ = write!(loads, " {}", series.samples.last().map_or(0, |s| s.1));
    }
    let _ = writeln!(out, "{loads}");
    let _ = writeln!(out);
}

fn render_convergence_section(out: &mut String, d: &Digest, partial: bool) {
    let _ = writeln!(out, "convergence");
    let _ = writeln!(out, "-----------");
    let _ = writeln!(out, "halt reason: {}", halt_reason(d, partial));
    let total_migrations: u64 = d.steps.iter().map(|&(_, _, m)| m).sum();
    let _ = writeln!(out, "total migrations: {total_migrations}");
    match d.last_oscillating {
        Some(n) => {
            let _ = writeln!(out, "oscillating vertices at halt: {n}");
        }
        None => {
            let _ = writeln!(out, "oscillating vertices at halt: not recorded");
        }
    }
    if !d.steps.is_empty() {
        let _ = writeln!(out, "frontier decay:");
        let max_frontier = d.steps.iter().map(|&(_, f, _)| f).max().unwrap_or(0);
        // At most 24 sampled rows, always including the final step.
        let n = d.steps.len();
        let stride = ((n + 23) / 24).max(1);
        let stepw = format!("{}", d.steps.last().unwrap().0).len().max(1);
        for (i, &(step, frontier, _)) in d.steps.iter().enumerate() {
            if i % stride != 0 && i + 1 != n {
                continue;
            }
            let _ = writeln!(
                out,
                "  step {step:>stepw$} |{:<30}| {frontier}",
                bar(frontier, max_frontier, 30)
            );
        }
    }
}

/// Render the full report. `partial` relaxes the parser for the prefix
/// a killed run leaves behind (torn final line, missing `run_end`).
pub fn render_report(text: &str, partial: bool) -> Result<String, String> {
    let d = digest(text, partial)?;
    let total: usize = d.kind_counts.values().sum();
    if total == 0 {
        return Err("no events in log".to_string());
    }
    let mut out = String::new();
    let _ = writeln!(out, "revolver run report");
    let _ = writeln!(out, "===================");
    let mut counts = String::new();
    for (kind, n) in &d.kind_counts {
        let _ = write!(counts, " {kind}={n}");
    }
    let _ = writeln!(out, "events: {total} total;{counts}");
    let src = match (partial, d.torn_tail) {
        (true, true) => "partial log (torn final line dropped)",
        (true, false) => "partial log (clean prefix)",
        _ => "complete log",
    };
    let _ = writeln!(out, "source: {src}");
    let _ = writeln!(out);
    render_flow_section(&mut out, &d);
    render_partition_section(&mut out, &d);
    render_convergence_section(&mut out, &d, partial);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::events::render;

    fn sample_log() -> String {
        let mut log = String::new();
        let mut push = |line: String| {
            log.push_str(&line);
            log.push('\n');
        };
        push(render("run_start", 0.0, &[]));
        push(render(
            "step",
            0.1,
            &[("step", 0.0), ("frontier", 6.0), ("evaluated", 6.0), ("migrations", 3.0)],
        ));
        push(render(
            "flow",
            0.1,
            &[("step", 0.0), ("from", 0.0), ("to", 1.0), ("moves", 2.0), ("mass", 20.0)],
        ));
        push(render(
            "flow",
            0.1,
            &[("step", 0.0), ("from", 1.0), ("to", 0.0), ("moves", 1.0), ("mass", 5.0)],
        ));
        push(render(
            "partition",
            0.1,
            &[
                ("step", 0.0),
                ("part", 0.0),
                ("load", 10.0),
                ("boundary", 4.0),
                ("local_frac", 0.5),
            ],
        ));
        push(render(
            "partition",
            0.1,
            &[
                ("step", 0.0),
                ("part", 1.0),
                ("load", 12.0),
                ("boundary", 4.0),
                ("local_frac", 0.6),
            ],
        ));
        push(render("diag", 0.1, &[("step", 0.0), ("oscillating", 1.0)]));
        push(render(
            "step",
            0.2,
            &[("step", 1.0), ("frontier", 2.0), ("evaluated", 2.0), ("migrations", 1.0)],
        ));
        push(render(
            "flow",
            0.2,
            &[("step", 1.0), ("from", 0.0), ("to", 1.0), ("moves", 1.0), ("mass", 10.0)],
        ));
        push(render(
            "partition",
            0.2,
            &[
                ("step", 1.0),
                ("part", 0.0),
                ("load", 8.0),
                ("boundary", 2.0),
                ("local_frac", 0.7),
            ],
        ));
        push(render(
            "partition",
            0.2,
            &[
                ("step", 1.0),
                ("part", 1.0),
                ("load", 14.0),
                ("boundary", 2.0),
                ("local_frac", 0.8),
            ],
        ));
        push(render("diag", 0.2, &[("step", 1.0), ("oscillating", 0.0), ("halt", 1.0)]));
        push(render("run_end", 0.3, &[("wall_s", 0.3)]));
        log
    }

    #[test]
    fn renders_all_sections_from_a_complete_log() {
        let report = render_report(&sample_log(), false).unwrap();
        assert!(report.contains("flow matrix"), "{report}");
        assert!(report.contains("per-partition trajectories"), "{report}");
        assert!(report.contains("halt reason: converged (halting window)"), "{report}");
        assert!(report.contains("total migrations: 4"), "{report}");
        assert!(report.contains("oscillating vertices at halt: 0"), "{report}");
        assert!(report.contains("final loads: 8 14"), "{report}");
        assert!(report.contains("churn (off-diagonal moves): 4"), "{report}");
        // Net mass flow: p0 out 30 in 5 -> -25; p1 +25; sums to zero.
        assert!(report.contains("net mass flow: p0 -25 p1 +25"), "{report}");
        assert!(report.contains("frontier decay:"), "{report}");
        assert!(report.contains("source: complete log"), "{report}");
    }

    #[test]
    fn partial_tolerates_a_torn_tail_and_attributes_the_kill() {
        let log = sample_log();
        // Cut mid-way through the final diag/run_end lines: keep a clean
        // prefix plus a torn last line.
        let keep = log.lines().take(8).collect::<Vec<_>>().join("\n");
        let torn = format!("{keep}\n{{\"ev\":\"flow\",\"t_s\":0.2,\"from\":0,");
        let report = render_report(&torn, true).unwrap();
        assert!(report.contains("source: partial log (torn final line dropped)"), "{report}");
        assert!(report.contains("halt reason: run interrupted (partial log"), "{report}");
        // The same torn input is an error without --partial.
        assert!(render_report(&torn, false).is_err());
    }

    #[test]
    fn clean_prefix_without_run_end_is_interrupted_too() {
        let log = sample_log();
        let keep = log.lines().take(7).collect::<Vec<_>>().join("\n");
        let report = render_report(&keep, true).unwrap();
        assert!(report.contains("source: partial log (clean prefix)"), "{report}");
        assert!(report.contains("halt reason: run interrupted"), "{report}");
        assert!(report.contains("oscillating vertices at halt: 1"), "{report}");
    }

    #[test]
    fn diagless_log_reports_missing_probes_not_errors() {
        let mut log = String::new();
        log.push_str(&render("run_start", 0.0, &[]));
        log.push('\n');
        log.push_str(&render(
            "step",
            0.1,
            &[("step", 0.0), ("frontier", 5.0), ("evaluated", 5.0), ("migrations", 2.0)],
        ));
        log.push('\n');
        log.push_str(&render("run_end", 0.2, &[("wall_s", 0.2)]));
        log.push('\n');
        let report = render_report(&log, false).unwrap();
        assert!(report.contains("no flow events"), "{report}");
        assert!(report.contains("no partition events"), "{report}");
        assert!(report.contains("halt reason: not recorded (run without --diag)"), "{report}");
        assert!(report.contains("total migrations: 2"), "{report}");
    }

    #[test]
    fn empty_and_garbage_inputs_are_errors() {
        assert!(render_report("", false).is_err());
        assert!(render_report("", true).is_err());
        assert!(render_report("not json\n", false).is_err());
        // A single torn line with nothing before it: tolerated shape-wise
        // but there are no events to report on.
        assert!(render_report("{\"ev\":", true).is_err());
    }
}
