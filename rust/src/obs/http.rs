//! Live telemetry endpoints over [`super::httpd`]: the `--metrics-addr`
//! server.
//!
//! | Endpoint | Payload |
//! |---|---|
//! | `/metrics` | live Prometheus snapshot ([`RunRecorder::prometheus`]) |
//! | `/healthz` | JSON liveness + current phase/step/epoch ([`super::Progress`]) |
//! | `/profile` | live `--profile` tree ([`RunRecorder::profile_report`]) |
//! | `/events?since=N` | long-poll tail of the event ring buffer |
//! | `/state` | JSON learning-dynamics snapshot (`--diag`; [`super::diag::DiagStore`]) |
//!
//! Scrapes read the same lock-or-atomic snapshots the exit-time
//! renderers use, so scrape-while-record needs no extra coordination
//! beyond what `RunRecorder` already provides; the hot path is
//! untouched (zero-overhead-off contract, pinned by `tests/obs.rs`).
//!
//! `/events` replies immediately when lines at or after `since` exist,
//! otherwise parks up to [`LONG_POLL_MAX`] on the recorder's event
//! condvar. The reply carries `X-Events-Start` (sequence number of the
//! first returned line — larger than requested when the bounded ring
//! already evicted older lines) and `X-Events-Next` (pass it back as
//! the next `since`).

use std::io;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::httpd::{self, Handler, Request, Response};
use crate::obs::RunRecorder;
use crate::util::json::Json;

/// Connection budget for the telemetry server: scrapers are few; a
/// small budget keeps a curl-happy operator from spawning unbounded
/// threads inside a partitioning run.
pub const DEFAULT_MAX_CONNS: usize = 8;

/// Upper bound on one `/events` long-poll before replying empty.
pub const LONG_POLL_MAX: Duration = Duration::from_secs(10);

/// Condvar wait slice inside a long-poll (bounds stop-flag latency).
const LONG_POLL_WAIT: Duration = Duration::from_millis(250);

/// The running telemetry server; owns the listener thread for the
/// lifetime of a run. Dropping it shuts it down (and wakes parked
/// long-polls via the shared stop flag).
pub struct MetricsServer {
    server: httpd::Server,
}

impl MetricsServer {
    /// Bind `addr` (`HOST:PORT`, port 0 allowed) and serve `rec` live.
    pub fn start(addr: &str, rec: Arc<RunRecorder>) -> io::Result<MetricsServer> {
        let stop = Arc::new(AtomicBool::new(false));
        let handler: Handler = {
            let stop = stop.clone();
            Arc::new(move |req: &Request| route(req, &rec, &stop))
        };
        let server = httpd::Server::bind(addr, DEFAULT_MAX_CONNS, stop, handler)?;
        Ok(MetricsServer { server })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.server.local_addr()
    }

    /// Stop accepting, wake long-polls, drain in-flight connections.
    pub fn shutdown(&mut self) {
        self.server.shutdown();
    }
}

fn route(req: &Request, rec: &RunRecorder, stop: &AtomicBool) -> Response {
    match req.path.as_str() {
        "/metrics" => {
            Response::new(200, "text/plain; version=0.0.4; charset=utf-8", rec.prometheus())
        }
        "/healthz" => healthz(rec),
        "/profile" => Response::text(200, rec.profile_report()),
        "/events" => events(req, rec, stop),
        "/state" => state(rec),
        _ => Response::not_found(),
    }
}

fn healthz(rec: &RunRecorder) -> Response {
    let p = crate::obs::progress().snapshot();
    let mut m = std::collections::BTreeMap::new();
    m.insert("ok".to_string(), Json::Bool(true));
    m.insert("uptime_s".to_string(), Json::Num(rec.elapsed_s()));
    m.insert("phase".to_string(), Json::Str(p.phase.to_string()));
    m.insert("step".to_string(), Json::Num(p.step as f64));
    m.insert("epoch".to_string(), Json::Num(p.epoch as f64));
    m.insert("events".to_string(), Json::Num(rec.events_end() as f64));
    Response::json(200, Json::Obj(m).to_string())
}

/// `/state`: the learning-dynamics observatory snapshot as nested JSON
/// (the flat number/string constraint applies to event *lines*, not
/// here). Serves zeroed fields until a `--diag` run reports in.
fn state(rec: &RunRecorder) -> Response {
    let d = rec.diag().snapshot();
    let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
    let arr_u64 = |v: &[u64]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
    let mut m = std::collections::BTreeMap::new();
    m.insert("step".to_string(), Json::Num(d.step as f64));
    m.insert("k".to_string(), Json::Num(d.k as f64));
    m.insert("flow_moves".to_string(), arr_u64(&d.flow_moves));
    m.insert("flow_mass".to_string(), arr_u64(&d.flow_mass));
    m.insert(
        "partitions".to_string(),
        Json::Arr(
            d.partitions
                .iter()
                .map(|s| {
                    let mut p = std::collections::BTreeMap::new();
                    p.insert("load".to_string(), Json::Num(s.load as f64));
                    p.insert("boundary".to_string(), Json::Num(s.boundary as f64));
                    p.insert("local_frac".to_string(), Json::Num(s.local_frac));
                    Json::Obj(p)
                })
                .collect(),
        ),
    );
    m.insert("oscillating".to_string(), Json::Num(d.oscillating as f64));
    m.insert("maxp_mean".to_string(), num(d.maxp_mean));
    m.insert("entropy_mean".to_string(), num(d.entropy_mean));
    Response::json(200, Json::Obj(m).to_string())
}

fn events(req: &Request, rec: &RunRecorder, stop: &AtomicBool) -> Response {
    let since: u64 = match req.query.get("since") {
        None => 0,
        Some(s) => match s.parse() {
            Ok(n) => n,
            Err(_) => return Response::text(400, "since must be a non-negative integer\n"),
        },
    };
    // A cursor past the ring's end can never be satisfied by any line
    // that existed at request time, and a client holding one has
    // skipped ahead of the stream (a stale cursor from a previous run,
    // say) — reply empty immediately with the real resume cursor
    // (`X-Events-Next == end`) instead of parking the full long-poll.
    // `since == end` is the normal tail position and still parks.
    let horizon = rec.events_end();
    let deadline = Instant::now() + LONG_POLL_MAX;
    loop {
        let (start, lines, next) = rec.events_since(since);
        if since > horizon
            || !lines.is_empty()
            || stop.load(Ordering::SeqCst)
            || Instant::now() >= deadline
        {
            let mut body = lines.join("\n");
            if !body.is_empty() {
                body.push('\n');
            }
            return Response::new(200, "application/x-ndjson", body)
                .header("X-Events-Start", start.to_string())
                .header("X-Events-Next", next.to_string());
        }
        rec.wait_events(since, LONG_POLL_WAIT);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Recorder as _;
    use std::thread;

    const T: Duration = Duration::from_secs(5);

    fn populated() -> Arc<RunRecorder> {
        let rec = Arc::new(RunRecorder::new());
        rec.counter_add("engine_steps", 7);
        rec.gauge_set("engine_mean_score", 0.5);
        rec.observe("engine_frontier_size", 64);
        rec.span_observe("engine", 2_000_000);
        rec.event("run_start", &[]);
        rec
    }

    fn body_str(resp: (u16, Vec<(String, String)>, Vec<u8>)) -> (u16, String) {
        (resp.0, String::from_utf8(resp.2).unwrap())
    }

    #[test]
    fn serves_metrics_profile_and_healthz() {
        let rec = populated();
        let srv = MetricsServer::start("127.0.0.1:0", rec.clone()).unwrap();
        let addr = srv.local_addr();

        let (status, prom) = body_str(httpd::get(addr, "/metrics", T).unwrap());
        assert_eq!(status, 200);
        // A scrape is exactly the in-process snapshot, rendered once.
        assert_eq!(prom, rec.prometheus());
        assert!(prom.contains("engine_steps 7"), "{prom}");

        let (status, tree) = body_str(httpd::get(addr, "/profile", T).unwrap());
        assert_eq!(status, 200);
        assert!(tree.contains("top-level spans:"), "{tree}");

        let (status, health) = body_str(httpd::get(addr, "/healthz", T).unwrap());
        assert_eq!(status, 200);
        let j = Json::parse(&health).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert!(j.get("uptime_s").and_then(Json::as_f64).unwrap() >= 0.0);
        assert!(j.get("phase").and_then(Json::as_str).is_some(), "{health}");
        assert_eq!(j.get("events").and_then(Json::as_f64), Some(1.0));

        let (status, _) = body_str(httpd::get(addr, "/nope", T).unwrap());
        assert_eq!(status, 404);
    }

    #[test]
    fn events_tail_returns_lines_and_cursors() {
        let rec = populated();
        rec.event("run_end", &[("wall_s", 0.5)]);
        let srv = MetricsServer::start("127.0.0.1:0", rec.clone()).unwrap();
        let (status, headers, body) = httpd::get(srv.local_addr(), "/events?since=0", T).unwrap();
        assert_eq!(status, 200);
        let text = String::from_utf8(body).unwrap();
        assert_eq!(text.lines().count(), 2, "{text}");
        crate::obs::events::validate_events(&text).expect("tail must be schema-valid");
        let hdr = |k: &str| headers.iter().find(|(n, _)| n == k).map(|(_, v)| v.clone());
        assert_eq!(hdr("X-Events-Start").as_deref(), Some("0"));
        assert_eq!(hdr("X-Events-Next").as_deref(), Some("2"));

        // Tail from the cursor: only lines at or after it come back.
        let (_, headers, body) = httpd::get(srv.local_addr(), "/events?since=1", T).unwrap();
        let text = String::from_utf8(body).unwrap();
        assert_eq!(text.lines().count(), 1, "{text}");
        assert!(text.contains("run_end"), "{text}");
        assert_eq!(
            headers.iter().find(|(n, _)| n == "X-Events-Next").map(|(_, v)| v.as_str()),
            Some("2")
        );
    }

    #[test]
    fn events_long_poll_wakes_on_new_event() {
        let rec = Arc::new(RunRecorder::new());
        let srv = MetricsServer::start("127.0.0.1:0", rec.clone()).unwrap();
        let addr = srv.local_addr();
        let poll = thread::spawn(move || body_str(httpd::get(addr, "/events?since=0", T).unwrap()));
        thread::sleep(Duration::from_millis(100));
        rec.event("run_start", &[]);
        let (status, text) = poll.join().unwrap();
        assert_eq!(status, 200);
        assert!(text.contains("run_start"), "long-poll must deliver the new event: {text}");
    }

    #[test]
    fn events_rejects_malformed_cursor() {
        let rec = Arc::new(RunRecorder::new());
        let srv = MetricsServer::start("127.0.0.1:0", rec).unwrap();
        let (status, _) = body_str(httpd::get(srv.local_addr(), "/events?since=x", T).unwrap());
        assert_eq!(status, 400);
    }

    /// Regression: a cursor past the ring's end must reply empty
    /// immediately with `X-Events-Next == end`, not park the full
    /// 10 s long-poll (the pre-fix behaviour).
    #[test]
    fn events_cursor_past_end_replies_empty_immediately() {
        let rec = populated(); // one event -> end == 1
        let srv = MetricsServer::start("127.0.0.1:0", rec).unwrap();
        let t0 = Instant::now();
        let (status, headers, body) =
            httpd::get(srv.local_addr(), "/events?since=101", T).unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(status, 200);
        assert!(body.is_empty(), "{:?}", String::from_utf8_lossy(&body));
        let hdr = |k: &str| headers.iter().find(|(n, _)| n == k).map(|(_, v)| v.clone());
        assert_eq!(hdr("X-Events-Start").as_deref(), Some("1"));
        assert_eq!(hdr("X-Events-Next").as_deref(), Some("1"));
        assert!(
            elapsed < LONG_POLL_MAX / 2,
            "past-end cursor must not long-poll: took {elapsed:?}"
        );
    }

    /// `/healthz` with no run active: a stable idle phase with step 0 /
    /// epoch 0 — never a torn or stale pair.
    #[test]
    fn healthz_idle_reports_idle_phase() {
        crate::obs::progress().reset();
        let rec = Arc::new(RunRecorder::new());
        let srv = MetricsServer::start("127.0.0.1:0", rec).unwrap();
        let (status, health) = body_str(httpd::get(srv.local_addr(), "/healthz", T).unwrap());
        assert_eq!(status, 200);
        let j = Json::parse(&health).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("phase").and_then(Json::as_str), Some("idle"));
        assert_eq!(j.get("step").and_then(Json::as_f64), Some(0.0));
        assert_eq!(j.get("epoch").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn state_serves_diag_snapshot() {
        let rec = Arc::new(RunRecorder::new());
        rec.diag_update(&crate::obs::diag::DiagUpdate {
            step: 3,
            k: 2,
            flow_moves: Some(vec![0, 5, 2, 0]),
            flow_mass: Some(vec![0, 50, 20, 0]),
            partitions: Some(vec![
                crate::obs::diag::PartSample { load: 10, boundary: 2, local_frac: 0.8 },
                crate::obs::diag::PartSample { load: 12, boundary: 3, local_frac: 0.75 },
            ]),
            oscillating: Some(4),
            maxp_mean: Some(0.9),
            entropy_mean: Some(0.2),
        });
        let srv = MetricsServer::start("127.0.0.1:0", rec).unwrap();
        let (status, text) = body_str(httpd::get(srv.local_addr(), "/state", T).unwrap());
        assert_eq!(status, 200);
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("step").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("k").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("oscillating").and_then(Json::as_f64), Some(4.0));
        assert_eq!(j.get("maxp_mean").and_then(Json::as_f64), Some(0.9));
        match j.get("flow_moves") {
            Some(Json::Arr(v)) => assert_eq!(v.len(), 4, "{text}"),
            other => panic!("flow_moves not an array: {other:?}"),
        }
        match j.get("partitions") {
            Some(Json::Arr(v)) => {
                assert_eq!(v.len(), 2, "{text}");
                assert_eq!(v[1].get("load").and_then(Json::as_f64), Some(12.0));
            }
            other => panic!("partitions not an array: {other:?}"),
        }
    }
}
