//! Leveled progress logging for the CLI (`--verbosity`).
//!
//! This replaces ad-hoc `eprintln!` progress lines: `info` is the
//! default chat (what the subcommands printed before), `debug` adds
//! detail, `quiet` silences both so long scripted runs produce only
//! their primary stdout output. Hard errors never route through here —
//! they stay on the `main` error path regardless of level.

use std::sync::atomic::{AtomicU8, Ordering};

/// Progress verbosity, ordered: `Quiet < Info < Debug`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Quiet = 0,
    Info = 1,
    Debug = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        1 => Level::Info,
        _ => Level::Debug,
    }
}

/// Progress line shown at `info` and above.
pub fn info(msg: &str) {
    if level() >= Level::Info {
        eprintln!("{msg}");
    }
}

/// Detail line shown only at `debug`.
pub fn debug(msg: &str) {
    if level() >= Level::Debug {
        eprintln!("{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        assert!(Level::Quiet < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    // set_level/level round-trips are exercised end-to-end by the CLI
    // tests (`--verbosity quiet` silences progress); mutating the
    // process-global level here would race other unit tests.
}
