//! Prometheus text exposition (`RunRecorder::prometheus` renders it on
//! demand; `obs::http` serves it live behind `GET /metrics`).
//!
//! Counters and gauges render as `name value`; histograms as
//! cumulative `_bucket{le="..."}` lines over the log2 bucket edges,
//! a terminal `+Inf` bucket, and the conventional `_sum`/`_count`
//! series; span stats as two labelled counter families,
//! `span_seconds_total{path="..."}` and `span_calls_total{path="..."}`
//! (paths are label *values* and go through [`escape_label`]).
//!
//! Histogram snapshots arrive self-consistent — `Registry` derives the
//! count from the bucket loads (see `registry::Histogram`) — so
//! `+Inf == _count == Σ buckets` holds even for a scrape racing the
//! run, which is exactly what scrapers validate.

use std::fmt::Write as _;

use crate::obs::registry::{bucket_upper, HistogramSnapshot};
use crate::obs::span::SpanStat;

/// Escape a string for use inside a Prometheus label value:
/// backslash, double quote, and newline must be backslash-escaped.
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Render one snapshot in Prometheus text format. Inputs come sorted
/// (registry snapshots iterate `BTreeMap`s), so output order is
/// deterministic.
pub fn render(
    counters: &[(String, u64)],
    gauges: &[(String, f64)],
    histograms: &[(String, HistogramSnapshot)],
    spans: &[(String, SpanStat)],
) -> String {
    let mut out = String::new();
    for (name, v) in counters {
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    }
    for (name, v) in gauges {
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
    }
    for (name, h) in histograms {
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        let top = h.buckets.iter().rposition(|&c| c > 0).unwrap_or(0);
        for (i, &c) in h.buckets.iter().enumerate().take(top + 1) {
            cum += c;
            let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", bucket_upper(i));
        }
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
        let _ = writeln!(out, "{name}_sum {}", h.sum);
        let _ = writeln!(out, "{name}_count {}", h.count);
    }
    if !spans.is_empty() {
        let _ = writeln!(out, "# TYPE span_seconds_total counter");
        for (path, s) in spans {
            let _ = writeln!(
                out,
                "span_seconds_total{{path=\"{}\"}} {}",
                escape_label(path),
                s.total_ns as f64 / 1e9
            );
        }
        let _ = writeln!(out, "# TYPE span_calls_total counter");
        for (path, s) in spans {
            let _ =
                writeln!(out, "span_calls_total{{path=\"{}\"}} {}", escape_label(path), s.count);
        }
    }
    out
}

/// Render the learning-dynamics observatory snapshot (`--diag`) as
/// labelled Prometheus families: the accumulated migration flow matrix
/// as a counter family (`from`/`to` labels, nonzero cells only) and
/// the latest per-partition sample as three gauge families (`part`
/// label). Empty (no diag data yet) renders as the empty string so
/// `/metrics` is unchanged when the observatory is off.
pub fn render_diag(d: &crate::obs::diag::DiagSnapshot) -> String {
    let mut out = String::new();
    let k = d.k;
    if k == 0 {
        return out;
    }
    if d.flow_moves.iter().any(|&m| m != 0) {
        let _ = writeln!(out, "# TYPE engine_flow_moves_total counter");
        for from in 0..k {
            for to in 0..k {
                let m = d.flow_moves[from * k + to];
                if m != 0 {
                    let _ =
                        writeln!(out, "engine_flow_moves_total{{from=\"{from}\",to=\"{to}\"}} {m}");
                }
            }
        }
        let _ = writeln!(out, "# TYPE engine_flow_mass_total counter");
        for from in 0..k {
            for to in 0..k {
                let m = d.flow_mass[from * k + to];
                if m != 0 {
                    let _ =
                        writeln!(out, "engine_flow_mass_total{{from=\"{from}\",to=\"{to}\"}} {m}");
                }
            }
        }
    }
    if !d.partitions.is_empty() {
        let _ = writeln!(out, "# TYPE partition_load gauge");
        for (p, s) in d.partitions.iter().enumerate() {
            let _ = writeln!(out, "partition_load{{part=\"{p}\"}} {}", s.load);
        }
        let _ = writeln!(out, "# TYPE partition_boundary_vertices gauge");
        for (p, s) in d.partitions.iter().enumerate() {
            let _ = writeln!(out, "partition_boundary_vertices{{part=\"{p}\"}} {}", s.boundary);
        }
        let _ = writeln!(out, "# TYPE partition_local_edge_frac gauge");
        for (p, s) in d.partitions.iter().enumerate() {
            let _ = writeln!(out, "partition_local_edge_frac{{part=\"{p}\"}} {}", s.local_frac);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping_covers_the_three_specials() {
        assert_eq!(escape_label("plain/path"), "plain/path");
        assert_eq!(escape_label(r#"a"b"#), r#"a\"b"#);
        assert_eq!(escape_label(r"a\b"), r"a\\b");
        assert_eq!(escape_label("a\nb"), r"a\nb");
        // Escaping composes: a literal backslash-n stays distinguishable
        // from a newline.
        assert_eq!(escape_label("x\\ny"), "x\\\\ny");
    }

    #[test]
    fn renders_all_four_families() {
        let mut buckets = vec![0; crate::obs::registry::BUCKETS];
        buckets[1] = 2; // two samples of value 1
        buckets[2] = 1; // one sample in [2,3]
        let h = HistogramSnapshot { buckets, sum: 5, count: 3 };
        let text = render(
            &[("engine_steps".to_string(), 5)],
            &[("engine_mean_score".to_string(), 0.75)],
            &[("engine_frontier_size".to_string(), h)],
            &[(
                "engine/phase_a".to_string(),
                SpanStat { total_ns: 2_000_000_000, count: 4, max_ns: 1_000_000_000 },
            )],
        );
        assert!(text.contains("# TYPE engine_steps counter\nengine_steps 5\n"));
        assert!(text.contains("# TYPE engine_mean_score gauge\nengine_mean_score 0.75\n"));
        // Buckets are cumulative and stop at the last occupied edge.
        assert!(text.contains("engine_frontier_size_bucket{le=\"0\"} 0"));
        assert!(text.contains("engine_frontier_size_bucket{le=\"1\"} 2"));
        assert!(text.contains("engine_frontier_size_bucket{le=\"3\"} 3"));
        assert!(!text.contains("le=\"7\""));
        assert!(text.contains("engine_frontier_size_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("engine_frontier_size_sum 5"));
        assert!(text.contains("engine_frontier_size_count 3"));
        assert!(text.contains("span_seconds_total{path=\"engine/phase_a\"} 2"));
        assert!(text.contains("span_calls_total{path=\"engine/phase_a\"} 4"));
    }

    #[test]
    fn empty_snapshot_renders_empty() {
        assert_eq!(render(&[], &[], &[], &[]), "");
    }

    /// Every line of a small snapshot, checked by hand against the
    /// Prometheus text-format spec (TYPE line per family, cumulative
    /// buckets, terminal `+Inf` equal to `_count`).
    #[test]
    fn matches_a_hand_checked_exposition_snippet() {
        let mut buckets = vec![0; crate::obs::registry::BUCKETS];
        buckets[0] = 1; // one sample of value 0
        buckets[2] = 2; // two samples in [2,3]
        let h = HistogramSnapshot { buckets, sum: 5, count: 3 };
        let text = render(
            &[("engine_runs".to_string(), 1)],
            &[("engine_mean_score".to_string(), 0.5)],
            &[("engine_frontier_size".to_string(), h)],
            &[(
                "engine".to_string(),
                SpanStat { total_ns: 1_500_000_000, count: 2, max_ns: 1_000_000_000 },
            )],
        );
        let expected = "\
# TYPE engine_runs counter
engine_runs 1
# TYPE engine_mean_score gauge
engine_mean_score 0.5
# TYPE engine_frontier_size histogram
engine_frontier_size_bucket{le=\"0\"} 1
engine_frontier_size_bucket{le=\"1\"} 1
engine_frontier_size_bucket{le=\"3\"} 3
engine_frontier_size_bucket{le=\"+Inf\"} 3
engine_frontier_size_sum 5
engine_frontier_size_count 3
# TYPE span_seconds_total counter
span_seconds_total{path=\"engine\"} 1.5
# TYPE span_calls_total counter
span_calls_total{path=\"engine\"} 2
";
        assert_eq!(text, expected);
    }
}
