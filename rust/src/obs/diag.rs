//! Learning-dynamics diagnostics (the `--diag` observatory).
//!
//! The rest of `obs` watches the *machinery* (spans, counters, worker
//! time); this module watches the *learning dynamics* the paper's
//! claims are actually about — who exchanges vertices with whom, how
//! decided the LA rows are, and why a run stopped:
//!
//! * [`FlowMatrix`] — a k×k matrix of u64 atomics recording every
//!   [`StepCtx::migrate`](crate::engine::StepCtx::migrate) call as a
//!   `from → to` cell (move count + load mass). Workers add with
//!   relaxed `fetch_add` during phase B; the coordinator drains with
//!   swap-to-zero between W3 and the next W1, when every worker is
//!   parked — the same quiescence window the checkpointer uses — so
//!   per-step cells are exact, and row sums equal the programs'
//!   migration counters because both increment once per call.
//! * [`partition_samples`] — per-partition load / boundary-vertex /
//!   local-edge-fraction gauges, sampled at trace cadence.
//! * [`Decisiveness`] — aggregate max-probability and entropy over the
//!   LA rows of the step's frontier (computed by
//!   `VertexProgram::la_decisiveness`, coordinator-side, pre-W1).
//! * [`OscillationDetector`] — vertices whose label 2-cycles
//!   (`A → B → A`) across a 3-step sliding window, the classic
//!   thrashing signature of an undecided LA.
//! * [`worker_skew`] — max/mean of per-worker busy time, the one-number
//!   scheduling-imbalance gauge.
//! * [`DiagStore`] — the recorder-side cumulative snapshot behind the
//!   `/state` endpoint and the labelled Prometheus families.
//!
//! Everything here is gated twice: behind the process-global
//! [`enabled`](crate::obs::enabled) check *and* the `--diag` config
//! knob, so the default path (diag off) emits none of the new events
//! and the disabled path stays bit-identical (`tests/obs.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::graph::Graph;
use crate::Label;

/// k×k migration flow accumulator: cell `(from, to)` counts the
/// migrate calls (and their total load mass) that moved a vertex from
/// partition `from` to partition `to` since the last [`drain`].
///
/// Every [`StepCtx::migrate`](crate::engine::StepCtx::migrate) call is
/// recorded — including degenerate `from == to` calls — so `Σ cells`
/// equals the engine's `migrations` counter exactly (the programs
/// increment it once per call too).
///
/// [`drain`]: FlowMatrix::drain
pub struct FlowMatrix {
    k: usize,
    moves: Vec<AtomicU64>,
    mass: Vec<AtomicU64>,
}

impl FlowMatrix {
    pub fn new(k: usize) -> FlowMatrix {
        FlowMatrix {
            k,
            moves: (0..k * k).map(|_| AtomicU64::new(0)).collect(),
            mass: (0..k * k).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Record one migration `from → to` carrying `mass`. Relaxed adds:
    /// cells are independent monotone counters, merged only at the
    /// drain point where no writer is live.
    #[inline]
    pub fn record(&self, from: u32, to: u32, mass: u64) {
        let i = from as usize * self.k + to as usize;
        self.moves[i].fetch_add(1, Ordering::Relaxed);
        self.mass[i].fetch_add(mass, Ordering::Relaxed);
    }

    /// Take the accumulated `(moves, mass)` matrices, resetting every
    /// cell to zero. Must only be called while workers are quiescent
    /// (coordinator, between W3 and the next W1).
    pub fn drain(&self) -> (Vec<u64>, Vec<u64>) {
        let moves = self.moves.iter().map(|c| c.swap(0, Ordering::Relaxed)).collect();
        let mass = self.mass.iter().map(|c| c.swap(0, Ordering::Relaxed)).collect();
        (moves, mass)
    }
}

/// Total off-diagonal moves of a k×k cell matrix — the churn summary
/// (diagonal cells are denied/degenerate moves that changed nothing).
pub fn churn(moves: &[u64], k: usize) -> u64 {
    debug_assert_eq!(moves.len(), k * k);
    let mut total = 0u64;
    for from in 0..k {
        for to in 0..k {
            if from != to {
                total += moves[from * k + to];
            }
        }
    }
    total
}

/// Per-partition net mass flow (inflow − outflow) of a k×k mass
/// matrix: positive = the partition grew, negative = it shed load.
/// Sums to zero over all partitions.
pub fn net_flow(mass: &[u64], k: usize) -> Vec<i64> {
    debug_assert_eq!(mass.len(), k * k);
    let mut net = vec![0i64; k];
    for from in 0..k {
        for to in 0..k {
            if from != to {
                let m = mass[from * k + to] as i64;
                net[to] += m;
                net[from] -= m;
            }
        }
    }
    net
}

/// Aggregate LA decisiveness over a set of probability rows: how
/// peaked the per-vertex action distributions are. `maxp → 1` and
/// `entropy → 0` as the automata converge.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Decisiveness {
    /// Rows measured (the frontier size at the sampling step).
    pub rows: u64,
    /// Σ over rows of `max_a p(a)`.
    pub maxp_sum: f64,
    /// Σ over rows of `−Σ_a p(a) ln p(a)` (nats).
    pub entropy_sum: f64,
}

impl Decisiveness {
    /// Mean max-probability per row (NaN when no rows were measured —
    /// the event renderer drops non-finite fields).
    pub fn maxp_mean(&self) -> f64 {
        if self.rows == 0 {
            f64::NAN
        } else {
            self.maxp_sum / self.rows as f64
        }
    }

    /// Mean row entropy in nats (NaN when no rows were measured).
    pub fn entropy_mean(&self) -> f64 {
        if self.rows == 0 {
            f64::NAN
        } else {
            self.entropy_sum / self.rows as f64
        }
    }
}

/// One partition's health sample at a trace-cadence step.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PartSample {
    /// Partition load in [`Graph::load_mass`] units (Σ = |E| on plain
    /// graphs) — the same units the capacity gate enforces.
    pub load: u64,
    /// Vertices with at least one undirected neighbour in another
    /// partition (the communication surface).
    pub boundary: u64,
    /// Fraction of the partition's out-edges staying internal (1.0 for
    /// an empty partition — nothing is cut).
    pub local_frac: f64,
}

/// One O(|E|) pass producing every partition's [`PartSample`].
pub fn partition_samples(g: &Graph, labels: &[Label], k: usize) -> Vec<PartSample> {
    debug_assert_eq!(labels.len(), g.num_vertices());
    let mut out = vec![PartSample::default(); k];
    let mut out_edges = vec![0u64; k];
    let mut local = vec![0u64; k];
    for v in 0..g.num_vertices() {
        let l = labels[v] as usize;
        debug_assert!(l < k, "label {l} out of range {k}");
        out[l].load += g.load_mass(v as u32) as u64;
        for &u in g.out_neighbors(v as u32) {
            out_edges[l] += 1;
            if labels[u as usize] as usize == l {
                local[l] += 1;
            }
        }
        if g.neighbors(v as u32).iter().any(|&u| labels[u as usize] as usize != l) {
            out[l].boundary += 1;
        }
    }
    for l in 0..k {
        out[l].local_frac =
            if out_edges[l] > 0 { local[l] as f64 / out_edges[l] as f64 } else { 1.0 };
    }
    out
}

/// Scheduling imbalance: max/mean of per-worker busy times. 1.0 is a
/// perfectly balanced step; also 1.0 for degenerate inputs (no
/// workers, or an all-idle step where the ratio is meaningless).
pub fn worker_skew(busy: &[f64]) -> f64 {
    if busy.is_empty() {
        return 1.0;
    }
    let max = busy.iter().cloned().fold(0.0f64, f64::max);
    let mean = busy.iter().sum::<f64>() / busy.len() as f64;
    if mean > 0.0 {
        max / mean
    } else {
        1.0
    }
}

/// Label 2-cycle detector over a 3-observation sliding window: vertex
/// `v` oscillates at observation `t` when `label_t(v) == label_{t-2}(v)
/// != label_{t-1}(v)` — it went somewhere and came straight back, the
/// thrashing signature of an undecided LA row (or two vertices swapping
/// places across a cut edge forever).
#[derive(Default)]
pub struct OscillationDetector {
    prev: Vec<Label>,
    prev2: Vec<Label>,
    seen: u32,
}

impl OscillationDetector {
    pub fn new() -> OscillationDetector {
        OscillationDetector::default()
    }

    /// Feed one label snapshot; returns the number of vertices that
    /// 2-cycled at this observation (0 until the window is primed, and
    /// 0 when |V| changed — dynamic epochs grow the graph, making the
    /// window incomparable).
    pub fn observe(&mut self, labels: &[Label]) -> u64 {
        let count = if self.seen >= 2
            && self.prev.len() == labels.len()
            && self.prev2.len() == labels.len()
        {
            labels
                .iter()
                .zip(self.prev.iter())
                .zip(self.prev2.iter())
                .filter(|((cur, prev), prev2)| cur == prev2 && cur != prev)
                .count() as u64
        } else {
            0
        };
        // Slide the window, reusing the oldest buffer's allocation.
        std::mem::swap(&mut self.prev2, &mut self.prev);
        self.prev.clear();
        self.prev.extend_from_slice(labels);
        self.seen = self.seen.saturating_add(1);
        count
    }
}

/// One step's (or epoch's) diagnostics batch, handed to
/// [`Recorder::diag_update`](crate::obs::Recorder::diag_update).
/// `None` fields were not measured this step (e.g. partition samples
/// off trace cadence, decisiveness from a program without LA rows).
#[derive(Debug, Clone, Default)]
pub struct DiagUpdate {
    pub step: u64,
    pub k: usize,
    /// This step's k×k move-count cells (row-major `from * k + to`).
    pub flow_moves: Option<Vec<u64>>,
    /// This step's k×k load-mass cells.
    pub flow_mass: Option<Vec<u64>>,
    pub partitions: Option<Vec<PartSample>>,
    pub oscillating: Option<u64>,
    pub maxp_mean: Option<f64>,
    pub entropy_mean: Option<f64>,
}

/// Point-in-time copy of a [`DiagStore`]: cumulative flow matrices
/// plus the latest value of every sampled series.
#[derive(Debug, Clone, Default)]
pub struct DiagSnapshot {
    pub step: u64,
    pub k: usize,
    pub flow_moves: Vec<u64>,
    pub flow_mass: Vec<u64>,
    pub partitions: Vec<PartSample>,
    pub oscillating: u64,
    pub maxp_mean: f64,
    pub entropy_mean: f64,
}

/// Recorder-side diagnostics state: flow cells accumulate across
/// steps, everything else keeps its last sample. Mutex'd — updates
/// arrive once per step from the coordinator, reads are rare `/state`
/// and `/metrics` scrapes, so the lock is never on a hot path.
#[derive(Default)]
pub struct DiagStore {
    inner: Mutex<DiagSnapshot>,
}

impl DiagStore {
    /// Fold one update in. A `k` change (a new run on the same
    /// recorder, e.g. a sweep) resets the accumulated state.
    pub fn apply(&self, u: &DiagUpdate) {
        let mut s = self.inner.lock().unwrap();
        if s.k != u.k {
            *s = DiagSnapshot { k: u.k, ..DiagSnapshot::default() };
            s.maxp_mean = f64::NAN;
            s.entropy_mean = f64::NAN;
        }
        s.step = u.step;
        if let Some(m) = &u.flow_moves {
            if s.flow_moves.len() != m.len() {
                s.flow_moves = vec![0; m.len()];
            }
            for (acc, &v) in s.flow_moves.iter_mut().zip(m.iter()) {
                *acc += v;
            }
        }
        if let Some(m) = &u.flow_mass {
            if s.flow_mass.len() != m.len() {
                s.flow_mass = vec![0; m.len()];
            }
            for (acc, &v) in s.flow_mass.iter_mut().zip(m.iter()) {
                *acc += v;
            }
        }
        if let Some(p) = &u.partitions {
            s.partitions = p.clone();
        }
        if let Some(o) = u.oscillating {
            s.oscillating = o;
        }
        if let Some(m) = u.maxp_mean {
            s.maxp_mean = m;
        }
        if let Some(e) = u.entropy_mean {
            s.entropy_mean = e;
        }
    }

    pub fn snapshot(&self) -> DiagSnapshot {
        self.inner.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn flow_matrix_records_and_drains_exactly() {
        let fm = FlowMatrix::new(3);
        fm.record(0, 1, 5);
        fm.record(0, 1, 2);
        fm.record(2, 0, 1);
        fm.record(1, 1, 9); // degenerate from==to still counted
        let (moves, mass) = fm.drain();
        assert_eq!(moves[0 * 3 + 1], 2);
        assert_eq!(mass[0 * 3 + 1], 7);
        assert_eq!(moves[2 * 3], 1);
        assert_eq!(moves[1 * 3 + 1], 1);
        assert_eq!(moves.iter().sum::<u64>(), 4);
        // Drain resets: a second drain is all zeros.
        let (moves, mass) = fm.drain();
        assert!(moves.iter().all(|&m| m == 0) && mass.iter().all(|&m| m == 0));
    }

    #[test]
    fn churn_and_net_flow_summarize_the_matrix() {
        let k = 3;
        let mut moves = vec![0u64; k * k];
        moves[0 * k + 1] = 4; // 0 → 1
        moves[1 * k + 0] = 1; // 1 → 0
        moves[2 * k + 2] = 7; // diagonal: not churn
        assert_eq!(churn(&moves, k), 5);
        let net = net_flow(&moves, k);
        assert_eq!(net, vec![-3, 3, 0]);
        assert_eq!(net.iter().sum::<i64>(), 0);
    }

    #[test]
    fn worker_skew_is_max_over_mean() {
        assert_eq!(worker_skew(&[]), 1.0);
        assert_eq!(worker_skew(&[0.0, 0.0]), 1.0);
        assert_eq!(worker_skew(&[2.0, 2.0, 2.0]), 1.0);
        // max 6 / mean 3 = 2.
        assert!((worker_skew(&[6.0, 2.0, 1.0]) - 2.0).abs() < 1e-12);
        // One straggler among idlers: max 4 / mean 1 = 4.
        assert!((worker_skew(&[4.0, 0.0, 0.0, 0.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn oscillation_detector_counts_two_cycles_only() {
        let mut d = OscillationDetector::new();
        assert_eq!(d.observe(&[0, 1, 2]), 0); // priming
        assert_eq!(d.observe(&[1, 1, 2]), 0); // priming
        // v0 returned to 0 (2-cycle), v1/v2 never moved.
        assert_eq!(d.observe(&[0, 1, 2]), 1);
        // v0 keeps flapping 0↔1: still exactly one oscillator.
        assert_eq!(d.observe(&[1, 1, 2]), 1);
        // v0 settles on 1: window [0,1,1] is not a 2-cycle.
        assert_eq!(d.observe(&[1, 1, 2]), 0);
        // A size change (dynamic growth) resets comparability.
        assert_eq!(d.observe(&[1, 1, 2, 0]), 0);
    }

    #[test]
    fn oscillation_ignores_monotone_progress() {
        // A vertex that keeps moving forward (0 → 1 → 2) is exploring,
        // not oscillating.
        let mut d = OscillationDetector::new();
        d.observe(&[0]);
        d.observe(&[1]);
        assert_eq!(d.observe(&[2]), 0);
    }

    #[test]
    fn partition_samples_measure_load_boundary_and_locality() {
        // Two triangles plus one bridge (quality.rs's two_cliques).
        let mut b = GraphBuilder::new(6);
        for &(i, j) in &[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)] {
            b.edge(i, j);
        }
        b.edge(0, 3);
        let g = b.build();
        let labels = vec![0, 0, 0, 1, 1, 1];
        let s = partition_samples(&g, &labels, 2);
        // Loads match quality::partition_loads (same units).
        let loads = crate::metrics::quality::partition_loads(&g, &labels, 2);
        assert_eq!(s[0].load, loads[0]);
        assert_eq!(s[1].load, loads[1]);
        // Only the bridge endpoints (0 and 3) are boundary vertices.
        assert_eq!(s[0].boundary, 1);
        assert_eq!(s[1].boundary, 1);
        // Partition 0 owns 4 out-edges, 3 internal; partition 1 owns 3,
        // all internal.
        assert!((s[0].local_frac - 3.0 / 4.0).abs() < 1e-12);
        assert!((s[1].local_frac - 1.0).abs() < 1e-12);
        // An empty partition is perfectly local by convention.
        let s3 = partition_samples(&g, &labels, 3);
        assert_eq!(s3[2], PartSample { load: 0, boundary: 0, local_frac: 1.0 });
    }

    #[test]
    fn diag_store_accumulates_flow_and_keeps_latest_samples() {
        let store = DiagStore::default();
        store.apply(&DiagUpdate {
            step: 0,
            k: 2,
            flow_moves: Some(vec![0, 3, 1, 0]),
            flow_mass: Some(vec![0, 6, 2, 0]),
            partitions: Some(vec![PartSample { load: 10, boundary: 2, local_frac: 0.5 }]),
            oscillating: Some(4),
            maxp_mean: Some(0.5),
            entropy_mean: Some(0.9),
        });
        store.apply(&DiagUpdate {
            step: 1,
            k: 2,
            flow_moves: Some(vec![0, 1, 0, 0]),
            flow_mass: Some(vec![0, 2, 0, 0]),
            partitions: None, // off trace cadence: keep the last sample
            oscillating: Some(1),
            maxp_mean: Some(0.8),
            entropy_mean: Some(0.3),
        });
        let s = store.snapshot();
        assert_eq!(s.step, 1);
        assert_eq!(s.flow_moves, vec![0, 4, 1, 0]); // cumulative
        assert_eq!(s.flow_mass, vec![0, 8, 2, 0]);
        assert_eq!(s.partitions.len(), 1);
        assert_eq!(s.oscillating, 1);
        assert_eq!(s.maxp_mean, 0.8);
        // A different k (new run on the same recorder) resets.
        store.apply(&DiagUpdate { step: 0, k: 4, ..DiagUpdate::default() });
        let s = store.snapshot();
        assert_eq!((s.k, s.flow_moves.len()), (4, 0));
        assert!(s.maxp_mean.is_nan());
    }
}
