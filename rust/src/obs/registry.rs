//! Atomic metrics registry: named counters, gauges, and log2-bucketed
//! histograms.
//!
//! Metric instruments are created on first use (no pre-registration)
//! and updated lock-free: the name→instrument map sits behind a
//! `Mutex`, but the instruments themselves are `Arc`-shared atomics,
//! so steady-state updates are one `fetch_add`. Names must be
//! `'static` — every metric the engine emits is listed in the README
//! metrics reference, and string literals keep the hot-path signature
//! allocation-free.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Histogram bucket count: bucket `i` holds values with
/// [`bucket_index`] `i`; index 64 catches `u64::MAX`.
pub const BUCKETS: usize = 65;

/// Log2 bucket index of a value: 0 → 0, and for v > 0 the bit length
/// of v — bucket `i ≥ 1` covers `[2^(i-1), 2^i - 1]`.
pub fn bucket_index(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// Inclusive upper edge of bucket `i`: `2^i - 1`, saturating at
/// `u64::MAX` for the last bucket.
pub fn bucket_upper(i: usize) -> u64 {
    if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Lock-free log2-bucketed histogram of `u64` samples.
///
/// There is deliberately no separate count cell: a snapshot derives
/// `count` as the sum of the bucket loads it just took, so the
/// Prometheus invariant `+Inf == _count == Σ buckets` holds in every
/// snapshot — including live `/metrics` scrapes racing `observe` —
/// instead of depending on the load order of independent atomics.
/// (`sum` is still its own cell; a racing scrape's `mean` may lag by
/// the in-flight samples, which is harmless.)
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count = buckets.iter().sum();
        HistogramSnapshot { buckets, sum: self.sum.load(Ordering::Relaxed), count }
    }
}

/// Point-in-time copy of a [`Histogram`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub sum: u64,
    pub count: u64,
}

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper edge of the first bucket whose cumulative count reaches
    /// `q · count` (an upper bound on the q-quantile, since buckets
    /// only know their edges). `q` is clamped to [0, 1].
    pub fn quantile_upper(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_upper(i);
            }
        }
        u64::MAX
    }
}

/// The named-instrument registry a [`crate::obs::RunRecorder`] owns.
/// Gauges store `f64::to_bits` in an `AtomicU64` (last write wins).
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<&'static str, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<&'static str, Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<&'static str, Arc<Histogram>>>,
}

impl Registry {
    /// Handle to a counter, created at zero on first use. Callers that
    /// update one counter in a loop can hoist this lookup out of it.
    pub fn counter(&self, name: &'static str) -> Arc<AtomicU64> {
        self.counters.lock().unwrap().entry(name).or_default().clone()
    }

    pub fn counter_add(&self, name: &'static str, delta: u64) {
        self.counter(name).fetch_add(delta, Ordering::Relaxed);
    }

    pub fn gauge_set(&self, name: &'static str, value: f64) {
        self.gauges
            .lock()
            .unwrap()
            .entry(name)
            .or_default()
            .store(value.to_bits(), Ordering::Relaxed);
    }

    /// Handle to a histogram, created empty on first use.
    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name)
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    pub fn observe(&self, name: &'static str, value: u64) {
        self.histogram(name).observe(value);
    }

    pub fn counters(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.to_string(), v.load(Ordering::Relaxed)))
            .collect()
    }

    pub fn gauges(&self) -> Vec<(String, f64)> {
        self.gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.to_string(), f64::from_bits(v.load(Ordering::Relaxed))))
            .collect()
    }

    pub fn histograms(&self) -> Vec<(String, HistogramSnapshot)> {
        self.histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.to_string(), v.snapshot()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(64), u64::MAX);
    }

    #[test]
    fn every_value_lands_inside_its_bucket_edges() {
        // Property: upper(i-1) < v <= upper(i) for i = bucket_index(v),
        // checked at the exact boundaries and at random draws.
        let mut vals: Vec<u64> = (0..64)
            .flat_map(|e| {
                let p = 1u64 << e;
                [p.saturating_sub(1), p, p.saturating_add(1)]
            })
            .collect();
        let mut rng = Rng::new(17);
        for _ in 0..1000 {
            vals.push(rng.next_u64());
        }
        vals.push(u64::MAX);
        for v in vals {
            let i = bucket_index(v);
            assert!(i < BUCKETS, "{v}: index {i} out of range");
            assert!(v <= bucket_upper(i), "{v} above upper edge of bucket {i}");
            if i > 0 {
                assert!(v > bucket_upper(i - 1), "{v} inside previous bucket {i}");
            }
        }
    }

    #[test]
    fn bucket_index_is_monotone() {
        let mut prev = bucket_index(0);
        for e in 0..64u32 {
            let cur = bucket_index(1u64 << e);
            assert!(cur >= prev);
            prev = cur;
        }
    }

    #[test]
    fn histogram_counts_sum_and_quantile() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 3, 100] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 105);
        assert_eq!(s.buckets[bucket_index(0)], 1);
        assert_eq!(s.buckets[bucket_index(1)], 2);
        assert_eq!(s.buckets[bucket_index(3)], 1);
        assert_eq!(s.buckets[bucket_index(100)], 1);
        assert!((s.mean() - 21.0).abs() < 1e-12);
        // Median bucket holds the two 1s: upper edge 1.
        assert_eq!(s.quantile_upper(0.5), 1);
        // Max quantile is bounded by the top occupied bucket's edge.
        assert!(s.quantile_upper(1.0) >= 100);
        assert_eq!(HistogramSnapshot::default().quantile_upper(0.5), 0);
    }

    #[test]
    fn snapshot_count_always_equals_bucket_sum() {
        // The live-scrape invariant: however a snapshot races with
        // observers, its count is by construction Σ buckets.
        let h = Arc::new(Histogram::new());
        let writers: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..5_000u64 {
                        h.observe(i % (100 + t));
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            let s = h.snapshot();
            assert_eq!(s.count, s.buckets.iter().sum::<u64>());
        }
        for w in writers {
            w.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 20_000);
        assert_eq!(s.count, s.buckets.iter().sum::<u64>());
    }

    #[test]
    fn registry_creates_on_first_use_and_accumulates() {
        let r = Registry::default();
        r.counter_add("a", 2);
        r.counter_add("a", 3);
        r.counter_add("b", 1);
        r.gauge_set("g", 1.5);
        r.gauge_set("g", 2.5); // last write wins
        r.observe("h", 7);
        assert_eq!(r.counters(), vec![("a".to_string(), 5), ("b".to_string(), 1)]);
        assert_eq!(r.gauges(), vec![("g".to_string(), 2.5)]);
        let hists = r.histograms();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].1.count, 1);
        assert_eq!(hists[0].1.sum, 7);
    }
}
