//! JSONL run events: the `--obs-log` stream.
//!
//! One JSON object per line. Every event carries `"ev"` (its kind) and
//! `"t_s"` (seconds since the recorder started); [`EVENT_SPEC`] fixes
//! the numeric fields each kind must additionally carry. The schema is
//! validated twice: in-process by [`validate_events`] (mirroring
//! `util::bench::validate_rows` — drift fails loudly, not in a
//! downstream parser) and out-of-process by
//! `scripts/check_obs_log.py` in CI.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Event kind → required numeric fields (besides `"ev"`/`"t_s"`).
/// Extra number/string fields are allowed; nested values are not.
pub const EVENT_SPEC: &[(&str, &[&str])] = &[
    ("run_start", &[]),
    ("step", &["step", "frontier", "evaluated", "migrations"]),
    ("stream_pass", &["pass", "edges"]),
    ("ml_level", &["level", "vertices"]),
    ("epoch", &["epoch", "placed", "seeds", "evaluated", "repair_s"]),
    ("fault", &["step"]),
    ("checkpoint", &["step", "epoch"]),
    // Learning-dynamics observatory (`--diag`; see `obs::diag`): one
    // `flow` line per nonzero k×k cell per step, one `partition` line
    // per partition at trace cadence, one `diag` summary per step
    // (optional extras: `maxp_mean`, `entropy_mean`, `frontier`,
    // `halt`, `epoch`).
    ("flow", &["step", "from", "to", "moves", "mass"]),
    ("partition", &["step", "part", "load", "boundary", "local_frac"]),
    ("diag", &["step", "oscillating"]),
    ("run_end", &["wall_s"]),
];

/// Render one event line (no trailing newline). Non-finite field
/// values are dropped rather than emitted as invalid JSON — if a
/// *required* field goes non-finite, [`validate_events`] reports it.
pub fn render(kind: &str, t_s: f64, fields: &[(&str, f64)]) -> String {
    let mut m = BTreeMap::new();
    m.insert("ev".to_string(), Json::Str(kind.to_string()));
    m.insert("t_s".to_string(), Json::Num(t_s));
    for &(k, v) in fields {
        if v.is_finite() {
            m.insert(k.to_string(), Json::Num(v));
        }
    }
    Json::Obj(m).to_string()
}

/// Validate a JSONL event stream against [`EVENT_SPEC`]; returns the
/// event count. Blank lines are permitted (and not counted).
pub fn validate_events(text: &str) -> Result<usize, String> {
    let mut n = 0usize;
    for (idx, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let lineno = idx + 1;
        let j = Json::parse(line).map_err(|e| format!("line {lineno}: {e}"))?;
        let obj = match &j {
            Json::Obj(m) => m,
            _ => return Err(format!("line {lineno}: not an object")),
        };
        let kind = j
            .get("ev")
            .and_then(|v| v.as_str())
            .ok_or(format!("line {lineno}: missing string \"ev\" tag"))?;
        let required = EVENT_SPEC
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, fields)| *fields)
            .ok_or(format!("line {lineno}: unknown event kind {kind:?}"))?;
        match j.get("t_s") {
            Some(Json::Num(x)) if x.is_finite() && *x >= 0.0 => {}
            _ => return Err(format!("line {lineno} ({kind}): \"t_s\" missing or invalid")),
        }
        for key in required {
            match j.get(key) {
                Some(Json::Num(x)) if x.is_finite() => {}
                Some(_) => return Err(format!("line {lineno} ({kind}): {key:?} not finite")),
                None => return Err(format!("line {lineno} ({kind}): missing {key:?}")),
            }
        }
        for (key, val) in obj.iter() {
            if !matches!(val, Json::Num(_) | Json::Str(_)) {
                return Err(format!("line {lineno} ({kind}): {key:?} must be number/string"));
            }
        }
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendered_events_validate() {
        let mut log = String::new();
        log.push_str(&render("run_start", 0.0, &[]));
        log.push('\n');
        log.push_str(&render(
            "step",
            0.5,
            &[("step", 0.0), ("frontier", 103.0), ("evaluated", 103.0), ("migrations", 7.0)],
        ));
        log.push('\n');
        log.push_str(&render("run_end", 1.25, &[("wall_s", 1.25)]));
        log.push('\n');
        assert_eq!(validate_events(&log), Ok(3));
        assert_eq!(validate_events(""), Ok(0));
    }

    #[test]
    fn diag_kinds_render_and_validate() {
        let mut log = String::new();
        log.push_str(&render(
            "flow",
            0.2,
            &[("step", 1.0), ("from", 0.0), ("to", 3.0), ("moves", 17.0), ("mass", 45.0)],
        ));
        log.push('\n');
        log.push_str(&render(
            "partition",
            0.3,
            &[
                ("step", 1.0),
                ("part", 3.0),
                ("load", 2048.0),
                ("boundary", 31.0),
                ("local_frac", 0.91),
            ],
        ));
        log.push('\n');
        log.push_str(&render(
            "diag",
            0.4,
            &[
                ("step", 1.0),
                ("oscillating", 5.0),
                ("frontier", 96.0),
                ("maxp_mean", 0.7),
                ("entropy_mean", 0.4),
            ],
        ));
        log.push('\n');
        assert_eq!(validate_events(&log), Ok(3), "{log}");
        // Missing required fields in each new kind are rejected.
        for bad in [
            r#"{"ev":"flow","t_s":0.1,"step":1,"from":0,"to":3,"moves":17}"#,
            r#"{"ev":"partition","t_s":0.1,"step":1,"part":3,"load":1,"boundary":0}"#,
            r#"{"ev":"diag","t_s":0.1,"step":1}"#,
        ] {
            assert!(validate_events(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn extra_flat_fields_are_allowed() {
        let line = render(
            "step",
            1.0,
            &[
                ("step", 1.0),
                ("frontier", 5.0),
                ("evaluated", 5.0),
                ("migrations", 0.0),
                ("mean_score", 0.83),
            ],
        );
        assert_eq!(validate_events(&line), Ok(1));
    }

    #[test]
    fn non_finite_optional_fields_are_dropped() {
        let line = render("run_start", 0.0, &[("junk", f64::NAN)]);
        assert!(!line.contains("junk"));
        assert_eq!(validate_events(&line), Ok(1));
        // A required field dropped for non-finiteness fails validation.
        let line = render("run_end", 0.0, &[("wall_s", f64::INFINITY)]);
        assert!(validate_events(&line).unwrap_err().contains("wall_s"));
    }

    #[test]
    fn schema_drift_is_rejected() {
        for bad in [
            "[1,2]",                                        // not an object
            r#"{"t_s":0.1}"#,                               // missing ev
            r#"{"ev":"mystery","t_s":0.1}"#,                // unknown kind
            r#"{"ev":"run_end","wall_s":1.0}"#,             // missing t_s
            r#"{"ev":"run_end","t_s":-1.0,"wall_s":1.0}"#,  // negative t_s
            r#"{"ev":"run_end","t_s":0.1}"#,                // missing required
            r#"{"ev":"run_end","t_s":0.1,"wall_s":"x"}"#,   // wrong type
            r#"{"ev":"run_end","t_s":0.1,"wall_s":1,"sub":{"a":1}}"#, // nested
            "not json",
        ] {
            assert!(validate_events(bad).is_err(), "{bad}");
        }
    }
}
