//! Nestable monotonic spans.
//!
//! A span is a named wall-time interval. Nesting is tracked per thread
//! through a stack of open span names; a span's *path* is the
//! '/'-joined stack at the moment it closes (`multilevel/refine/engine`),
//! which is what makes the `--profile` tree hierarchical: the engine
//! records the same relative segment names whether it runs standalone
//! (`engine/phase_a`) or under a multilevel refine pass
//! (`multilevel/refine/engine/phase_a`).
//!
//! Two recording shapes:
//! * [`SpanGuard`] (via [`crate::obs::span`]) — RAII: open on
//!   construction, record on drop. Inert, with **no clock read**, when
//!   observability is disabled at construction.
//! * [`Segments`] — a coordinator-side segment timer: each
//!   [`Segments::cut`] records the time since the previous cut, so
//!   consecutive cuts tile an enclosing span exactly (the engine's
//!   per-step phases sum to the engine total by construction).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

thread_local! {
    static STACK: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
}

pub(crate) fn enter(name: &'static str) {
    STACK.with(|s| s.borrow_mut().push(name));
}

/// Pop `name` off this thread's stack and return the full path it ran
/// under (the remaining stack joined with '/', then `name`).
pub(crate) fn exit_path(name: &'static str) -> String {
    STACK.with(|s| {
        let mut st = s.borrow_mut();
        debug_assert_eq!(st.last().copied(), Some(name), "span guards must drop LIFO");
        st.pop();
        joined(&st, name)
    })
}

/// `rel` prefixed by this thread's currently open spans.
pub(crate) fn prefixed(rel: &str) -> String {
    STACK.with(|s| joined(&s.borrow(), rel))
}

fn joined(stack: &[&'static str], leaf: &str) -> String {
    let cap = stack.iter().map(|p| p.len() + 1).sum::<usize>() + leaf.len();
    let mut out = String::with_capacity(cap);
    for part in stack {
        out.push_str(part);
        out.push('/');
    }
    out.push_str(leaf);
    out
}

/// RAII span handle returned by [`crate::obs::span`]. When armed it
/// pushed its name onto the thread's span stack at construction; on
/// drop it pops the name and records the elapsed wall time under the
/// nested path. When disarmed (observability disabled) it is a no-op
/// that never touches the clock.
#[derive(Debug)]
pub struct SpanGuard {
    armed: Option<(&'static str, Instant)>,
}

impl SpanGuard {
    pub(crate) fn new(name: &'static str, armed: bool) -> SpanGuard {
        if !armed {
            return SpanGuard { armed: None };
        }
        enter(name);
        SpanGuard { armed: Some((name, Instant::now())) }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((name, start)) = self.armed.take() {
            let ns = start.elapsed().as_nanos() as u64;
            let path = exit_path(name);
            crate::obs::span_record_absolute(&path, ns);
        }
    }
}

/// Segment timer for straight-line phase accounting: `cut(name)`
/// records the wall time since the previous cut under `name` (prefixed
/// by the thread's open spans, like every span). Started disarmed it
/// never reads the clock.
#[derive(Debug)]
pub struct Segments {
    last: Option<Instant>,
}

impl Segments {
    pub fn start(armed: bool) -> Segments {
        Segments { last: armed.then(Instant::now) }
    }

    pub fn cut(&mut self, rel_path: &str) {
        if let Some(prev) = self.last {
            let now = Instant::now();
            crate::obs::span_record(rel_path, now.duration_since(prev).as_nanos() as u64);
            self.last = Some(now);
        }
    }
}

/// Aggregate statistics of one span path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpanStat {
    pub total_ns: u64,
    pub count: u64,
    pub max_ns: u64,
}

/// Path → [`SpanStat`] accumulator owned by the run recorder. A
/// `BTreeMap` keeps paths sorted, which the profile tree relies on:
/// a child path (`parent/child`) sorts directly after its parent.
#[derive(Debug, Default)]
pub struct SpanSet {
    stats: Mutex<BTreeMap<String, SpanStat>>,
}

impl SpanSet {
    pub fn record(&self, path: &str, ns: u64) {
        let mut m = self.stats.lock().unwrap();
        let e = m.entry(path.to_string()).or_default();
        e.total_ns += ns;
        e.count += 1;
        e.max_ns = e.max_ns.max(ns);
    }

    pub fn snapshot(&self) -> Vec<(String, SpanStat)> {
        self.stats.lock().unwrap().iter().map(|(k, v)| (k.clone(), *v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nesting_builds_slash_paths() {
        // The stack is thread-local; this test never enables the
        // global recorder, it drives the path bookkeeping directly.
        enter("a");
        enter("b");
        assert_eq!(prefixed("leaf"), "a/b/leaf");
        assert_eq!(exit_path("b"), "a/b");
        assert_eq!(prefixed("leaf"), "a/leaf");
        assert_eq!(exit_path("a"), "a");
        assert_eq!(prefixed("leaf"), "leaf");
    }

    #[test]
    fn disarmed_guard_and_segments_touch_nothing() {
        {
            let _g = SpanGuard::new("x", false);
            assert_eq!(prefixed("leaf"), "leaf", "disarmed guard must not push");
        }
        let mut seg = Segments::start(false);
        seg.cut("y"); // must not record or read the clock
        assert_eq!(prefixed("leaf"), "leaf");
    }

    #[test]
    fn span_set_accumulates_per_path() {
        let s = SpanSet::default();
        s.record("a", 10);
        s.record("a/b", 4);
        s.record("a", 30);
        let snap = s.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[0].1, SpanStat { total_ns: 40, count: 2, max_ns: 30 });
        assert_eq!(snap[1].0, "a/b");
        assert_eq!(snap[1].1.count, 1);
    }
}
