//! Observability: spans, metrics, events — std-only, zero overhead off.
//!
//! The paper's claims are about *where time goes* (asynchronous
//! supersteps, straggler-free degree-balanced scheduling); this module
//! makes that measurable without touching the numerics. Three layers:
//!
//! * A process-global [`Recorder`] slot. Disabled (the default) every
//!   entry point is one relaxed atomic load and a branch — the engine
//!   additionally captures [`enabled`] once per run and skips even
//!   clock reads, so the disabled path stays bit-identical to the
//!   pre-instrumentation engine (pinned by the parity suite and the
//!   `obs_overhead` bench section).
//! * Instruments: an atomic [`registry::Registry`] of named counters,
//!   gauges and log2-bucketed histograms; nestable monotonic
//!   [`span::SpanGuard`]s whose '/'-joined paths form the `--profile`
//!   tree; JSONL [`events`] streamed to `--obs-log`.
//! * Exports: [`RunRecorder::profile_report`] (hierarchical timing
//!   tree), [`RunRecorder::prometheus`] ([`expose`]), and the validated
//!   event log — all also served *live* over HTTP by [`http`] (the
//!   `--metrics-addr` flag) from the same snapshots, plus a bounded
//!   in-memory event ring ([`RunRecorder::events_since`]) so the
//!   `/events` tail works without `--obs-log`.
//!
//! **Overhead contract.** Instrumentation must never change engine
//! trajectories: recorders observe wall time and counts only — no
//! RNG draws, no allocation on worker hot paths while disabled, no
//! barrier reordering. `tests/obs.rs` asserts label-for-label equality
//! with and without a recorder installed.

pub mod diag;
pub mod events;
pub mod expose;
pub mod http;
pub mod httpd;
pub mod log;
pub mod registry;
pub mod report;
pub mod span;

use std::collections::VecDeque;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::obs::registry::Registry;
use crate::obs::span::{SpanGuard, SpanSet, SpanStat};

/// Where instrumentation lands. All methods default to no-ops, so a
/// recorder only implements what it keeps; implementations must be
/// cheap and lock-light — calls come from worker threads mid-step.
pub trait Recorder: Send + Sync {
    fn counter_add(&self, _name: &'static str, _delta: u64) {}
    fn gauge_set(&self, _name: &'static str, _value: f64) {}
    fn observe(&self, _name: &'static str, _value: u64) {}
    fn span_observe(&self, _path: &str, _ns: u64) {}
    fn event(&self, _kind: &'static str, _fields: &[(&'static str, f64)]) {}
    /// One step's learning-dynamics batch (`--diag`; see [`diag`]).
    /// Carries structured per-partition data that the flat
    /// `&'static str`-named instrument calls cannot express.
    fn diag_update(&self, _u: &diag::DiagUpdate) {}
    fn flush(&self) {}
}

/// A recorder that drops everything (the trait's defaults verbatim).
/// Installing it measures the pure call-dispatch overhead — that is
/// exactly what the `obs_overhead` bench section compares against the
/// disabled path and a full [`RunRecorder`].
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopRecorder;

impl Recorder for NoopRecorder {}

static ENABLED: AtomicBool = AtomicBool::new(false);
static RECORDER: RwLock<Option<Arc<dyn Recorder>>> = RwLock::new(None);

/// One relaxed load. Hot loops capture this once per run and gate
/// every clock read on the captured bool.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install `rec` as the process-global recorder and enable recording.
/// Also resets the [`Progress`] readout, so `/healthz` reports this
/// run, not a previous one.
pub fn install(rec: Arc<dyn Recorder>) {
    PROGRESS.reset();
    *RECORDER.write().unwrap() = Some(rec);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disable recording and drop the global recorder reference.
pub fn uninstall() {
    ENABLED.store(false, Ordering::SeqCst);
    *RECORDER.write().unwrap() = None;
}

fn with_recorder(f: impl FnOnce(&dyn Recorder)) {
    if !enabled() {
        return;
    }
    if let Some(rec) = RECORDER.read().unwrap().as_ref() {
        f(rec.as_ref());
    }
}

pub fn counter_add(name: &'static str, delta: u64) {
    with_recorder(|r| r.counter_add(name, delta));
}

pub fn gauge_set(name: &'static str, value: f64) {
    with_recorder(|r| r.gauge_set(name, value));
}

/// Record one histogram sample.
pub fn observe(name: &'static str, value: u64) {
    with_recorder(|r| r.observe(name, value));
}

/// Emit one JSONL event (kind + numeric fields; see [`events`]).
pub fn event(kind: &'static str, fields: &[(&'static str, f64)]) {
    with_recorder(|r| r.event(kind, fields));
}

/// Hand one diagnostics batch to the recorder (`--diag`; see [`diag`]).
pub fn diag_update(u: &diag::DiagUpdate) {
    with_recorder(|r| r.diag_update(u));
}

/// Open a nested span; records on drop. Inert (no clock read, no stack
/// push) when recording is disabled at the call.
pub fn span(name: &'static str) -> SpanGuard {
    SpanGuard::new(name, enabled())
}

/// Record `ns` under `rel_path` prefixed by this thread's open spans
/// (see [`span::Segments`] for the tiling use).
pub fn span_record(rel_path: &str, ns: u64) {
    with_recorder(|r| r.span_observe(&span::prefixed(rel_path), ns));
}

pub(crate) fn span_record_absolute(path: &str, ns: u64) {
    with_recorder(|r| r.span_observe(path, ns));
}

/// Live run progress for the `/healthz` endpoint: which phase the run
/// is in plus the engine-step and dynamic-epoch counters. The engine,
/// dynamic, and multilevel layers update it behind their captured
/// `obs_on` / [`enabled`] gates, so the disabled path stays untouched.
/// Step and epoch are packed into one relaxed atomic (step in the high
/// 32 bits, epoch in the low 32) so a snapshot is a single load and a
/// scraper can never observe a torn step/epoch pair, no matter how the
/// writers interleave. The phase label is `&'static str` behind a
/// `Mutex` (phase transitions are per-phase, not per-vertex — the lock
/// is never on a hot path, and readers are rare `/healthz` hits).
pub struct Progress {
    phase: Mutex<&'static str>,
    step_epoch: AtomicU64,
}

/// Point-in-time copy of [`Progress`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgressSnapshot {
    pub phase: &'static str,
    pub step: u64,
    pub epoch: u64,
}

impl Progress {
    const fn new() -> Progress {
        Progress { phase: Mutex::new("idle"), step_epoch: AtomicU64::new(0) }
    }

    pub fn set_phase(&self, phase: &'static str) {
        *self.phase.lock().unwrap() = phase;
    }

    /// Values saturate at `u32::MAX` — both counters are step/epoch
    /// indices, far below 2^32 in any real run.
    pub fn set_step(&self, step: u64) {
        let hi = step.min(u32::MAX as u64) << 32;
        let _ = self.step_epoch.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            Some((cur & u32::MAX as u64) | hi)
        });
    }

    pub fn set_epoch(&self, epoch: u64) {
        let lo = epoch.min(u32::MAX as u64);
        let _ = self.step_epoch.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            Some((cur & !(u32::MAX as u64)) | lo)
        });
    }

    pub fn snapshot(&self) -> ProgressSnapshot {
        let se = self.step_epoch.load(Ordering::Relaxed);
        ProgressSnapshot {
            phase: *self.phase.lock().unwrap(),
            step: se >> 32,
            epoch: se & u32::MAX as u64,
        }
    }

    fn reset(&self) {
        self.set_phase("idle");
        self.step_epoch.store(0, Ordering::Relaxed);
    }
}

static PROGRESS: Progress = Progress::new();

/// The process-global progress readout (reset by [`install`]).
pub fn progress() -> &'static Progress {
    &PROGRESS
}

/// Capacity of the per-recorder event ring: at the engine's one event
/// per superstep, 4096 lines is minutes of tail at full tilt, and the
/// memory bound is a few hundred KiB of short JSON lines.
pub const EVENT_RING_CAPACITY: usize = 4096;

/// Bounded in-memory tail of rendered event lines. `first_seq` is the
/// global sequence number of `lines[0]`; eviction advances it, so
/// sequence numbers are stable cursors for `/events?since=N`.
struct EventRing {
    lines: VecDeque<String>,
    first_seq: u64,
}

impl EventRing {
    fn end(&self) -> u64 {
        self.first_seq + self.lines.len() as u64
    }
}

/// The concrete recorder the CLI installs: atomic registry + span set
/// + optional JSONL sink + bounded event ring. Callers keep the
/// concrete `Arc<RunRecorder>` (and install a clone as
/// `Arc<dyn Recorder>`) so they can render the profile tree and
/// Prometheus snapshot after the run — and so `obs::http` can serve
/// the same snapshots live while the run records.
pub struct RunRecorder {
    start: Instant,
    registry: Registry,
    spans: SpanSet,
    sink: Option<Mutex<Box<dyn Write + Send>>>,
    ring: Mutex<EventRing>,
    ring_cv: Condvar,
    diag: diag::DiagStore,
}

impl RunRecorder {
    pub fn new() -> RunRecorder {
        RunRecorder::build(None)
    }

    /// Recorder that additionally streams JSONL events into `sink`
    /// (`--obs-log`).
    pub fn with_sink(sink: Box<dyn Write + Send>) -> RunRecorder {
        RunRecorder::build(Some(Mutex::new(sink)))
    }

    fn build(sink: Option<Mutex<Box<dyn Write + Send>>>) -> RunRecorder {
        RunRecorder {
            start: Instant::now(),
            registry: Registry::default(),
            spans: SpanSet::default(),
            sink,
            ring: Mutex::new(EventRing { lines: VecDeque::new(), first_seq: 0 }),
            ring_cv: Condvar::new(),
            diag: diag::DiagStore::default(),
        }
    }

    /// Seconds since the recorder was created (the `t_s` event clock).
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn spans(&self) -> Vec<(String, SpanStat)> {
        self.spans.snapshot()
    }

    /// The learning-dynamics store behind `/state` (`--diag` runs
    /// populate it; otherwise it stays empty).
    pub fn diag(&self) -> &diag::DiagStore {
        &self.diag
    }

    /// Prometheus text snapshot of everything recorded so far,
    /// including the labelled diagnostics families when a `--diag` run
    /// populated them.
    pub fn prometheus(&self) -> String {
        let mut out = expose::render(
            &self.registry.counters(),
            &self.registry.gauges(),
            &self.registry.histograms(),
            &self.spans.snapshot(),
        );
        out.push_str(&expose::render_diag(&self.diag.snapshot()));
        out
    }

    /// The `--profile` timing tree, percentages relative to this
    /// recorder's lifetime.
    pub fn profile_report(&self) -> String {
        profile_tree(&self.spans.snapshot(), self.elapsed_s())
    }

    /// Event lines at sequence numbers `>= since`, plus cursors:
    /// `(start, lines, next)` where `start` is the sequence number of
    /// `lines[0]` (greater than `since` when the bounded ring already
    /// evicted older lines) and `next` is the cursor to resume from.
    pub fn events_since(&self, since: u64) -> (u64, Vec<String>, u64) {
        let ring = self.ring.lock().unwrap();
        let end = ring.end();
        let start = since.clamp(ring.first_seq, end);
        let lines = ring.lines.iter().skip((start - ring.first_seq) as usize).cloned().collect();
        (start, lines, end)
    }

    /// One past the newest event's sequence number.
    pub fn events_end(&self) -> u64 {
        self.ring.lock().unwrap().end()
    }

    /// Park until an event with sequence number `>= since` exists or
    /// `timeout` elapses (the `/events` long-poll primitive).
    pub fn wait_events(&self, since: u64, timeout: Duration) {
        let ring = self.ring.lock().unwrap();
        if ring.end() > since {
            return;
        }
        let _ = self.ring_cv.wait_timeout(ring, timeout);
    }
}

impl Default for RunRecorder {
    fn default() -> Self {
        RunRecorder::new()
    }
}

impl Recorder for RunRecorder {
    fn counter_add(&self, name: &'static str, delta: u64) {
        self.registry.counter_add(name, delta);
    }

    fn gauge_set(&self, name: &'static str, value: f64) {
        self.registry.gauge_set(name, value);
    }

    fn observe(&self, name: &'static str, value: u64) {
        self.registry.observe(name, value);
    }

    fn span_observe(&self, path: &str, ns: u64) {
        self.spans.record(path, ns);
    }

    fn event(&self, kind: &'static str, fields: &[(&'static str, f64)]) {
        let line = events::render(kind, self.elapsed_s(), fields);
        if let Some(sink) = &self.sink {
            // Line-buffered contract: one `write_all` for the whole
            // line, then an immediate flush — a killed run damages at
            // most its final line, never the buffered tail.
            let mut bytes = Vec::with_capacity(line.len() + 1);
            bytes.extend_from_slice(line.as_bytes());
            bytes.push(b'\n');
            let mut w = sink.lock().unwrap();
            let _ = w.write_all(&bytes);
            let _ = w.flush();
        }
        let mut ring = self.ring.lock().unwrap();
        if ring.lines.len() >= EVENT_RING_CAPACITY {
            ring.lines.pop_front();
            ring.first_seq += 1;
        }
        ring.lines.push_back(line);
        self.ring_cv.notify_all();
    }

    fn diag_update(&self, u: &diag::DiagUpdate) {
        self.diag.apply(u);
    }

    fn flush(&self) {
        if let Some(sink) = &self.sink {
            let _ = sink.lock().unwrap().flush();
        }
    }
}

/// Render span stats as an indented tree: seconds, percent of `wall_s`,
/// and call count per path. Paths arrive sorted (child `a/b` directly
/// after parent `a`), so indentation by '/'-depth prints a tree. Ends
/// with the top-level sum — the line the acceptance check reads: the
/// engine's segment cuts tile its run, so top-level spans account for
/// the reported wall time.
pub fn profile_tree(spans: &[(String, SpanStat)], wall_s: f64) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "── profile ({wall_s:.3}s wall) ──");
    if spans.is_empty() {
        let _ = writeln!(out, "  (no spans recorded)");
        return out;
    }
    let mut top_ns = 0u64;
    for (path, stat) in spans {
        let depth = path.matches('/').count();
        if depth == 0 {
            top_ns += stat.total_ns;
        }
        let name = match path.rfind('/') {
            Some(i) => &path[i + 1..],
            None => path.as_str(),
        };
        let secs = stat.total_ns as f64 / 1e9;
        let pct = if wall_s > 0.0 { 100.0 * secs / wall_s } else { 0.0 };
        let pad = 30usize.saturating_sub(2 * depth).max(name.len());
        let _ = writeln!(
            out,
            "  {:indent$}{name:<pad$} {secs:>9.3}s {pct:>5.1}%  ×{}",
            "",
            stat.count,
            indent = 2 * depth,
        );
    }
    let top_s = top_ns as f64 / 1e9;
    let top_pct = if wall_s > 0.0 { 100.0 * top_s / wall_s } else { 0.0 };
    let _ = writeln!(out, "  top-level spans: {top_s:.3}s ({top_pct:.1}% of wall)");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests use a RunRecorder *directly* (never installed into
    // the process-global slot — unit tests run concurrently; the
    // global install path is exercised by `tests/obs.rs`, which
    // serializes itself).

    #[test]
    fn run_recorder_keeps_metrics_spans_and_events() {
        let buf = Arc::new(Mutex::new(Vec::<u8>::new()));
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedBuf {
            fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(data);
                Ok(data.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let rec = RunRecorder::with_sink(Box::new(SharedBuf(buf.clone())));
        rec.counter_add("engine_steps", 5);
        rec.gauge_set("engine_mean_score", 0.5);
        rec.observe("engine_frontier_size", 103);
        rec.span_observe("engine", 1000);
        rec.span_observe("engine/phase_a", 400);
        rec.event("run_start", &[]);
        rec.event("run_end", &[("wall_s", 0.01)]);
        rec.flush();

        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        assert_eq!(events::validate_events(&text), Ok(2));
        let prom = rec.prometheus();
        assert!(prom.contains("engine_steps 5"));
        assert!(prom.contains("span_seconds_total{path=\"engine/phase_a\"}"));
        let tree = rec.profile_report();
        assert!(tree.contains("engine"));
        assert!(tree.contains("phase_a"));
        assert!(tree.contains("top-level spans:"));
    }

    #[test]
    fn event_ring_keeps_a_bounded_cursor_stable_tail() {
        let rec = RunRecorder::new();
        rec.event("run_start", &[]);
        rec.event("run_end", &[("wall_s", 0.1)]);
        let (start, lines, next) = rec.events_since(0);
        assert_eq!((start, next), (0, 2));
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("run_start") && lines[1].contains("run_end"));
        // Resuming from the returned cursor yields nothing new.
        let (start, lines, next) = rec.events_since(next);
        assert_eq!((start, next), (2, 2));
        assert!(lines.is_empty());
        assert_eq!(rec.events_end(), 2);

        // Overflow evicts oldest lines but keeps sequence numbers
        // stable: a stale cursor resumes at the ring's first line.
        let rec = RunRecorder::new();
        for _ in 0..EVENT_RING_CAPACITY + 10 {
            rec.event("run_start", &[]);
        }
        let (start, lines, next) = rec.events_since(0);
        assert_eq!(start, 10);
        assert_eq!(lines.len(), EVENT_RING_CAPACITY);
        assert_eq!(next, (EVENT_RING_CAPACITY + 10) as u64);
    }

    #[test]
    fn events_survive_without_a_sink_and_validate() {
        let rec = RunRecorder::new();
        rec.event("run_start", &[]);
        rec.event(
            "step",
            &[("step", 0.0), ("frontier", 7.0), ("evaluated", 7.0), ("migrations", 1.0)],
        );
        let (_, lines, _) = rec.events_since(0);
        let text = lines.join("\n");
        assert_eq!(events::validate_events(&text), Ok(2), "{text}");
    }

    #[test]
    fn progress_snapshot_reflects_last_writes() {
        let p = Progress::new();
        assert_eq!(p.snapshot(), ProgressSnapshot { phase: "idle", step: 0, epoch: 0 });
        p.set_phase("engine");
        p.set_step(12);
        p.set_epoch(3);
        assert_eq!(p.snapshot(), ProgressSnapshot { phase: "engine", step: 12, epoch: 3 });
        p.reset();
        assert_eq!(p.snapshot().phase, "idle");
    }

    /// The packed step/epoch atomic makes snapshots untearable: the
    /// writer always advances step *before* epoch, so `epoch <= step`
    /// holds at every instant — a reader racing the two separate
    /// stores of the old representation could observe the fresh epoch
    /// with the stale step and break it.
    #[test]
    fn progress_snapshot_is_never_torn() {
        let p = Progress::new();
        std::thread::scope(|s| {
            s.spawn(|| {
                for j in 0..20_000u64 {
                    p.set_step(j);
                    p.set_epoch(j);
                }
            });
            s.spawn(|| {
                for _ in 0..20_000 {
                    let snap = p.snapshot();
                    assert!(
                        snap.epoch <= snap.step,
                        "torn pair: step={} epoch={}",
                        snap.step,
                        snap.epoch
                    );
                }
            });
        });
        let snap = p.snapshot();
        assert_eq!((snap.step, snap.epoch), (19_999, 19_999));
    }

    /// The line-buffered sink contract (kill-safety): every event is
    /// one `write_all` + `flush`, so a sink that dies after N lines
    /// still holds N complete, schema-valid lines — and a sink that
    /// truncates mid-line damages only the line it died on.
    #[test]
    fn failing_and_truncating_sinks_leave_a_valid_prefix() {
        // Each event is exactly one `write` call (full acceptance), so
        // the sink's behaviour is counted in calls, not bytes:
        // `full_calls` lines land whole, then one call may land
        // `partial_bytes` before the sink dies for good.
        struct LimitedSink {
            out: Arc<Mutex<Vec<u8>>>,
            full_calls: usize,
            partial_bytes: usize,
        }
        impl Write for LimitedSink {
            fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
                if self.full_calls > 0 {
                    self.full_calls -= 1;
                    self.out.lock().unwrap().extend_from_slice(data);
                    return Ok(data.len());
                }
                if self.partial_bytes > 0 {
                    let n = self.partial_bytes.min(data.len().max(1) - 1);
                    self.partial_bytes = 0;
                    self.out.lock().unwrap().extend_from_slice(&data[..n]);
                    if n == 0 {
                        return Err(std::io::Error::other("sink died"));
                    }
                    return Ok(n);
                }
                Err(std::io::Error::other("sink died"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }

        // Hard failure between lines: complete-line prefix survives.
        let out = Arc::new(Mutex::new(Vec::new()));
        let sink = LimitedSink { out: out.clone(), full_calls: 2, partial_bytes: 0 };
        let rec = RunRecorder::with_sink(Box::new(sink));
        for _ in 0..5 {
            rec.event("run_start", &[]);
        }
        let text = String::from_utf8(out.lock().unwrap().clone()).unwrap();
        assert_eq!(events::validate_events(&text), Ok(2), "{text}");
        assert!(text.ends_with('\n'), "no partial line: {text:?}");

        // Truncation mid-line: only the final line is damaged; the
        // prefix up to the last newline stays schema-valid.
        let out = Arc::new(Mutex::new(Vec::new()));
        let sink = LimitedSink { out: out.clone(), full_calls: 2, partial_bytes: 3 };
        let rec = RunRecorder::with_sink(Box::new(sink));
        for _ in 0..5 {
            rec.event("run_start", &[]);
        }
        let text = String::from_utf8(out.lock().unwrap().clone()).unwrap();
        let (intact, partial) = text.rsplit_once('\n').unwrap();
        assert_eq!(events::validate_events(intact), Ok(2), "{intact}");
        assert!(!partial.is_empty(), "expected a truncated tail in {text:?}");
    }

    #[test]
    fn profile_tree_sums_top_level_only() {
        let spans = vec![
            ("engine".to_string(), SpanStat { total_ns: 2_000_000_000, count: 1, max_ns: 0 }),
            (
                "engine/phase_a".to_string(),
                SpanStat { total_ns: 1_500_000_000, count: 5, max_ns: 0 },
            ),
            ("stream_pass".to_string(), SpanStat { total_ns: 500_000_000, count: 3, max_ns: 0 }),
        ];
        let tree = profile_tree(&spans, 2.5);
        assert!(tree.contains("top-level spans: 2.500s (100.0% of wall)"), "{tree}");
        let empty = profile_tree(&[], 1.0);
        assert!(empty.contains("no spans recorded"));
    }
}
