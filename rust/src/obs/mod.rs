//! Observability: spans, metrics, events — std-only, zero overhead off.
//!
//! The paper's claims are about *where time goes* (asynchronous
//! supersteps, straggler-free degree-balanced scheduling); this module
//! makes that measurable without touching the numerics. Three layers:
//!
//! * A process-global [`Recorder`] slot. Disabled (the default) every
//!   entry point is one relaxed atomic load and a branch — the engine
//!   additionally captures [`enabled`] once per run and skips even
//!   clock reads, so the disabled path stays bit-identical to the
//!   pre-instrumentation engine (pinned by the parity suite and the
//!   `obs_overhead` bench section).
//! * Instruments: an atomic [`registry::Registry`] of named counters,
//!   gauges and log2-bucketed histograms; nestable monotonic
//!   [`span::SpanGuard`]s whose '/'-joined paths form the `--profile`
//!   tree; JSONL [`events`] streamed to `--obs-log`.
//! * Exports: [`RunRecorder::profile_report`] (hierarchical timing
//!   tree), [`RunRecorder::prometheus`] ([`expose`], ready for the
//!   future serve layer), and the validated event log.
//!
//! **Overhead contract.** Instrumentation must never change engine
//! trajectories: recorders observe wall time and counts only — no
//! RNG draws, no allocation on worker hot paths while disabled, no
//! barrier reordering. `tests/obs.rs` asserts label-for-label equality
//! with and without a recorder installed.

pub mod events;
pub mod expose;
pub mod log;
pub mod registry;
pub mod span;

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::obs::registry::Registry;
use crate::obs::span::{SpanGuard, SpanSet, SpanStat};

/// Where instrumentation lands. All methods default to no-ops, so a
/// recorder only implements what it keeps; implementations must be
/// cheap and lock-light — calls come from worker threads mid-step.
pub trait Recorder: Send + Sync {
    fn counter_add(&self, _name: &'static str, _delta: u64) {}
    fn gauge_set(&self, _name: &'static str, _value: f64) {}
    fn observe(&self, _name: &'static str, _value: u64) {}
    fn span_observe(&self, _path: &str, _ns: u64) {}
    fn event(&self, _kind: &'static str, _fields: &[(&'static str, f64)]) {}
    fn flush(&self) {}
}

/// A recorder that drops everything (the trait's defaults verbatim).
/// Installing it measures the pure call-dispatch overhead — that is
/// exactly what the `obs_overhead` bench section compares against the
/// disabled path and a full [`RunRecorder`].
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopRecorder;

impl Recorder for NoopRecorder {}

static ENABLED: AtomicBool = AtomicBool::new(false);
static RECORDER: RwLock<Option<Arc<dyn Recorder>>> = RwLock::new(None);

/// One relaxed load. Hot loops capture this once per run and gate
/// every clock read on the captured bool.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install `rec` as the process-global recorder and enable recording.
pub fn install(rec: Arc<dyn Recorder>) {
    *RECORDER.write().unwrap() = Some(rec);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disable recording and drop the global recorder reference.
pub fn uninstall() {
    ENABLED.store(false, Ordering::SeqCst);
    *RECORDER.write().unwrap() = None;
}

fn with_recorder(f: impl FnOnce(&dyn Recorder)) {
    if !enabled() {
        return;
    }
    if let Some(rec) = RECORDER.read().unwrap().as_ref() {
        f(rec.as_ref());
    }
}

pub fn counter_add(name: &'static str, delta: u64) {
    with_recorder(|r| r.counter_add(name, delta));
}

pub fn gauge_set(name: &'static str, value: f64) {
    with_recorder(|r| r.gauge_set(name, value));
}

/// Record one histogram sample.
pub fn observe(name: &'static str, value: u64) {
    with_recorder(|r| r.observe(name, value));
}

/// Emit one JSONL event (kind + numeric fields; see [`events`]).
pub fn event(kind: &'static str, fields: &[(&'static str, f64)]) {
    with_recorder(|r| r.event(kind, fields));
}

/// Open a nested span; records on drop. Inert (no clock read, no stack
/// push) when recording is disabled at the call.
pub fn span(name: &'static str) -> SpanGuard {
    SpanGuard::new(name, enabled())
}

/// Record `ns` under `rel_path` prefixed by this thread's open spans
/// (see [`span::Segments`] for the tiling use).
pub fn span_record(rel_path: &str, ns: u64) {
    with_recorder(|r| r.span_observe(&span::prefixed(rel_path), ns));
}

pub(crate) fn span_record_absolute(path: &str, ns: u64) {
    with_recorder(|r| r.span_observe(path, ns));
}

/// The concrete recorder the CLI installs: atomic registry + span set
/// + optional JSONL sink. Callers keep the concrete `Arc<RunRecorder>`
/// (and install a clone as `Arc<dyn Recorder>`) so they can render the
/// profile tree and Prometheus snapshot after the run.
pub struct RunRecorder {
    start: Instant,
    registry: Registry,
    spans: SpanSet,
    sink: Option<Mutex<Box<dyn Write + Send>>>,
}

impl RunRecorder {
    pub fn new() -> RunRecorder {
        RunRecorder::build(None)
    }

    /// Recorder that additionally streams JSONL events into `sink`
    /// (`--obs-log`).
    pub fn with_sink(sink: Box<dyn Write + Send>) -> RunRecorder {
        RunRecorder::build(Some(Mutex::new(sink)))
    }

    fn build(sink: Option<Mutex<Box<dyn Write + Send>>>) -> RunRecorder {
        RunRecorder {
            start: Instant::now(),
            registry: Registry::default(),
            spans: SpanSet::default(),
            sink,
        }
    }

    /// Seconds since the recorder was created (the `t_s` event clock).
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn spans(&self) -> Vec<(String, SpanStat)> {
        self.spans.snapshot()
    }

    /// Prometheus text snapshot of everything recorded so far.
    pub fn prometheus(&self) -> String {
        expose::render(
            &self.registry.counters(),
            &self.registry.gauges(),
            &self.registry.histograms(),
            &self.spans.snapshot(),
        )
    }

    /// The `--profile` timing tree, percentages relative to this
    /// recorder's lifetime.
    pub fn profile_report(&self) -> String {
        profile_tree(&self.spans.snapshot(), self.elapsed_s())
    }
}

impl Default for RunRecorder {
    fn default() -> Self {
        RunRecorder::new()
    }
}

impl Recorder for RunRecorder {
    fn counter_add(&self, name: &'static str, delta: u64) {
        self.registry.counter_add(name, delta);
    }

    fn gauge_set(&self, name: &'static str, value: f64) {
        self.registry.gauge_set(name, value);
    }

    fn observe(&self, name: &'static str, value: u64) {
        self.registry.observe(name, value);
    }

    fn span_observe(&self, path: &str, ns: u64) {
        self.spans.record(path, ns);
    }

    fn event(&self, kind: &'static str, fields: &[(&'static str, f64)]) {
        let Some(sink) = &self.sink else { return };
        let line = events::render(kind, self.elapsed_s(), fields);
        let mut w = sink.lock().unwrap();
        let _ = writeln!(w, "{line}");
    }

    fn flush(&self) {
        if let Some(sink) = &self.sink {
            let _ = sink.lock().unwrap().flush();
        }
    }
}

/// Render span stats as an indented tree: seconds, percent of `wall_s`,
/// and call count per path. Paths arrive sorted (child `a/b` directly
/// after parent `a`), so indentation by '/'-depth prints a tree. Ends
/// with the top-level sum — the line the acceptance check reads: the
/// engine's segment cuts tile its run, so top-level spans account for
/// the reported wall time.
pub fn profile_tree(spans: &[(String, SpanStat)], wall_s: f64) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "── profile ({wall_s:.3}s wall) ──");
    if spans.is_empty() {
        let _ = writeln!(out, "  (no spans recorded)");
        return out;
    }
    let mut top_ns = 0u64;
    for (path, stat) in spans {
        let depth = path.matches('/').count();
        if depth == 0 {
            top_ns += stat.total_ns;
        }
        let name = match path.rfind('/') {
            Some(i) => &path[i + 1..],
            None => path.as_str(),
        };
        let secs = stat.total_ns as f64 / 1e9;
        let pct = if wall_s > 0.0 { 100.0 * secs / wall_s } else { 0.0 };
        let pad = 30usize.saturating_sub(2 * depth).max(name.len());
        let _ = writeln!(
            out,
            "  {:indent$}{name:<pad$} {secs:>9.3}s {pct:>5.1}%  ×{}",
            "",
            stat.count,
            indent = 2 * depth,
        );
    }
    let top_s = top_ns as f64 / 1e9;
    let top_pct = if wall_s > 0.0 { 100.0 * top_s / wall_s } else { 0.0 };
    let _ = writeln!(out, "  top-level spans: {top_s:.3}s ({top_pct:.1}% of wall)");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests use a RunRecorder *directly* (never installed into
    // the process-global slot — unit tests run concurrently; the
    // global install path is exercised by `tests/obs.rs`, which
    // serializes itself).

    #[test]
    fn run_recorder_keeps_metrics_spans_and_events() {
        let buf = Arc::new(Mutex::new(Vec::<u8>::new()));
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedBuf {
            fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(data);
                Ok(data.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let rec = RunRecorder::with_sink(Box::new(SharedBuf(buf.clone())));
        rec.counter_add("engine_steps", 5);
        rec.gauge_set("engine_mean_score", 0.5);
        rec.observe("engine_frontier_size", 103);
        rec.span_observe("engine", 1000);
        rec.span_observe("engine/phase_a", 400);
        rec.event("run_start", &[]);
        rec.event("run_end", &[("wall_s", 0.01)]);
        rec.flush();

        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        assert_eq!(events::validate_events(&text), Ok(2));
        let prom = rec.prometheus();
        assert!(prom.contains("engine_steps 5"));
        assert!(prom.contains("span_seconds_total{path=\"engine/phase_a\"}"));
        let tree = rec.profile_report();
        assert!(tree.contains("engine"));
        assert!(tree.contains("phase_a"));
        assert!(tree.contains("top-level spans:"));
    }

    #[test]
    fn profile_tree_sums_top_level_only() {
        let spans = vec![
            ("engine".to_string(), SpanStat { total_ns: 2_000_000_000, count: 1, max_ns: 0 }),
            (
                "engine/phase_a".to_string(),
                SpanStat { total_ns: 1_500_000_000, count: 5, max_ns: 0 },
            ),
            ("stream_pass".to_string(), SpanStat { total_ns: 500_000_000, count: 3, max_ns: 0 }),
        ];
        let tree = profile_tree(&spans, 2.5);
        assert!(tree.contains("top-level spans: 2.500s (100.0% of wall)"), "{tree}");
        let empty = profile_tree(&[], 1.0);
        assert!(empty.contains("no spans recorded"));
    }
}
