//! Minimal std-only HTTP/1.1 server core over `std::net::TcpListener`.
//!
//! Deliberately a *substrate*, not a framework: one blocking accept
//! loop on its own thread (shutdown wakes it with a loopback
//! self-connect, so accepted requests pay no poll-interval latency),
//! thread-per-connection bounded by a connection budget (excess
//! requests get an immediate `503` instead of queueing behind a stuck
//! handler), and graceful shutdown that joins the accept loop and
//! drains in-flight connections with a deadline. `obs::http` mounts
//! the telemetry endpoints on it today; ROADMAP item 1's
//! partition-serving layer is the second intended tenant.
//!
//! Scope: `GET` only (anything else is `405`), request heads up to
//! [`MAX_REQUEST_BYTES`], `Connection: close` on every response, no
//! percent-decoding of query values (the telemetry query grammar is
//! `since=<integer>`).

use std::collections::BTreeMap;
use std::io::{self, Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Per-connection socket read/write timeout — a stalled peer cannot
/// pin a connection slot forever.
const IO_TIMEOUT: Duration = Duration::from_secs(5);

/// Upper bound on the request head (request line + headers).
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// How long [`Server::shutdown`] waits for in-flight connections.
const DRAIN_DEADLINE: Duration = Duration::from_secs(2);

/// A parsed request: method, path, and query pairs (`a=b` split on
/// `&`; keys without `=` map to the empty string; no percent-decoding).
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: BTreeMap<String, String>,
}

/// A response the handler returns; the server adds `Content-Length`
/// and `Connection: close`.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub headers: Vec<(&'static str, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16, content_type: &'static str, body: impl Into<Vec<u8>>) -> Response {
        Response { status, content_type, headers: Vec::new(), body: body.into() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response::new(status, "text/plain; charset=utf-8", body.into().into_bytes())
    }

    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response::new(status, "application/json", body.into().into_bytes())
    }

    pub fn not_found() -> Response {
        Response::text(404, "not found\n")
    }

    /// Attach an extra header (e.g. the `/events` cursor headers).
    pub fn header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.headers.push((name, value.into()));
        self
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

/// The request handler: called on a per-connection thread; must be
/// `Sync` because the budget allows concurrent connections.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A running HTTP server. Dropping it shuts it down.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (`HOST:PORT`; port 0 picks a free port — read the
    /// result back via [`Server::local_addr`]) and start serving
    /// `handler` with at most `max_conns` concurrent connections.
    ///
    /// `stop` is shared: the caller may hold a clone (long-poll
    /// handlers check it to end waits early), and [`Server::shutdown`]
    /// sets it.
    pub fn bind(
        addr: &str,
        max_conns: usize,
        stop: Arc<AtomicBool>,
        handler: Handler,
    ) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let active = Arc::new(AtomicUsize::new(0));
        let accept = {
            let stop = stop.clone();
            let active = active.clone();
            thread::Builder::new()
                .name("obs-httpd".into())
                .spawn(move || accept_loop(listener, max_conns.max(1), stop, active, handler))?
        };
        Ok(Server { addr: local, stop, active, accept: Some(accept) })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal stop, wake the blocking accept with a loopback
    /// self-connect, join the accept loop (closes the listener), then
    /// wait up to [`DRAIN_DEADLINE`] for in-flight connections.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect_timeout(&wake_addr(self.addr), Duration::from_millis(250));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let deadline = Instant::now() + DRAIN_DEADLINE;
        while self.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(5));
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Loopback address that reaches `local`'s listener from this host —
/// the shutdown wake target (an unspecified bind like `0.0.0.0` is not
/// connectable as written; its loopback of the same family is).
fn wake_addr(local: SocketAddr) -> SocketAddr {
    let mut addr = local;
    if addr.ip().is_unspecified() {
        match addr {
            SocketAddr::V4(_) => addr.set_ip(std::net::Ipv4Addr::LOCALHOST.into()),
            SocketAddr::V6(_) => addr.set_ip(std::net::Ipv6Addr::LOCALHOST.into()),
        }
    }
    addr
}

fn accept_loop(
    listener: TcpListener,
    max_conns: usize,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    handler: Handler,
) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(conn) => conn,
            // Transient accept errors (EMFILE, aborted handshake):
            // back off and keep serving.
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        // A post-stop accept is the shutdown self-connect (or a client
        // racing shutdown): drop it and exit.
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if active.fetch_add(1, Ordering::SeqCst) >= max_conns {
            active.fetch_sub(1, Ordering::SeqCst);
            respond_busy(stream);
            continue;
        }
        let handler = handler.clone();
        let done = active.clone();
        let spawned = thread::Builder::new().name("obs-http-conn".into()).spawn(move || {
            handle_connection(stream, handler.as_ref());
            done.fetch_sub(1, Ordering::SeqCst);
        });
        if spawned.is_err() {
            // Spawn failure dropped (closed) the stream with the move.
            active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Over-budget path: a canned `503` written on the accept thread.
fn respond_busy(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    let body = "busy: connection budget exhausted\n";
    let _ = write_response(&mut stream, &Response::text(503, body));
}

fn handle_connection(
    mut stream: TcpStream,
    handler: &(dyn Fn(&Request) -> Response + Send + Sync),
) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let resp = match read_request(&mut stream) {
        Ok(req) if req.method == "GET" => handler(&req),
        Ok(_) => Response::text(405, "method not allowed\n"),
        Err(_) => Response::text(400, "bad request\n"),
    };
    let _ = write_response(&mut stream, &resp);
}

/// Read and parse one request head (up to the blank line). Any body is
/// ignored — the served API is GET-only.
fn read_request(stream: &mut TcpStream) -> io::Result<Request> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        if find_head_end(&buf).is_some() {
            break;
        }
        if buf.len() >= MAX_REQUEST_BYTES {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "request head too large"));
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof before head end"));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
    let head = String::from_utf8_lossy(&buf);
    let line = head
        .lines()
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "empty request"))?;
    parse_request_line(line)
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "malformed request line"))
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

fn parse_request_line(line: &str) -> Option<Request> {
    let mut parts = line.split_whitespace();
    let method = parts.next()?.to_string();
    let target = parts.next()?;
    let version = parts.next()?;
    if !version.starts_with("HTTP/1.") {
        return None;
    }
    let (path, rawq) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut query = BTreeMap::new();
    for pair in rawq.split('&').filter(|p| !p.is_empty()) {
        match pair.split_once('=') {
            Some((k, v)) => query.insert(k.to_string(), v.to_string()),
            None => query.insert(pair.to_string(), String::new()),
        };
    }
    Some(Request { method, path: path.to_string(), query })
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> io::Result<()> {
    use std::fmt::Write as _;
    let mut head = String::with_capacity(128);
    let _ = write!(head, "HTTP/1.1 {} {}\r\n", resp.status, reason(resp.status));
    let _ = write!(head, "Content-Type: {}\r\n", resp.content_type);
    let _ = write!(head, "Content-Length: {}\r\n", resp.body.len());
    head.push_str("Connection: close\r\n");
    for (name, value) in &resp.headers {
        let _ = write!(head, "{name}: {value}\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

/// Tiny blocking client for tests, benches, and loopback self-checks:
/// one `GET target` with `Connection: close`, returning
/// `(status, headers, body)`. Not a general client — it reads to EOF
/// and assumes no transfer-encoding, which is exactly what [`Server`]
/// produces.
pub fn get(
    addr: SocketAddr,
    target: &str,
    timeout: Duration,
) -> io::Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let mut stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout.max(Duration::from_millis(1))))?;
    stream.set_write_timeout(Some(timeout.max(Duration::from_millis(1))))?;
    let req = format!("GET {target} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let head_end = find_head_end(&raw)
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "no response head"))?;
    let head = String::from_utf8_lossy(&raw[..head_end]);
    let mut lines = head.lines();
    let status_line =
        lines.next().ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "empty response"))?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad status line"))?;
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        .collect();
    Ok((status, headers, raw[head_end..].to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: Duration = Duration::from_secs(5);

    fn echo_server(max_conns: usize) -> Server {
        let handler: Handler = Arc::new(|req: &Request| {
            let q = req
                .query
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join("&");
            Response::text(200, format!("{} {} [{}]", req.method, req.path, q))
        });
        Server::bind("127.0.0.1:0", max_conns, Arc::new(AtomicBool::new(false)), handler)
            .expect("bind loopback")
    }

    #[test]
    fn serves_get_with_path_and_query() {
        let srv = echo_server(4);
        let (status, headers, body) = get(srv.local_addr(), "/p?a=1&b=two&flag", T).unwrap();
        assert_eq!(status, 200);
        assert_eq!(String::from_utf8(body).unwrap(), "GET /p [a=1&b=two&flag=]");
        let clen = headers.iter().find(|(k, _)| k == "Content-Length").unwrap();
        assert_eq!(clen.1, "24");
    }

    #[test]
    fn rejects_non_get_with_405() {
        let srv = echo_server(4);
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        s.write_all(b"POST /p HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).unwrap();
        assert!(raw.starts_with(b"HTTP/1.1 405 "), "{}", String::from_utf8_lossy(&raw));
    }

    #[test]
    fn malformed_request_line_gets_400() {
        let srv = echo_server(4);
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        s.write_all(b"garbage\r\n\r\n").unwrap();
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).unwrap();
        assert!(raw.starts_with(b"HTTP/1.1 400 "), "{}", String::from_utf8_lossy(&raw));
    }

    #[test]
    fn over_budget_connections_get_503() {
        // One slot; the first request parks inside the handler until
        // released, so the second deterministically exceeds the budget.
        let entered = Arc::new(AtomicUsize::new(0));
        let release = Arc::new(AtomicBool::new(false));
        let handler: Handler = {
            let entered = entered.clone();
            let release = release.clone();
            Arc::new(move |_req: &Request| {
                entered.fetch_add(1, Ordering::SeqCst);
                while !release.load(Ordering::SeqCst) {
                    thread::sleep(Duration::from_millis(2));
                }
                Response::text(200, "slow\n")
            })
        };
        let srv =
            Server::bind("127.0.0.1:0", 1, Arc::new(AtomicBool::new(false)), handler).unwrap();
        let addr = srv.local_addr();
        let slow = thread::spawn(move || get(addr, "/slow", T).unwrap().0);
        while entered.load(Ordering::SeqCst) == 0 {
            thread::sleep(Duration::from_millis(2));
        }
        let (status, _, _) = get(addr, "/busy", T).unwrap();
        assert_eq!(status, 503);
        release.store(true, Ordering::SeqCst);
        assert_eq!(slow.join().unwrap(), 200);
    }

    #[test]
    fn shutdown_closes_the_listener() {
        let mut srv = echo_server(2);
        let addr = srv.local_addr();
        assert_eq!(get(addr, "/x", T).unwrap().0, 200);
        srv.shutdown();
        assert!(get(addr, "/x", Duration::from_millis(500)).is_err());
    }

    #[test]
    fn request_line_parsing_covers_the_grammar() {
        let r = parse_request_line("GET /events?since=12 HTTP/1.1").unwrap();
        assert_eq!((r.method.as_str(), r.path.as_str()), ("GET", "/events"));
        assert_eq!(r.query.get("since").map(String::as_str), Some("12"));
        assert!(parse_request_line("GET /x").is_none(), "missing version");
        assert!(parse_request_line("GET /x SMTP/1.0").is_none(), "wrong protocol");
        assert!(parse_request_line("").is_none());
    }
}
