//! Minimal JSON reader/writer (serde is unavailable in the offline
//! vendored crate set).
//!
//! The reader covers the full JSON grammar minus exotic escapes
//! (`\uXXXX` surrogate pairs are decoded; everything the Python-emitted
//! `manifest.json` and our own reports contain round-trips). The writer
//! is used by the metrics reporters.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pair?
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i + 1..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    out.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                out.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 5 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::parse(r#""a\n\t\"\\ b é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ b é");
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(re, j);
    }

    #[test]
    fn surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
    }

    #[test]
    fn manifest_shape() {
        // The exact structure aot.py emits.
        let j = Json::parse(
            r#"{"alpha": 1.0, "batch": 256,
                "entries": [{"name": "step_b256_k8", "k": 8,
                  "inputs": [{"name": "hist", "shape": [256, 8], "dtype": "f32"}],
                  "outputs": ["scores", "p_next"]}]}"#,
        )
        .unwrap();
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("step_b256_k8"));
        assert_eq!(e.get("k").unwrap().as_usize(), Some(8));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(256));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn writer_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("x".into(), Json::Num(1.5));
        m.insert("y".into(), Json::Arr(vec![Json::Bool(false), Json::Null]));
        let j = Json::Obj(m);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
