//! Small self-contained substrates the rest of the crate builds on.
//!
//! The build environment is fully offline with a narrow vendored crate
//! set, so the pieces a typical project would pull from crates.io —
//! deterministic RNG, CLI parsing, JSON — are implemented here from
//! scratch (see DESIGN.md §3, S6/S16/S17).

pub mod args;
pub mod bench;
pub mod json;
pub mod rng;

/// Format a large count with thousands separators (`1234567` → `1,234,567`).
pub fn with_commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Simple wall-clock stopwatch for coarse phase timing.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commas() {
        assert_eq!(with_commas(0), "0");
        assert_eq!(with_commas(999), "999");
        assert_eq!(with_commas(1000), "1,000");
        assert_eq!(with_commas(1234567), "1,234,567");
        assert_eq!(with_commas(58333344), "58,333,344");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        assert!(sw.elapsed_ms() >= 0.0);
        assert!(sw.elapsed_s() >= 0.0);
    }
}
