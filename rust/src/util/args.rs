//! Minimal CLI argument parser (clap is unavailable in the offline
//! vendored crate set, so the launcher parses flags with this).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! flags, and positional arguments. Unknown-flag detection is the
//! caller's responsibility via [`Args::finish`].

use std::collections::BTreeMap;

/// Parsed command line: subcommand-style positionals + `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    consumed: std::collections::BTreeSet<String>,
}

#[derive(Debug)]
pub enum ArgError {
    MissingValue(String),
    BadValue(String, String, String),
    Unknown(Vec<String>),
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::MissingValue(flag) => write!(f, "flag --{flag} expects a value"),
            ArgError::BadValue(flag, value, err) => {
                write!(f, "flag --{flag}: cannot parse {value:?}: {err}")
            }
            ArgError::Unknown(flags) => write!(f, "unknown flags: {flags:?}"),
        }
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, ArgError> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else {
                    // `--flag value` unless next token is another flag or absent.
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.entry(rest.to_string()).or_default().push(v);
                        }
                        _ => {
                            out.flags.entry(rest.to_string()).or_default().push(String::new());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process's own command line.
    pub fn from_env() -> Result<Self, ArgError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Raw string flag (last occurrence wins). Marks the flag consumed.
    pub fn get(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.flags.get(key).and_then(|v| v.last()).cloned()
    }

    /// Boolean flag: present (with or without value) => true; `--x=false`
    /// and `--x false` are honoured.
    pub fn get_bool(&mut self, key: &str) -> bool {
        match self.get(key) {
            None => false,
            Some(v) => v.is_empty() || v == "true" || v == "1" || v == "yes",
        }
    }

    /// Typed flag with default.
    pub fn get_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T, ArgError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) if v.is_empty() => Err(ArgError::MissingValue(key.to_string())),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| ArgError::BadValue(key.to_string(), v, e.to_string())),
        }
    }

    /// Comma-separated list flag, e.g. `--parts 2,4,8`.
    pub fn get_list<T: std::str::FromStr>(
        &mut self,
        key: &str,
        default: &[T],
    ) -> Result<Vec<T>, ArgError>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) if v.is_empty() => Err(ArgError::MissingValue(key.to_string())),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<T>()
                        .map_err(|e| ArgError::BadValue(key.to_string(), s.to_string(), e.to_string()))
                })
                .collect(),
        }
    }

    /// Error if any flag was never consumed (caught typos).
    pub fn finish(self) -> Result<(), ArgError> {
        let unknown: Vec<String> = self
            .flags
            .keys()
            .filter(|k| !self.consumed.contains(*k))
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(ArgError::Unknown(unknown))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let mut a = parse(&["sweep", "--parts", "2,4", "--seed=7", "--verbose"]);
        assert_eq!(a.subcommand(), Some("sweep"));
        assert_eq!(a.get_list::<u32>("parts", &[]).unwrap(), vec![2, 4]);
        assert_eq!(a.get_or("seed", 0u64).unwrap(), 7);
        assert!(a.get_bool("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults() {
        let mut a = parse(&["run"]);
        assert_eq!(a.get_or("steps", 290u32).unwrap(), 290);
        assert_eq!(a.get_list::<u32>("parts", &[2, 4]).unwrap(), vec![2, 4]);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn equals_form_and_last_wins() {
        let mut a = parse(&["--k=8", "--k=16"]);
        assert_eq!(a.get_or("k", 0u32).unwrap(), 16);
    }

    #[test]
    fn bad_value_is_error() {
        let mut a = parse(&["--k", "banana"]);
        assert!(a.get_or("k", 0u32).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let mut a = parse(&["--real", "1", "--typo", "2"]);
        let _ = a.get("real");
        match a.finish() {
            Err(ArgError::Unknown(u)) => assert_eq!(u, vec!["typo".to_string()]),
            other => panic!("expected Unknown, got {other:?}"),
        }
    }

    #[test]
    fn bool_explicit_false() {
        let mut a = parse(&["--flag", "false"]);
        assert!(!a.get_bool("flag"));
    }

    #[test]
    fn negative_number_value() {
        // `--x -3` : "-3" does not start with "--" so it is a value.
        let mut a = parse(&["--x", "-3"]);
        assert_eq!(a.get_or("x", 0i64).unwrap(), -3);
    }
}
