//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component in the system (generators, roulette
//! wheels, migration coin-flips) draws from [`Rng`], seeded explicitly,
//! so whole experiments replay bit-identically from a CLI `--seed`.
//!
//! The generator is xoshiro256** (Blackman & Vigna), seeded through
//! SplitMix64 — the standard, fast, high-quality non-crypto combination.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state and
/// to derive independent per-thread / per-vertex streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** deterministic RNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // xoshiro must not be seeded with all zeros; splitmix64 of any
        // seed never yields four zeros, but guard anyway.
        if s == [0; 4] {
            return Rng { s: [1, 2, 3, 4] };
        }
        Rng { s }
    }

    /// Derive an independent stream for worker `idx` (per-thread RNGs).
    pub fn fork(&self, idx: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[3] ^ idx.wrapping_mul(0xA0761D6478BD642F);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift method
    /// (no modulo bias for bound << 2^64).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "seeds 1 and 2 should give unrelated streams");
    }

    #[test]
    fn fork_independent() {
        let base = Rng::new(7);
        let mut f0 = base.fork(0);
        let mut f1 = base.fork(1);
        let same = (0..64).filter(|_| f0.next_u64() == f1.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn below_never_exceeds_bound() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            assert!(r.below(3) < 3);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(17);
        assert!(!(0..1000).any(|_| r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }
}
