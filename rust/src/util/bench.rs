//! Micro-benchmark harness (criterion is unavailable in the offline
//! vendored crate set): warmup, repeated timed runs, and a
//! median/mean/min report. Used by every target under `rust/benches/`.

use std::time::Instant;

use crate::util::json::Json;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput in items/second given `items` processed per iteration.
    pub fn throughput(&self, items: u64) -> f64 {
        items as f64 / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10} median {:>10} mean {:>10} min ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` iterations, returning
/// per-iteration statistics. `f` should return something observable to
/// keep the optimizer honest; its result is passed through
/// `std::hint::black_box`.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let median = samples[samples.len() / 2];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: median,
        min_ns: samples[0],
        max_ns: *samples.last().unwrap(),
    }
}

/// Scale knob for bench workloads: `REVOLVER_BENCH_SCALE=full` runs the
/// paper-shaped sweep, anything else (default) a fast smoke variant so
/// `cargo bench` completes in minutes on one core.
pub fn full_scale() -> bool {
    std::env::var("REVOLVER_BENCH_SCALE").map(|v| v == "full").unwrap_or(false)
}

/// Pick a scale exponent by bench mode: `full` under
/// `REVOLVER_BENCH_SCALE=full`, otherwise `smoke`.
pub fn scale_exp(full: u32, smoke: u32) -> u32 {
    if full_scale() {
        full
    } else {
        smoke
    }
}

/// The shared power-law benchmark graph: R-MAT with the Graph500
/// (0.57, 0.19, 0.19) probabilities, 16 edges per vertex, fixed seed 11,
/// at `|V| = 2^scale_exp`. One recipe for every bench section that
/// needs a skewed graph (schedule, stream, multilevel, frontier)
/// instead of per-file copies of the same call.
pub fn bench_rmat(scale_exp: u32) -> crate::graph::Graph {
    let n = 1usize << scale_exp;
    crate::graph::gen::rmat::rmat(n, 16 * n, 0.57, 0.19, 0.19, 11)
}

/// Validate a `BENCH_JSON` row array against a section spec before it
/// is printed (and when CI re-parses the harvested line): `spec` maps
/// each legal `"bench"` section tag to the numeric keys every row of
/// that section must carry. Rows must be objects, carry a string
/// `"bench"` tag listed in the spec, hold only string/number values
/// (the flat schema BENCH_hotpath.json documents), and provide every
/// required key as a number. Returns the row count.
///
/// This is the schema gate for the recorded bench trajectory — a
/// renamed key or dropped section fails here, in-process, instead of
/// silently producing unmergeable history rows.
pub fn validate_rows(rows: &Json, spec: &[(&str, &[&str])]) -> Result<usize, String> {
    let arr = rows.as_arr().ok_or("BENCH_JSON payload must be an array")?;
    for (i, row) in arr.iter().enumerate() {
        let obj = match row {
            Json::Obj(m) => m,
            _ => return Err(format!("row {i}: not an object")),
        };
        let section = row
            .get("bench")
            .and_then(|b| b.as_str())
            .ok_or(format!("row {i}: missing string \"bench\" tag"))?;
        let required = spec
            .iter()
            .find(|(name, _)| *name == section)
            .map(|(_, keys)| *keys)
            .ok_or(format!("row {i}: unknown section {section:?}"))?;
        for key in required {
            match row.get(key) {
                Some(Json::Num(x)) if x.is_finite() => {}
                Some(_) => return Err(format!("row {i} ({section}): {key:?} not finite")),
                None => return Err(format!("row {i} ({section}): missing {key:?}")),
            }
        }
        for (key, val) in obj.iter() {
            if !matches!(val, Json::Num(_) | Json::Str(_)) {
                return Err(format!("row {i} ({section}): {key:?} must be number/string"));
            }
        }
    }
    Ok(arr.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 9, || {
            std::hint::black_box((0..100).sum::<u64>())
        });
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.max_ns);
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 9);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            min_ns: 1e9,
            max_ns: 1e9,
        };
        assert!((r.throughput(1000) - 1000.0).abs() < 1e-9);
        assert!((r.mean_ms() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn bench_rmat_recipe_is_deterministic() {
        let a = bench_rmat(8);
        let b = bench_rmat(8);
        assert_eq!(a.num_vertices(), 256);
        assert!(a.num_edges() > 0);
        assert_eq!(a.num_edges(), b.num_edges(), "fixed seed must reproduce");
    }

    #[test]
    fn display_formats() {
        let r = bench("fmt", 0, 3, || 1 + 1);
        let s = format!("{r}");
        assert!(s.contains("fmt"));
    }

    #[test]
    fn validate_rows_accepts_spec_conformant_rows() {
        let spec: &[(&str, &[&str])] =
            &[("alpha", &["mean_ns"]), ("beta", &["mean_ns", "evaluated"])];
        let rows = Json::parse(
            r#"[{"bench":"alpha","mean_ns":12.5,"note":"x"},
                {"bench":"beta","mean_ns":3,"evaluated":400}]"#,
        )
        .unwrap();
        assert_eq!(validate_rows(&rows, spec), Ok(2));
        assert_eq!(validate_rows(&Json::Arr(vec![]), spec), Ok(0));
    }

    #[test]
    fn validate_rows_rejects_schema_drift() {
        let spec: &[(&str, &[&str])] = &[("alpha", &["mean_ns"])];
        // Not an array.
        assert!(validate_rows(&Json::Num(1.0), spec).is_err());
        // Missing tag / unknown section / missing required key.
        for bad in [
            r#"[{"mean_ns":1}]"#,
            r#"[{"bench":"gamma","mean_ns":1}]"#,
            r#"[{"bench":"alpha"}]"#,
            // Required key present but not a finite number.
            r#"[{"bench":"alpha","mean_ns":"fast"}]"#,
            // Nested values break the flat schema.
            r#"[{"bench":"alpha","mean_ns":1,"sub":{"x":1}}]"#,
        ] {
            assert!(validate_rows(&Json::parse(bad).unwrap(), spec).is_err(), "{bad}");
        }
    }
}
