//! Fault tolerance: deterministic fault injection, checkpoint/resume.
//!
//! Cloud partitioning runs on preemptible, failure-prone machines
//! (PAPER.md §I; Spinner's deployment story). This module makes those
//! failure modes *first-class and reproducible*:
//!
//! * [`FaultPlan`] — a parsed `--faults` spec that injects worker
//!   panics, checkpoint IO errors and truncated update logs at exact,
//!   seeded points, so every crash-recovery path in the test suite and
//!   the CI crash smoke exercises the same code a real preemption
//!   would, deterministically.
//! * [`checkpoint`] — the versioned, checksummed `RVCK` snapshot
//!   format plus the atomic [`checkpoint::Checkpointer`] writer and
//!   [`checkpoint::load_latest`] resume entry point.
//!
//! The containment half of the story — `catch_unwind` around worker
//! phases, the poison flag checked at every barrier — lives in
//! [`crate::engine`] (it is inseparable from the barrier protocol);
//! this module only owns the injection spec and the durable state.

pub mod checkpoint;

pub use checkpoint::{load_latest, Checkpointer, LaSlab, Snapshot};

use anyhow::{bail, Result};

/// A deterministic fault-injection plan, parsed from
/// `--faults "panic@step:7,io@checkpoint:2,truncate@log:40%"`.
///
/// Each clause arms one failure site:
///
/// * `panic@step:N` — worker 0 panics inside phase A of superstep `N`
///   (0-based), exercising the engine's containment protocol.
/// * `io@checkpoint:N` — the `N`-th checkpoint write attempt (1-based)
///   fails with an injected IO error; the run continues and counts it.
/// * `truncate@log:P%` — the update log is truncated to the first `P`
///   percent of its lines before parsing, simulating a torn write.
///
/// The empty string parses to the empty plan (nothing armed).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Panic worker 0 in phase A of this superstep.
    pub panic_at_step: Option<u32>,
    /// Fail this (1-based) checkpoint write attempt.
    pub io_at_checkpoint: Option<u64>,
    /// Truncate the update log to this fraction of its lines, in
    /// percent (0..=100).
    pub truncate_log_pct: Option<f64>,
}

impl FaultPlan {
    /// True when no fault is armed — the common production case.
    pub fn is_empty(&self) -> bool {
        self.panic_at_step.is_none()
            && self.io_at_checkpoint.is_none()
            && self.truncate_log_pct.is_none()
    }
}

impl std::str::FromStr for FaultPlan {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut plan = FaultPlan::default();
        for clause in s.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (site, arg) = match clause.split_once(':') {
                Some(pair) => pair,
                None => bail!(
                    "fault clause {clause:?} needs an argument, e.g. panic@step:7"
                ),
            };
            match site.to_lowercase().as_str() {
                "panic@step" => {
                    let step: u32 = arg
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad step in {clause:?}"))?;
                    plan.panic_at_step = Some(step);
                }
                "io@checkpoint" => {
                    let nth: u64 = arg
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad attempt index in {clause:?}"))?;
                    anyhow::ensure!(nth >= 1, "io@checkpoint attempt is 1-based, got {nth}");
                    plan.io_at_checkpoint = Some(nth);
                }
                "truncate@log" => {
                    let pct_str = arg.strip_suffix('%').unwrap_or(arg);
                    let pct: f64 = pct_str
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad percentage in {clause:?}"))?;
                    anyhow::ensure!(
                        (0.0..=100.0).contains(&pct),
                        "truncate@log percentage must be in 0..=100, got {pct}"
                    );
                    plan.truncate_log_pct = Some(pct);
                }
                other => bail!(
                    "unknown fault site {other:?} (expected panic@step|io@checkpoint|truncate@log)"
                ),
            }
        }
        Ok(plan)
    }
}

impl std::fmt::Display for FaultPlan {
    /// Canonical form: clauses in `panic@step, io@checkpoint,
    /// truncate@log` order — round-trips through [`FromStr`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut sep = "";
        if let Some(s) = self.panic_at_step {
            write!(f, "panic@step:{s}")?;
            sep = ",";
        }
        if let Some(n) = self.io_at_checkpoint {
            write!(f, "{sep}io@checkpoint:{n}")?;
            sep = ",";
        }
        if let Some(p) = self.truncate_log_pct {
            write!(f, "{sep}truncate@log:{p}%")?;
        }
        Ok(())
    }
}

/// Truncate `text` to the first `pct`% of its lines (rounding down) —
/// the `truncate@log` fault applied to an in-memory update log.
pub fn truncate_lines(text: &str, pct: f64) -> String {
    let lines: Vec<&str> = text.lines().collect();
    let keep = ((lines.len() as f64) * pct / 100.0).floor() as usize;
    let mut out = String::with_capacity(text.len());
    for line in &lines[..keep.min(lines.len())] {
        out.push_str(line);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_parses_and_is_empty() {
        let p: FaultPlan = "".parse().unwrap();
        assert!(p.is_empty());
        assert_eq!(p, FaultPlan::default());
    }

    #[test]
    fn full_plan_parses_all_clauses() {
        let p: FaultPlan = "panic@step:7,io@checkpoint:2,truncate@log:40%".parse().unwrap();
        assert_eq!(p.panic_at_step, Some(7));
        assert_eq!(p.io_at_checkpoint, Some(2));
        assert_eq!(p.truncate_log_pct, Some(40.0));
        assert!(!p.is_empty());
    }

    #[test]
    fn display_round_trips_canonical_order() {
        for spec in [
            "panic@step:0",
            "io@checkpoint:1",
            "truncate@log:12.5%",
            "panic@step:3,io@checkpoint:9",
            "panic@step:7,io@checkpoint:2,truncate@log:40%",
        ] {
            let p: FaultPlan = spec.parse().unwrap();
            assert_eq!(p.to_string(), spec);
            let back: FaultPlan = p.to_string().parse().unwrap();
            assert_eq!(back, p, "{spec}");
        }
    }

    #[test]
    fn clause_order_and_case_are_forgiving() {
        let p: FaultPlan = " TRUNCATE@LOG:50 , panic@step:1 ".parse().unwrap();
        assert_eq!(p.panic_at_step, Some(1));
        assert_eq!(p.truncate_log_pct, Some(50.0));
    }

    #[test]
    fn malformed_plans_are_rejected() {
        for bad in [
            "panic@step",          // no argument
            "panic@step:x",        // non-numeric
            "io@checkpoint:0",     // 1-based
            "truncate@log:101%",   // out of range
            "truncate@log:-1",     // out of range
            "explode@heap:1",      // unknown site
        ] {
            assert!(bad.parse::<FaultPlan>().is_err(), "{bad}");
        }
    }

    #[test]
    fn truncate_lines_keeps_prefix() {
        let text = "a\nb\nc\nd\n";
        assert_eq!(truncate_lines(text, 50.0), "a\nb\n");
        assert_eq!(truncate_lines(text, 100.0), text);
        assert_eq!(truncate_lines(text, 0.0), "");
        // 40% of 4 lines floors to 1.
        assert_eq!(truncate_lines(text, 40.0), "a\n");
    }
}
