//! The `RVCK` checkpoint format: a versioned, checksummed binary
//! snapshot of everything a run needs to restart — assignment labels,
//! per-partition load masses, the RNG/step/epoch cursors, and
//! (optionally) Revolver's learning-automata slab so a resumed run
//! keeps its learned action probabilities instead of re-warming them.
//!
//! ## Layout (little-endian throughout)
//!
//! ```text
//! "RVCK"  magic            4 bytes
//! version u32              currently 1
//! seed    u64              the run's RNG seed (per-step RNGs are pure
//!                          functions of (seed, salt, step, worker), so
//!                          no raw generator state is stored)
//! step    u32              next engine superstep to execute
//! epoch   u64              next dynamic epoch to apply
//! k       u32              partition count
//! n       u64              vertex count
//! labels  n × u32          the assignment
//! loads   k × u64          per-partition load masses b(l)
//! slab    u8 tag           0 = none, 1 = f32, 2 = q16
//!         [rows u64, cols u32, rows×cols payload]   when tag != 0
//! fnv     u64              FNV-1a-64 over every preceding byte
//! ```
//!
//! The checksum is verified *before* any field is parsed: FNV-1a's
//! per-byte transform (xor then odd multiply) is injective in the
//! hash state, so any single-byte corruption is guaranteed to change
//! the digest — the corrupt-one-byte property test relies on this.
//!
//! Writes are atomic: encode to a sibling `*.tmp`, `sync_all`, then
//! `rename` into place — a crash mid-write leaves at most a stale tmp
//! file, never a torn checkpoint that [`load_latest`] could pick up.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::FaultPlan;
use crate::Label;

const MAGIC: &[u8; 4] = b"RVCK";
const VERSION: u32 = 1;

/// A captured learning-automata slab, in whichever storage format the
/// run used (`--prob-format`). Restoring checks shape, not format:
/// the slab round-trips bit-identically into the same `ProbSlab`
/// variant it was dumped from.
#[derive(Debug, Clone, PartialEq)]
pub enum LaSlab {
    F32 { cols: u32, data: Vec<f32> },
    Q16 { cols: u32, data: Vec<u16> },
}

impl LaSlab {
    /// Row count (vertices covered by the slab).
    pub fn rows(&self) -> usize {
        match self {
            LaSlab::F32 { cols, data } => data.len() / (*cols).max(1) as usize,
            LaSlab::Q16 { cols, data } => data.len() / (*cols).max(1) as usize,
        }
    }

    /// Column count (actions per row = partitions).
    pub fn cols(&self) -> u32 {
        match self {
            LaSlab::F32 { cols, .. } | LaSlab::Q16 { cols, .. } => *cols,
        }
    }
}

/// One durable restart point.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// The run's RNG seed — per-step RNGs are derived, never stored.
    pub seed: u64,
    /// Next engine superstep to execute (0-based).
    pub step: u32,
    /// Next dynamic epoch to apply (0-based).
    pub epoch: u64,
    /// Partition count.
    pub k: u32,
    /// The assignment, `labels[v]` in `0..k`.
    pub labels: Vec<Label>,
    /// Per-partition load masses, `loads.len() == k`.
    pub loads: Vec<u64>,
    /// Revolver's LA slab, when the program exposes one.
    pub la: Option<LaSlab>,
}

impl Snapshot {
    /// The monotone cursor a filename encodes: dynamic checkpoints
    /// advance by epoch, partition checkpoints by step. A run uses one
    /// cadence or the other, so the max is strictly increasing within
    /// a run and `load_latest`'s lexicographic pick is the newest.
    pub fn cursor(&self) -> u64 {
        self.epoch.max(self.step as u64)
    }
}

/// FNV-1a 64-bit. The per-byte update `h = (h ^ b) * PRIME` is a
/// bijection on the 64-bit state for fixed `b` (xor is, and the prime
/// is odd hence invertible mod 2^64), so two payloads differing in
/// exactly one byte can never collide.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize a snapshot, checksum included.
pub fn encode(s: &Snapshot) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + s.labels.len() * 4 + s.loads.len() * 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&s.seed.to_le_bytes());
    out.extend_from_slice(&s.step.to_le_bytes());
    out.extend_from_slice(&s.epoch.to_le_bytes());
    out.extend_from_slice(&s.k.to_le_bytes());
    out.extend_from_slice(&(s.labels.len() as u64).to_le_bytes());
    for &l in &s.labels {
        out.extend_from_slice(&l.to_le_bytes());
    }
    for &m in &s.loads {
        out.extend_from_slice(&m.to_le_bytes());
    }
    match &s.la {
        None => out.push(0),
        Some(LaSlab::F32 { cols, data }) => {
            out.push(1);
            out.extend_from_slice(&(data.len() as u64 / (*cols).max(1) as u64).to_le_bytes());
            out.extend_from_slice(&cols.to_le_bytes());
            for &x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Some(LaSlab::Q16 { cols, data }) => {
            out.push(2);
            out.extend_from_slice(&(data.len() as u64 / (*cols).max(1) as u64).to_le_bytes());
            out.extend_from_slice(&cols.to_le_bytes());
            for &x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    let sum = fnv1a64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Bounds-checked little-endian cursor — every read is validated, so
/// a truncated or hostile payload yields a structured error, never a
/// panic or an unbounded allocation.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.bytes.len());
        match end {
            Some(end) => {
                let s = &self.bytes[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => bail!("checkpoint truncated at byte {}", self.pos),
        }
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }
}

/// Deserialize and verify a snapshot. The checksum is checked before
/// any field is trusted; all counts are validated against the actual
/// payload size before allocation.
pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
    if bytes.len() < MAGIC.len() + 8 {
        bail!("checkpoint too short ({} bytes)", bytes.len());
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let computed = fnv1a64(body);
    if stored != computed {
        bail!("checkpoint checksum mismatch (stored {stored:#018x}, computed {computed:#018x})");
    }
    let mut c = Cursor { bytes: body, pos: 0 };
    if c.take(4)? != MAGIC {
        bail!("not a revolver checkpoint (bad magic)");
    }
    let version = c.u32()?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let seed = c.u64()?;
    let step = c.u32()?;
    let epoch = c.u64()?;
    let k = c.u32()?;
    let n = c.u64()? as usize;
    if n.checked_mul(4).map_or(true, |b| b > c.remaining()) {
        bail!("checkpoint claims {n} labels but only {} bytes remain", c.remaining());
    }
    let mut labels = Vec::with_capacity(n);
    for chunk in c.take(n * 4)?.chunks_exact(4) {
        labels.push(u32::from_le_bytes(chunk.try_into().unwrap()));
    }
    let kk = k as usize;
    if kk.checked_mul(8).map_or(true, |b| b > c.remaining()) {
        bail!("checkpoint claims {k} loads but only {} bytes remain", c.remaining());
    }
    let mut loads = Vec::with_capacity(kk);
    for chunk in c.take(kk * 8)?.chunks_exact(8) {
        loads.push(u64::from_le_bytes(chunk.try_into().unwrap()));
    }
    let la = match c.u8()? {
        0 => None,
        tag @ (1 | 2) => {
            let rows = c.u64()? as usize;
            let cols = c.u32()?;
            let cells = rows
                .checked_mul(cols as usize)
                .with_context(|| format!("slab shape overflow ({rows}×{cols})"))?;
            let width = if tag == 1 { 4 } else { 2 };
            if cells.checked_mul(width).map_or(true, |b| b > c.remaining()) {
                bail!(
                    "checkpoint claims a {rows}×{cols} slab but only {} bytes remain",
                    c.remaining()
                );
            }
            let raw = c.take(cells * width)?;
            Some(if tag == 1 {
                LaSlab::F32 {
                    cols,
                    data: raw
                        .chunks_exact(4)
                        .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
                        .collect(),
                }
            } else {
                LaSlab::Q16 {
                    cols,
                    data: raw
                        .chunks_exact(2)
                        .map(|ch| u16::from_le_bytes(ch.try_into().unwrap()))
                        .collect(),
                }
            })
        }
        other => bail!("unknown slab tag {other}"),
    };
    if c.remaining() != 0 {
        bail!("{} trailing bytes after checkpoint payload", c.remaining());
    }
    anyhow::ensure!(
        loads.len() == k as usize,
        "checkpoint has {} loads for k={k}",
        loads.len()
    );
    Ok(Snapshot { seed, step, epoch, k, labels, loads, la })
}

/// Write `bytes` to `path` atomically: sibling tmp + fsync + rename.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        f.write_all(bytes).with_context(|| format!("write {tmp:?}"))?;
        f.sync_all().with_context(|| format!("sync {tmp:?}"))?;
    }
    fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Periodic checkpoint writer with deterministic IO-fault injection.
///
/// `write` is infallible from the run's point of view in the sense
/// that the caller decides whether a failed checkpoint is fatal — the
/// engine and the dynamic loop both log-and-continue (a lost
/// checkpoint widens the replay window, it doesn't corrupt state).
pub struct Checkpointer {
    dir: PathBuf,
    /// 1-based write attempts so far (successful or not).
    attempts: u64,
    /// Inject an IO error on this attempt (`io@checkpoint:N`).
    io_fault_at: Option<u64>,
}

impl Checkpointer {
    pub fn new<P: Into<PathBuf>>(dir: P, faults: &FaultPlan) -> Self {
        Checkpointer {
            dir: dir.into(),
            attempts: 0,
            io_fault_at: faults.io_at_checkpoint,
        }
    }

    /// Write one snapshot as `ckpt-{cursor:012}.rvck`. Counts the
    /// attempt, injects the planned IO fault, and emits the
    /// `checkpoint` obs event + counters on success.
    pub fn write(&mut self, snap: &Snapshot) -> Result<PathBuf> {
        self.attempts += 1;
        if self.io_at_fault() {
            crate::obs::counter_add("checkpoint_failures", 1);
            bail!("injected fault: io@checkpoint:{}", self.attempts);
        }
        fs::create_dir_all(&self.dir).with_context(|| format!("create {:?}", self.dir))?;
        let path = self.dir.join(format!("ckpt-{:012}.rvck", snap.cursor()));
        write_atomic(&path, &encode(snap))?;
        crate::obs::counter_add("checkpoint_writes", 1);
        crate::obs::event(
            "checkpoint",
            &[("step", snap.step as f64), ("epoch", snap.epoch as f64)],
        );
        crate::obs::log::debug(&format!(
            "checkpoint: wrote {path:?} (step {}, epoch {})",
            snap.step, snap.epoch
        ));
        Ok(path)
    }

    fn io_at_fault(&self) -> bool {
        self.io_fault_at == Some(self.attempts)
    }
}

/// Load the newest checkpoint in `dir`, or `None` when the directory
/// is missing/empty. Filenames encode a zero-padded monotone cursor,
/// so the lexicographically greatest `ckpt-*.rvck` is the newest; a
/// corrupt newest checkpoint is a hard error (silently falling back
/// to an older one would hide data loss).
pub fn load_latest(dir: &Path) -> Result<Option<Snapshot>> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(ref e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("read {dir:?}")),
    };
    let mut newest: Option<PathBuf> = None;
    for entry in entries {
        let path = entry?.path();
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        if name.starts_with("ckpt-") && name.ends_with(".rvck") {
            if newest.as_ref().map_or(true, |cur| path > *cur) {
                newest = Some(path);
            }
        }
    }
    match newest {
        None => Ok(None),
        Some(path) => {
            let bytes = fs::read(&path).with_context(|| format!("read {path:?}"))?;
            let snap = decode(&bytes).with_context(|| format!("decode {path:?}"))?;
            Ok(Some(snap))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(seed: u64, n: usize, k: u32, la: Option<LaSlab>) -> Snapshot {
        let mut rng = Rng::new(seed);
        Snapshot {
            seed,
            step: rng.below(1000) as u32,
            epoch: rng.below(50),
            k,
            labels: (0..n).map(|_| rng.below(k as u64) as Label).collect(),
            loads: (0..k).map(|_| rng.below(1 << 20)).collect(),
            la,
        }
    }

    fn slab_f32(seed: u64, rows: usize, cols: u32) -> LaSlab {
        let mut rng = Rng::new(seed ^ 0xF32);
        LaSlab::F32 {
            cols,
            data: (0..rows * cols as usize).map(|_| rng.next_f32()).collect(),
        }
    }

    fn slab_q16(seed: u64, rows: usize, cols: u32) -> LaSlab {
        let mut rng = Rng::new(seed ^ 0x916);
        LaSlab::Q16 {
            cols,
            data: (0..rows * cols as usize).map(|_| rng.below(65536) as u16).collect(),
        }
    }

    #[test]
    fn round_trip_is_bit_identical() {
        for seed in [1u64, 7, 42, 1234] {
            for la in [
                None,
                Some(slab_f32(seed, 33, 4)),
                Some(slab_q16(seed, 33, 4)),
            ] {
                let snap = sample(seed, 33, 4, la);
                let back = decode(&encode(&snap)).unwrap();
                assert_eq!(back, snap, "seed={seed}");
            }
        }
    }

    #[test]
    fn corrupt_any_single_byte_is_rejected() {
        // Property: flipping any one byte of the encoding — header,
        // labels, loads, slab payload, or the checksum itself — must
        // make decode fail. FNV-1a's injective per-byte transform
        // guarantees the digest moves; a flipped trailer byte changes
        // the stored sum instead.
        for seed in [3u64, 99, 2024] {
            for la in [None, Some(slab_f32(seed, 9, 3)), Some(slab_q16(seed, 9, 3))] {
                let snap = sample(seed, 17, 3, la);
                let clean = encode(&snap);
                assert!(decode(&clean).is_ok());
                let mut rng = Rng::new(seed ^ 0xC0);
                // Exhaustive would be O(len²) comparisons; 64 random
                // positions per layout plus the first/last bytes cover
                // every section across seeds.
                let mut positions: Vec<usize> =
                    (0..64).map(|_| rng.below(clean.len() as u64) as usize).collect();
                positions.push(0);
                positions.push(clean.len() - 1);
                for pos in positions {
                    let mut bad = clean.clone();
                    let flip = 1u8 << rng.below(8);
                    bad[pos] ^= flip;
                    let err = decode(&bad);
                    assert!(err.is_err(), "seed={seed} pos={pos} flip={flip:#x}");
                }
            }
        }
    }

    #[test]
    fn truncation_and_garbage_are_structured_errors() {
        let snap = sample(5, 10, 2, Some(slab_q16(5, 10, 2)));
        let clean = encode(&snap);
        for cut in [0, 3, 11, clean.len() / 2, clean.len() - 1] {
            assert!(decode(&clean[..cut]).is_err(), "cut={cut}");
        }
        assert!(decode(b"").is_err());
        assert!(decode(b"RVCKxxxxxxxxxxxx").is_err());
        // A huge claimed label count must not allocate: craft a valid
        // checksum over a hostile body.
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.extend_from_slice(&7u64.to_le_bytes()); // seed
        body.extend_from_slice(&0u32.to_le_bytes()); // step
        body.extend_from_slice(&0u64.to_le_bytes()); // epoch
        body.extend_from_slice(&2u32.to_le_bytes()); // k
        body.extend_from_slice(&u64::MAX.to_le_bytes()); // n — hostile
        let sum = fnv1a64(&body);
        body.extend_from_slice(&sum.to_le_bytes());
        let err = decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("labels"), "{err:#}");
    }

    #[test]
    fn checkpointer_writes_and_load_latest_picks_newest() {
        let dir = std::env::temp_dir().join("revolver_ckpt_test_latest");
        let _ = fs::remove_dir_all(&dir);
        let mut ck = Checkpointer::new(&dir, &FaultPlan::default());
        let mut older = sample(11, 20, 4, None);
        older.step = 0;
        older.epoch = 2;
        let mut newer = older.clone();
        newer.epoch = 5;
        ck.write(&older).unwrap();
        ck.write(&newer).unwrap();
        let got = load_latest(&dir).unwrap().expect("checkpoint present");
        assert_eq!(got, newer);
        // Missing directory is a clean None, not an error.
        let missing = dir.join("nope");
        assert!(load_latest(&missing).unwrap().is_none());
    }

    #[test]
    fn injected_io_fault_fails_exactly_the_nth_attempt() {
        let dir = std::env::temp_dir().join("revolver_ckpt_test_iofault");
        let _ = fs::remove_dir_all(&dir);
        let faults: FaultPlan = "io@checkpoint:2".parse().unwrap();
        let mut ck = Checkpointer::new(&dir, &faults);
        let mut snap = sample(13, 8, 2, None);
        snap.epoch = 1;
        assert!(ck.write(&snap).is_ok(), "attempt 1 succeeds");
        snap.epoch = 2;
        let err = ck.write(&snap).unwrap_err();
        assert!(format!("{err}").contains("injected fault"), "{err}");
        snap.epoch = 3;
        assert!(ck.write(&snap).is_ok(), "attempt 3 succeeds");
        // The failed epoch-2 write left no file; latest is epoch 3.
        let got = load_latest(&dir).unwrap().unwrap();
        assert_eq!(got.epoch, 3);
    }

    #[test]
    fn corrupt_newest_checkpoint_is_a_hard_error() {
        let dir = std::env::temp_dir().join("revolver_ckpt_test_corrupt");
        let _ = fs::remove_dir_all(&dir);
        let mut ck = Checkpointer::new(&dir, &FaultPlan::default());
        let snap = sample(17, 6, 2, None);
        let path = ck.write(&snap).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        let err = load_latest(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }
}
