//! Graph updates: the [`UpdateBatch`] model, a text update-log reader
//! (built on [`crate::graph::parse`] — same line grammar and id
//! densification as every other text reader in the system), and
//! synthetic churn generators for benchmarks and tests.
//!
//! ## Update-log format
//!
//! One operation per line; `#` / `%` comments and blank lines are
//! skipped (exactly like edge-list files):
//!
//! ```text
//! src dst        add edge          (a plain edge list is a valid log)
//! a src dst      add edge          (explicit form)
//! d src dst      delete edge
//! av id          add vertex        (isolated arrival)
//! dv id          delete vertex     (tombstone)
//! commit         batch boundary    (one epoch of updates)
//! ```
//!
//! Raw ids are densified in first-appearance order through the shared
//! [`crate::graph::parse::densify`], with the id map pre-seeded as the
//! identity over the base graph's `0..n` — so a log written against a
//! loaded/generated graph's dense ids means what it says, and unseen
//! ids become arrivals with the next dense id (the same mapping
//! [`crate::graph::io::read_edge_list`] would produce had the log been
//! an edge list). Only *adding* ops allocate ids: a delete (`d` / `dv`)
//! naming an unseen id is a guaranteed no-op and is skipped via lookup,
//! never densified — otherwise a stale delete line would mint phantom
//! vertices that materialize on the next arrival.

use std::collections::HashMap;
use std::io::BufRead;

use anyhow::{bail, Context, Result};

use crate::config::IngestMode;
use crate::graph::parse::{densify, line_err, parse_edge_line, read_raw_line, snippet};
use crate::graph::Graph;
use crate::util::rng::Rng;
use crate::VertexId;

/// One graph mutation, in dense vertex ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Update {
    AddEdge(VertexId, VertexId),
    RemoveEdge(VertexId, VertexId),
    /// Ensure the vertex exists and is alive (isolated arrival /
    /// revival).
    AddVertex(VertexId),
    /// Tombstone the vertex and drop its incident edges.
    RemoveVertex(VertexId),
}

/// An atomic group of updates — what one [`super::IncrementalPartitioner`]
/// epoch applies and repairs against.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct UpdateBatch {
    pub updates: Vec<Update>,
}

impl UpdateBatch {
    pub fn is_empty(&self) -> bool {
        self.updates.is_empty()
    }

    pub fn len(&self) -> usize {
        self.updates.len()
    }
}

/// Read a whole update log into its `commit`-separated batches.
/// `base_vertices` pre-seeds the densification map with the identity
/// over `0..base_vertices` (pass 0 to build a graph from scratch out
/// of a pure-add log). A trailing unterminated batch is kept; empty
/// batches (consecutive `commit`s) are dropped.
pub fn read_update_log<R: BufRead>(r: R, base_vertices: usize) -> Result<Vec<UpdateBatch>> {
    read_update_log_named(r, base_vertices, "<update log>", IngestMode::Strict)
}

/// [`read_update_log`] with a source label for diagnostics and an
/// explicit [`IngestMode`]. Lines are read under the same
/// [`crate::graph::parse::MAX_LINE_BYTES`] cap as every other text
/// reader; in `Lenient` mode malformed lines are skipped-and-counted
/// (`ingest_skipped_lines`) without densifying any of their ids, so a
/// skipped line can never mint phantom vertices.
pub fn read_update_log_named<R: BufRead>(
    mut r: R,
    base_vertices: usize,
    label: &str,
    mode: IngestMode,
) -> Result<Vec<UpdateBatch>> {
    let mut ids: HashMap<u64, VertexId> = HashMap::with_capacity(base_vertices);
    for v in 0..base_vertices as u64 {
        ids.insert(v, v as VertexId);
    }
    let mut batches = Vec::new();
    let mut cur = UpdateBatch::default();
    let mut buf = Vec::new();
    let mut lineno = 0usize;
    let mut skipped = 0u64;
    while let Some(fits) = read_raw_line(&mut r, &mut buf)? {
        lineno += 1;
        let parsed: Result<Option<Update>> = if !fits {
            Err(line_err(label, lineno, "line exceeds the 1 MiB length cap", &buf))
        } else if let Ok(text) = std::str::from_utf8(&buf) {
            let t = text.trim();
            if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
                continue;
            }
            if t == "commit" {
                if !cur.is_empty() {
                    batches.push(std::mem::take(&mut cur));
                }
                continue;
            }
            parse_update_line(t, lineno, &mut ids)
                .map_err(|e| e.context(format!("{label}: line {lineno}: {:?}", snippet(&buf))))
        } else {
            Err(line_err(label, lineno, "invalid UTF-8", &buf))
        };
        match (parsed, mode) {
            (Ok(Some(up)), _) => cur.updates.push(up),
            (Ok(None), _) => {}
            (Err(e), IngestMode::Strict) => return Err(e),
            (Err(e), IngestMode::Lenient) => {
                skipped += 1;
                crate::obs::counter_add("ingest_skipped_lines", 1);
                if skipped <= 8 {
                    crate::obs::log::debug(&format!("ingest: skipping {e:#}"));
                }
            }
        }
    }
    if skipped > 0 {
        crate::obs::log::info(&format!(
            "ingest: {label}: skipped {skipped} malformed line(s) (lenient mode)"
        ));
    }
    if !cur.is_empty() {
        batches.push(cur);
    }
    Ok(batches)
}

/// Parse one non-comment, non-`commit` update-log line (module docs).
/// `Ok(None)` = a structurally valid no-op (a delete naming unseen
/// ids); ids are densified only on fully-parsed adding ops, so an `Err`
/// never mutates the map.
fn parse_update_line(
    t: &str,
    lineno: usize,
    ids: &mut HashMap<u64, VertexId>,
) -> Result<Option<Update>> {
    let mut words = t.split_whitespace();
    let op = words.next().expect("non-empty line has a first token");
    let parse_one_id = |words: &mut std::str::SplitWhitespace<'_>| -> Result<u64> {
        let w = words
            .next()
            .with_context(|| format!("line {lineno}: expected `{op} <id>`"))?;
        w.parse::<u64>().with_context(|| format!("line {lineno}: bad vertex id"))
    };
    let up = match op {
        "a" | "d" => {
            // The rest of the line is a plain `src dst` pair.
            let rest = t[1..].trim_start();
            let (a, b) = parse_edge_line(rest, lineno)?
                .with_context(|| format!("line {lineno}: expected `{op} src dst`"))?;
            if op == "a" {
                Update::AddEdge(densify(a, ids), densify(b, ids))
            } else {
                // Deletes only *look up* ids: an edge with an
                // unseen endpoint cannot exist, so the op is a
                // guaranteed no-op — minting a dense id for it
                // would permanently skew the map and materialize
                // phantom vertices on the next arrival.
                match (ids.get(&a), ids.get(&b)) {
                    (Some(&s), Some(&d)) => Update::RemoveEdge(s, d),
                    _ => return Ok(None),
                }
            }
        }
        "av" | "dv" => {
            let raw = parse_one_id(&mut words)?;
            anyhow::ensure!(
                words.next().is_none(),
                "line {lineno}: trailing tokens after `{op} <id>`"
            );
            if op == "av" {
                Update::AddVertex(densify(raw, ids))
            } else {
                // Same lookup-only rule as `d` (see above).
                match ids.get(&raw) {
                    Some(&v) => Update::RemoveVertex(v),
                    None => return Ok(None),
                }
            }
        }
        _ => {
            // Bare `src dst` line: an add, same as an edge list.
            match parse_edge_line(t, lineno)? {
                Some((a, b)) => {
                    let (s, d) = (densify(a, ids), densify(b, ids));
                    Update::AddEdge(s, d)
                }
                None => return Ok(None),
            }
        }
    };
    Ok(Some(up))
}

/// A named synthetic churn workload, parseable from the CLI
/// (`--churn uniform:0.02`, `hub:0.02`, `arrivals:256x4`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnRecipe {
    /// Remove `frac·|E|` uniform-random existing edges, add the same
    /// number of uniform-random new ones — stationary size, drifting
    /// structure.
    Uniform { frac: f64 },
    /// Like `Uniform`, but new endpoints are degree-biased (sampled as
    /// endpoints of random existing edges) — churn concentrates on
    /// hubs, the hardest case for a frontier because hub wakes fan wide.
    HubBiased { frac: f64 },
    /// `count` new vertices arrive, each wiring `edges_per` out-edges
    /// to degree-biased existing targets (BA-style growth).
    Arrivals { count: usize, edges_per: usize },
}

impl ChurnRecipe {
    /// Generate one epoch's batch against the current graph state.
    pub fn generate(&self, g: &Graph, seed: u64) -> UpdateBatch {
        match *self {
            ChurnRecipe::Uniform { frac } => edge_churn(g, frac, seed, false),
            ChurnRecipe::HubBiased { frac } => edge_churn(g, frac, seed, true),
            ChurnRecipe::Arrivals { count, edges_per } => {
                vertex_arrivals(g, count, edges_per, seed)
            }
        }
    }
}

impl std::str::FromStr for ChurnRecipe {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let low = s.to_lowercase();
        let (kind, arg) = low
            .split_once(':')
            .with_context(|| format!("churn recipe {s:?} needs an argument, e.g. uniform:0.02"))?;
        match kind {
            "uniform" | "hub" => {
                let frac: f64 = arg.parse().with_context(|| format!("bad churn fraction {arg:?}"))?;
                anyhow::ensure!(
                    frac > 0.0 && frac <= 1.0,
                    "churn fraction must be in (0, 1], got {frac}"
                );
                Ok(if kind == "uniform" {
                    ChurnRecipe::Uniform { frac }
                } else {
                    ChurnRecipe::HubBiased { frac }
                })
            }
            "arrivals" => {
                let (count, per) = arg
                    .split_once('x')
                    .with_context(|| format!("arrivals needs <count>x<edges>, got {arg:?}"))?;
                let count: usize = count.parse().context("bad arrival count")?;
                let edges_per: usize = per.parse().context("bad arrival edge count")?;
                anyhow::ensure!(count >= 1 && edges_per >= 1, "arrivals need count, edges >= 1");
                Ok(ChurnRecipe::Arrivals { count, edges_per })
            }
            other => bail!("unknown churn recipe {other:?} (expected uniform|hub|arrivals)"),
        }
    }
}

/// Out-degree prefix sums — O(log n) degree-biased edge sampling
/// (pick a uniform edge index, binary-search its source).
struct EdgeSampler {
    prefix: Vec<u64>,
}

impl EdgeSampler {
    fn new(g: &Graph) -> Self {
        let n = g.num_vertices();
        let mut prefix = Vec::with_capacity(n + 1);
        let mut acc = 0u64;
        prefix.push(acc);
        for v in 0..n {
            acc += g.out_degree(v as VertexId) as u64;
            prefix.push(acc);
        }
        EdgeSampler { prefix }
    }

    /// The `i`-th directed edge in CSR order.
    fn edge(&self, g: &Graph, i: u64) -> (VertexId, VertexId) {
        debug_assert!(i < *self.prefix.last().unwrap());
        // partition_point: first v with prefix[v+1] > i.
        let v = self.prefix.partition_point(|&p| p <= i) - 1;
        let off = (i - self.prefix[v]) as usize;
        (v as VertexId, g.out_neighbors(v as VertexId)[off])
    }

    /// A degree-biased vertex: the source or target of a uniform edge.
    fn biased_vertex(&self, g: &Graph, rng: &mut Rng) -> VertexId {
        let m = *self.prefix.last().unwrap();
        let (s, d) = self.edge(g, rng.below(m));
        if rng.below(2) == 0 {
            s
        } else {
            d
        }
    }
}

/// Shared body of the two edge-churn recipes: `frac·|E|` deletions of
/// distinct uniform-random existing edges plus the same number of
/// additions (uniform or degree-biased endpoints) that neither
/// duplicate an existing edge nor another addition in the batch.
fn edge_churn(g: &Graph, frac: f64, seed: u64, hub_biased: bool) -> UpdateBatch {
    assert!(frac > 0.0 && frac <= 1.0, "churn fraction must be in (0, 1]");
    let m = g.num_edges() as u64;
    assert!(m > 0, "cannot churn an edgeless graph");
    let n = g.num_vertices() as u64;
    let count = ((m as f64 * frac).round() as u64).clamp(1, m);
    let mut rng = Rng::new(seed ^ 0x4348_524E /* "CHRN" */);
    let sampler = EdgeSampler::new(g);

    // Deletions: distinct uniform edge indices.
    let mut picked: Vec<u64> = Vec::with_capacity(count as usize);
    let mut seen = std::collections::HashSet::with_capacity(count as usize * 2);
    while (picked.len() as u64) < count {
        let i = rng.below(m);
        if seen.insert(i) {
            picked.push(i);
        }
    }
    let mut updates: Vec<Update> = picked
        .iter()
        .map(|&i| {
            let (s, d) = sampler.edge(g, i);
            Update::RemoveEdge(s, d)
        })
        .collect();

    // Additions: new (u, v) pairs absent from the graph and the batch.
    let has = |u: VertexId, v: VertexId| g.out_neighbors(u).binary_search(&v).is_ok();
    let mut fresh = std::collections::HashSet::with_capacity(count as usize * 2);
    let mut added = 0u64;
    // Bounded retry: dense tiny graphs can run out of absent pairs.
    let mut attempts = 0u64;
    let max_attempts = count * 64 + 256;
    while added < count && attempts < max_attempts {
        attempts += 1;
        let (u, v) = if hub_biased {
            (sampler.biased_vertex(g, &mut rng), sampler.biased_vertex(g, &mut rng))
        } else {
            (rng.below(n) as VertexId, rng.below(n) as VertexId)
        };
        if u == v || has(u, v) || !fresh.insert((u, v)) {
            continue;
        }
        updates.push(Update::AddEdge(u, v));
        added += 1;
    }
    UpdateBatch { updates }
}

/// BA-style growth batch: `count` arrivals with `edges_per` out-edges
/// each to degree-biased existing targets (distinct per arrival).
fn vertex_arrivals(g: &Graph, count: usize, edges_per: usize, seed: u64) -> UpdateBatch {
    assert!(count >= 1 && edges_per >= 1);
    assert!(g.num_edges() > 0, "degree-biased arrival targets need an edge to sample");
    let mut rng = Rng::new(seed ^ 0x4152_5256 /* "ARRV" */);
    let sampler = EdgeSampler::new(g);
    let base = g.num_vertices() as VertexId;
    let mut updates = Vec::with_capacity(count * (edges_per + 1));
    for i in 0..count {
        let v = base + i as VertexId;
        updates.push(Update::AddVertex(v));
        let mut targets: Vec<VertexId> = Vec::with_capacity(edges_per);
        let mut attempts = 0;
        while targets.len() < edges_per && attempts < edges_per * 32 + 32 {
            attempts += 1;
            let t = sampler.biased_vertex(g, &mut rng);
            if t != v && !targets.contains(&t) {
                targets.push(t);
            }
        }
        for t in targets {
            updates.push(Update::AddEdge(v, t));
        }
    }
    UpdateBatch { updates }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::rmat;
    use crate::graph::GraphBuilder;
    use std::io::Cursor;

    #[test]
    fn log_reader_parses_all_ops_and_batches() {
        let log = "# header\n0 1\na 1 2\nd 0 1\ncommit\nav 9\ndv 2\n\ncommit\ncommit\n3 0\n";
        let batches = read_update_log(Cursor::new(log), 4).unwrap();
        assert_eq!(batches.len(), 3);
        assert_eq!(
            batches[0].updates,
            vec![
                Update::AddEdge(0, 1),
                Update::AddEdge(1, 2),
                Update::RemoveEdge(0, 1),
            ]
        );
        // Raw id 9 is unseen with base_vertices = 4 ⇒ next dense id 4.
        assert_eq!(
            batches[1].updates,
            vec![Update::AddVertex(4), Update::RemoveVertex(2)]
        );
        assert_eq!(batches[2].updates, vec![Update::AddEdge(3, 0)]);
    }

    #[test]
    fn log_reader_densifies_like_edge_list_loader() {
        // A pure-add log with sparse raw ids must produce the same
        // dense-id edges as loading the same lines as an edge list.
        let txt = "1000 5\n5 42\n42 1000\n";
        let batches = read_update_log(Cursor::new(txt), 0).unwrap();
        assert_eq!(batches.len(), 1);
        let g = crate::graph::io::read_edge_list(Cursor::new(txt)).unwrap();
        let expect: Vec<Update> =
            g.edges().map(|(s, d)| Update::AddEdge(s, d)).collect();
        // read_edge_list sorts edges into CSR order; compare as sets.
        let mut got = batches[0].updates.clone();
        let mut want = expect;
        let key = |u: &Update| match *u {
            Update::AddEdge(a, b) => (a, b),
            _ => unreachable!(),
        };
        got.sort_by_key(key);
        want.sort_by_key(key);
        assert_eq!(got, want);
    }

    #[test]
    fn log_reader_skips_deletes_of_unseen_ids_without_densifying() {
        // `d 999 998` and `dv 777` name ids the map has never seen:
        // both are guaranteed no-ops and must neither appear as updates
        // nor consume dense ids — the later arrival of raw id 1234 must
        // still get dense id 4 (base 0..4).
        let log = "d 999 998\ndv 777\na 0 1234\n";
        let batches = read_update_log(Cursor::new(log), 4).unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].updates, vec![Update::AddEdge(0, 4)]);
    }

    #[test]
    fn log_reader_rejects_malformed_lines() {
        let err = read_update_log(Cursor::new("a 1\n"), 4).unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
        let err = read_update_log(Cursor::new("0 1\nd x 1\n"), 4).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
        let err = read_update_log(Cursor::new("av\n"), 4).unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
        let err = read_update_log(Cursor::new("dv 1 2\n"), 4).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn log_reader_lenient_mode_skips_without_minting_ids() {
        // Malformed lines (bad int, invalid UTF-8, truncated op) are
        // skipped in lenient mode, and the ids they *partially* named
        // never enter the map: raw id 1234 still gets dense id 4.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"0 1\n");
        bytes.extend_from_slice(b"a x 7\nav\n");
        bytes.extend_from_slice(&[0xC0, 0xAF, b'\n']);
        bytes.extend_from_slice(b"a 0 1234\ncommit\n");
        let batches = read_update_log_named(
            Cursor::new(&bytes),
            4,
            "log.txt",
            IngestMode::Lenient,
        )
        .unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(
            batches[0].updates,
            vec![Update::AddEdge(0, 1), Update::AddEdge(0, 4)]
        );
        // Strict mode aborts on the same input, naming the source file.
        let err =
            read_update_log_named(Cursor::new(&bytes), 4, "log.txt", IngestMode::Strict)
                .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("log.txt") && msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn churn_recipe_parsing() {
        assert_eq!(
            "uniform:0.02".parse::<ChurnRecipe>().unwrap(),
            ChurnRecipe::Uniform { frac: 0.02 }
        );
        assert_eq!(
            "HUB:0.1".parse::<ChurnRecipe>().unwrap(),
            ChurnRecipe::HubBiased { frac: 0.1 }
        );
        assert_eq!(
            "arrivals:256x4".parse::<ChurnRecipe>().unwrap(),
            ChurnRecipe::Arrivals { count: 256, edges_per: 4 }
        );
        assert!("uniform".parse::<ChurnRecipe>().is_err());
        assert!("uniform:0".parse::<ChurnRecipe>().is_err());
        assert!("uniform:2".parse::<ChurnRecipe>().is_err());
        assert!("arrivals:256".parse::<ChurnRecipe>().is_err());
        assert!("metis:1".parse::<ChurnRecipe>().is_err());
    }

    fn churn_graph() -> Graph {
        rmat::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 7)
    }

    #[test]
    fn edge_churn_deletes_existing_and_adds_fresh() {
        let g = churn_graph();
        for recipe in [ChurnRecipe::Uniform { frac: 0.05 }, ChurnRecipe::HubBiased { frac: 0.05 }]
        {
            let batch = recipe.generate(&g, 11);
            let mut dels = 0usize;
            let mut adds = 0usize;
            for up in &batch.updates {
                match *up {
                    Update::RemoveEdge(u, v) => {
                        dels += 1;
                        assert!(
                            g.out_neighbors(u).binary_search(&v).is_ok(),
                            "{recipe:?}: delete of absent edge ({u},{v})"
                        );
                    }
                    Update::AddEdge(u, v) => {
                        adds += 1;
                        assert_ne!(u, v, "{recipe:?}: self-loop add");
                        assert!(
                            g.out_neighbors(u).binary_search(&v).is_err(),
                            "{recipe:?}: duplicate add ({u},{v})"
                        );
                    }
                    other => panic!("{recipe:?}: unexpected {other:?}"),
                }
            }
            let expect = (g.num_edges() as f64 * 0.05).round() as usize;
            assert_eq!(dels, expect, "{recipe:?}");
            assert_eq!(adds, expect, "{recipe:?}");
            // Determinism: same graph + seed ⇒ same batch.
            assert_eq!(batch, recipe.generate(&g, 11), "{recipe:?}");
        }
    }

    #[test]
    fn vertex_arrivals_wire_new_ids_to_existing_targets() {
        let g = churn_graph();
        let n = g.num_vertices() as VertexId;
        let batch = ChurnRecipe::Arrivals { count: 8, edges_per: 3 }.generate(&g, 5);
        let mut arrivals = Vec::new();
        for up in &batch.updates {
            match *up {
                Update::AddVertex(v) => {
                    assert!(v >= n);
                    arrivals.push(v);
                }
                Update::AddEdge(u, v) => {
                    assert!(u >= n, "arrival edges originate at the new vertex");
                    assert!(v < n, "targets are existing vertices");
                    assert_ne!(u, v);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(arrivals, (n..n + 8).collect::<Vec<_>>(), "contiguous new ids");
        assert_eq!(batch.updates.len(), 8 * 4, "1 vertex + 3 edges each");
    }

    #[test]
    fn hub_biased_churn_touches_hubs_more() {
        // Star over 0..32 (0 is the hub) plus a path over 32..64: the
        // hub carries over half the degree mass, and fresh hub edges
        // (0 ↔ path vertices) still exist to add. Degree-biased
        // endpoint draws must produce hub-incident additions; a
        // uniform draw would pick 0 with probability ~2/64 per slot.
        let mut b = GraphBuilder::new(64);
        for v in 1..32u32 {
            b.edge(0, v);
            b.edge(v, 0);
        }
        for v in 32..63u32 {
            b.edge(v, v + 1);
        }
        let g = b.build();
        let batch = ChurnRecipe::HubBiased { frac: 0.2 }.generate(&g, 3);
        let hub_adds = batch
            .updates
            .iter()
            .filter(|u| matches!(u, Update::AddEdge(a, b) if *a == 0 || *b == 0))
            .count();
        // ~19 additions, each endpoint drawn from edge endpoints where
        // 0 owns ~1/3 of the slots — at least one hub-incident add is
        // essentially certain (and deterministic for this seed).
        assert!(hub_adds > 0, "{batch:?}");
    }
}
