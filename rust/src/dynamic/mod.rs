//! Dynamic graphs (L4): incremental updates with frontier-localized
//! repartitioning — the evolving-graph workload class (social streams,
//! road updates, arriving users) the static pipeline cannot serve
//! without a full rebuild and a cold repartition per change.
//!
//! Three pieces:
//!
//! * [`delta`] — [`DynamicGraph`]: an immutable base CSR plus
//!   per-vertex sorted insert/delete adjacency deltas and vertex
//!   tombstones. Degrees, neighbourhoods and load mass compose
//!   base+delta on the fly; a ratio-gated [`DynamicGraph::compact`]
//!   rebuilds a fresh base once the deltas grow past
//!   `compact_ratio` of the base's edges.
//! * [`updates`] — [`UpdateBatch`] (add/remove edge, add/remove
//!   vertex), a text update-log reader sharing
//!   [`crate::graph::parse`]'s grammar and densification with every
//!   other reader, and synthetic [`ChurnRecipe`] generators (uniform
//!   edge churn, hub-biased churn, vertex arrival streams).
//! * [`incremental`] — [`IncrementalPartitioner`]: applies a batch,
//!   places arrivals greedily against the full current assignment
//!   (LDG/Fennel, per Prioritized Restreaming), then runs a bounded
//!   repair pass whose step-0 frontier is **only** the changed
//!   endpoints and their undirected neighbourhoods
//!   ([`crate::engine::InitialFrontier::Seeds`]), for either Revolver
//!   or Spinner — followed by the deterministic ε-rebalance. Spinner
//!   (ICDE'17) demonstrated the restart-from-previous-assignment
//!   strategy; the active-set engine makes it *priced* like an
//!   incremental computation: an epoch costs ~|affected region|
//!   vertex-evaluations instead of ~|V| per superstep.
//!
//! CLI: `revolver dynamic --graph lj --churn uniform:0.02 --epochs 5`
//! (or `--update-log file`), with per-epoch quality reporting and a
//! quality-over-time CSV via [`crate::metrics::trace::RunTrace`].

pub mod delta;
pub mod incremental;
pub mod updates;

pub use delta::{ApplyStats, DynamicGraph};
pub use incremental::{EpochStats, IncrementalPartitioner};
pub use updates::{read_update_log, read_update_log_named, ChurnRecipe, Update, UpdateBatch};
