//! The [`DynamicGraph`] overlay: an immutable base CSR plus per-vertex
//! sorted insert/delete adjacency deltas and vertex tombstones, so a
//! graph can evolve *between* CSR materializations instead of paying a
//! full rebuild per update.
//!
//! ## Model
//!
//! The current graph is always
//!
//! ```text
//! out(v) = (base_out(v) \ del_out[v]) ∪ add_out[v]
//! und(v) = (base_und(v) \ del_und[v]) ∪ add_und[v]
//! ```
//!
//! with the disjointness invariants `add ∩ base = ∅` and
//! `del ⊆ base` (re-adding a deleted base edge shrinks `del` instead of
//! growing `add`, so the delta mass tracks *net* divergence from the
//! base). The undirected deltas are maintained transactionally with the
//! directed ones — an undirected edge appears when its first direction
//! does and disappears when its last direction goes — so neighbour
//! iteration and degrees are O(Δ)-merge reads, never a scan of the
//! other endpoint's list.
//!
//! Vertex ids are **stable**: `remove_vertex` tombstones (drops every
//! incident edge and marks the id dead) rather than renumbering, so
//! label vectors, traces and update logs stay valid across arbitrary
//! churn; a compacted CSR keeps the dead id as an isolated vertex. New
//! vertices take the next dense id.
//!
//! ## Compaction
//!
//! Delta reads cost a merge against two (usually tiny) sorted vecs.
//! [`DynamicGraph::apply`] auto-compacts — rebuilds a fresh base CSR
//! via [`GraphBuilder`] and clears every delta — once the delta
//! adjacency entries exceed `compact_ratio` of the base's edges, which
//! bounds query cost no matter how many batches accumulate between
//! repair passes. [`DynamicGraph::compact`] does the same on demand:
//! the epoch boundary of [`super::IncrementalPartitioner`] is one
//! (the superstep engine and the quality metrics run on CSR), and
//! keeping the materialized CSR as the new base makes that rebuild do
//! double duty. Compaction never changes the observable graph —
//! property-tested in `tests/invariants.rs`.

use anyhow::Result;

use crate::graph::{Graph, GraphBuilder};
use crate::VertexId;

use super::updates::{Update, UpdateBatch};

/// Sorted-vec insert; returns false if already present.
fn ins(v: &mut Vec<VertexId>, x: VertexId) -> bool {
    match v.binary_search(&x) {
        Ok(_) => false,
        Err(i) => {
            v.insert(i, x);
            true
        }
    }
}

/// Sorted-vec remove; returns false if absent.
fn rem(v: &mut Vec<VertexId>, x: VertexId) -> bool {
    match v.binary_search(&x) {
        Ok(i) => {
            v.remove(i);
            true
        }
        Err(_) => false,
    }
}

/// Merge iterator over `(base \ del) ∪ add` — all three slices sorted,
/// `del ⊆ base`, `add ∩ base = ∅`, so equal heads between the add
/// stream and the surviving base stream are impossible.
pub struct DeltaNeighbors<'a> {
    base: &'a [VertexId],
    del: &'a [VertexId],
    add: &'a [VertexId],
    bi: usize,
    di: usize,
    ai: usize,
}

impl Iterator for DeltaNeighbors<'_> {
    type Item = VertexId;

    fn next(&mut self) -> Option<VertexId> {
        // Advance the base cursor past deleted entries.
        let b = loop {
            match self.base.get(self.bi) {
                None => break None,
                Some(&b) => {
                    while self.di < self.del.len() && self.del[self.di] < b {
                        self.di += 1;
                    }
                    if self.del.get(self.di) == Some(&b) {
                        self.bi += 1;
                        self.di += 1;
                        continue;
                    }
                    break Some(b);
                }
            }
        };
        let a = self.add.get(self.ai).copied();
        match (b, a) {
            (None, None) => None,
            (Some(b), None) => {
                self.bi += 1;
                Some(b)
            }
            (None, Some(a)) => {
                self.ai += 1;
                Some(a)
            }
            (Some(b), Some(a)) => {
                if b < a {
                    self.bi += 1;
                    Some(b)
                } else {
                    self.ai += 1;
                    Some(a)
                }
            }
        }
    }
}

/// Outcome of [`DynamicGraph::apply`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ApplyStats {
    /// Updates that changed the graph.
    pub applied: usize,
    /// No-op updates (duplicate adds, removes of absent edges, …).
    pub skipped: usize,
    /// Whether the batch tripped the ratio-gated auto-compaction.
    pub compacted: bool,
}

/// A mutable graph: immutable base CSR + sorted adjacency deltas +
/// tombstones (module docs above). Plain graphs only — the multilevel
/// contractions' weighted CSRs are derived artifacts, rebuilt from the
/// (dynamic) fine graph rather than mutated in place.
pub struct DynamicGraph {
    base: Graph,
    add_out: Vec<Vec<VertexId>>,
    del_out: Vec<Vec<VertexId>>,
    add_und: Vec<Vec<VertexId>>,
    del_und: Vec<Vec<VertexId>>,
    alive: Vec<bool>,
    /// Current vertex count (base vertices + arrivals; tombstones keep
    /// their id, so this never shrinks).
    n: usize,
    /// Current directed edge count.
    edges: usize,
    /// Directed delta adjacency entries (Σ |add_out| + |del_out|) —
    /// the compaction trigger's mass.
    delta_entries: usize,
    compact_ratio: f64,
    compactions: u64,
}

impl DynamicGraph {
    /// Wrap `base` as the starting state. `compact_ratio` is the
    /// delta-mass fraction of the base's edges beyond which
    /// [`DynamicGraph::apply`] auto-compacts (must be positive).
    pub fn new(base: Graph, compact_ratio: f64) -> Self {
        assert!(
            !base.is_weighted() && !base.has_vertex_weights(),
            "DynamicGraph overlays plain graphs only"
        );
        assert!(
            compact_ratio.is_finite() && compact_ratio > 0.0,
            "compact_ratio must be positive"
        );
        let n = base.num_vertices();
        let edges = base.num_edges();
        DynamicGraph {
            base,
            add_out: vec![Vec::new(); n],
            del_out: vec![Vec::new(); n],
            add_und: vec![Vec::new(); n],
            del_und: vec![Vec::new(); n],
            alive: vec![true; n],
            n,
            edges,
            delta_entries: 0,
            compact_ratio,
            compactions: 0,
        }
    }

    /// Current vertex count, dead ids included (ids are stable).
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Current directed edge count.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.edges
    }

    /// False once `v` has been tombstoned (and not revived by a new
    /// incident edge).
    #[inline]
    pub fn is_alive(&self, v: VertexId) -> bool {
        self.alive[v as usize]
    }

    /// The base CSR the deltas diverge from — the *current* graph
    /// whenever [`DynamicGraph::is_dirty`] is false (i.e. right after a
    /// compaction), which is how the repair pass gets its CSR.
    #[inline]
    pub fn base(&self) -> &Graph {
        &self.base
    }

    /// True when any delta (edge or arrival) is pending against the
    /// base.
    #[inline]
    pub fn is_dirty(&self) -> bool {
        self.delta_entries > 0 || self.n > self.base.num_vertices()
    }

    /// Net delta adjacency entries as a fraction of the base's edges.
    pub fn delta_ratio(&self) -> f64 {
        self.delta_entries as f64 / self.base.num_edges().max(1) as f64
    }

    /// Compactions performed so far (ratio-triggered + explicit).
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    fn base_out(&self, v: VertexId) -> &[VertexId] {
        if (v as usize) < self.base.num_vertices() {
            self.base.out_neighbors(v)
        } else {
            &[]
        }
    }

    fn base_und(&self, v: VertexId) -> &[VertexId] {
        if (v as usize) < self.base.num_vertices() {
            self.base.neighbors(v)
        } else {
            &[]
        }
    }

    /// Grow the id space to cover `v` (new ids are alive and isolated).
    fn ensure(&mut self, v: VertexId) {
        let want = v as usize + 1;
        if want > self.n {
            assert!(v < VertexId::MAX, "vertex id space exhausted");
            self.add_out.resize(want, Vec::new());
            self.del_out.resize(want, Vec::new());
            self.add_und.resize(want, Vec::new());
            self.del_und.resize(want, Vec::new());
            self.alive.resize(want, true);
            self.n = want;
        }
    }

    /// Does the directed edge (u, v) currently exist?
    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        if (u as usize) >= self.n || (v as usize) >= self.n {
            return false;
        }
        if self.add_out[u as usize].binary_search(&v).is_ok() {
            return true;
        }
        self.base_out(u).binary_search(&v).is_ok()
            && self.del_out[u as usize].binary_search(&v).is_err()
    }

    /// Are u and v currently connected in either direction?
    #[inline]
    pub fn und_connected(&self, u: VertexId, v: VertexId) -> bool {
        self.has_edge(u, v) || self.has_edge(v, u)
    }

    /// Current out-degree of `v` — O(1) from the list lengths.
    #[inline]
    pub fn out_degree(&self, v: VertexId) -> u32 {
        (self.base_out(v).len() - self.del_out[v as usize].len()
            + self.add_out[v as usize].len()) as u32
    }

    /// Current undirected degree |N(v)|.
    #[inline]
    pub fn und_degree(&self, v: VertexId) -> u32 {
        (self.base_und(v).len() - self.del_und[v as usize].len()
            + self.add_und[v as usize].len()) as u32
    }

    /// Load mass of `v` in the units the whole system balances —
    /// out-degree, exactly [`Graph::load_mass`] on plain graphs.
    #[inline]
    pub fn load_mass(&self, v: VertexId) -> u32 {
        self.out_degree(v)
    }

    /// Current out-neighbours of `v`, ascending.
    pub fn out_neighbors(&self, v: VertexId) -> DeltaNeighbors<'_> {
        DeltaNeighbors {
            base: self.base_out(v),
            del: &self.del_out[v as usize],
            add: &self.add_out[v as usize],
            bi: 0,
            di: 0,
            ai: 0,
        }
    }

    /// Current undirected neighbourhood N(v), ascending, deduplicated.
    pub fn und_neighbors(&self, v: VertexId) -> DeltaNeighbors<'_> {
        DeltaNeighbors {
            base: self.base_und(v),
            del: &self.del_und[v as usize],
            add: &self.add_und[v as usize],
            bi: 0,
            di: 0,
            ai: 0,
        }
    }

    /// Record that the undirected edge a—b now exists.
    fn und_insert(&mut self, a: VertexId, b: VertexId) {
        if self.base_und(a).binary_search(&b).is_ok() {
            // Base edge coming back from deletion.
            let undeleted = rem(&mut self.del_und[a as usize], b);
            debug_assert!(undeleted, "base und edge neither live nor deleted");
        } else {
            let added = ins(&mut self.add_und[a as usize], b);
            debug_assert!(added, "und delta out of sync (duplicate add)");
        }
    }

    /// Record that the undirected edge a—b no longer exists.
    fn und_remove(&mut self, a: VertexId, b: VertexId) {
        if self.base_und(a).binary_search(&b).is_ok() {
            let deleted = ins(&mut self.del_und[a as usize], b);
            debug_assert!(deleted, "und delta out of sync (double delete)");
        } else {
            let removed = rem(&mut self.add_und[a as usize], b);
            debug_assert!(removed, "und delta out of sync (remove of absent add)");
        }
    }

    /// Add the directed edge (u, v). Unknown endpoints grow the id
    /// space (that is how arrivals referenced by an update log enter);
    /// tombstoned endpoints are revived. Self-loops and duplicates are
    /// no-ops. Returns whether the graph changed.
    pub fn add_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v {
            return false;
        }
        self.ensure(u.max(v));
        if self.has_edge(u, v) {
            return false;
        }
        // Check *before* the directed insert: the pair is newly
        // und-connected iff the reverse direction is absent too.
        let und_new = !self.has_edge(v, u);
        if self.base_out(u).binary_search(&v).is_ok() {
            // Base edge coming back: shrink the delete delta.
            let undeleted = rem(&mut self.del_out[u as usize], v);
            debug_assert!(undeleted, "directed delta out of sync");
            self.delta_entries -= 1;
        } else {
            let added = ins(&mut self.add_out[u as usize], v);
            debug_assert!(added);
            self.delta_entries += 1;
        }
        if und_new {
            self.und_insert(u, v);
            self.und_insert(v, u);
        }
        self.alive[u as usize] = true;
        self.alive[v as usize] = true;
        self.edges += 1;
        true
    }

    /// Remove the directed edge (u, v) if present. Returns whether the
    /// graph changed.
    pub fn remove_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        if !self.has_edge(u, v) {
            return false;
        }
        if rem(&mut self.add_out[u as usize], v) {
            self.delta_entries -= 1;
        } else {
            let deleted = ins(&mut self.del_out[u as usize], v);
            debug_assert!(deleted);
            self.delta_entries += 1;
        }
        self.edges -= 1;
        // After the directed removal: the und edge survives iff the
        // reverse direction still exists.
        if !self.has_edge(v, u) {
            self.und_remove(u, v);
            self.und_remove(v, u);
        }
        true
    }

    /// Add a fresh isolated vertex; returns its (next dense) id.
    pub fn add_vertex(&mut self) -> VertexId {
        let v = self.n as VertexId;
        self.ensure(v);
        v
    }

    /// Tombstone `v`: drop every incident edge (both directions) and
    /// mark the id dead. The id is never reused; a later incident
    /// `add_edge` revives it. Returns whether the graph changed.
    pub fn remove_vertex(&mut self, v: VertexId) -> bool {
        if (v as usize) >= self.n || !self.alive[v as usize] {
            return false;
        }
        let outs: Vec<VertexId> = self.out_neighbors(v).collect();
        for u in outs {
            self.remove_edge(v, u);
        }
        let in_sources: Vec<VertexId> =
            self.und_neighbors(v).filter(|&u| self.has_edge(u, v)).collect();
        for u in in_sources {
            self.remove_edge(u, v);
        }
        debug_assert_eq!(self.und_degree(v), 0, "tombstoned vertex keeps neighbours");
        self.alive[v as usize] = false;
        true
    }

    /// Apply a whole [`UpdateBatch`], pushing the endpoints of every
    /// *effective* edge change (and new/revived vertex ids) onto
    /// `touched` — the seed set for the frontier-localized repair pass.
    /// A removed vertex contributes its former neighbours, not its own
    /// (now dead) id. Auto-compacts afterwards when the delta mass
    /// exceeds the configured ratio of the base's edges.
    pub fn apply(&mut self, batch: &UpdateBatch, touched: &mut Vec<VertexId>) -> ApplyStats {
        let mut stats = ApplyStats::default();
        for up in &batch.updates {
            let changed = match *up {
                Update::AddEdge(u, v) => {
                    let changed = self.add_edge(u, v);
                    if changed {
                        touched.push(u);
                        touched.push(v);
                    }
                    changed
                }
                Update::RemoveEdge(u, v) => {
                    let changed = self.remove_edge(u, v);
                    if changed {
                        touched.push(u);
                        touched.push(v);
                    }
                    changed
                }
                Update::AddVertex(v) => {
                    let existed = (v as usize) < self.n;
                    self.ensure(v);
                    let changed = !existed || !self.alive[v as usize];
                    self.alive[v as usize] = true;
                    if changed {
                        touched.push(v);
                    }
                    changed
                }
                Update::RemoveVertex(v) => {
                    if (v as usize) < self.n && self.alive[v as usize] {
                        touched.extend(self.und_neighbors(v));
                        self.remove_vertex(v)
                    } else {
                        false
                    }
                }
            };
            if changed {
                stats.applied += 1;
            } else {
                stats.skipped += 1;
            }
        }
        if self.delta_ratio() > self.compact_ratio {
            self.compact();
            stats.compacted = true;
        }
        stats
    }

    /// Materialize the current graph as a fresh CSR (the base is left
    /// untouched — see [`DynamicGraph::compact`] for the consuming
    /// variant). Tombstoned ids come out isolated; eq.-(4) undirected
    /// weights are recomputed by the builder.
    pub fn to_graph(&self) -> Graph {
        let mut b = GraphBuilder::with_capacity(self.n.max(1), self.edges);
        for v in 0..self.n as VertexId {
            for u in self.out_neighbors(v) {
                b.edge(v, u);
            }
        }
        b.build()
    }

    /// Rebuild the base CSR from the current state and clear every
    /// delta. O(|V| + |E| log |E|); afterwards [`DynamicGraph::base`]
    /// *is* the current graph and reads are pure CSR until the next
    /// mutation.
    pub fn compact(&mut self) {
        if !self.is_dirty() {
            return;
        }
        self.base = self.to_graph();
        let n = self.n;
        self.add_out = vec![Vec::new(); n];
        self.del_out = vec![Vec::new(); n];
        self.add_und = vec![Vec::new(); n];
        self.del_und = vec![Vec::new(); n];
        self.delta_entries = 0;
        self.compactions += 1;
        debug_assert_eq!(self.base.num_edges(), self.edges, "compaction lost edges");
    }

    /// Structural self-check of every overlay invariant (tests).
    pub fn check_invariants(&self) -> Result<()> {
        anyhow::ensure!(self.n >= self.base.num_vertices(), "id space shrank");
        let mut edges = 0usize;
        let mut delta = 0usize;
        for v in 0..self.n as VertexId {
            let vi = v as usize;
            for w in [&self.add_out[vi], &self.del_out[vi], &self.add_und[vi], &self.del_und[vi]]
            {
                for p in w.windows(2) {
                    anyhow::ensure!(p[0] < p[1], "delta list of {v} not sorted/dedup");
                }
            }
            for &u in &self.add_out[vi] {
                anyhow::ensure!(
                    self.base_out(v).binary_search(&u).is_err(),
                    "add_out of {v} overlaps base"
                );
            }
            for &u in &self.del_out[vi] {
                anyhow::ensure!(
                    self.base_out(v).binary_search(&u).is_ok(),
                    "del_out of {v} not in base"
                );
            }
            delta += self.add_out[vi].len() + self.del_out[vi].len();
            let deg = self.out_degree(v);
            edges += deg as usize;
            anyhow::ensure!(
                self.out_neighbors(v).count() == deg as usize,
                "merged out list of {v} disagrees with out_degree"
            );
            // Undirected view: symmetric, consistent with the directed
            // edges, and dead vertices are isolated.
            let und: Vec<VertexId> = self.und_neighbors(v).collect();
            anyhow::ensure!(und.len() == self.und_degree(v) as usize, "und degree mismatch");
            for &u in &und {
                anyhow::ensure!(self.und_connected(v, u), "phantom und edge {v}–{u}");
                anyhow::ensure!(
                    self.und_neighbors(u).any(|x| x == v),
                    "und edge {v}–{u} not symmetric"
                );
            }
            if !self.alive[vi] {
                anyhow::ensure!(und.is_empty(), "dead vertex {v} keeps edges");
            }
        }
        anyhow::ensure!(edges == self.edges, "edge count drifted: {edges} vs {}", self.edges);
        anyhow::ensure!(delta == self.delta_entries, "delta_entries drifted");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamic::updates::{Update, UpdateBatch};

    fn diamond() -> Graph {
        // 0->1, 0->2, 1->3, 2->3, 3->0.
        GraphBuilder::new(4)
            .edges(&[(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)])
            .build()
    }

    #[test]
    fn fresh_overlay_mirrors_base() {
        let g = diamond();
        let d = DynamicGraph::new(g.clone(), 0.25);
        assert_eq!(d.num_vertices(), 4);
        assert_eq!(d.num_edges(), 5);
        assert!(!d.is_dirty());
        for v in 0..4u32 {
            assert_eq!(d.out_degree(v), g.out_degree(v));
            assert_eq!(d.und_degree(v), g.und_degree(v));
            assert_eq!(d.load_mass(v), g.load_mass(v));
            assert_eq!(d.out_neighbors(v).collect::<Vec<_>>(), g.out_neighbors(v));
            assert_eq!(d.und_neighbors(v).collect::<Vec<_>>(), g.neighbors(v));
        }
        d.check_invariants().unwrap();
    }

    #[test]
    fn add_and_remove_edges_compose() {
        let mut d = DynamicGraph::new(diamond(), 100.0);
        assert!(d.add_edge(1, 2));
        assert!(!d.add_edge(1, 2), "duplicate add is a no-op");
        assert!(!d.add_edge(1, 1), "self-loop rejected");
        assert!(d.has_edge(1, 2));
        assert_eq!(d.num_edges(), 6);
        assert_eq!(d.und_neighbors(1).collect::<Vec<_>>(), vec![0, 2, 3]);

        assert!(d.remove_edge(0, 1));
        assert!(!d.remove_edge(0, 1), "double delete is a no-op");
        assert!(!d.has_edge(0, 1));
        assert_eq!(d.num_edges(), 5);
        // 0—1 had only one direction: the und edge is gone too.
        assert_eq!(d.und_neighbors(0).collect::<Vec<_>>(), vec![2, 3]);
        d.check_invariants().unwrap();
    }

    #[test]
    fn und_edge_survives_until_both_directions_gone() {
        // 3->0 and 0->3? diamond has 3->0 only; add the reverse first.
        let mut d = DynamicGraph::new(diamond(), 100.0);
        assert!(d.add_edge(0, 3));
        assert!(d.und_neighbors(0).any(|u| u == 3));
        assert!(d.remove_edge(3, 0));
        assert!(d.und_neighbors(0).any(|u| u == 3), "reverse direction keeps und edge");
        assert!(d.remove_edge(0, 3));
        assert!(!d.und_neighbors(0).any(|u| u == 3));
        d.check_invariants().unwrap();
    }

    #[test]
    fn readd_deleted_base_edge_shrinks_delta() {
        let mut d = DynamicGraph::new(diamond(), 100.0);
        assert!(d.remove_edge(0, 1));
        assert!(d.is_dirty());
        assert!(d.add_edge(0, 1));
        assert_eq!(d.delta_ratio(), 0.0, "net divergence is zero again");
        assert!(!d.is_dirty());
        assert_eq!(d.out_neighbors(0).collect::<Vec<_>>(), vec![1, 2]);
        d.check_invariants().unwrap();
    }

    #[test]
    fn vertex_arrival_and_tombstone() {
        let mut d = DynamicGraph::new(diamond(), 100.0);
        let v = d.add_vertex();
        assert_eq!(v, 4);
        assert_eq!(d.num_vertices(), 5);
        assert!(d.is_alive(v));
        assert_eq!(d.und_degree(v), 0);
        assert!(d.add_edge(v, 0));
        assert!(d.add_edge(2, v));
        assert_eq!(d.und_neighbors(v).collect::<Vec<_>>(), vec![0, 2]);
        d.check_invariants().unwrap();

        assert!(d.remove_vertex(v));
        assert!(!d.is_alive(v));
        assert_eq!(d.und_degree(v), 0);
        assert!(!d.has_edge(2, v), "in-edges dropped too");
        assert_eq!(d.num_edges(), 5);
        assert!(!d.remove_vertex(v), "double tombstone is a no-op");
        d.check_invariants().unwrap();

        // An incident add revives the id.
        assert!(d.add_edge(0, v));
        assert!(d.is_alive(v));
        d.check_invariants().unwrap();
    }

    #[test]
    fn edge_to_unknown_id_grows_id_space() {
        let mut d = DynamicGraph::new(diamond(), 100.0);
        assert!(d.add_edge(1, 9));
        assert_eq!(d.num_vertices(), 10);
        assert!(d.is_alive(9));
        assert!((4..9).all(|v| d.is_alive(v) && d.und_degree(v) == 0));
        d.check_invariants().unwrap();
    }

    #[test]
    fn to_graph_matches_overlay_observations() {
        let mut d = DynamicGraph::new(diamond(), 100.0);
        d.add_edge(1, 2);
        d.remove_edge(2, 3);
        d.add_edge(4, 0);
        let g = d.to_graph();
        assert_eq!(g.num_vertices(), d.num_vertices());
        assert_eq!(g.num_edges(), d.num_edges());
        for v in 0..d.num_vertices() as VertexId {
            assert_eq!(g.out_neighbors(v), d.out_neighbors(v).collect::<Vec<_>>(), "v={v}");
            assert_eq!(g.neighbors(v), d.und_neighbors(v).collect::<Vec<_>>(), "v={v}");
        }
        g.validate().unwrap();
    }

    #[test]
    fn compact_is_observationally_invisible() {
        let mut d = DynamicGraph::new(diamond(), 100.0);
        d.add_edge(3, 1);
        d.remove_edge(0, 2);
        let before: Vec<Vec<VertexId>> =
            (0..4).map(|v| d.und_neighbors(v).collect()).collect();
        let (n, m) = (d.num_vertices(), d.num_edges());
        d.compact();
        assert!(!d.is_dirty());
        assert_eq!(d.compactions(), 1);
        assert_eq!((d.num_vertices(), d.num_edges()), (n, m));
        for v in 0..4u32 {
            assert_eq!(d.und_neighbors(v).collect::<Vec<_>>(), before[v as usize]);
        }
        d.compact();
        assert_eq!(d.compactions(), 1, "clean compact is a no-op");
        d.check_invariants().unwrap();
    }

    #[test]
    fn apply_collects_touched_and_auto_compacts() {
        // ratio 0.2 of 5 base edges = 1 entry: two effective updates
        // must trip auto-compaction.
        let mut d = DynamicGraph::new(diamond(), 0.2);
        let batch = UpdateBatch {
            updates: vec![
                Update::AddEdge(1, 2),
                Update::AddEdge(1, 2), // duplicate: skipped
                Update::RemoveEdge(3, 0),
                Update::RemoveEdge(3, 0), // absent now: skipped
            ],
        };
        let mut touched = Vec::new();
        let stats = d.apply(&batch, &mut touched);
        assert_eq!(stats.applied, 2);
        assert_eq!(stats.skipped, 2);
        assert!(stats.compacted, "2 delta entries > 0.2 × 5");
        assert_eq!(touched, vec![1, 2, 3, 0]);
        assert!(!d.is_dirty());
        d.check_invariants().unwrap();
    }

    #[test]
    fn apply_remove_vertex_touches_former_neighbors() {
        let mut d = DynamicGraph::new(diamond(), 100.0);
        let mut touched = Vec::new();
        let batch =
            UpdateBatch { updates: vec![Update::RemoveVertex(3), Update::AddVertex(7)] };
        let stats = d.apply(&batch, &mut touched);
        assert_eq!(stats.applied, 2);
        // 3's und neighbourhood was {0, 1, 2}; the arrival contributes
        // its own id.
        assert_eq!(touched, vec![0, 1, 2, 7]);
        assert!(!d.is_alive(3));
        assert_eq!(d.num_vertices(), 8);
        d.check_invariants().unwrap();
    }
}
