//! The [`IncrementalPartitioner`]: keeps a partition assignment alive
//! across graph updates instead of recomputing it from scratch.
//!
//! Per epoch ([`IncrementalPartitioner::epoch`]):
//!
//! 1. **Apply** the [`UpdateBatch`] to the [`DynamicGraph`] overlay,
//!    collecting the endpoints of every effective change.
//! 2. **Place** arriving vertices greedily against the *full* current
//!    assignment (LDG / Fennel score via
//!    [`StreamState::from_assignment`] — Prioritized Restreaming's
//!    placement rule, [`crate::config::Placement`]).
//! 3. **Repair**: a bounded `engine` pass (`repair_steps` supersteps)
//!    whose step-0 frontier is seeded with **only** the changed
//!    endpoints and their undirected neighbourhoods
//!    ([`crate::engine::InitialFrontier::Seeds`]) — the PR 4 active-set
//!    machinery wakes whatever the repair actually disturbs, so an
//!    epoch of 2% churn costs ~|affected region| vertex-evaluations,
//!    not ~|V| (Spinner's "adapting to dynamic graph changes", made
//!    frontier-exact).
//! 4. **Rebalance**: the deterministic ε-envelope drain
//!    ([`crate::multilevel::rebalance`]) — removals can leave a
//!    partition over capacity, and engine refinement only gates inflow.
//!
//! The epoch boundary doubles as the overlay's compaction point: the
//! superstep engine and the quality metrics both run on CSR, so the
//! materialization the repair needs anyway becomes the new base and
//! delta queries reset to O(1) CSR reads.

use crate::config::{Placement, RevolverConfig};
use crate::graph::Graph;
use crate::metrics::trace::RunTrace;
use crate::multilevel::{rebalance, Refiner};
use crate::partitioners::{by_name, revolver, spinner};
use crate::stream::{Objective, StreamState, UNASSIGNED};
use crate::{Label, VertexId};

use super::delta::DynamicGraph;
use super::updates::UpdateBatch;

/// What one epoch did — the per-epoch report row of the `dynamic` CLI
/// subcommand and the acceptance tests' accounting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EpochStats {
    /// Updates that changed the graph / no-ops.
    pub applied: usize,
    pub skipped: usize,
    /// Arriving vertices placed against the full assignment.
    pub placed: usize,
    /// Size of the repair pass's step-0 frontier (changed endpoints +
    /// their undirected neighbourhoods).
    pub seeds: usize,
    /// Supersteps the repair pass executed (≤ `cfg.repair_steps`;
    /// empty-frontier / convergence halting can stop earlier).
    pub repair_steps: u32,
    /// Vertex-evaluations the repair pass spent — the number the
    /// acceptance criteria compare against a cold restart.
    pub evaluated: u64,
    /// Boundary moves of the post-repair ε-rebalance.
    pub rebalance_moves: u64,
    /// Wall seconds of the repair pass alone (0.0 when no seeds, i.e.
    /// no repair ran) — surfaced as the `mean_score` column of the
    /// dynamic trace CSV.
    pub repair_wall_s: f64,
}

/// A partition assignment maintained incrementally over a
/// [`DynamicGraph`] (module docs above).
pub struct IncrementalPartitioner {
    cfg: RevolverConfig,
    refiner: Refiner,
    graph: DynamicGraph,
    labels: Vec<Label>,
    total_evaluated: u64,
    total_repair_steps: u32,
    total_wall_s: f64,
    epochs_run: u64,
    /// Learning-dynamics observatory (`cfg.diag`): the last epoch's
    /// k×k label-diff flow cells (moves, mass), ready for
    /// [`IncrementalPartitioner::record_epoch`] to emit. `None` when
    /// diag is off or no epoch ran yet.
    diag_flow: Option<(Vec<u64>, Vec<u64>)>,
    /// Epoch-granularity 2-cycle detector over the full assignment.
    diag_osc: crate::obs::diag::OscillationDetector,
    diag_oscillating: u64,
}

impl IncrementalPartitioner {
    /// Cold start: partition `g` from scratch with the refiner's own
    /// algorithm (full `cfg.max_steps` budget), then track updates
    /// incrementally. The cold run's cost is *not* counted into
    /// [`IncrementalPartitioner::total_evaluated`] — that tracks epoch
    /// work only, which is what restart comparisons meter.
    pub fn new(
        g: Graph,
        cfg: RevolverConfig,
        refiner: Refiner,
    ) -> Result<Self, crate::engine::EngineError> {
        cfg.validate().expect("invalid config");
        let algo = match refiner {
            Refiner::Spinner => "spinner",
            Refiner::Revolver => "revolver",
        };
        let out = by_name(algo, cfg.clone())
            .expect("refiner algorithms are registered")
            .try_partition(&g)?;
        Ok(Self::from_assignment(g, cfg, refiner, out.labels))
    }

    /// Adopt an existing assignment (warm handoff from any partitioner).
    pub fn from_assignment(
        g: Graph,
        cfg: RevolverConfig,
        refiner: Refiner,
        labels: Vec<Label>,
    ) -> Self {
        cfg.validate().expect("invalid config");
        assert_eq!(labels.len(), g.num_vertices(), "one label per vertex");
        assert!(
            labels.iter().all(|&l| (l as usize) < cfg.parts),
            "labels must be < parts"
        );
        let compact_ratio = cfg.compact_ratio;
        IncrementalPartitioner {
            cfg,
            refiner,
            graph: DynamicGraph::new(g, compact_ratio),
            labels,
            total_evaluated: 0,
            total_repair_steps: 0,
            total_wall_s: 0.0,
            epochs_run: 0,
            diag_flow: None,
            diag_osc: crate::obs::diag::OscillationDetector::new(),
            diag_oscillating: 0,
        }
    }

    /// The evolving graph.
    pub fn graph(&self) -> &DynamicGraph {
        &self.graph
    }

    /// The current graph as a CSR. Valid whenever no updates are
    /// pending — [`IncrementalPartitioner::epoch`] always leaves the
    /// overlay compacted, so between epochs this *is* the graph the
    /// labels partition (what churn generators and quality metrics
    /// should run against).
    pub fn current(&self) -> &Graph {
        debug_assert!(!self.graph.is_dirty(), "current() between epochs only");
        self.graph.base()
    }

    /// Current assignment (one label per vertex id, dead ids included).
    pub fn labels(&self) -> &[Label] {
        &self.labels
    }

    /// Σ vertex-evaluations across all epochs' repair passes.
    pub fn total_evaluated(&self) -> u64 {
        self.total_evaluated
    }

    /// Σ supersteps across all epochs' repair passes.
    pub fn total_repair_steps(&self) -> u32 {
        self.total_repair_steps
    }

    /// Σ wall seconds across all epochs (apply + place + repair +
    /// rebalance; the cold start is not counted, matching
    /// [`IncrementalPartitioner::total_evaluated`]).
    pub fn total_wall_s(&self) -> f64 {
        self.total_wall_s
    }

    /// Apply one update batch and repair the assignment around it.
    /// `Err` means a repair-pass worker panicked (contained,
    /// [`crate::engine::EngineError`]); the overlay is already compacted
    /// but the labels are the pre-repair assignment.
    pub fn epoch(
        &mut self,
        batch: &UpdateBatch,
    ) -> Result<EpochStats, crate::engine::EngineError> {
        let k = self.cfg.parts;
        let sw = crate::util::Stopwatch::start();
        let _ep = crate::obs::span("dynamic_epoch");
        self.epochs_run += 1;
        if crate::obs::enabled() {
            let p = crate::obs::progress();
            p.set_phase("dynamic_epoch");
            p.set_epoch(self.epochs_run);
        }
        let mut stats = EpochStats::default();
        // Diag flow at the dynamic layer is an epoch-granularity label
        // diff (placement + repair + rebalance combined), so the
        // pre-epoch assignment is the baseline. Arrivals placed this
        // epoch sit past the stashed length and are excluded — they
        // arrive, they don't migrate.
        let diag_on = crate::obs::enabled() && self.cfg.diag;
        let pre_labels = if diag_on { Some(self.labels.clone()) } else { None };

        // 1. Mutate the overlay, collecting changed endpoints.
        let mut touched: Vec<VertexId> = Vec::new();
        {
            let _s = crate::obs::span("apply");
            let applied = self.graph.apply(batch, &mut touched);
            stats.applied = applied.applied;
            stats.skipped = applied.skipped;
        }

        // 2. Greedy placement of arrivals against the full assignment.
        {
            let _s = crate::obs::span("place");
            stats.placed = self.place_new_vertices();
        }

        // 3. Materialize the CSR for repair + metrics (epoch boundary =
        //    compaction point, see module docs).
        {
            let _s = crate::obs::span("compact");
            self.graph.compact();
        }
        let g = self.graph.base();

        // Seed set: live changed endpoints plus their undirected
        // neighbourhoods — the region whose scores an update can have
        // shifted. Everything else starts settled; wake events extend
        // the frontier only where repair actually propagates.
        touched.retain(|&v| (v as usize) < g.num_vertices() && self.graph.is_alive(v));
        let mut seeds = touched.clone();
        for &v in &touched {
            seeds.extend_from_slice(g.neighbors(v));
        }
        seeds.sort_unstable();
        seeds.dedup();
        stats.seeds = seeds.len();

        if !seeds.is_empty() {
            let _s = crate::obs::span("repair");
            let rsw = crate::util::Stopwatch::start();
            let mut rcfg = self.cfg.clone();
            rcfg.max_steps = self.cfg.repair_steps;
            // Checkpoint cadence belongs to the dynamic driver (epoch
            // granularity), never to the inner bounded repair pass —
            // interleaved step-level snapshots would corrupt the
            // resume cursor ordering.
            rcfg.checkpoint_dir.clear();
            // Same ownership split for diag: the epoch-level label
            // diff below is the single flow accounting; the inner
            // pass emitting per-step flow too would double-count
            // every repair move.
            rcfg.diag = false;
            let out = match self.refiner {
                Refiner::Spinner => {
                    spinner::refine_seeded(g, &rcfg, self.labels.clone(), seeds)?
                }
                Refiner::Revolver => {
                    revolver::refine_seeded(g, &rcfg, self.labels.clone(), seeds)?
                }
            };
            stats.repair_steps = out.trace.steps();
            stats.evaluated = out.trace.total_evaluated;
            stats.repair_wall_s = rsw.elapsed_s();
            self.labels = out.labels;
        }

        // 4. Pin the ε envelope (removals can strand b(l) > C; the
        //    engine's gate only bounds inflow).
        {
            let _s = crate::obs::span("rebalance");
            stats.rebalance_moves = rebalance(g, &mut self.labels, k, self.cfg.epsilon);
        }

        self.diag_flow = pre_labels.map(|pre| {
            let k = self.cfg.parts;
            let mut moves = vec![0u64; k * k];
            let mut mass = vec![0u64; k * k];
            for v in 0..pre.len().min(self.labels.len()) {
                let (from, to) = (pre[v] as usize, self.labels[v] as usize);
                if from != to && from < k && to < k {
                    moves[from * k + to] += 1;
                    mass[from * k + to] += u64::from(g.load_mass(v as VertexId));
                }
            }
            self.diag_oscillating = self.diag_osc.observe(&self.labels);
            (moves, mass)
        });

        self.total_evaluated += stats.evaluated;
        self.total_repair_steps += stats.repair_steps;
        self.total_wall_s += sw.elapsed_s();
        Ok(stats)
    }

    /// Build a per-epoch quality trace point — the quality-over-time
    /// CSV rows the `dynamic` subcommand emits ride the existing
    /// [`RunTrace`] machinery, with columns reinterpreted (schema
    /// note, mirrored in the CLI output): `step` is the epoch index,
    /// `migrations` carries the post-repair *rebalance boundary moves*
    /// (the repair pass's internal engine migrations are not
    /// surfaced), `mean_score` carries the epoch's repair-pass wall
    /// seconds (0.0 when no repair ran), and `elapsed_s` is cumulative
    /// epoch wall time (cold start excluded).
    pub fn trace_point(&self, epoch: u32, stats: &EpochStats) -> crate::metrics::trace::TracePoint {
        use crate::metrics::quality;
        let g = self.current();
        crate::metrics::trace::TracePoint {
            step: epoch,
            local_edges: quality::local_edges(g, &self.labels),
            max_normalized_load: quality::max_normalized_load(g, &self.labels, self.cfg.parts),
            mean_score: stats.repair_wall_s,
            migrations: stats.rebalance_moves,
            evaluated: stats.evaluated,
            elapsed_s: self.total_wall_s,
        }
    }

    /// Fold a finished epoch into `trace` (point + running totals).
    pub fn record_epoch(&self, trace: &mut RunTrace, epoch: u32, stats: &EpochStats) {
        trace.push(self.trace_point(epoch, stats));
        trace.total_evaluated += stats.evaluated;
        crate::obs::event(
            "epoch",
            &[
                ("epoch", epoch as f64),
                ("placed", stats.placed as f64),
                ("seeds", stats.seeds as f64),
                ("evaluated", stats.evaluated as f64),
                ("repair_s", stats.repair_wall_s),
            ],
        );
        // Observatory lines at epoch granularity: `step` carries the
        // epoch index (the extra `epoch` field disambiguates them from
        // an engine run's per-step lines in the same log).
        if let Some((moves, mass)) = &self.diag_flow {
            let k = self.cfg.parts;
            for from in 0..k {
                for to in 0..k {
                    let m = moves[from * k + to];
                    if m != 0 {
                        crate::obs::event(
                            "flow",
                            &[
                                ("step", epoch as f64),
                                ("epoch", epoch as f64),
                                ("from", from as f64),
                                ("to", to as f64),
                                ("moves", m as f64),
                                ("mass", mass[from * k + to] as f64),
                            ],
                        );
                    }
                }
            }
            let g = self.current();
            let samples = crate::obs::diag::partition_samples(g, &self.labels, k);
            for (p, s) in samples.iter().enumerate() {
                crate::obs::event(
                    "partition",
                    &[
                        ("step", epoch as f64),
                        ("part", p as f64),
                        ("load", s.load as f64),
                        ("boundary", s.boundary as f64),
                        ("local_frac", s.local_frac),
                    ],
                );
            }
            crate::obs::event(
                "diag",
                &[
                    ("step", epoch as f64),
                    ("epoch", epoch as f64),
                    ("oscillating", self.diag_oscillating as f64),
                ],
            );
            crate::obs::diag_update(&crate::obs::diag::DiagUpdate {
                step: epoch as u64,
                k,
                flow_moves: Some(moves.clone()),
                flow_mass: Some(mass.clone()),
                partitions: Some(samples),
                oscillating: Some(self.diag_oscillating),
                ..Default::default()
            });
        }
    }

    /// Assign every not-yet-labelled vertex (arrivals, including ids
    /// implicitly created by edges to unseen endpoints) by the
    /// configured greedy score against the full current assignment.
    fn place_new_vertices(&mut self) -> usize {
        let n = self.graph.num_vertices();
        if n == self.labels.len() {
            return 0;
        }
        let old = self.labels.len();
        self.labels.resize(n, UNASSIGNED);
        // Current per-vertex charged mass: what each already-placed
        // vertex contributes to its partition's load, in the same
        // units the repair's capacity gate uses (out-degree).
        let charged: Vec<u32> = (0..n)
            .map(|v| {
                if self.labels[v] == UNASSIGNED {
                    0
                } else {
                    self.graph.load_mass(v as VertexId)
                }
            })
            .collect();
        let obj = match self.cfg.placement {
            Placement::Ldg => Objective::Ldg,
            Placement::Fennel => Objective::Fennel { gamma: self.cfg.fennel_gamma },
        };
        let mut st = StreamState::from_assignment(
            self.labels.clone(),
            charged,
            self.cfg.parts,
            self.cfg.epsilon,
            Some(self.graph.num_edges() as u64),
        );
        let mut placed = 0usize;
        let mut nbrs: Vec<VertexId> = Vec::new();
        for v in old..n {
            let vid = v as VertexId;
            nbrs.clear();
            nbrs.extend(self.graph.und_neighbors(vid));
            st.place(vid, &nbrs, &[], self.graph.load_mass(vid), obj, false);
            placed += 1;
        }
        // finish() round-robins anything still unassigned (defensive;
        // every arrival was just placed) and hands the labels back.
        self.labels = st.finish(n);
        placed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Placement;
    use crate::dynamic::updates::{ChurnRecipe, Update};
    use crate::graph::gen::rmat;
    use crate::graph::GraphBuilder;
    use crate::metrics::quality;

    fn cfg(k: usize) -> RevolverConfig {
        RevolverConfig {
            parts: k,
            threads: 1,
            seed: 9,
            max_steps: 40,
            repair_steps: 5,
            ..Default::default()
        }
    }

    /// Two reciprocal 6-cliques with a perfect 2-way assignment.
    fn two_cliques() -> (Graph, Vec<Label>) {
        let sz = 6usize;
        let mut b = GraphBuilder::new(2 * sz);
        for base in [0, sz] {
            for i in 0..sz {
                for j in 0..sz {
                    if i != j {
                        b.edge((base + i) as u32, (base + j) as u32);
                    }
                }
            }
        }
        let labels = (0..2 * sz).map(|v| (v >= sz) as u32).collect();
        (b.build(), labels)
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let (g, labels) = two_cliques();
        let mut inc =
            IncrementalPartitioner::from_assignment(g, cfg(2), Refiner::Spinner, labels.clone());
        let stats = inc.epoch(&UpdateBatch::default()).unwrap();
        assert_eq!(stats, EpochStats::default());
        assert_eq!(inc.labels(), labels.as_slice());
        assert_eq!(inc.total_evaluated(), 0);
    }

    #[test]
    fn settled_graph_pays_only_for_the_touched_region() {
        // One intra-clique edge toggled: the seed set is confined to
        // that clique, and a stable assignment repairs in O(clique)
        // evaluations, never O(|V|) per step.
        let (g, labels) = two_cliques();
        let n = g.num_vertices() as u64;
        let mut inc =
            IncrementalPartitioner::from_assignment(g, cfg(2), Refiner::Spinner, labels.clone());
        let batch = UpdateBatch { updates: vec![Update::RemoveEdge(0, 1)] };
        let stats = inc.epoch(&batch).unwrap();
        assert_eq!(stats.applied, 1);
        assert!(stats.seeds <= 6, "seeds confined to the touched clique: {stats:?}");
        assert!(
            stats.evaluated < n * u64::from(stats.repair_steps.max(1)),
            "repair must not sweep the full graph each step: {stats:?}"
        );
        assert_eq!(inc.labels(), labels.as_slice(), "stable cut must survive repair");
    }

    #[test]
    fn arrival_is_placed_with_its_neighbors() {
        let (g, labels) = two_cliques();
        let mut c = cfg(2);
        c.placement = Placement::Ldg;
        let mut inc = IncrementalPartitioner::from_assignment(g, c, Refiner::Spinner, labels);
        // New vertex 12 wired into the second clique (labels 1).
        let batch = UpdateBatch {
            updates: vec![
                Update::AddVertex(12),
                Update::AddEdge(12, 6),
                Update::AddEdge(12, 7),
                Update::AddEdge(8, 12),
            ],
        };
        let stats = inc.epoch(&batch).unwrap();
        assert_eq!(stats.placed, 1);
        assert_eq!(inc.labels().len(), 13);
        assert_eq!(inc.labels()[12], 1, "neighbour majority must win placement");
    }

    #[test]
    fn churn_epochs_keep_labels_valid_and_balanced() {
        let g = rmat::rmat(1 << 10, 8 << 10, 0.57, 0.19, 0.19, 3);
        let k = 4;
        for refiner in [Refiner::Spinner, Refiner::Revolver] {
            let mut inc = IncrementalPartitioner::new(g.clone(), cfg(k), refiner).unwrap();
            let recipe = ChurnRecipe::Uniform { frac: 0.03 };
            for e in 0..3u64 {
                let batch = recipe.generate(inc.current(), 100 + e);
                let stats = inc.epoch(&batch).unwrap();
                assert!(stats.applied > 0, "{refiner:?} epoch {e}: churn applied");
                let gq = inc.current();
                assert_eq!(inc.labels().len(), gq.num_vertices());
                assert!(inc.labels().iter().all(|&l| (l as usize) < k));
                let mnl = quality::max_normalized_load(gq, inc.labels(), k);
                assert!(mnl <= 1.10 + 1e-9, "{refiner:?} epoch {e}: mnl={mnl}");
            }
            assert!(inc.total_evaluated() > 0);
        }
    }

    #[test]
    fn arrivals_epochs_grow_the_assignment() {
        let g = rmat::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 5);
        let mut inc = IncrementalPartitioner::new(g, cfg(4), Refiner::Spinner).unwrap();
        let n0 = inc.current().num_vertices();
        let recipe = ChurnRecipe::Arrivals { count: 32, edges_per: 3 };
        let batch = recipe.generate(inc.current(), 7);
        let stats = inc.epoch(&batch).unwrap();
        assert_eq!(stats.placed, 32);
        assert_eq!(inc.current().num_vertices(), n0 + 32);
        assert_eq!(inc.labels().len(), n0 + 32);
        assert!(inc.labels().iter().all(|&l| l < 4));
    }

    #[test]
    fn deterministic_across_reconstructions() {
        let g = rmat::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 8);
        let run = || {
            let mut inc = IncrementalPartitioner::new(g.clone(), cfg(4), Refiner::Spinner).unwrap();
            for e in 0..2u64 {
                let batch =
                    ChurnRecipe::Uniform { frac: 0.05 }.generate(inc.current(), 50 + e);
                inc.epoch(&batch).unwrap();
            }
            (inc.labels().to_vec(), inc.total_evaluated())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn record_epoch_builds_quality_trace() {
        let (g, labels) = two_cliques();
        let mut inc =
            IncrementalPartitioner::from_assignment(g, cfg(2), Refiner::Spinner, labels);
        let mut trace = RunTrace::default();
        let batch = UpdateBatch { updates: vec![Update::RemoveEdge(0, 1)] };
        let stats = inc.epoch(&batch).unwrap();
        inc.record_epoch(&mut trace, 0, &stats);
        assert_eq!(trace.points.len(), 1);
        assert_eq!(trace.points[0].step, 0);
        assert!(trace.points[0].local_edges > 0.9, "{:?}", trace.points[0]);
        assert_eq!(trace.total_evaluated, stats.evaluated);
        let csv = trace.to_csv();
        assert!(csv.lines().count() == 2, "{csv}");
    }
}
