//! # Revolver — vertex-centric graph partitioning with reinforcement learning
//!
//! A full reproduction of *"Partitioning Graphs for the Cloud using
//! Reinforcement Learning"* (Hasanzadeh Mofrad, Melhem, Hammoud, 2019):
//! an asynchronous, shared-memory, vertex-centric balanced graph
//! partitioner where every vertex owns a **weighted learning automaton**
//! trained by a **normalized label-propagation** objective.
//!
//! ## Architecture (four layers)
//!
//! * **L4 — algorithms** ([`partitioners`]) — the four partitioners
//!   (Revolver / Spinner / Hash / Range). The iterative ones are pure
//!   [`engine::VertexProgram`]s: per-vertex math plus the per-step data
//!   they need frozen, and nothing else.
//! * **L3 — execution engine** ([`engine`], [`coordinator`],
//!   [`partition`]) — the shared superstep runtime: persistent workers
//!   over contiguous vertex chunks (vertex- or degree-balanced, see
//!   [`config::Schedule`]), the four-barrier step protocol, the
//!   async/sync snapshot machinery, per-step aggregate reduction, trace
//!   recording and convergence-driven halting — plus the graph
//!   substrate, shared partition state, metrics, config and CLI.
//! * **L2 (python/compile/model.py)** — the dense per-batch numeric step
//!   (normalized LP scores, signal construction, weighted-LA update) as
//!   a JAX computation, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the LA update
//!   (eqs. 8–9) and LP scoring (eqs. 10–12).
//!
//! New partitioners implement [`engine::VertexProgram`] and inherit the
//! thread pool, scheduling, snapshots and halting for free — no thread
//! plumbing is ever written in an algorithm module (DESIGN.md §Engine).
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (the `xla`
//! crate, gated behind the `xla` cargo feature; stubbed otherwise) so
//! Revolver's probability updates can run through the compiled
//! XLA path (`--engine xla`); the default pure-Rust path (`--engine
//! native`) is asserted numerically equivalent in integration tests.
//! Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use revolver::graph::gen::{Dataset, generate_dataset};
//! use revolver::partitioners::{Partitioner, revolver::Revolver};
//! use revolver::config::RevolverConfig;
//! use revolver::metrics::quality;
//!
//! let graph = generate_dataset(Dataset::Lj, 1 << 14, 7).unwrap();
//! let cfg = RevolverConfig { parts: 8, ..Default::default() };
//! let out = Revolver::new(cfg).partition(&graph);
//! println!("local edges = {:.3}", quality::local_edges(&graph, &out.labels));
//! println!("max norm load = {:.3}", quality::max_normalized_load(&graph, &out.labels, 8));
//! ```

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod la;
pub mod lp;
pub mod metrics;
pub mod partition;
pub mod partitioners;
pub mod runtime;
pub mod util;

/// Vertex id type. Graphs in the paper reach 23.9M vertices; `u32` covers
/// 4.29B and halves CSR memory versus `u64`.
pub type VertexId = u32;

/// Partition label type. The paper sweeps k up to 256; `u32` leaves room.
pub type Label = u32;
