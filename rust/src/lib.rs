//! # Revolver — vertex-centric graph partitioning with reinforcement learning
//!
//! A full reproduction of *"Partitioning Graphs for the Cloud using
//! Reinforcement Learning"* (Hasanzadeh Mofrad, Melhem, Hammoud, 2019):
//! an asynchronous, shared-memory, vertex-centric balanced graph
//! partitioner where every vertex owns a **weighted learning automaton**
//! trained by a **normalized label-propagation** objective.
//!
//! ## Architecture (four layers)
//!
//! * **L4 — algorithms** ([`partitioners`], [`stream`], [`multilevel`],
//!   [`dynamic`]) — the algorithm families behind one
//!   [`partitioners::Partitioner`] trait:
//!   - *Iterative* (Revolver / Spinner): pure
//!     [`engine::VertexProgram`]s — per-vertex math plus the per-step
//!     data they need frozen, and nothing else.
//!   - *Streaming* ([`stream`]): one-pass LDG and Fennel, and
//!     prioritized restreaming — each vertex is placed once, in stream
//!     order, from O(k) decision state. Streams come from the CSR in
//!     pluggable orders ([`config::StreamOrder`]) or straight off an
//!     edge-list file without materializing CSR
//!     ([`stream::FileEdgeStream`]).
//!   - *Multilevel* ([`multilevel`]): heavy-edge coarsening down a
//!     [`multilevel::Hierarchy`] of weighted contractions, coarsest
//!     level partitioned by any registered algorithm (default Fennel),
//!     then per-level bounded Spinner/Revolver refinement through
//!     [`engine::run_with_init`] on the way back up — coarse levels
//!     balance in cluster-size units via [`graph::Graph::load_mass`],
//!     and a deterministic rebalance pass pins the ε envelope at every
//!     level (`multilevel` / `ml-spinner` / `ml-revolver`).
//!   - *Dynamic* ([`dynamic`]): evolving graphs. A
//!     [`dynamic::DynamicGraph`] overlay (sorted insert/delete
//!     adjacency deltas + tombstones over the immutable CSR, with
//!     ratio-gated compaction) absorbs [`dynamic::UpdateBatch`]es —
//!     from a text update log or synthetic [`dynamic::ChurnRecipe`]s —
//!     and the [`dynamic::IncrementalPartitioner`] keeps the
//!     assignment alive: arrivals placed greedily against the full
//!     assignment ([`config::Placement`]), then a bounded repair pass
//!     whose step-0 frontier is only the changed endpoints and their
//!     neighbourhoods ([`engine::InitialFrontier::Seeds`]) — an epoch
//!     of churn costs ~|affected region| vertex-evaluations, not
//!     ~|V| per superstep (CLI: the `dynamic` subcommand).
//!   Hash / Range round out the trivial baselines.
//! * **L3 — execution engine** ([`engine`], [`coordinator`],
//!   [`partition`]) — the shared superstep runtime: persistent workers
//!   over per-step work lists, the four-barrier step protocol, the
//!   async/sync snapshot machinery, per-step aggregate reduction, trace
//!   recording and convergence-driven halting. Scheduling is
//!   **active-set by default** ([`config::Frontier`], `--frontier`):
//!   an epoch-stamped activation array tracks which vertices'
//!   neighbourhoods changed, each superstep evaluates only that
//!   frontier (degree-balanced chunks rebuilt over it), and an empty
//!   frontier halts the run — late supersteps cost ~|frontier| instead
//!   of ~|V|. `--frontier off` restores the paper's full sweeps
//!   bit-exactly (legacy chunking via [`config::Schedule`]). Plus the
//!   graph substrate, shared partition state, metrics, config and CLI.
//! * **L2 (python/compile/model.py)** — the dense per-batch numeric step
//!   (normalized LP scores, signal construction, weighted-LA update) as
//!   a JAX computation, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the LA update
//!   (eqs. 8–9) and LP scoring (eqs. 10–12).
//!
//! New iterative partitioners implement [`engine::VertexProgram`] and
//! inherit the thread pool, scheduling, snapshots and halting for free —
//! no thread plumbing is ever written in an algorithm module (DESIGN.md
//! §Engine). New streaming objectives slot into
//! [`stream::Objective`]'s scoring and inherit both stream adapters.
//!
//! ## Warm start (streaming → iterative)
//!
//! `--init stream:<ldg|fennel|restream>` ([`config::Init`]) chains the
//! two families: [`engine::initial_assignment`] runs the streaming pass
//! and seeds the shared label state from it, Spinner then iterates from
//! those labels, and Revolver additionally biases every vertex's LA
//! probability row toward its streamed label — replacing the
//! uniform-random start so the automata refine an already-good cut
//! instead of rediscovering it (fewer steps to the §IV-D.9 halting
//! threshold).
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (the `xla`
//! crate, gated behind the `xla` cargo feature; stubbed otherwise) so
//! Revolver's probability updates can run through the compiled
//! XLA path (`--engine xla`); the default pure-Rust path (`--engine
//! native`) is asserted numerically equivalent in integration tests.
//! Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use revolver::graph::gen::{Dataset, generate_dataset};
//! use revolver::partitioners::{by_name, Partitioner, revolver::Revolver};
//! use revolver::config::{Init, RevolverConfig, StreamAlgo};
//! use revolver::metrics::quality;
//!
//! let graph = generate_dataset(Dataset::Lj, 1 << 14, 7).unwrap();
//! let cfg = RevolverConfig { parts: 8, ..Default::default() };
//! let out = Revolver::new(cfg.clone()).partition(&graph);
//! println!("local edges = {:.3}", quality::local_edges(&graph, &out.labels));
//! println!("max norm load = {:.3}", quality::max_normalized_load(&graph, &out.labels, 8));
//!
//! // Streaming baseline: one Fennel pass over the same graph...
//! let fast = by_name("fennel", cfg.clone()).unwrap().partition(&graph);
//! println!("fennel local edges = {:.3}", quality::local_edges(&graph, &fast.labels));
//!
//! // Multilevel V-cycle (CLI: `partition --algo multilevel`): coarsen,
//! // partition the coarsest level, refine each level on the way up —
//! // Metis-class superstep economy with the same vertex programs doing
//! // the refinement.
//! let ml = by_name("multilevel", cfg.clone()).unwrap().partition(&graph);
//! println!(
//!     "multilevel local edges = {:.3} in {} supersteps",
//!     quality::local_edges(&graph, &ml.labels),
//!     ml.trace.steps()
//! );
//!
//! // ...or as a warm start for Revolver (`--init stream:fennel` on
//! // the CLI): same quality, far fewer steps to converge.
//! let warm_cfg = RevolverConfig {
//!     init: Init::Stream(StreamAlgo::Fennel),
//!     ..cfg
//! };
//! let warm = Revolver::new(warm_cfg).partition(&graph);
//! println!("steps: cold {} vs warm {}", out.trace.steps(), warm.trace.steps());
//!
//! // Huge edge-list files partition without ever building CSR:
//! let res = revolver::stream::partition_edge_list_file(
//!     "data/edges.txt",
//!     &RevolverConfig::default(),
//!     StreamAlgo::Ldg,
//! ).unwrap();
//! println!("streamed {} edges into {} parts", res.edges, 8);
//! ```

pub mod config;
pub mod coordinator;
pub mod dynamic;
pub mod engine;
pub mod fault;
pub mod graph;
pub mod la;
pub mod lp;
pub mod metrics;
pub mod multilevel;
pub mod obs;
pub mod partition;
pub mod partitioners;
pub mod runtime;
pub mod stream;
pub mod util;

/// Vertex id type. Graphs in the paper reach 23.9M vertices; `u32` covers
/// 4.29B and halves CSR memory versus `u64`.
pub type VertexId = u32;

/// Partition label type. The paper sweeps k up to 256; `u32` leaves room.
pub type Label = u32;
