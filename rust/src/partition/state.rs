//! Atomic partition state shared by all worker threads.

use std::sync::atomic::{AtomicI64, AtomicU32, Ordering};

use super::InitialAssignment;
use crate::graph::Graph;
use crate::util::rng::Rng;
use crate::{Label, VertexId};

/// Shared mutable state of a k-way partitioning in progress.
///
/// * `labels[v]` — current partition of vertex v (relaxed atomics).
/// * `loads[l]`  — b(l): total [`Graph::load_mass`] of vertices in l —
///   **out-degree** for the paper's graphs (§II counts partition size
///   in outgoing edges), the coarse vertex weight for multilevel
///   contractions (balance in cluster-size units).
/// * `capacity`  — C = (1+ε)·(Σ_v mass)/k, i.e. (1+ε)·|E|/k for plain
///   graphs.
///
/// Invariant: Σ_l loads[l] == Σ_v mass(v) at every quiescent point
/// (each migration moves exactly `mass(v)` between two partitions
/// atomically enough for the async model — the paper relies on
/// progressive load exchange, not strict consistency).
pub struct PartitionState {
    k: usize,
    capacity: f64,
    epsilon: f64,
    total_mass: u64,
    labels: Vec<AtomicU32>,
    loads: Vec<AtomicI64>,
}

impl PartitionState {
    /// Build state over `g` with `k` partitions, imbalance `epsilon`,
    /// and the given initial assignment.
    pub fn new(g: &Graph, k: usize, epsilon: f64, init: InitialAssignment) -> Self {
        assert!(k >= 2, "need at least 2 partitions");
        let n = g.num_vertices();
        let labels: Vec<AtomicU32> = match init {
            InitialAssignment::Hash => {
                (0..n).map(|v| AtomicU32::new((v % k) as u32)).collect()
            }
            InitialAssignment::Range => (0..n)
                .map(|v| AtomicU32::new(((v as u128 * k as u128) / n as u128) as u32))
                .collect(),
            InitialAssignment::Random(seed) => {
                let mut rng = Rng::new(seed);
                (0..n).map(|_| AtomicU32::new(rng.below(k as u64) as u32)).collect()
            }
            InitialAssignment::Given(init_labels) => {
                assert_eq!(init_labels.len(), n, "Given labels must cover every vertex");
                init_labels
                    .into_iter()
                    .map(|l| {
                        assert!((l as usize) < k, "Given label {l} out of range for k={k}");
                        AtomicU32::new(l)
                    })
                    .collect()
            }
        };

        let loads: Vec<AtomicI64> = (0..k).map(|_| AtomicI64::new(0)).collect();
        for v in 0..n {
            let l = labels[v].load(Ordering::Relaxed) as usize;
            loads[l].fetch_add(g.load_mass(v as VertexId) as i64, Ordering::Relaxed);
        }

        let total_mass = g.total_load_mass();
        let capacity = (1.0 + epsilon) * total_mass as f64 / k as f64;
        PartitionState { k, capacity, epsilon, total_mass, labels, loads }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Per-partition capacity C = (1+ε)·(Σ mass)/k — (1+ε)·|E|/k on
    /// plain graphs — what the migration gate's remaining capacity
    /// r(l) = C − b(l) is measured against.
    #[inline]
    pub fn capacity(&self) -> f64 {
        self.capacity
    }

    /// System-level capacity (1+ε)·|E| — what eq. (12)'s penalty term is
    /// normalized against ("π is normalized based on the total load of
    /// the system", §IV-B). Normalizing against the *per-partition*
    /// capacity instead amplifies sub-percent load differences into
    /// order-one penalty swings and makes every vertex chase the
    /// globally emptiest partition (DESIGN.md F2).
    #[inline]
    pub fn system_capacity(&self) -> f64 {
        self.capacity * self.k as f64
    }

    #[inline]
    pub fn epsilon(&self) -> f64 {
        self.epsilon
    }

    /// Current label of `v` (relaxed — async engines tolerate staleness).
    #[inline]
    pub fn label(&self, v: VertexId) -> Label {
        self.labels[v as usize].load(Ordering::Relaxed)
    }

    /// Current load b(l).
    #[inline]
    pub fn load(&self, l: usize) -> i64 {
        self.loads[l].load(Ordering::Relaxed)
    }

    /// Snapshot all loads into `out` as f32 (for the scoring kernels).
    pub fn loads_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.k);
        for (o, l) in out.iter_mut().zip(self.loads.iter()) {
            *o = l.load(Ordering::Relaxed) as f32;
        }
    }

    /// Remaining capacity r(l) = C − b(l) (may be negative transiently).
    #[inline]
    pub fn remaining(&self, l: usize) -> f64 {
        self.capacity - self.load(l) as f64
    }

    /// Migrate `v` (with load mass `deg` — its out-degree on plain
    /// graphs, its vertex weight on coarse ones) from its current label
    /// to `to`. Returns the previous label. No-op if already there.
    ///
    /// The label swap uses `swap` so two racing migrations of the same
    /// vertex still keep the load invariant: each swap observes the
    /// true previous label and moves exactly `deg` of load.
    #[inline]
    pub fn migrate(&self, v: VertexId, to: Label, deg: u32) -> Label {
        let from = self.labels[v as usize].swap(to, Ordering::Relaxed);
        if from != to {
            self.loads[from as usize].fetch_sub(deg as i64, Ordering::Relaxed);
            self.loads[to as usize].fetch_add(deg as i64, Ordering::Relaxed);
        }
        from
    }

    /// Clone the labels into a plain vector (for metrics / reporting).
    pub fn labels_snapshot(&self) -> Vec<Label> {
        self.labels.iter().map(|l| l.load(Ordering::Relaxed)).collect()
    }

    /// Check Σ loads == Σ mass (test/debug invariant); the total is |E|
    /// for plain graphs.
    pub fn check_load_invariant(&self) -> anyhow::Result<()> {
        let sum: i64 = self.loads.iter().map(|l| l.load(Ordering::Relaxed)).sum();
        anyhow::ensure!(
            sum as u64 == self.total_mass,
            "load invariant violated: Σb(l)={} != total mass {}",
            sum,
            self.total_mass
        );
        Ok(())
    }
}

/// Per-step migration demand m(l) = Σ_{u∈M(l)} deg(u): the out-degree
/// mass of vertices whose LA selected partition l this step (§IV-D.2).
pub struct DemandTracker {
    demand: Vec<AtomicI64>,
}

impl DemandTracker {
    pub fn new(k: usize) -> Self {
        DemandTracker { demand: (0..k).map(|_| AtomicI64::new(0)).collect() }
    }

    /// Register that a vertex with out-degree `deg` wants to join `l`.
    #[inline]
    pub fn add(&self, l: usize, deg: u32) {
        self.demand[l].fetch_add(deg as i64, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self, l: usize) -> i64 {
        self.demand[l].load(Ordering::Relaxed)
    }

    /// Zero all counters (start of each step).
    pub fn reset(&self) {
        for d in &self.demand {
            d.store(0, Ordering::Relaxed);
        }
    }

    /// Migration probability for candidate partition `l` given current
    /// state: min(1, r(l)/m(l)), 0 when the partition is full (§IV-D.2).
    #[inline]
    pub fn migration_probability(&self, state: &PartitionState, l: usize) -> f64 {
        let demand = self.get(l) as f64;
        if demand <= 0.0 {
            return 1.0;
        }
        let remaining = state.remaining(l);
        if remaining <= 0.0 {
            return 0.0;
        }
        (remaining / demand).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path_graph(n: usize) -> Graph {
        let mut b = GraphBuilder::new(n);
        for v in 0..n as u32 - 1 {
            b.edge(v, v + 1);
        }
        b.build()
    }

    #[test]
    fn hash_init_balanced() {
        let g = path_graph(100);
        let st = PartitionState::new(&g, 4, 0.05, InitialAssignment::Hash);
        for v in 0..100u32 {
            assert_eq!(st.label(v), v % 4);
        }
        st.check_load_invariant().unwrap();
    }

    #[test]
    fn range_init_contiguous() {
        let g = path_graph(100);
        let st = PartitionState::new(&g, 4, 0.05, InitialAssignment::Range);
        assert_eq!(st.label(0), 0);
        assert_eq!(st.label(24), 0);
        assert_eq!(st.label(25), 1);
        assert_eq!(st.label(99), 3);
        st.check_load_invariant().unwrap();
    }

    #[test]
    fn random_init_in_range_and_deterministic() {
        let g = path_graph(50);
        let a = PartitionState::new(&g, 3, 0.05, InitialAssignment::Random(7));
        let b = PartitionState::new(&g, 3, 0.05, InitialAssignment::Random(7));
        for v in 0..50u32 {
            assert!(a.label(v) < 3);
            assert_eq!(a.label(v), b.label(v));
        }
    }

    #[test]
    fn given_init_uses_supplied_labels() {
        let g = path_graph(10);
        let labels = vec![1, 0, 1, 0, 1, 0, 1, 0, 1, 0];
        let st = PartitionState::new(&g, 2, 0.05, InitialAssignment::Given(labels.clone()));
        for (v, &l) in labels.iter().enumerate() {
            assert_eq!(st.label(v as u32), l);
        }
        st.check_load_invariant().unwrap();
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn given_init_rejects_bad_label() {
        let g = path_graph(3);
        PartitionState::new(&g, 2, 0.05, InitialAssignment::Given(vec![0, 5, 1]));
    }

    #[test]
    fn capacity_formula() {
        let g = path_graph(101); // 100 edges
        let st = PartitionState::new(&g, 4, 0.05, InitialAssignment::Hash);
        assert!((st.capacity() - 1.05 * 100.0 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn migrate_moves_load() {
        let g = path_graph(10); // vertices 0..8 have out-degree 1
        let st = PartitionState::new(&g, 2, 0.05, InitialAssignment::Hash);
        let before0 = st.load(0);
        let before1 = st.load(1);
        // v=0 has label 0, degree 1 -> move to 1.
        let prev = st.migrate(0, 1, 1);
        assert_eq!(prev, 0);
        assert_eq!(st.load(0), before0 - 1);
        assert_eq!(st.load(1), before1 + 1);
        st.check_load_invariant().unwrap();
        // Idempotent when target == current.
        let prev = st.migrate(0, 1, 1);
        assert_eq!(prev, 1);
        st.check_load_invariant().unwrap();
    }

    #[test]
    fn concurrent_migrations_keep_invariant() {
        let g = path_graph(1000);
        let st = std::sync::Arc::new(PartitionState::new(
            &g,
            8,
            0.05,
            InitialAssignment::Hash,
        ));
        let degs: Vec<u32> = (0..1000).map(|v| g.out_degree(v as u32)).collect();
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let st = st.clone();
            let degs = degs.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..10_000 {
                    let v = rng.below(1000) as u32;
                    let to = rng.below(8) as u32;
                    st.migrate(v, to, degs[v as usize]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        st.check_load_invariant().unwrap();
    }

    #[test]
    fn demand_tracker_probability() {
        let g = path_graph(101); // 100 edges, C = 52.5 at k=2, eps=.05
        let st = PartitionState::new(&g, 2, 0.05, InitialAssignment::Hash);
        let d = DemandTracker::new(2);
        assert_eq!(d.migration_probability(&st, 0), 1.0, "no demand => free move");
        d.add(0, 10);
        let p = d.migration_probability(&st, 0);
        // remaining = 52.5 - 50 = 2.5 over demand 10 => 0.25.
        assert!((p - 0.25).abs() < 1e-6, "p={p}");
        d.reset();
        assert_eq!(d.get(0), 0);
    }

    #[test]
    #[should_panic(expected = "at least 2 partitions")]
    fn k1_rejected() {
        let g = path_graph(10);
        PartitionState::new(&g, 1, 0.05, InitialAssignment::Hash);
    }
}
