//! Shared partition state: labels, per-partition loads, capacities,
//! per-step migration demand.
//!
//! Concurrency model (DESIGN.md §6): labels and loads are atomics with
//! relaxed ordering — the asynchronous engine *wants* vertices to see
//! fresh-but-unsynchronized state (§V-H.2), and every individual
//! migration keeps the load invariant exact via `fetch_add` pairs.

pub mod state;

pub use state::{DemandTracker, PartitionState};

/// Initial assignment policies for partition state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InitialAssignment {
    /// `v mod k` — what Hash partitioning produces; Revolver and Spinner
    /// both start from a random-ish balanced assignment.
    Hash,
    /// `⌊v·k/|V|⌋` — contiguous ranges.
    Range,
    /// Uniform random.
    Random(u64),
    /// Explicit per-vertex labels — the streaming warm-start path
    /// ([`crate::config::Init::Stream`]). Must supply one label `< k`
    /// per vertex.
    Given(Vec<crate::Label>),
}
