//! Streaming partitioning (L4): one-pass and multi-pass algorithms
//! that assign each vertex once, in stream order, from O(k) state —
//! the strongest cheap baselines the paper compares against, and the
//! warm-start source for the iterative partitioners.
//!
//! ## Model
//!
//! An [`EdgeStream`] yields the graph one *vertex group* at a time: a
//! vertex id, the neighbours visible in its group, and the group's
//! out-edge count (the load unit the rest of the system balances).
//! Two adapters exist:
//!
//! * [`CsrEdgeStream`] — over an in-memory [`crate::graph::Graph`], in
//!   a pluggable [`crate::config::StreamOrder`] (natural, shuffled,
//!   BFS) or any explicit order (the prioritized-restreaming path).
//!   Groups carry the full undirected neighbourhood.
//! * [`FileEdgeStream`] — directly over an edge-list text file through
//!   a chunked reader with one reusable line buffer, so huge graphs
//!   are partitioned without ever materializing CSR. Groups are runs
//!   of consecutive same-source lines (exact for the sorted files
//!   SNAP-style dumps are); capacities adapt as the edge count is
//!   discovered, and `reset()` enables multi-pass restreaming with
//!   stable dense ids.
//!
//! ## Algorithms
//!
//! [`run_pass`] drives one pass of a greedy [`Objective`] over a
//! [`StreamState`]:
//!
//! * **LDG** (Stanton & Kliot): `|N(v) ∩ P_l| · (1 − b(l)/C)`.
//! * **Fennel** (Tsourakakis et al.): `|N(v) ∩ P_l| − α·((b(l)+d)^γ −
//!   b(l)^γ)` with `α = (k/|E|)^{γ−1}`, the marginal cost of the
//!   superlinear load term, in the out-edge load units of
//!   [`crate::metrics::quality::max_normalized_load`].
//!
//! Both are capacity-gated at `C = (1+ε)|E|/k` — a full partition is
//! only eligible when every partition is full — so streaming output
//! satisfies the same eq. (1) balance bound the iterative partitioners
//! target. [`Restream`] runs N passes: pass 1 in the configured order,
//! later passes in descending-degree *priority* order re-placing each
//! vertex against the full previous assignment (Awadelkarim & Ugander,
//! arXiv:2007.03131), keeping the best pass by local edges.
//!
//! ## Warm start
//!
//! [`stream_labels`] is the bridge the engine calls for
//! `--init stream:<algo>`: Spinner starts from the streamed labels,
//! and Revolver additionally biases each vertex's LA probability row
//! toward its streamed label (see `partitioners/revolver.rs`).

pub mod algos;
pub mod edge_stream;
pub mod pass;

pub use algos::{
    partition_edge_list_file, stream_labels, Fennel, FileStreamResult, Ldg, Restream,
};
pub use edge_stream::{CsrEdgeStream, EdgeStream, FileEdgeStream, StreamGroup};
pub use pass::{run_pass, Objective, StreamState, UNASSIGNED};
