//! The greedy streaming pass: O(k) decision state, capacity-gated
//! LDG/Fennel scoring, and the pass driver shared by the one-pass and
//! restreaming partitioners (and both stream adapters).

use anyhow::Result;

use crate::{Label, VertexId};

use super::edge_stream::EdgeStream;

/// Sentinel label for a vertex not yet placed.
pub const UNASSIGNED: Label = Label::MAX;

/// Greedy objective a streaming pass maximizes per vertex.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Linear deterministic greedy: `|N(v) ∩ P_l| · (1 − b(l)/C)`.
    Ldg,
    /// Fennel: `|N(v) ∩ P_l| − α·((b(l)+d)^γ − b(l)^γ)` with
    /// `α = (k/|E|)^{γ−1}` — the marginal superlinear load cost in
    /// out-edge units.
    Fennel { gamma: f64 },
}

/// Mutable state of a streaming partitioning: per-vertex labels (grown
/// on demand for file streams), per-partition out-edge loads, and the
/// capacity bookkeeping. Persists across restreaming passes.
pub struct StreamState {
    k: usize,
    epsilon: f64,
    labels: Vec<Label>,
    /// Out-edge load currently charged per vertex (subtracted when a
    /// restreaming pass re-places it).
    charged: Vec<u32>,
    loads: Vec<f64>,
    hist: Vec<f64>,
    /// Exact |E| when the stream announced it; otherwise capacities
    /// adapt to the edge mass streamed so far.
    known_edges: Option<u64>,
    streamed_edges: u64,
}

impl StreamState {
    pub fn new(n_hint: usize, k: usize, epsilon: f64, known_edges: Option<u64>) -> Self {
        assert!(k >= 2, "need at least 2 partitions");
        StreamState {
            k,
            epsilon,
            labels: vec![UNASSIGNED; n_hint],
            charged: vec![0; n_hint],
            loads: vec![0.0; k],
            hist: vec![0.0; k],
            known_edges,
            streamed_edges: 0,
        }
    }

    /// Rehydrate streaming state from an existing assignment — the
    /// dynamic subsystem's arrival-placement path
    /// ([`crate::dynamic::IncrementalPartitioner`]): labels are the
    /// full current assignment ([`UNASSIGNED`] for vertices awaiting
    /// placement) and `charged[v]` is the load mass vertex `v`
    /// currently contributes to its partition (0 for unplaced ones).
    /// Per-partition loads are derived by summation, so subsequent
    /// [`StreamState::place`] calls score exactly as if the assignment
    /// had been streamed — Prioritized Restreaming's "place against the
    /// full previous assignment", without replaying it.
    pub fn from_assignment(
        labels: Vec<Label>,
        charged: Vec<u32>,
        k: usize,
        epsilon: f64,
        known_edges: Option<u64>,
    ) -> Self {
        assert!(k >= 2, "need at least 2 partitions");
        assert_eq!(labels.len(), charged.len(), "one charged mass per label");
        let mut loads = vec![0.0f64; k];
        let mut streamed = 0u64;
        for (&l, &c) in labels.iter().zip(&charged) {
            if l == UNASSIGNED {
                debug_assert_eq!(c, 0, "unplaced vertices cannot carry charged mass");
                continue;
            }
            assert!((l as usize) < k, "label {l} out of range for k={k}");
            loads[l as usize] += c as f64;
            streamed += c as u64;
        }
        StreamState {
            k,
            epsilon,
            labels,
            charged,
            loads,
            hist: vec![0.0; k],
            known_edges,
            streamed_edges: streamed,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn labels(&self) -> &[Label] {
        &self.labels
    }

    pub fn loads(&self) -> &[f64] {
        &self.loads
    }

    pub fn streamed_edges(&self) -> u64 {
        self.streamed_edges
    }

    /// Pin the edge count once a first file pass discovered it, so
    /// later passes score against exact capacities.
    pub fn set_known_edges(&mut self, m: Option<u64>) {
        if m.is_some() {
            self.known_edges = m;
        }
    }

    fn edge_mass(&self) -> f64 {
        self.known_edges.unwrap_or(self.streamed_edges).max(1) as f64
    }

    /// Per-partition capacity `C = (1+ε)·|E|/k` in out-edge units —
    /// exact or adaptive, see [`StreamState::set_known_edges`].
    pub fn capacity(&self) -> f64 {
        (1.0 + self.epsilon) * self.edge_mass() / self.k as f64
    }

    fn ensure(&mut self, v: usize) {
        if v >= self.labels.len() {
            self.labels.resize(v + 1, UNASSIGNED);
            self.charged.resize(v + 1, 0);
        }
    }

    /// Fold an extra same-source run of an already-placed vertex into
    /// its current partition's load.
    fn add_load(&mut self, v: VertexId, load_mass: u32, count_edges: bool) {
        let vi = v as usize;
        self.ensure(vi);
        debug_assert_ne!(self.labels[vi], UNASSIGNED);
        self.loads[self.labels[vi] as usize] += load_mass as f64;
        self.charged[vi] += load_mass;
        if count_edges {
            self.streamed_edges += load_mass as u64;
        }
    }

    /// Place (or, on a restreaming pass, re-place) vertex `v` given its
    /// visible neighbours. `nbr_ws` carries the neighbour edge weights
    /// when the stream has meaningful ones (weighted multilevel
    /// contractions — a coarse edge stands for many fine edges and the
    /// affinity histogram must see that); empty means unit weights (the
    /// plain one-pass model). Returns the chosen label.
    pub fn place(
        &mut self,
        v: VertexId,
        nbrs: &[VertexId],
        nbr_ws: &[f32],
        load_mass: u32,
        obj: Objective,
        revisit: bool,
    ) -> Label {
        debug_assert!(nbr_ws.is_empty() || nbr_ws.len() == nbrs.len());
        let vi = v as usize;
        self.ensure(vi);
        if self.labels[vi] != UNASSIGNED {
            if !revisit {
                // Duplicate group in a plain pass (unsorted file):
                // extra edges stay where the vertex already lives.
                self.add_load(v, load_mass, true);
                return self.labels[vi];
            }
            // Restreaming: lift v out before rescoring, so the gate
            // sees loads without its own mass.
            self.loads[self.labels[vi] as usize] -= self.charged[vi] as f64;
            self.charged[vi] = 0;
        } else if !revisit {
            self.streamed_edges += load_mass as u64;
        }

        // Histogram of already-placed neighbours (unplaced ones
        // contribute nothing — the standard one-pass model), weighted
        // by the stream's edge weights when it has them.
        self.hist.fill(0.0);
        for (i, &u) in nbrs.iter().enumerate() {
            match self.labels.get(u as usize) {
                Some(&l) if l != UNASSIGNED => {
                    let w = if nbr_ws.is_empty() { 1.0 } else { nbr_ws[i] as f64 };
                    self.hist[l as usize] += w;
                }
                _ => {}
            }
        }

        let l = self.choose(load_mass, obj);
        self.labels[vi] = l;
        self.charged[vi] = load_mass;
        self.loads[l as usize] += load_mass as f64;
        l
    }

    /// Argmax of the objective over partitions with room for `d` more
    /// out-edges; if every partition is full, least-loaded. Ties break
    /// to the lighter partition, then the lower index — deterministic.
    fn choose(&self, load_mass: u32, obj: Objective) -> Label {
        let d = load_mass as f64;
        let cap = self.capacity();
        let alpha = match obj {
            Objective::Ldg => 0.0,
            Objective::Fennel { gamma } => {
                (self.k as f64 / self.edge_mass()).powf(gamma - 1.0)
            }
        };
        let mut chosen: Option<usize> = None;
        let mut best_score = 0.0;
        let mut best_load = 0.0;
        for l in 0..self.k {
            let load = self.loads[l];
            if load + d > cap {
                continue;
            }
            let score = match obj {
                Objective::Ldg => self.hist[l] * (1.0 - load / cap),
                Objective::Fennel { gamma } => {
                    self.hist[l] - alpha * ((load + d).powf(gamma) - load.powf(gamma))
                }
            };
            let better = match chosen {
                None => true,
                Some(_) => score > best_score || (score == best_score && load < best_load),
            };
            if better {
                chosen = Some(l);
                best_score = score;
                best_load = load;
            }
        }
        match chosen {
            Some(l) => l as Label,
            None => {
                // Every partition full: overflow into the lightest.
                let mut best = 0usize;
                for l in 1..self.k {
                    if self.loads[l] < self.loads[best] {
                        best = l;
                    }
                }
                best as Label
            }
        }
    }

    /// Close out a pass: place any vertex never seen as a group source
    /// (dst-only ids in file streams, isolated vertices) and return the
    /// first `n` labels. No adjacency or out-edge load is known for
    /// these, so round-robin keeps vertex counts balanced without
    /// touching edge loads.
    pub fn finish(&mut self, n: usize) -> Vec<Label> {
        if n > 0 {
            self.ensure(n - 1);
        }
        let mut next = 0usize;
        for v in 0..n {
            if self.labels[v] == UNASSIGNED {
                self.labels[v] = (next % self.k) as Label;
                next += 1;
            }
        }
        self.labels[..n].to_vec()
    }
}

/// Run one full pass of `stream` through `state`. `revisit = true` is
/// a restreaming pass: already-placed vertices are lifted out and
/// re-placed (and the pass adds no new edge mass).
pub fn run_pass<S: EdgeStream + ?Sized>(
    stream: &mut S,
    state: &mut StreamState,
    obj: Objective,
    revisit: bool,
) -> Result<()> {
    let mut nbrs: Vec<VertexId> = Vec::new();
    let mut nbr_ws: Vec<f32> = Vec::new();
    // "First group this pass" (re-place) vs "later run of the same
    // source" (fold into load) only needs tracking when both can
    // happen: a plain pass gets it for free from the UNASSIGNED
    // sentinel inside `place`, and exactly-once streams (CSR) never
    // produce duplicate groups at all. That leaves revisit passes over
    // file streams.
    let track_dups = revisit && !stream.exactly_once_per_pass();
    let mut visited = if track_dups { vec![false; stream.num_vertices()] } else { Vec::new() };
    while let Some(group) = stream.next_group(&mut nbrs, &mut nbr_ws)? {
        if track_dups {
            let vi = group.v as usize;
            if vi >= visited.len() {
                visited.resize(vi + 1, false);
            }
            if visited[vi] {
                state.add_load(group.v, group.load_mass, false);
                continue;
            }
            visited[vi] = true;
        }
        state.place(group.v, &nbrs, &nbr_ws, group.load_mass, obj, revisit);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StreamOrder;
    use crate::graph::{Graph, GraphBuilder};
    use crate::metrics::quality;
    use crate::stream::edge_stream::CsrEdgeStream;

    /// Two disjoint directed 8-cliques joined by one bridge edge.
    fn two_cliques(sz: usize) -> Graph {
        let mut b = GraphBuilder::new(2 * sz);
        for base in [0, sz] {
            for i in 0..sz {
                for j in 0..sz {
                    if i != j {
                        b.edge((base + i) as u32, (base + j) as u32);
                    }
                }
            }
        }
        b.edge(0, sz as u32);
        b.build()
    }

    fn one_pass(g: &Graph, k: usize, obj: Objective) -> Vec<Label> {
        let mut s = CsrEdgeStream::new(g, StreamOrder::Natural, 1);
        let mut state = StreamState::new(g.num_vertices(), k, 0.05, Some(g.num_edges() as u64));
        run_pass(&mut s, &mut state, obj, false).unwrap();
        state.finish(g.num_vertices())
    }

    #[test]
    fn ldg_separates_cliques() {
        let g = two_cliques(8);
        let labels = one_pass(&g, 2, Objective::Ldg);
        // Each clique must land whole in one partition (the bridge may
        // go either way).
        for c in 0..2 {
            let l0 = labels[c * 8];
            assert!((0..8).all(|i| labels[c * 8 + i] == l0), "{labels:?}");
        }
        assert_ne!(labels[0], labels[8], "cliques must split across partitions");
        assert!(quality::local_edges(&g, &labels) > 0.95);
    }

    #[test]
    fn fennel_keeps_locality_and_balances() {
        // On a toy graph Fennel's superlinear penalty trades some
        // clique purity for balance (the first few vertices see hugely
        // divergent relative loads), so unlike LDG it need not keep
        // each clique whole — but it must stay well above a random
        // split (≈0.47 here) while honouring the ε envelope.
        let g = two_cliques(8);
        let labels = one_pass(&g, 2, Objective::Fennel { gamma: 1.5 });
        assert!(labels.iter().all(|&l| l < 2));
        assert!(quality::local_edges(&g, &labels) > 0.55);
        assert!(quality::max_normalized_load(&g, &labels, 2) <= 1.1);
    }

    #[test]
    fn capacity_gate_bounds_load() {
        // A graph where everything prefers one partition: a star-heavy
        // blob. The gate must keep max normalized load near 1+ε.
        use crate::graph::gen::rmat;
        let g = rmat::rmat(1 << 10, 16 << 10, 0.57, 0.19, 0.19, 3);
        for obj in [Objective::Ldg, Objective::Fennel { gamma: 1.5 }] {
            let labels = one_pass(&g, 4, obj);
            assert!(labels.iter().all(|&l| l < 4));
            let mnl = quality::max_normalized_load(&g, &labels, 4);
            assert!(mnl <= 1.1, "{obj:?}: mnl={mnl}");
        }
    }

    #[test]
    fn restream_pass_preserves_edge_mass() {
        let g = two_cliques(6);
        let mut s = CsrEdgeStream::new(&g, StreamOrder::Natural, 1);
        let obj = Objective::Fennel { gamma: 1.5 };
        let mut state =
            StreamState::new(g.num_vertices(), 2, 0.05, Some(g.num_edges() as u64));
        run_pass(&mut s, &mut state, obj, false).unwrap();
        let mass: f64 = state.loads().iter().sum();
        assert!((mass - g.num_edges() as f64).abs() < 1e-9);
        // A revisit pass moves vertices but never edge mass.
        s.reset().unwrap();
        run_pass(&mut s, &mut state, obj, true).unwrap();
        let mass2: f64 = state.loads().iter().sum();
        assert!((mass2 - mass).abs() < 1e-9);
        assert_eq!(state.streamed_edges(), g.num_edges() as u64);
    }

    #[test]
    fn weighted_stream_hist_follows_heavy_edges() {
        // 0—2 (w=1), 1—2 (w=10). Natural order: 0 → p0; 1 (no placed
        // neighbours) → lighter p1; 2 then sees p0 with weight 1 and p1
        // with weight 10 — the weighted histogram must send it to p1
        // (the unit histogram would tie 1:1 and fall to p0).
        use crate::graph::WeightedGraphBuilder;
        let mut b = WeightedGraphBuilder::new(3);
        b.edge(0, 2, 1.0).edge(1, 2, 10.0);
        let g = b.build();
        let mut s = CsrEdgeStream::new(&g, StreamOrder::Natural, 1);
        // ε = 1.0 so the capacity gate (total mass 3, C = 3) admits all.
        let mut state = StreamState::new(3, 2, 1.0, Some(g.total_load_mass()));
        run_pass(&mut s, &mut state, Objective::Ldg, false).unwrap();
        let labels = state.finish(3);
        assert_ne!(labels[0], labels[1]);
        assert_eq!(labels[2], labels[1], "heavy edge must win: {labels:?}");
    }

    #[test]
    fn from_assignment_scores_against_existing_labels() {
        // Assignment: 0,1 in p0 (mass 2 each), 2 in p1 (mass 1); vertex
        // 3 arrives with neighbours {0, 1} — LDG must follow the
        // neighbour majority into p0 (capacity permits: ε=1 ⇒ C=8).
        let labels = vec![0, 0, 1, UNASSIGNED];
        let charged = vec![2, 2, 1, 0];
        let mut st = StreamState::from_assignment(labels, charged, 2, 1.0, Some(8));
        assert_eq!(st.loads(), &[4.0, 1.0]);
        assert_eq!(st.streamed_edges(), 5);
        let l = st.place(3, &[0, 1], &[], 3, Objective::Ldg, false);
        assert_eq!(l, 0, "neighbour majority wins");
        assert_eq!(st.loads(), &[7.0, 1.0]);
        // finish() leaves placed labels untouched.
        let out = st.finish(4);
        assert_eq!(out, vec![0, 0, 1, 0]);
    }

    #[test]
    fn finish_places_leftovers_balanced() {
        let mut state = StreamState::new(0, 4, 0.05, None);
        let labels = state.finish(16);
        assert_eq!(labels.len(), 16);
        assert!(labels.iter().all(|&l| l < 4));
        let counts = quality::partition_vertex_counts(&labels, 4);
        assert!(counts.iter().all(|&c| c == 4), "{counts:?}");
    }

    #[test]
    fn adaptive_capacity_without_known_edges() {
        let g = two_cliques(8);
        let mut s = CsrEdgeStream::new(&g, StreamOrder::Natural, 1);
        let mut state = StreamState::new(g.num_vertices(), 2, 0.05, None);
        run_pass(&mut s, &mut state, Objective::Ldg, false).unwrap();
        let labels = state.finish(g.num_vertices());
        assert!(labels.iter().all(|&l| l < 2));
        // Adaptive capacities still end within the ε envelope-ish.
        assert!(quality::max_normalized_load(&g, &labels, 2) <= 1.3);
    }
}
