//! Sources a streaming pass consumes: vertex groups from CSR (any
//! order) or directly from edge-list files (chunked, CSR never built).

use std::collections::{HashMap, VecDeque};
use std::fs::File;
use std::io::BufReader;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::StreamOrder;
use crate::graph::parse::{densify, line_err, parse_edge_line, read_raw_line, snippet};
use crate::graph::Graph;
use crate::util::rng::Rng;
use crate::VertexId;

/// One unit of a streaming pass: a vertex and its group's load mass.
/// The group's visible neighbours are written into the caller's buffer
/// by [`EdgeStream::next_group`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamGroup {
    pub v: VertexId,
    /// Load mass carried by this group — the vertex's contribution to
    /// partition load: its out-edges (exact for CSR; per-run for file
    /// streams), or the coarse vertex weight when the CSR carries
    /// explicit vertex weights ([`Graph::load_mass`] — multilevel
    /// coarsest-level seeding balances in cluster-size units).
    pub load_mass: u32,
}

/// A graph presented as a stream of vertex groups.
pub trait EdgeStream {
    /// Best-known vertex count: exact for CSR, ids-seen-so-far for
    /// file streams (final once a pass completed).
    fn num_vertices(&self) -> usize;

    /// Total load mass of a full pass if known *before* streaming —
    /// enables exact capacities. This is the directed edge count |E|
    /// for plain sources, but Σ vertex weights for weighted multilevel
    /// contractions: always the same units as
    /// [`StreamGroup::load_mass`], never mix it with per-edge
    /// statistics on weighted graphs. File streams learn it during
    /// their first pass.
    fn num_edges(&self) -> Option<u64>;

    /// Produce the next group: fills `nbrs` with the group's visible
    /// neighbours — and `nbr_ws` with their edge weights when the
    /// source carries meaningful ones (weighted multilevel
    /// contractions; left **empty** otherwise, meaning unit weight per
    /// neighbour) — and returns its vertex, or `None` at end of pass.
    fn next_group(
        &mut self,
        nbrs: &mut Vec<VertexId>,
        nbr_ws: &mut Vec<f32>,
    ) -> Result<Option<StreamGroup>>;

    /// Rewind for another pass (dense ids stay stable).
    fn reset(&mut self) -> Result<()>;

    /// `true` when every vertex appears as at most one group per pass
    /// (CSR streams, by construction) — lets the pass driver skip its
    /// duplicate-group bookkeeping. Unsorted files may split a
    /// vertex's edges across runs, so the default is `false`.
    fn exactly_once_per_pass(&self) -> bool {
        false
    }
}

/// Stream adapter over an in-memory CSR graph. Every vertex appears
/// exactly once per pass, with its full undirected neighbourhood.
pub struct CsrEdgeStream<'a> {
    g: &'a Graph,
    order: Vec<VertexId>,
    pos: usize,
}

impl<'a> CsrEdgeStream<'a> {
    /// Stream `g` in one of the pluggable orders.
    pub fn new(g: &'a Graph, order: StreamOrder, seed: u64) -> Self {
        let n = g.num_vertices();
        let order = match order {
            StreamOrder::Natural => (0..n as VertexId).collect(),
            StreamOrder::Shuffled => {
                let mut v: Vec<VertexId> = (0..n as VertexId).collect();
                // Salted so the stream permutation is independent of
                // the partitioners' other seed-derived streams.
                Rng::new(seed ^ 0x5354524D /* "STRM" */).shuffle(&mut v);
                v
            }
            StreamOrder::Bfs => bfs_order(g),
        };
        Self::with_order(g, order)
    }

    /// Stream `g` in an explicit order (must be a permutation of
    /// `0..n` for full coverage; the restreaming priority path).
    pub fn with_order(g: &'a Graph, order: Vec<VertexId>) -> Self {
        CsrEdgeStream { g, order, pos: 0 }
    }

    /// Vertices by descending undirected degree (stable by id) — the
    /// priority order of prioritized restreaming.
    pub fn degree_descending(g: &Graph) -> Vec<VertexId> {
        let mut order: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
        order.sort_by_key(|&v| (std::cmp::Reverse(g.und_degree(v)), v));
        order
    }
}

impl EdgeStream for CsrEdgeStream<'_> {
    fn num_vertices(&self) -> usize {
        self.g.num_vertices()
    }

    fn num_edges(&self) -> Option<u64> {
        // Total load mass, so capacities stay in the same units as the
        // per-group masses below (== |E| for plain graphs).
        Some(self.g.total_load_mass())
    }

    fn next_group(
        &mut self,
        nbrs: &mut Vec<VertexId>,
        nbr_ws: &mut Vec<f32>,
    ) -> Result<Option<StreamGroup>> {
        let Some(&v) = self.order.get(self.pos) else {
            return Ok(None);
        };
        self.pos += 1;
        nbrs.clear();
        nbrs.extend_from_slice(self.g.neighbors(v));
        nbr_ws.clear();
        // Surface accumulated weights only for weighted contractions —
        // a coarse edge can stand for 100+ fine edges and the seed's
        // affinity histogram must see that. Plain graphs keep the
        // streaming literature's unweighted |N(v) ∩ P| histogram
        // (empty = unit weights), bit-identical to before.
        if self.g.is_weighted() {
            nbr_ws.extend_from_slice(self.g.neighbor_weights(v));
        }
        Ok(Some(StreamGroup { v, load_mass: self.g.load_mass(v) }))
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn exactly_once_per_pass(&self) -> bool {
        true
    }
}

/// BFS from vertex 0, restarting at the next unvisited vertex per
/// component, over the undirected adjacency.
fn bfs_order(g: &Graph) -> Vec<VertexId> {
    let n = g.num_vertices();
    let mut seen = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut queue = VecDeque::new();
    for start in 0..n {
        if seen[start] {
            continue;
        }
        seen[start] = true;
        queue.push_back(start as VertexId);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &u in g.neighbors(v) {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    queue.push_back(u);
                }
            }
        }
    }
    order
}

/// Stream adapter over an edge-list text file: chunked reads through
/// one reusable line buffer, no CSR. A group is a maximal run of
/// consecutive lines sharing a source (exact adjacency for
/// source-sorted files; a best-effort split otherwise — the pass layer
/// folds extra runs of an already-placed vertex into its load). Raw
/// ids are densified to `0..n` in first-appearance order and
/// self-loops are skipped after densification — identical to
/// [`crate::graph::io::read_edge_list`] + `GraphBuilder`, so labels
/// line up with a CSR later loaded from the same file. One divergence
/// remains: duplicate edge lines are charged to loads again (the
/// loader dedups them); exact for the simple-graph dumps this format
/// is used for.
pub struct FileEdgeStream {
    path: PathBuf,
    reader: BufReader<File>,
    ids: HashMap<u64, VertexId>,
    line: Vec<u8>,
    lineno: usize,
    /// First edge of the next group (read-ahead past a run boundary).
    pending: Option<(VertexId, VertexId)>,
    edges_this_pass: u64,
    known_edges: Option<u64>,
}

impl FileEdgeStream {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let f = File::open(&path).with_context(|| format!("open {path:?}"))?;
        Ok(FileEdgeStream {
            path,
            reader: BufReader::new(f),
            ids: HashMap::new(),
            line: Vec::new(),
            lineno: 0,
            pending: None,
            edges_this_pass: 0,
            known_edges: None,
        })
    }

    /// Next parsed edge. Lines are read as raw bytes under the
    /// [`crate::graph::parse::MAX_LINE_BYTES`] cap (hostile unbounded
    /// lines cost one bounded buffer, never line-proportional memory),
    /// and every diagnostic names the file.
    fn next_edge(&mut self) -> Result<Option<(VertexId, VertexId)>> {
        let label = self.path.display().to_string();
        loop {
            let Some(fits) = read_raw_line(&mut self.reader, &mut self.line)? else {
                // Pass complete: the edge count is now exact.
                self.known_edges = Some(self.edges_this_pass);
                return Ok(None);
            };
            self.lineno += 1;
            if !fits {
                return Err(line_err(
                    &label,
                    self.lineno,
                    "line exceeds the 1 MiB length cap",
                    &self.line,
                ));
            }
            let text = std::str::from_utf8(&self.line)
                .map_err(|_| line_err(&label, self.lineno, "invalid UTF-8", &self.line))?;
            let parsed = parse_edge_line(text, self.lineno).map_err(|e| {
                e.context(format!("{label}: line {}: {:?}", self.lineno, snippet(&self.line)))
            })?;
            if let Some((a, b)) = parsed {
                // Densify before the self-loop check so a vertex that
                // only ever self-loops still gets an id — exactly what
                // `read_edge_list` + `GraphBuilder` (which drops the
                // loop edge but keeps the vertex) produce.
                let s = densify(a, &mut self.ids);
                let d = densify(b, &mut self.ids);
                if s == d {
                    continue;
                }
                self.edges_this_pass += 1;
                return Ok(Some((s, d)));
            }
        }
    }
}

impl EdgeStream for FileEdgeStream {
    fn num_vertices(&self) -> usize {
        self.ids.len()
    }

    fn num_edges(&self) -> Option<u64> {
        self.known_edges
    }

    fn next_group(
        &mut self,
        nbrs: &mut Vec<VertexId>,
        nbr_ws: &mut Vec<f32>,
    ) -> Result<Option<StreamGroup>> {
        nbr_ws.clear(); // edge-list files carry no weights: unit per neighbour
        let (src, first_dst) = match self.pending.take() {
            Some(e) => e,
            None => match self.next_edge()? {
                Some(e) => e,
                None => return Ok(None),
            },
        };
        nbrs.clear();
        nbrs.push(first_dst);
        let mut load_mass = 1u32;
        loop {
            match self.next_edge()? {
                Some((s, d)) if s == src => {
                    nbrs.push(d);
                    load_mass += 1;
                }
                Some(e) => {
                    self.pending = Some(e);
                    break;
                }
                None => break,
            }
        }
        Ok(Some(StreamGroup { v: src, load_mass }))
    }

    fn reset(&mut self) -> Result<()> {
        let f = File::open(&self.path).with_context(|| format!("open {:?}", self.path))?;
        self.reader = BufReader::new(f);
        self.lineno = 0;
        self.pending = None;
        self.known_edges = self.known_edges.or(Some(self.edges_this_pass));
        self.edges_this_pass = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn diamond() -> Graph {
        // 0->1, 0->2, 1->3, 2->3 plus back-edge 3->0.
        GraphBuilder::new(4)
            .edges(&[(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)])
            .build()
    }

    fn drain<S: EdgeStream>(s: &mut S) -> Vec<(VertexId, u32, Vec<VertexId>)> {
        let mut nbrs = Vec::new();
        let mut ws = Vec::new();
        let mut out = Vec::new();
        while let Some(gp) = s.next_group(&mut nbrs, &mut ws).unwrap() {
            assert!(ws.is_empty() || ws.len() == nbrs.len());
            out.push((gp.v, gp.load_mass, nbrs.clone()));
        }
        out
    }

    #[test]
    fn csr_natural_covers_all_vertices_in_order() {
        let g = diamond();
        let mut s = CsrEdgeStream::new(&g, StreamOrder::Natural, 1);
        assert_eq!(s.num_edges(), Some(5));
        let groups = drain(&mut s);
        assert_eq!(groups.iter().map(|g| g.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        // Out-degrees from the forward CSR, neighbours undirected.
        assert_eq!(groups[0].1, 2);
        assert_eq!(groups[0].2, vec![1, 2, 3]);
        assert_eq!(groups[3].1, 1);
        assert_eq!(groups[3].2, vec![0, 1, 2]);
        // Reset replays identically.
        s.reset().unwrap();
        assert_eq!(drain(&mut s), groups);
    }

    #[test]
    fn csr_orders_are_permutations() {
        let g = diamond();
        for order in [StreamOrder::Natural, StreamOrder::Shuffled, StreamOrder::Bfs] {
            let mut s = CsrEdgeStream::new(&g, order, 7);
            let mut vs: Vec<VertexId> = drain(&mut s).iter().map(|g| g.0).collect();
            vs.sort_unstable();
            assert_eq!(vs, vec![0, 1, 2, 3], "{order:?}");
        }
    }

    #[test]
    fn bfs_order_visits_neighbors_before_strangers() {
        // Two components: 0-1-2 path and isolated 3, 4-5 edge.
        let g = GraphBuilder::new(6).edges(&[(0, 1), (1, 2), (4, 5)]).build();
        let order = bfs_order(&g);
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn degree_descending_priority() {
        let g = diamond(); // und degrees: 0:3, 1:2, 2:2, 3:3
        let order = CsrEdgeStream::degree_descending(&g);
        assert_eq!(order, vec![0, 3, 1, 2]);
    }

    #[test]
    fn file_stream_groups_runs_and_learns_counts() {
        let dir = std::env::temp_dir().join("revolver_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("grouped.txt");
        // Includes a self-loop (`40 40`): its vertex must get a dense
        // id (like the CSR loader) but no edge, load, or group.
        std::fs::write(&p, "# c\n10 20\n10 30\n20 30\n\n30 10\n40 40\n").unwrap();
        let mut s = FileEdgeStream::open(&p).unwrap();
        assert_eq!(s.num_edges(), None, "edge count unknown before a pass");
        let groups = drain(&mut s);
        // Dense ids in first appearance order: 10->0, 20->1, 30->2, 40->3.
        assert_eq!(
            groups,
            vec![(0, 2, vec![1, 2]), (1, 1, vec![2]), (2, 1, vec![0])]
        );
        assert_eq!(s.num_edges(), Some(4));
        assert_eq!(s.num_vertices(), 4);
        // Second pass: same dense ids, counts already known.
        s.reset().unwrap();
        assert_eq!(s.num_edges(), Some(4));
        assert_eq!(drain(&mut s), groups);
    }

    #[test]
    fn file_stream_propagates_parse_errors() {
        let dir = std::env::temp_dir().join("revolver_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.txt");
        std::fs::write(&p, "0 1\nbogus\n").unwrap();
        let mut s = FileEdgeStream::open(&p).unwrap();
        let mut nbrs = Vec::new();
        let mut ws = Vec::new();
        let err = loop {
            match s.next_group(&mut nbrs, &mut ws) {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("expected a parse error"),
                Err(e) => break e,
            }
        };
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }
}
