//! The streaming partitioners as [`Partitioner`]s, the warm-start
//! bridge ([`stream_labels`]), and the CSR-free file entry point.

use anyhow::Result;

use crate::config::{RevolverConfig, StreamAlgo};
use crate::graph::Graph;
use crate::metrics::quality;
use crate::metrics::trace::RunTrace;
use crate::partitioners::{PartitionOutput, Partitioner};
use crate::Label;

use super::edge_stream::{CsrEdgeStream, EdgeStream, FileEdgeStream};
use super::pass::{run_pass, Objective, StreamState};

/// One-pass linear deterministic greedy.
pub struct Ldg {
    cfg: RevolverConfig,
}

impl Ldg {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Ldg { cfg }
    }
}

impl Partitioner for Ldg {
    fn name(&self) -> &'static str {
        "ldg"
    }

    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, crate::engine::EngineError> {
        Ok(PartitionOutput {
            labels: one_pass_labels(g, &self.cfg, Objective::Ldg),
            trace: RunTrace::default(),
        })
    }
}

/// One-pass Fennel (γ from `fennel_gamma`).
pub struct Fennel {
    cfg: RevolverConfig,
}

impl Fennel {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Fennel { cfg }
    }
}

impl Partitioner for Fennel {
    fn name(&self) -> &'static str {
        "fennel"
    }

    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, crate::engine::EngineError> {
        let obj = Objective::Fennel { gamma: self.cfg.fennel_gamma };
        Ok(PartitionOutput {
            labels: one_pass_labels(g, &self.cfg, obj),
            trace: RunTrace::default(),
        })
    }
}

/// Prioritized restreaming: `restream_passes` Fennel passes, the first
/// in the configured stream order, later ones in descending-degree
/// priority order re-placing every vertex against the full previous
/// assignment. Keeps the best pass by local edges, so more passes are
/// never worse than fewer. (Both guarantees are properties of this
/// CSR-backed path — the CSR can be replayed in priority order and
/// scored between passes; the file entry point
/// [`partition_edge_list_file`] restreams in file order and returns
/// the final pass, see its docs.)
pub struct Restream {
    cfg: RevolverConfig,
}

impl Restream {
    pub fn new(cfg: RevolverConfig) -> Self {
        cfg.validate().expect("invalid config");
        Restream { cfg }
    }
}

impl Partitioner for Restream {
    fn name(&self) -> &'static str {
        "restream"
    }

    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, crate::engine::EngineError> {
        Ok(PartitionOutput { labels: restream_labels(g, &self.cfg), trace: RunTrace::default() })
    }
}

/// One `stream_pass` span + event around a finished pass (`pass` is the
/// 0-based pass index, `edges` the pass's streamed-edge count).
fn note_pass(pass: u32, edges: u64) {
    crate::obs::event("stream_pass", &[("pass", pass as f64), ("edges", edges as f64)]);
}

fn one_pass_labels(g: &Graph, cfg: &RevolverConfig, obj: Objective) -> Vec<Label> {
    let mut stream = CsrEdgeStream::new(g, cfg.stream_order, cfg.seed);
    // Capacities in load-mass units: |E| on plain graphs, Σ vertex
    // weights on multilevel contractions (matches the per-group masses
    // the stream yields).
    let mut state =
        StreamState::new(g.num_vertices(), cfg.parts, cfg.epsilon, Some(g.total_load_mass()));
    {
        let _s = crate::obs::span("stream_pass");
        run_pass(&mut stream, &mut state, obj, false).expect("CSR streams cannot fail");
    }
    note_pass(0, state.streamed_edges());
    state.finish(g.num_vertices())
}

fn restream_labels(g: &Graph, cfg: &RevolverConfig) -> Vec<Label> {
    let obj = Objective::Fennel { gamma: cfg.fennel_gamma };
    let n = g.num_vertices();
    let mut state = StreamState::new(n, cfg.parts, cfg.epsilon, Some(g.total_load_mass()));

    let mut stream = CsrEdgeStream::new(g, cfg.stream_order, cfg.seed);
    {
        let _s = crate::obs::span("stream_pass");
        run_pass(&mut stream, &mut state, obj, false).expect("CSR streams cannot fail");
    }
    note_pass(0, state.streamed_edges());
    let mut best = state.finish(n);
    let mut best_le = quality::local_edges(g, &best);

    let mut priority = CsrEdgeStream::with_order(g, CsrEdgeStream::degree_descending(g));
    for pass in 1..cfg.restream_passes {
        {
            let _s = crate::obs::span("stream_pass");
            run_pass(&mut priority, &mut state, obj, true).expect("CSR streams cannot fail");
        }
        note_pass(pass, state.streamed_edges());
        priority.reset().expect("CSR streams cannot fail");
        let labels = state.finish(n);
        let le = quality::local_edges(g, &labels);
        if le >= best_le {
            best_le = le;
            best = labels;
        }
    }
    best
}

/// Labels from a streaming pass over `g` — the warm-start source for
/// `--init stream:<algo>` (engine + Revolver LA seeding).
pub fn stream_labels(g: &Graph, algo: StreamAlgo, cfg: &RevolverConfig) -> Vec<Label> {
    match algo {
        StreamAlgo::Ldg => one_pass_labels(g, cfg, Objective::Ldg),
        StreamAlgo::Fennel => {
            one_pass_labels(g, cfg, Objective::Fennel { gamma: cfg.fennel_gamma })
        }
        StreamAlgo::Restream => restream_labels(g, cfg),
    }
}

/// Result of partitioning an edge-list file without building CSR.
pub struct FileStreamResult {
    /// One label per dense vertex id (first-appearance order — the
    /// same densification [`crate::graph::io::read_edge_list`] uses).
    pub labels: Vec<Label>,
    pub vertices: usize,
    pub edges: u64,
    /// Final per-partition out-edge loads.
    pub loads: Vec<f64>,
}

/// Partition an edge-list file straight off disk: one chunked pass for
/// `ldg`/`fennel` (capacities adapt as |E| is discovered), plus
/// re-stream passes over the file for `restream`. The CSR is never
/// materialized — which also bounds what file-mode restreaming can
/// promise: passes replay in *file* order (a file cannot be reordered
/// by priority), and with no adjacency to score passes against, the
/// *final* pass's labels are returned rather than the best pass. The
/// monotone best-pass guarantee belongs to the CSR-backed
/// [`Restream`] partitioner.
pub fn partition_edge_list_file<P: AsRef<std::path::Path>>(
    path: P,
    cfg: &RevolverConfig,
    algo: StreamAlgo,
) -> Result<FileStreamResult> {
    cfg.validate()?;
    let obj = match algo {
        StreamAlgo::Ldg => Objective::Ldg,
        StreamAlgo::Fennel | StreamAlgo::Restream => {
            Objective::Fennel { gamma: cfg.fennel_gamma }
        }
    };
    let mut stream = FileEdgeStream::open(path)?;
    let mut state = StreamState::new(1024, cfg.parts, cfg.epsilon, None);
    {
        let _s = crate::obs::span("stream_pass");
        run_pass(&mut stream, &mut state, obj, false)?;
    }
    note_pass(0, state.streamed_edges());
    anyhow::ensure!(stream.num_vertices() > 0, "edge list contains no edges");
    if algo == StreamAlgo::Restream {
        for pass in 1..cfg.restream_passes {
            stream.reset()?;
            state.set_known_edges(stream.num_edges());
            {
                let _s = crate::obs::span("stream_pass");
                run_pass(&mut stream, &mut state, obj, true)?;
            }
            note_pass(pass, state.streamed_edges());
        }
    }
    let vertices = stream.num_vertices();
    let labels = state.finish(vertices);
    Ok(FileStreamResult {
        labels,
        vertices,
        edges: state.streamed_edges(),
        loads: state.loads().to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::rmat;
    use crate::metrics::quality;
    use crate::partitioners::hash::HashPartitioner;

    fn cfg(k: usize) -> RevolverConfig {
        RevolverConfig { parts: k, seed: 11, ..Default::default() }
    }

    fn test_graph() -> Graph {
        rmat::rmat(1 << 11, 16 << 11, 0.57, 0.19, 0.19, 5)
    }

    #[test]
    fn ldg_and_fennel_beat_hash() {
        let g = test_graph();
        let k = 8;
        let hash_le =
            quality::local_edges(&g, &HashPartitioner::new(k).partition(&g).labels);
        let ps: Vec<Box<dyn Partitioner>> =
            vec![Box::new(Ldg::new(cfg(k))), Box::new(Fennel::new(cfg(k)))];
        for p in &ps {
            let out = p.partition(&g);
            assert_eq!(out.labels.len(), g.num_vertices());
            let q = quality::evaluate(&g, &out.labels, k);
            assert!(
                q.local_edges > hash_le,
                "{}: {} vs hash {}",
                p.name(),
                q.local_edges,
                hash_le
            );
            assert!(q.max_normalized_load <= 1.1, "{}: {}", p.name(), q.max_normalized_load);
        }
    }

    #[test]
    fn streaming_is_deterministic() {
        let g = test_graph();
        for algo in [StreamAlgo::Ldg, StreamAlgo::Fennel, StreamAlgo::Restream] {
            let a = stream_labels(&g, algo, &cfg(4));
            let b = stream_labels(&g, algo, &cfg(4));
            assert_eq!(a, b, "{algo:?}");
        }
    }

    #[test]
    fn stream_orders_all_valid() {
        use crate::config::StreamOrder;
        let g = test_graph();
        for order in [StreamOrder::Natural, StreamOrder::Shuffled, StreamOrder::Bfs] {
            let mut c = cfg(4);
            c.stream_order = order;
            let out = Ldg::new(c).partition(&g);
            assert!(out.labels.iter().all(|&l| l < 4), "{order:?}");
            let mnl = quality::max_normalized_load(&g, &out.labels, 4);
            // Natural order streams R-MAT's hubs first, so the gate
            // holds the ε envelope exactly; a shuffled order can land a
            // hub after every partition is full, overflowing by up to
            // one hub's degree — allow that headroom here.
            let bound = if order == StreamOrder::Natural { 1.1 } else { 1.35 };
            assert!(mnl <= bound, "{order:?}: {mnl}");
        }
    }

    // Restream monotonicity (3 passes >= pass 1) is asserted at
    // acceptance scale in tests/integration.rs, not duplicated here.

    #[test]
    fn file_partition_matches_csr_densification() {
        let g = test_graph();
        let dir = std::env::temp_dir().join("revolver_stream_algos");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rmat.txt");
        crate::graph::io::save_edge_list(&g, &p).unwrap();
        // The stream and the loader densify raw ids identically
        // (first-appearance order), so file-stream labels line up with
        // a CSR loaded from the same file — that's the graph to
        // evaluate against.
        let g2 = crate::graph::io::load_edge_list(&p).unwrap();

        for algo in [StreamAlgo::Ldg, StreamAlgo::Fennel, StreamAlgo::Restream] {
            let res = partition_edge_list_file(&p, &cfg(4), algo).unwrap();
            assert_eq!(res.vertices, g2.num_vertices(), "{algo:?}");
            assert_eq!(res.edges, g2.num_edges() as u64, "{algo:?}");
            assert!(res.labels.iter().all(|&l| l < 4));
            // The file path must beat hash on locality too.
            let hash_le =
                quality::local_edges(&g2, &HashPartitioner::new(4).partition(&g2).labels);
            let le = quality::local_edges(&g2, &res.labels);
            assert!(le > hash_le, "{algo:?}: {le} vs {hash_le}");
        }
    }

    #[test]
    fn file_partition_missing_file_errors() {
        assert!(partition_edge_list_file(
            "/nonexistent/edges.txt",
            &cfg(4),
            StreamAlgo::Ldg
        )
        .is_err());
    }
}
