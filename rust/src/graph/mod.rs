//! Graph substrate: storage (CSR), construction, I/O, synthetic
//! generators and dataset statistics.
//!
//! The paper's partitioners need, per vertex `v`:
//!   * out-neighbours (directed edges define partition load, §II),
//!   * the full undirected neighbourhood `N(v)` with the edge weight
//!     `ŵ(u,v)` of eq. (4): 1 for a one-way edge, 2 for a reciprocal
//!     pair,
//!   * `deg(v)` = out-degree (load accounting is in outgoing edges).
//!
//! [`csr::Graph`] stores exactly that: a forward CSR over out-edges plus
//! a merged *undirected* CSR whose per-edge weights are precomputed by
//! [`builder::GraphBuilder`].

pub mod builder;
pub mod csr;
pub mod gen;
pub mod io;
pub mod parse;
pub mod stats;

pub use csr::Graph;
pub use builder::{GraphBuilder, WeightedGraphBuilder};
