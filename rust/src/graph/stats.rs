//! Dataset statistics — everything Table I reports: |V|, |E|, density
//! `D = |E| / (|V|·(|V|−1))`, and Pearson's 1st skewness coefficient
//! `(μ − mode) / σ` of the out-degree distribution.

use super::csr::Graph;

/// Summary statistics for a graph (the Table I row).
#[derive(Debug, Clone, PartialEq)]
pub struct GraphStats {
    pub vertices: usize,
    pub edges: usize,
    /// Density ×1 (Table I prints ×10⁻⁵).
    pub density: f64,
    /// Pearson's 1st skewness coefficient of the out-degree distribution.
    pub skewness: f64,
    pub mean_out_degree: f64,
    pub mode_out_degree: u32,
    pub stddev_out_degree: f64,
    pub max_out_degree: u32,
}

/// Compute the full Table-I statistics for `g`.
pub fn compute(g: &Graph) -> GraphStats {
    let n = g.num_vertices();
    let m = g.num_edges();
    let density = if n > 1 {
        m as f64 / (n as f64 * (n as f64 - 1.0))
    } else {
        0.0
    };

    // Out-degree distribution.
    let mut sum = 0.0f64;
    let mut max_deg = 0u32;
    let mut hist: std::collections::HashMap<u32, u64> = std::collections::HashMap::new();
    for v in 0..n {
        let d = g.out_degree(v as u32);
        sum += d as f64;
        max_deg = max_deg.max(d);
        *hist.entry(d).or_insert(0) += 1;
    }
    let mean = sum / n as f64;

    let mut var = 0.0f64;
    for v in 0..n {
        let d = g.out_degree(v as u32) as f64;
        var += (d - mean) * (d - mean);
    }
    let stddev = (var / n as f64).sqrt();

    // Mode: most frequent out-degree (ties -> smallest degree, for
    // determinism).
    let mode = hist
        .iter()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
        .map(|(&d, _)| d)
        .unwrap_or(0);

    let skewness = if stddev > 0.0 {
        (mean - mode as f64) / stddev
    } else {
        0.0
    };

    GraphStats {
        vertices: n,
        edges: m,
        density,
        skewness,
        mean_out_degree: mean,
        mode_out_degree: mode,
        stddev_out_degree: stddev,
        max_out_degree: max_deg,
    }
}

/// Skew classification used by the paper's analysis (§V-G).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SkewClass {
    /// Pearson coefficient < −0.3 (e.g. USA road).
    LeftSkewed,
    /// |coefficient| ≤ 0.15 (e.g. SO, EU).
    SkewFree,
    /// 0.15 < coefficient ≤ 0.6 (e.g. WIKI, LJ, OK).
    RightSkewed,
    /// coefficient > 0.6 (e.g. UK).
    HighlyRightSkewed,
}

pub fn classify_skew(pearson: f64) -> SkewClass {
    if pearson < -0.3 {
        SkewClass::LeftSkewed
    } else if pearson.abs() <= 0.15 {
        SkewClass::SkewFree
    } else if pearson <= 0.6 {
        SkewClass::RightSkewed
    } else {
        SkewClass::HighlyRightSkewed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn complete_graph_density_one() {
        // K4 directed both ways: density = 12 / (4*3) = 1.
        let mut b = GraphBuilder::new(4);
        for i in 0..4u32 {
            for j in 0..4u32 {
                if i != j {
                    b.edge(i, j);
                }
            }
        }
        let s = compute(&b.build());
        assert!((s.density - 1.0).abs() < 1e-12);
        // All degrees equal -> stddev 0 -> skewness 0 by convention.
        assert_eq!(s.skewness, 0.0);
        assert_eq!(s.mode_out_degree, 3);
    }

    #[test]
    fn right_skew_positive() {
        // One hub with high out-degree, many leaves with degree 0:
        // mode = 0, mean > 0 => positive Pearson coefficient.
        let mut b = GraphBuilder::new(101);
        for i in 1..=100u32 {
            b.edge(0, i);
        }
        let s = compute(&b.build());
        assert!(s.skewness > 0.0, "hub graph must be right-skewed, got {}", s.skewness);
        assert_eq!(s.mode_out_degree, 0);
        assert_eq!(s.max_out_degree, 100);
    }

    #[test]
    fn left_skew_negative() {
        // Most vertices at degree 3 (mode=3), a few at 0 =>
        // mean < mode => negative coefficient.
        let n = 50u32;
        let mut b = GraphBuilder::new(n as usize + 10);
        for v in 0..n {
            for j in 1..=3u32 {
                b.edge(v, (v + j) % n);
            }
        }
        // 10 extra isolated vertices pull the mean below the mode.
        let s = compute(&b.build());
        assert!(s.skewness < 0.0, "got {}", s.skewness);
    }

    #[test]
    fn classify_bands() {
        assert_eq!(classify_skew(-0.59), SkewClass::LeftSkewed);
        assert_eq!(classify_skew(0.08), SkewClass::SkewFree);
        assert_eq!(classify_skew(0.35), SkewClass::RightSkewed);
        assert_eq!(classify_skew(0.81), SkewClass::HighlyRightSkewed);
    }

    #[test]
    fn mean_matches_m_over_n() {
        let g = GraphBuilder::new(10)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)])
            .build();
        let s = compute(&g);
        assert!((s.mean_out_degree - 0.5).abs() < 1e-12);
    }
}
