//! Graph construction: edge accumulation -> dedup -> CSR + undirected
//! weighted adjacency (eq. 4) — plus the *weighted* construction path
//! ([`WeightedGraphBuilder`]) the multilevel contraction uses, where
//! parallel edges accumulate weight instead of deduplicating and each
//! vertex carries an explicit balance weight.

use crate::VertexId;
use super::csr::Graph;

/// Accumulates directed edges and finalizes them into a [`Graph`].
///
/// Self-loops are dropped and duplicate directed edges are deduplicated
/// (the paper's datasets are simple graphs). The undirected adjacency
/// merges both directions; an edge present in both directions gets
/// weight 2.0 (eq. 4), otherwise 1.0.
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
}

impl GraphBuilder {
    pub fn new(num_vertices: usize) -> Self {
        assert!(num_vertices > 0, "graph must have at least one vertex");
        assert!(
            num_vertices <= u32::MAX as usize,
            "VertexId is u32; at most 2^32-1 vertices"
        );
        GraphBuilder { n: num_vertices, edges: Vec::new() }
    }

    /// Pre-reserve for `m` edges.
    pub fn with_capacity(num_vertices: usize, m: usize) -> Self {
        let mut b = Self::new(num_vertices);
        b.edges.reserve(m);
        b
    }

    /// Add one directed edge. Out-of-range endpoints panic (programmer
    /// error); self-loops are silently dropped (data artifact).
    #[inline]
    pub fn edge(&mut self, src: VertexId, dst: VertexId) -> &mut Self {
        assert!((src as usize) < self.n && (dst as usize) < self.n, "edge out of range");
        if src != dst {
            self.edges.push((src, dst));
        }
        self
    }

    /// Add many edges (builder-chaining convenience).
    pub fn edges(mut self, es: &[(VertexId, VertexId)]) -> Self {
        for &(s, d) in es {
            self.edge(s, d);
        }
        self
    }

    /// Number of (pre-dedup) edges accumulated so far.
    pub fn pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Finalize into CSR form.
    pub fn build(mut self) -> Graph {
        let n = self.n;

        // Sort + dedup directed edges. Sorting by (src, dst) also gives
        // us the forward CSR layout directly.
        self.edges.sort_unstable();
        self.edges.dedup();

        // Unit weights through the shared assembly reproduce eq. (4)
        // exactly: the undirected weight sums both directions, giving
        // 2.0 for a reciprocal pair and 1.0 for a one-way edge. The
        // iterator adapter avoids materializing a weighted copy of the
        // (possibly huge) edge list.
        assemble_csr(n, self.edges.iter().map(|&(s, d)| (s, d, 1.0)), None, false)
    }
}

/// Shared CSR assembly: turn a **sorted, parallel-merged** stream of
/// directed weighted edges into the forward CSR plus the mirrored
/// undirected adjacency whose per-pair weight sums both directions.
/// Both builders end here — [`GraphBuilder`] with deduplicated unit
/// weights (⇒ the eq.-(4) 1-or-2 values), [`WeightedGraphBuilder`]
/// with accumulated weights and explicit vertex weights.
fn assemble_csr<I>(
    n: usize,
    merged: I,
    vertex_weights: Option<Vec<u32>>,
    weighted: bool,
) -> Graph
where
    I: ExactSizeIterator<Item = (VertexId, VertexId, f32)>,
{
    let m = merged.len();
    // One pass builds the forward counts/targets and the mirrored
    // undirected list together.
    let mut fwd_offsets = vec![0u64; n + 1];
    let mut fwd_targets: Vec<VertexId> = Vec::with_capacity(m);
    let mut und: Vec<(VertexId, VertexId, f32)> = Vec::with_capacity(2 * m);
    let mut prev: Option<(VertexId, VertexId)> = None;
    for (s, d, w) in merged {
        debug_assert!(
            match prev {
                None => true,
                Some(p) => p < (s, d),
            },
            "edges must arrive sorted and parallel-merged"
        );
        prev = Some((s, d));
        fwd_offsets[s as usize + 1] += 1;
        fwd_targets.push(d);
        und.push((s, d, w));
        und.push((d, s, w));
    }
    for i in 0..n {
        fwd_offsets[i + 1] += fwd_offsets[i];
    }

    // Undirected adjacency: sum the mirrored weights per (v, u) run —
    // per-vertex neighbour lists come out sorted from the sort below.
    und.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

    let mut und_offsets = vec![0u64; n + 1];
    let mut und_targets: Vec<VertexId> = Vec::with_capacity(und.len());
    let mut und_weights: Vec<f32> = Vec::with_capacity(und.len());
    let mut i = 0;
    while i < und.len() {
        let (v, u, mut w) = und[i];
        let mut j = i + 1;
        while j < und.len() && und[j].0 == v && und[j].1 == u {
            w += und[j].2;
            j += 1;
        }
        und_offsets[v as usize + 1] += 1;
        und_targets.push(u);
        und_weights.push(w);
        i = j;
    }
    for i in 0..n {
        und_offsets[i + 1] += und_offsets[i];
    }

    Graph::from_parts(
        n,
        fwd_offsets,
        fwd_targets,
        und_offsets,
        und_targets,
        und_weights,
        vertex_weights,
        weighted,
    )
}

/// Weighted-CSR construction: directed edges carry an explicit weight,
/// parallel edges are **merged by summing** (not deduplicated), and each
/// vertex carries a balance weight (default 1).
///
/// This is the substrate of multilevel coarsening: contracting a
/// matching produces parallel edges between cluster pairs whose weights
/// must accumulate, and a coarse vertex must weigh the number of fine
/// vertices it stands for. The undirected adjacency sums the weight of
/// both directions — for unit weights that reduces exactly to eq. (4)'s
/// ŵ (2 for a reciprocal pair, 1 otherwise).
pub struct WeightedGraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId, f32)>,
    vertex_weights: Vec<u32>,
}

impl WeightedGraphBuilder {
    pub fn new(num_vertices: usize) -> Self {
        assert!(num_vertices > 0, "graph must have at least one vertex");
        assert!(
            num_vertices <= u32::MAX as usize,
            "VertexId is u32; at most 2^32-1 vertices"
        );
        WeightedGraphBuilder {
            n: num_vertices,
            edges: Vec::new(),
            vertex_weights: vec![1; num_vertices],
        }
    }

    /// Pre-reserve for `m` edges.
    pub fn with_capacity(num_vertices: usize, m: usize) -> Self {
        let mut b = Self::new(num_vertices);
        b.edges.reserve(m);
        b
    }

    /// Add one weighted directed edge. Weights must be finite and
    /// positive; self-loops are silently dropped (contracting a matched
    /// pair folds their connecting edge away).
    #[inline]
    pub fn edge(&mut self, src: VertexId, dst: VertexId, w: f32) -> &mut Self {
        assert!((src as usize) < self.n && (dst as usize) < self.n, "edge out of range");
        assert!(w.is_finite() && w > 0.0, "edge weight must be finite and positive");
        if src != dst {
            self.edges.push((src, dst, w));
        }
        self
    }

    /// Set the balance weight of one vertex (default 1).
    pub fn set_vertex_weight(&mut self, v: VertexId, w: u32) -> &mut Self {
        assert!((v as usize) < self.n, "vertex out of range");
        assert!(w >= 1, "vertex weight must be >= 1");
        self.vertex_weights[v as usize] = w;
        self
    }

    /// Replace all vertex weights at once (must cover every vertex).
    pub fn vertex_weights(mut self, ws: Vec<u32>) -> Self {
        assert_eq!(ws.len(), self.n, "vertex weights must cover every vertex");
        assert!(ws.iter().all(|&w| w >= 1), "vertex weights must be >= 1");
        self.vertex_weights = ws;
        self
    }

    /// Finalize into a weighted CSR [`Graph`].
    pub fn build(mut self) -> Graph {
        let n = self.n;

        // Merge parallel directed edges by summing weights. Sorting by
        // (src, dst) gives the forward CSR layout directly.
        self.edges
            .sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut merged: Vec<(VertexId, VertexId, f32)> = Vec::with_capacity(self.edges.len());
        for &(s, d, w) in &self.edges {
            match merged.last_mut() {
                Some(last) if last.0 == s && last.1 == d => last.2 += w,
                _ => merged.push((s, d, w)),
            }
        }
        assemble_csr(n, merged.into_iter(), Some(self.vertex_weights), true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_directed() {
        let g = GraphBuilder::new(2).edges(&[(0, 1), (0, 1), (0, 1)]).build();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn self_loops_dropped() {
        let g = GraphBuilder::new(2).edges(&[(0, 0), (0, 1), (1, 1)]).build();
        assert_eq!(g.num_edges(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn undirected_merge() {
        // star: 0->1, 0->2, 2->0  (0-2 reciprocal)
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 2), (2, 0)]).build();
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbor_weights(0), &[1.0, 2.0]);
        assert_eq!(g.neighbors(1), &[0]);
        assert_eq!(g.neighbor_weights(1), &[1.0]);
        assert_eq!(g.neighbors(2), &[0]);
        assert_eq!(g.neighbor_weights(2), &[2.0]);
    }

    #[test]
    #[should_panic(expected = "edge out of range")]
    fn out_of_range_panics() {
        let mut b = GraphBuilder::new(2);
        b.edge(0, 5);
    }

    #[test]
    fn weights_total_matches_eq4() {
        // Sum over v of sum_{u in N(v)} w(u,v) counts one-way edges twice
        // (once per endpoint, weight 1) and reciprocal pairs twice * 2.
        // 0->1 one-way, 1<->2 reciprocal.
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2), (2, 1)]).build();
        let total: f32 = (0..3)
            .flat_map(|v| g.neighbor_weights(v).iter().copied())
            .sum();
        assert_eq!(total, 2.0 * 1.0 + 2.0 * 2.0);
    }

    #[test]
    fn weighted_parallel_edges_accumulate() {
        let mut b = WeightedGraphBuilder::new(3);
        b.edge(0, 1, 1.0).edge(0, 1, 2.5).edge(1, 0, 0.5).edge(2, 1, 1.0);
        let g = b.build();
        assert!(g.is_weighted());
        // Directed (0,1) runs merged into one forward edge of weight 3.5.
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.out_neighbors(0), &[1]);
        // Undirected weight 0-1 = 3.5 + 0.5 (both directions summed).
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbor_weights(0), &[4.0]);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.neighbor_weights(1), &[4.0, 1.0]);
        g.validate().unwrap();
    }

    #[test]
    fn weighted_vertex_weights_drive_mass() {
        let mut b = WeightedGraphBuilder::new(3).vertex_weights(vec![2, 3, 1]);
        b.edge(0, 1, 1.0);
        b.set_vertex_weight(2, 4);
        let g = b.build();
        assert!(g.has_vertex_weights());
        assert_eq!(g.vertex_weight(0), 2);
        assert_eq!(g.vertex_weight(2), 4);
        assert_eq!(g.load_mass(0), 2, "mass is the vertex weight, not out-degree");
        assert_eq!(g.total_load_mass(), 2 + 3 + 4);
        assert_eq!(g.total_vertex_weight(), 9);
        g.validate().unwrap();
    }

    #[test]
    fn weighted_unit_graph_matches_eq4() {
        // Unit weights through the weighted path reproduce eq. (4):
        // reciprocal pairs sum to 2, one-way edges to 1.
        let mut b = WeightedGraphBuilder::new(3);
        b.edge(0, 1, 1.0).edge(1, 0, 1.0).edge(0, 2, 1.0);
        let g = b.build();
        assert_eq!(g.neighbor_weights(0), &[2.0, 1.0]);
        assert_eq!(g.neighbor_weights(1), &[2.0]);
        assert_eq!(g.neighbor_weights(2), &[1.0]);
    }

    #[test]
    fn weighted_self_loops_dropped() {
        let mut b = WeightedGraphBuilder::new(2);
        b.edge(0, 0, 5.0).edge(0, 1, 1.0);
        let g = b.build();
        assert_eq!(g.num_edges(), 1);
        g.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn weighted_rejects_nonpositive_weight() {
        let mut b = WeightedGraphBuilder::new(2);
        b.edge(0, 1, 0.0);
    }

    #[test]
    fn large_random_graph_validates() {
        use crate::util::rng::Rng;
        let n = 500;
        let mut rng = Rng::new(99);
        let mut b = GraphBuilder::with_capacity(n, 5000);
        for _ in 0..5000 {
            b.edge(rng.below(n as u64) as u32, rng.below(n as u64) as u32);
        }
        let g = b.build();
        g.validate().unwrap();
        // Undirected degree >= max(out_degree contribution).
        for v in 0..n as u32 {
            assert!(g.und_degree(v) >= 0u32);
            assert!(g.out_degree(v) as usize <= n);
        }
    }
}
