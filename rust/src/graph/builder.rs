//! Graph construction: edge accumulation -> dedup -> CSR + undirected
//! weighted adjacency (eq. 4).

use crate::VertexId;
use super::csr::Graph;

/// Accumulates directed edges and finalizes them into a [`Graph`].
///
/// Self-loops are dropped and duplicate directed edges are deduplicated
/// (the paper's datasets are simple graphs). The undirected adjacency
/// merges both directions; an edge present in both directions gets
/// weight 2.0 (eq. 4), otherwise 1.0.
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
}

impl GraphBuilder {
    pub fn new(num_vertices: usize) -> Self {
        assert!(num_vertices > 0, "graph must have at least one vertex");
        assert!(
            num_vertices <= u32::MAX as usize,
            "VertexId is u32; at most 2^32-1 vertices"
        );
        GraphBuilder { n: num_vertices, edges: Vec::new() }
    }

    /// Pre-reserve for `m` edges.
    pub fn with_capacity(num_vertices: usize, m: usize) -> Self {
        let mut b = Self::new(num_vertices);
        b.edges.reserve(m);
        b
    }

    /// Add one directed edge. Out-of-range endpoints panic (programmer
    /// error); self-loops are silently dropped (data artifact).
    #[inline]
    pub fn edge(&mut self, src: VertexId, dst: VertexId) -> &mut Self {
        assert!((src as usize) < self.n && (dst as usize) < self.n, "edge out of range");
        if src != dst {
            self.edges.push((src, dst));
        }
        self
    }

    /// Add many edges (builder-chaining convenience).
    pub fn edges(mut self, es: &[(VertexId, VertexId)]) -> Self {
        for &(s, d) in es {
            self.edge(s, d);
        }
        self
    }

    /// Number of (pre-dedup) edges accumulated so far.
    pub fn pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Finalize into CSR form.
    pub fn build(mut self) -> Graph {
        let n = self.n;

        // Sort + dedup directed edges. Sorting by (src, dst) also gives
        // us the forward CSR layout directly.
        self.edges.sort_unstable();
        self.edges.dedup();

        // Forward CSR.
        let mut fwd_offsets = vec![0u64; n + 1];
        for &(s, _) in &self.edges {
            fwd_offsets[s as usize + 1] += 1;
        }
        for i in 0..n {
            fwd_offsets[i + 1] += fwd_offsets[i];
        }
        let fwd_targets: Vec<VertexId> = self.edges.iter().map(|&(_, d)| d).collect();

        // Undirected adjacency with eq.-(4) weights. Build a mirrored
        // edge list tagged by direction, then merge per (min-endpoint
        // ordering is irrelevant; we need per-vertex sorted lists).
        // For each vertex v, the neighbour u gets weight 2.0 iff both
        // (v,u) and (u,v) exist in the directed graph.
        let m = self.edges.len();
        let mut und: Vec<(VertexId, VertexId, bool)> = Vec::with_capacity(2 * m);
        // tag=true => original direction (v -> u), false => reversed.
        for &(s, d) in &self.edges {
            und.push((s, d, true));
            und.push((d, s, false));
        }
        und.sort_unstable_by_key(|&(a, b, _)| (a, b));

        let mut und_offsets = vec![0u64; n + 1];
        let mut und_targets: Vec<VertexId> = Vec::with_capacity(und.len());
        let mut und_weights: Vec<f32> = Vec::with_capacity(und.len());

        let mut i = 0;
        while i < und.len() {
            let (v, u, _) = und[i];
            let mut j = i + 1;
            let mut both = false;
            while j < und.len() && und[j].0 == v && und[j].1 == u {
                both = true; // a (v,u) pair appearing twice means both directions exist
                j += 1;
            }
            und_offsets[v as usize + 1] += 1;
            und_targets.push(u);
            und_weights.push(if both { 2.0 } else { 1.0 });
            i = j;
        }
        for i in 0..n {
            und_offsets[i + 1] += und_offsets[i];
        }

        Graph::from_parts(n, fwd_offsets, fwd_targets, und_offsets, und_targets, und_weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_directed() {
        let g = GraphBuilder::new(2).edges(&[(0, 1), (0, 1), (0, 1)]).build();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn self_loops_dropped() {
        let g = GraphBuilder::new(2).edges(&[(0, 0), (0, 1), (1, 1)]).build();
        assert_eq!(g.num_edges(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn undirected_merge() {
        // star: 0->1, 0->2, 2->0  (0-2 reciprocal)
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 2), (2, 0)]).build();
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbor_weights(0), &[1.0, 2.0]);
        assert_eq!(g.neighbors(1), &[0]);
        assert_eq!(g.neighbor_weights(1), &[1.0]);
        assert_eq!(g.neighbors(2), &[0]);
        assert_eq!(g.neighbor_weights(2), &[2.0]);
    }

    #[test]
    #[should_panic(expected = "edge out of range")]
    fn out_of_range_panics() {
        let mut b = GraphBuilder::new(2);
        b.edge(0, 5);
    }

    #[test]
    fn weights_total_matches_eq4() {
        // Sum over v of sum_{u in N(v)} w(u,v) counts one-way edges twice
        // (once per endpoint, weight 1) and reciprocal pairs twice * 2.
        // 0->1 one-way, 1<->2 reciprocal.
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2), (2, 1)]).build();
        let total: f32 = (0..3)
            .flat_map(|v| g.neighbor_weights(v).iter().copied())
            .sum();
        assert_eq!(total, 2.0 * 1.0 + 2.0 * 2.0);
    }

    #[test]
    fn large_random_graph_validates() {
        use crate::util::rng::Rng;
        let n = 500;
        let mut rng = Rng::new(99);
        let mut b = GraphBuilder::with_capacity(n, 5000);
        for _ in 0..5000 {
            b.edge(rng.below(n as u64) as u32, rng.below(n as u64) as u32);
        }
        let g = b.build();
        g.validate().unwrap();
        // Undirected degree >= max(out_degree contribution).
        for v in 0..n as u32 {
            assert!(g.und_degree(v) >= 0u32);
            assert!(g.out_degree(v) as usize <= n);
        }
    }
}
