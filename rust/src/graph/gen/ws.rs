//! Watts–Strogatz ring + Erdős–Rényi mix — surrogate for EU-2015-host:
//! near-skew-free degree distribution *with* strong id locality (hosts
//! are crawled in order, so adjacent ids interlink heavily).

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// `k_ring` out-edges per vertex to ring neighbours, each rewired to a
/// uniform random target with probability `rewire`.
pub fn watts_strogatz_mix(n: usize, k_ring: usize, rewire: f64, seed: u64) -> Graph {
    assert!(n >= 8);
    assert!((0.0..=1.0).contains(&rewire));
    let k_ring = k_ring.max(1).min(n / 2 - 1);
    let mut rng = Rng::new(seed ^ 0x57415453); // "WATS"
    let mut builder = GraphBuilder::with_capacity(n, n * k_ring);

    for v in 0..n {
        for j in 1..=k_ring {
            let mut target = (v + j) % n;
            if rng.chance(rewire) {
                // Rewire to a uniform non-self target.
                loop {
                    target = rng.below_usize(n);
                    if target != v {
                        break;
                    }
                }
            }
            builder.edge(v as u32, target as u32);
        }
    }
    builder.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn size_and_validity() {
        let g = watts_strogatz_mix(1000, 10, 0.1, 1);
        g.validate().unwrap();
        let f = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(f > 9.0 && f <= 10.0, "edge factor {f}");
    }

    #[test]
    fn near_zero_skew() {
        let g = watts_strogatz_mix(4096, 34, 0.12, 2);
        let s = stats::compute(&g);
        // Out-degree is exactly k_ring (constant) minus dedup losses:
        // skew must be tiny.
        assert!(s.skewness.abs() < 0.35, "got {}", s.skewness);
    }

    #[test]
    fn id_locality_high() {
        let g = watts_strogatz_mix(2048, 16, 0.1, 3);
        let local = g
            .edges()
            .filter(|(s, d)| {
                let diff = (*s as i64 - *d as i64).rem_euclid(2048);
                diff <= 16 || diff >= 2048 - 16
            })
            .count();
        let frac = local as f64 / g.num_edges() as f64;
        assert!(frac > 0.8, "ring locality {frac}");
    }

    #[test]
    fn rewire_one_is_er_like() {
        let g = watts_strogatz_mix(1024, 8, 1.0, 4);
        let local = g
            .edges()
            .filter(|(s, d)| ((*s as i64 - *d as i64).abs()) <= 8)
            .count();
        assert!((local as f64 / g.num_edges() as f64) < 0.1);
    }

    #[test]
    fn deterministic() {
        let a = watts_strogatz_mix(256, 6, 0.2, 9);
        let b = watts_strogatz_mix(256, 6, 0.2, 9);
        assert_eq!(a.edges().collect::<Vec<_>>(), b.edges().collect::<Vec<_>>());
    }
}
