//! Barabási–Albert preferential attachment — surrogate for the dense
//! right-skewed social graphs (Orkut, Hollywood).

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// Generate a BA graph: each new vertex attaches to `m_attach` existing
/// vertices chosen proportionally to degree (implemented with the
/// repeated-endpoint-list trick), plus the reciprocal edge — BA models
/// friendships, which are mutual, giving the dense symmetric core Orkut
/// and Hollywood have.
pub fn barabasi_albert(n: usize, m_attach: usize, seed: u64) -> Graph {
    assert!(n >= 4);
    let m_attach = m_attach.max(1).min(n - 1);
    let mut rng = Rng::new(seed ^ 0x42414247); // "BABG"
    let mut builder = GraphBuilder::with_capacity(n, 2 * n * m_attach);

    // `endpoints` holds every edge endpoint ever created; sampling
    // uniformly from it IS degree-proportional sampling.
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * m_attach);

    // Seed clique over the first m_attach+1 vertices.
    let seed_sz = (m_attach + 1).min(n);
    for i in 0..seed_sz as u32 {
        for j in 0..seed_sz as u32 {
            if i < j {
                builder.edge(i, j);
                builder.edge(j, i);
                endpoints.push(i);
                endpoints.push(j);
            }
        }
    }

    for v in seed_sz as u32..n as u32 {
        // BTreeSet: deterministic iteration order (HashSet's RandomState
        // would make the generator nondeterministic across processes).
        let mut picked = std::collections::BTreeSet::new();
        let mut guard = 0;
        while picked.len() < m_attach && guard < 50 * m_attach {
            guard += 1;
            let u = endpoints[rng.below_usize(endpoints.len())];
            if u != v {
                picked.insert(u);
            }
        }
        for &u in &picked {
            builder.edge(v, u);
            builder.edge(u, v);
            endpoints.push(v);
            endpoints.push(u);
        }
    }
    builder.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn size_and_validity() {
        let g = barabasi_albert(1000, 10, 1);
        g.validate().unwrap();
        // ~2 * m_attach directed edges per vertex.
        let f = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(f > 15.0 && f < 25.0, "edge factor {f}");
    }

    #[test]
    fn right_skewed_with_hubs() {
        let g = barabasi_albert(4096, 20, 2);
        let s = stats::compute(&g);
        assert!(s.skewness > 0.1, "BA must be right-skewed, got {}", s.skewness);
        assert!(s.max_out_degree as f64 > 4.0 * s.mean_out_degree);
    }

    #[test]
    fn mostly_reciprocal() {
        // BA friendships are mutual: most und-weights should be 2.0.
        let g = barabasi_albert(512, 8, 3);
        let mut twos = 0usize;
        let mut total = 0usize;
        for v in 0..512u32 {
            for &w in g.neighbor_weights(v) {
                total += 1;
                if w == 2.0 {
                    twos += 1;
                }
            }
        }
        assert!(twos as f64 / total as f64 > 0.95, "{twos}/{total}");
    }

    #[test]
    fn deterministic() {
        let a = barabasi_albert(256, 6, 9);
        let b = barabasi_albert(256, 6, 9);
        assert_eq!(a.edges().collect::<Vec<_>>(), b.edges().collect::<Vec<_>>());
    }

    #[test]
    fn m_attach_clamped() {
        // m_attach > n-1 must not panic.
        let g = barabasi_albert(8, 100, 1);
        g.validate().unwrap();
    }
}
