//! Erdős–Rényi G(n, m) generator — the skew-free baseline regime
//! (surrogate for Stackoverflow, §V-G.3).

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// Uniform random directed graph with `n` vertices and ~`m` edges.
/// Binomial out-degrees concentrate near m/n => Pearson skew ≈ 0.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> Graph {
    assert!(n >= 2);
    let mut rng = Rng::new(seed ^ 0x4552444F); // "ERDO"
    let mut builder = GraphBuilder::with_capacity(n, m);
    let mut emitted = 0usize;
    let mut attempts = 0usize;
    let max_attempts = m.saturating_mul(3).max(64);
    while emitted < m && attempts < max_attempts {
        attempts += 1;
        let s = rng.below(n as u64) as u32;
        let d = rng.below(n as u64) as u32;
        if s != d {
            builder.edge(s, d);
            emitted += 1;
        }
    }
    builder.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn size_and_validity() {
        let g = erdos_renyi(1000, 12_000, 1);
        g.validate().unwrap();
        assert!(g.num_edges() > 11_000);
    }

    #[test]
    fn near_zero_skew() {
        let g = erdos_renyi(4096, 24 * 4096, 2);
        let s = stats::compute(&g);
        assert!(s.skewness.abs() < 0.3, "ER should be ~skew-free, got {}", s.skewness);
    }

    #[test]
    fn degrees_concentrated() {
        let g = erdos_renyi(2048, 20 * 2048, 3);
        let s = stats::compute(&g);
        // Poisson(20): stddev ~ sqrt(20) ≈ 4.5, far below the mean.
        assert!(s.stddev_out_degree < s.mean_out_degree);
    }

    #[test]
    fn deterministic() {
        let a = erdos_renyi(256, 2048, 9);
        let b = erdos_renyi(256, 2048, 9);
        assert_eq!(a.edges().collect::<Vec<_>>(), b.edges().collect::<Vec<_>>());
    }
}
