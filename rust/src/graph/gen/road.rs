//! Road-network generator — surrogate for USA-road (§V-G.4).
//!
//! A √n × √n planar grid where each cell connects to its 4 neighbours
//! bidirectionally, with a seeded fraction of diagonal shortcuts and
//! random deletions. Interior vertices sit at the mode out-degree
//! (4–5), boundary/deleted vertices below it, so the mode exceeds the
//! mean — exactly the *left-skewed* Pearson signature of Table I's USA
//! row — and consecutive ids are spatially adjacent, the id-locality
//! Range partitioning exploits.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// Generate a road-like network with ~`n` vertices.
pub fn road(n: usize, seed: u64) -> Graph {
    assert!(n >= 9);
    let mut side = (n as f64).sqrt().floor() as usize;
    // An odd side keeps row-stride edges from aliasing with power-of-two
    // partition counts under `v mod k` (a degenerate alignment real road
    // ids don't have).
    if side % 2 == 0 {
        side -= 1;
    }
    let n = side * side;
    let mut rng = Rng::new(seed ^ 0x524F4144); // "ROAD"
    let mut builder = GraphBuilder::with_capacity(n, 5 * n);

    let idx = |r: usize, c: usize| (r * side + c) as u32;

    for r in 0..side {
        for c in 0..side {
            let v = idx(r, c);
            // 4-neighbour bidirectional roads; ~9% of segments are
            // missing (rivers, dead ends). The deletions spread mass
            // *below* the grid mode (4), which is what drives Pearson's
            // coefficient toward USA-road's −0.59.
            if c + 1 < side && !rng.chance(0.09) {
                builder.edge(v, idx(r, c + 1));
                builder.edge(idx(r, c + 1), v);
            }
            if r + 1 < side && !rng.chance(0.09) {
                builder.edge(v, idx(r + 1, c));
                builder.edge(idx(r + 1, c), v);
            }
            // Sparse diagonal shortcuts (highways).
            if r + 1 < side && c + 1 < side && rng.chance(0.03) {
                builder.edge(v, idx(r + 1, c + 1));
                builder.edge(idx(r + 1, c + 1), v);
            }
        }
    }
    builder.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn left_skewed() {
        let g = road(4096, 1);
        g.validate().unwrap();
        let s = stats::compute(&g);
        assert!(s.skewness < 0.0, "road must be left-skewed, got {}", s.skewness);
        // Mode at full grid connectivity.
        assert!(s.mode_out_degree >= 3, "mode={}", s.mode_out_degree);
    }

    #[test]
    fn sparse_like_usa() {
        let g = road(4096, 2);
        let f = g.num_edges() as f64 / g.num_vertices() as f64;
        // USA-road has |E|/|V| ≈ 2.44.
        assert!(f > 1.5 && f < 4.5, "edge factor {f}");
    }

    #[test]
    fn id_locality() {
        // Consecutive ids are grid-adjacent: the average |src-dst| id
        // distance must be tiny relative to n (this is what Range
        // partitioning exploits on USA).
        let g = road(2500, 3);
        let side = 50i64;
        let mean_dist: f64 = g
            .edges()
            .map(|(s, d)| ((s as i64) - (d as i64)).abs() as f64)
            .sum::<f64>()
            / g.num_edges() as f64;
        assert!(mean_dist <= (side + 1) as f64, "mean id distance {mean_dist}");
    }

    #[test]
    fn deterministic() {
        let a = road(400, 5);
        let b = road(400, 5);
        assert_eq!(a.edges().collect::<Vec<_>>(), b.edges().collect::<Vec<_>>());
    }
}
