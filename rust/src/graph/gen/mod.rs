//! Synthetic graph generators — the substitute substrate for the paper's
//! nine datasets (DESIGN.md §4).
//!
//! The paper's own analysis attributes every partitioning-quality result
//! to two dataset properties: **density** and **out-degree skewness**
//! (plus id-locality for Range). Each generator below reproduces one of
//! those regimes; [`generate_dataset`] maps each paper dataset to a
//! surrogate with matching |E|/|V| ratio and skew class.

pub mod ba;
pub mod erdos_renyi;
pub mod rmat;
pub mod road;
pub mod ws;

use super::csr::Graph;
use anyhow::Result;

/// The nine paper datasets (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// Wiki-topcats: right-skewed web graph, |E|/|V| ≈ 16.
    Wiki,
    /// UK-2007@1M: *highly* right-skewed web graph, |E|/|V| ≈ 41.
    Uk,
    /// USA-road: left-skewed planar road network, |E|/|V| ≈ 2.4.
    Usa,
    /// Stackoverflow: skew-free interaction graph, |E|/|V| ≈ 24.
    So,
    /// LiveJournal: right-skewed social network, |E|/|V| ≈ 14.
    Lj,
    /// EN-wiki-2013: right-skewed web graph, |E|/|V| ≈ 24.
    En,
    /// Orkut: right-skewed dense social network, |E|/|V| ≈ 38.
    Ok,
    /// Hollywood-2011: right-skewed very dense collaboration, |E|/|V| ≈ 105.
    Hlwd,
    /// EU-2015-host: near-skew-free huge host graph, |E|/|V| ≈ 34.
    Eu,
}

impl Dataset {
    pub const ALL: [Dataset; 9] = [
        Dataset::Wiki,
        Dataset::Uk,
        Dataset::Usa,
        Dataset::So,
        Dataset::Lj,
        Dataset::En,
        Dataset::Ok,
        Dataset::Hlwd,
        Dataset::Eu,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Wiki => "wiki",
            Dataset::Uk => "uk",
            Dataset::Usa => "usa",
            Dataset::So => "so",
            Dataset::Lj => "lj",
            Dataset::En => "en",
            Dataset::Ok => "ok",
            Dataset::Hlwd => "hlwd",
            Dataset::Eu => "eu",
        }
    }

    pub fn from_name(s: &str) -> Option<Dataset> {
        Dataset::ALL.iter().copied().find(|d| d.name() == s.to_lowercase())
    }

    /// Paper Table I reference values (full-scale originals).
    pub fn paper_stats(&self) -> PaperStats {
        match self {
            Dataset::Wiki => PaperStats::new("Wiki-topcats", 1.79e6, 28.51e6, 0.88, 0.35),
            Dataset::Uk => PaperStats::new("UK-2007@1M", 1.00e6, 41.24e6, 4.12, 0.81),
            Dataset::Usa => PaperStats::new("USA-road", 23.9e6, 58.33e6, 0.01, -0.59),
            Dataset::So => PaperStats::new("Stackoverflow", 2.60e6, 63.49e6, 0.93, 0.08),
            Dataset::Lj => PaperStats::new("LiveJournal", 4.84e6, 68.99e6, 0.29, 0.36),
            Dataset::En => PaperStats::new("EN-wiki-2013", 4.20e6, 101.3e6, 0.57, 0.35),
            Dataset::Ok => PaperStats::new("Orkut", 3.07e6, 117.1e6, 1.24, 0.29),
            Dataset::Hlwd => PaperStats::new("Hollywood", 2.18e6, 228.9e6, 4.81, 0.32),
            Dataset::Eu => PaperStats::new("EU-2015-host", 11.2e6, 386.9e6, 0.30, 0.07),
        }
    }
}

/// Table I reference row for a paper dataset.
#[derive(Debug, Clone)]
pub struct PaperStats {
    pub full_name: &'static str,
    pub vertices: f64,
    pub edges: f64,
    /// Density ×10⁻⁵ as printed in Table I.
    pub density_e5: f64,
    pub skew: f64,
}

impl PaperStats {
    fn new(full_name: &'static str, v: f64, e: f64, d: f64, s: f64) -> Self {
        PaperStats { full_name, vertices: v, edges: e, density_e5: d, skew: s }
    }
}

/// Generate the surrogate for `ds` with approximately `target_vertices`
/// vertices (edge count follows the dataset's |E|/|V| ratio).
///
/// Deterministic in (`ds`, `target_vertices`, `seed`).
pub fn generate_dataset(ds: Dataset, target_vertices: usize, seed: u64) -> Result<Graph> {
    anyhow::ensure!(target_vertices >= 64, "need at least 64 vertices");
    let n = target_vertices;
    let g = match ds {
        // Right-skewed web/social graphs: R-MAT with the Graph500-ish
        // skew parameters; edge factor from Table I's |E|/|V|.
        Dataset::Wiki => rmat::rmat(n, 16 * n, 0.57, 0.19, 0.19, seed),
        Dataset::Lj => rmat::rmat(n, 14 * n, 0.57, 0.19, 0.19, seed),
        Dataset::En => rmat::rmat(n, 24 * n, 0.57, 0.19, 0.19, seed),
        // UK: highly right-skewed — raise `a` to deepen the power law —
        // and webgraph-like id clustering (BFS-ish relabel inside rmat
        // keeps consecutive-id locality high, which is what lets Range
        // exploit it; see §V-G.2).
        Dataset::Uk => rmat::rmat_clustered(n, 41 * n, 0.65, 0.16, 0.16, seed),
        // USA: planar grid-with-diagonals road network; left-skewed
        // (mode degree > mean because most intersections have full
        // connectivity, boundary ones fewer).
        Dataset::Usa => road::road(n, seed),
        // SO: skew-free Erdős–Rényi.
        Dataset::So => erdos_renyi::erdos_renyi(n, 24 * n, seed),
        // OK / HLWD: dense right-skewed social graphs — Barabási–Albert
        // preferential attachment (heavier tail than ER, denser core
        // than R-MAT at the same edge factor).
        Dataset::Ok => ba::barabasi_albert(n, 38, seed),
        Dataset::Hlwd => ba::barabasi_albert(n, 105.min(n / 4), seed),
        // EU: huge, near-skew-free, with strong id locality (hosts are
        // crawled in order) — Watts–Strogatz ring (locality) + ER noise.
        Dataset::Eu => ws::watts_strogatz_mix(n, 34, 0.12, seed),
    };
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn all_datasets_generate_and_validate() {
        for ds in Dataset::ALL {
            let g = generate_dataset(ds, 512, 1).unwrap();
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", ds.name()));
            assert!(g.num_edges() > 0, "{} empty", ds.name());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_dataset(Dataset::Lj, 256, 7).unwrap();
        let b = generate_dataset(Dataset::Lj, 256, 7).unwrap();
        assert_eq!(a.edges().collect::<Vec<_>>(), b.edges().collect::<Vec<_>>());
        let c = generate_dataset(Dataset::Lj, 256, 8).unwrap();
        assert_ne!(a.edges().collect::<Vec<_>>(), c.edges().collect::<Vec<_>>());
    }

    #[test]
    fn skew_classes_match_paper() {
        // At 4096 vertices the skew sign must match Table I's class:
        // the generators are tuned for this (DESIGN.md §4).
        let right = [Dataset::Wiki, Dataset::Lj, Dataset::Ok, Dataset::Hlwd];
        for ds in right {
            let g = generate_dataset(ds, 4096, 3).unwrap();
            let s = stats::compute(&g);
            assert!(s.skewness > 0.1, "{} expected right skew, got {}", ds.name(), s.skewness);
        }
        let usa = generate_dataset(Dataset::Usa, 4096, 3).unwrap();
        let s = stats::compute(&usa);
        assert!(s.skewness < 0.0, "usa expected left skew, got {}", s.skewness);
    }

    #[test]
    fn edge_factors_roughly_match() {
        for (ds, lo, hi) in [
            (Dataset::Wiki, 8.0, 17.0),
            (Dataset::So, 15.0, 25.0),
            (Dataset::Usa, 1.5, 4.5),
        ] {
            let g = generate_dataset(ds, 2048, 5).unwrap();
            let f = g.num_edges() as f64 / g.num_vertices() as f64;
            assert!(f >= lo && f <= hi, "{}: edge factor {f} outside [{lo},{hi}]", ds.name());
        }
    }

    #[test]
    fn name_roundtrip() {
        for ds in Dataset::ALL {
            assert_eq!(Dataset::from_name(ds.name()), Some(ds));
        }
        assert_eq!(Dataset::from_name("nope"), None);
    }

    #[test]
    fn too_small_is_error() {
        assert!(generate_dataset(Dataset::Lj, 10, 0).is_err());
    }
}
