//! R-MAT recursive-matrix generator (Chakrabarti et al., 2004) — the
//! standard model for right-skewed power-law web/social graphs.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// Generate an R-MAT graph with `n` vertices (rounded up to a power of
/// two internally, then trimmed) and ~`m` directed edges.
///
/// `(a, b, c)` are the recursive quadrant probabilities (`d = 1-a-b-c`).
/// Graph500 uses (0.57, 0.19, 0.19); larger `a` deepens the skew.
pub fn rmat(n: usize, m: usize, a: f64, b: f64, c: f64, seed: u64) -> Graph {
    rmat_impl(n, m, a, b, c, seed, false)
}

/// R-MAT variant preserving id locality: vertex ids are *not* scrambled,
/// so low-id vertices are the hubs and consecutive ids share quadrant
/// prefixes — mimicking crawl-ordered webgraph ids (UK-2007), which is
/// the structure Range partitioning exploits (§V-G.2). A per-source
/// out-degree cap models the crawler's per-page link limit, which is
/// what keeps the real UK graph's out-degree σ comparable to its mean
/// (and hence its Pearson coefficient high, +0.81) despite the heavy
/// in-degree tail.
pub fn rmat_clustered(n: usize, m: usize, a: f64, b: f64, c: f64, seed: u64) -> Graph {
    let cap = (3 * m / n).max(8) as u32;
    rmat_impl_capped(n, m, a, b, c, seed, true, Some(cap))
}

fn rmat_impl(n: usize, m: usize, a: f64, b: f64, c: f64, seed: u64, clustered: bool) -> Graph {
    rmat_impl_capped(n, m, a, b, c, seed, clustered, None)
}

#[allow(clippy::too_many_arguments)]
fn rmat_impl_capped(
    n: usize,
    m: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
    clustered: bool,
    max_out: Option<u32>,
) -> Graph {
    assert!(n >= 2);
    assert!(a > 0.0 && b >= 0.0 && c >= 0.0 && a + b + c < 1.0 + 1e-9);
    let levels = (n as f64).log2().ceil() as u32;
    let side = 1usize << levels;
    let mut rng = Rng::new(seed ^ 0x524D4154); // "RMAT"

    // Optional id scrambling decorrelates hub-ness from vertex id,
    // which is the realistic setting for social graphs (LJ/OK ids are
    // insertion-ordered, not degree-ordered).
    let perm: Option<Vec<u32>> = if clustered {
        None
    } else {
        let mut p: Vec<u32> = (0..side as u32).collect();
        rng.shuffle(&mut p);
        Some(p)
    };

    let mut builder = GraphBuilder::with_capacity(n, m);
    let mut out_deg = vec![0u32; if max_out.is_some() { n } else { 0 }];
    let ab = a + b;
    let abc = a + b + c;
    let mut emitted = 0usize;
    // Emit up to 3x m attempts: dedup + self-loop drops + out-of-range
    // trims eat some of them.
    let mut attempts = 0usize;
    let max_attempts = m.saturating_mul(4).max(64);
    while emitted < m && attempts < max_attempts {
        attempts += 1;
        let (mut src, mut dst) = (0usize, 0usize);
        for _ in 0..levels {
            let r = rng.next_f64();
            // Add ±10% noise per level (standard smoothing to avoid
            // grid artifacts in the degree distribution).
            let noise = 0.9 + 0.2 * rng.next_f64();
            let (ra, rab, rabc) = (a * noise, ab * noise, abc * noise);
            src <<= 1;
            dst <<= 1;
            if r < ra {
                // top-left
            } else if r < rab {
                dst |= 1;
            } else if r < rabc {
                src |= 1;
            } else {
                src |= 1;
                dst |= 1;
            }
        }
        let (mut s, mut d) = match &perm {
            Some(p) => (p[src] as usize, p[dst] as usize),
            None => (src, dst),
        };
        if s >= n || d >= n {
            // Trim: fold out-of-range ids back uniformly.
            s %= n;
            d %= n;
        }
        if s == d {
            continue;
        }
        if let Some(cap) = max_out {
            if out_deg[s] >= cap {
                continue;
            }
            out_deg[s] += 1;
        }
        builder.edge(s as u32, d as u32);
        emitted += 1;
    }
    builder.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn size_and_validity() {
        let g = rmat(1000, 10_000, 0.57, 0.19, 0.19, 1);
        g.validate().unwrap();
        assert_eq!(g.num_vertices(), 1000);
        // Dedup eats some edges, but most should survive.
        assert!(g.num_edges() > 7_000, "got {}", g.num_edges());
    }

    #[test]
    fn power_law_right_skew() {
        let g = rmat(4096, 16 * 4096, 0.57, 0.19, 0.19, 2);
        let s = stats::compute(&g);
        assert!(s.skewness > 0.1, "R-MAT must be right-skewed, got {}", s.skewness);
        // Hubs exist: max degree far above mean.
        assert!(s.max_out_degree as f64 > 5.0 * s.mean_out_degree);
    }

    #[test]
    fn clustered_keeps_low_id_hubs() {
        let g = rmat_clustered(2048, 20 * 2048, 0.65, 0.16, 0.16, 3);
        // With a=0.65 and no scrambling, low ids must have higher average
        // degree than high ids.
        let half = 1024u32;
        let low: f64 = (0..half).map(|v| g.out_degree(v) as f64).sum::<f64>() / half as f64;
        let high: f64 =
            (half..2048).map(|v| g.out_degree(v) as f64).sum::<f64>() / half as f64;
        assert!(low > 1.5 * high, "low={low} high={high}");
    }

    #[test]
    fn scrambled_spreads_hubs() {
        let g = rmat(2048, 20 * 2048, 0.65, 0.16, 0.16, 3);
        let half = 1024u32;
        let low: f64 = (0..half).map(|v| g.out_degree(v) as f64).sum::<f64>() / half as f64;
        let high: f64 =
            (half..2048).map(|v| g.out_degree(v) as f64).sum::<f64>() / half as f64;
        let ratio = low / high.max(1e-9);
        assert!(ratio < 1.5 && ratio > 0.6, "scrambled ratio={ratio}");
    }

    #[test]
    fn deterministic() {
        let a = rmat(512, 4096, 0.57, 0.19, 0.19, 42);
        let b = rmat(512, 4096, 0.57, 0.19, 0.19, 42);
        assert_eq!(a.edges().collect::<Vec<_>>(), b.edges().collect::<Vec<_>>());
    }
}
