//! Shared edge-line parsing and id densification — the two text-format
//! primitives every reader of `src<ws>dst` data uses: the edge-list
//! loader ([`super::io::read_edge_list`]), the CSR-free streaming
//! reader ([`crate::stream::FileEdgeStream`]), and the dynamic
//! update-log reader ([`crate::dynamic::read_update_log`]). Keeping
//! them in one module guarantees every path densifies raw ids in the
//! same first-appearance order, so labels produced against one reader
//! line up with a graph loaded by another.

use std::collections::HashMap;
use std::io::BufRead;

use anyhow::{bail, Context, Result};

use crate::VertexId;

/// Hard per-line byte cap for every text ingest path. A hostile input
/// whose "line" never ends (multi-GB of bytes with no `\n`) must not
/// buffer unboundedly: [`read_raw_line`] stops accumulating at this cap
/// and drains the remainder, so the worst case costs one bounded buffer
/// plus streaming I/O, never resident memory proportional to the line.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Read one newline-terminated line as raw bytes into `buf` (reused
/// across calls), stripping the trailing `\r` if present.
///
/// Returns `Ok(None)` at EOF, `Ok(Some(true))` for a line within
/// [`MAX_LINE_BYTES`], and `Ok(Some(false))` for an oversized line —
/// `buf` then holds the first `MAX_LINE_BYTES` bytes and the rest of
/// the physical line has been consumed from the reader, so the caller
/// can report or skip it and continue at the next line.
pub fn read_raw_line<R: BufRead>(r: &mut R, buf: &mut Vec<u8>) -> std::io::Result<Option<bool>> {
    buf.clear();
    let mut oversized = false;
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            // EOF: a partial final line (no trailing newline) is a line.
            if buf.is_empty() && !oversized {
                return Ok(None);
            }
            break;
        }
        let (take, done) = match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => (i, true),
            None => (chunk.len(), false),
        };
        if !oversized {
            let room = MAX_LINE_BYTES - buf.len();
            if take <= room {
                buf.extend_from_slice(&chunk[..take]);
            } else {
                buf.extend_from_slice(&chunk[..room]);
                oversized = true;
            }
        }
        r.consume(take + usize::from(done));
        if done {
            break;
        }
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    Ok(Some(!oversized))
}

/// A human-safe ≤64-byte excerpt of a raw line for diagnostics: lossy
/// UTF-8 (invalid bytes render as U+FFFD) with an ellipsis marking the
/// cut, so hostile bytes can't explode an error message.
pub fn snippet(bytes: &[u8]) -> String {
    const MAX: usize = 64;
    let cut = bytes.len().min(MAX);
    let mut s = String::from_utf8_lossy(&bytes[..cut]).into_owned();
    if bytes.len() > MAX {
        s.push('…');
    }
    s
}

/// The uniform ingest diagnostic every text reader emits:
/// `<path>: line <lineno>: <why>: "<snippet>"`.
pub fn line_err(path: &str, lineno: usize, why: &str, bytes: &[u8]) -> anyhow::Error {
    anyhow::anyhow!("{path}: line {lineno}: {why}: {:?}", snippet(bytes))
}

/// Parse one `src<ws>dst` edge-list line. `Ok(None)` for comment
/// (`#` / `%`) and blank lines.
pub fn parse_edge_line(line: &str, lineno: usize) -> Result<Option<(u64, u64)>> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
        return Ok(None);
    }
    let mut it = t.split_whitespace();
    let (a, b) = match (it.next(), it.next()) {
        (Some(a), Some(b)) => (a, b),
        _ => bail!("line {lineno}: expected `src dst`, got {t:?}"),
    };
    if it.next().is_some() {
        bail!("line {lineno}: trailing tokens after `src dst`, got {:?}", snippet(t.as_bytes()));
    }
    let a: u64 = a.parse().with_context(|| format!("line {lineno}: bad src"))?;
    let b: u64 = b.parse().with_context(|| format!("line {lineno}: bad dst"))?;
    Ok(Some((a, b)))
}

/// Densify an arbitrary raw id to 0..n in first-appearance order.
#[inline]
pub fn densify(raw: u64, ids: &mut HashMap<u64, VertexId>) -> VertexId {
    let next = ids.len() as VertexId;
    *ids.entry(raw).or_insert(next)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pairs_and_skips_comments() {
        assert_eq!(parse_edge_line("3 7", 1).unwrap(), Some((3, 7)));
        assert_eq!(parse_edge_line("3\t7\r\n", 1).unwrap(), Some((3, 7)));
        assert_eq!(parse_edge_line("# comment", 1).unwrap(), None);
        assert_eq!(parse_edge_line("% comment", 1).unwrap(), None);
        assert_eq!(parse_edge_line("   ", 1).unwrap(), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_edge_line("7", 13).unwrap_err();
        assert!(format!("{err:#}").contains("line 13"), "{err:#}");
        let err = parse_edge_line("x 1", 4).unwrap_err();
        assert!(format!("{err:#}").contains("line 4"), "{err:#}");
        let err = parse_edge_line("1 y", 9).unwrap_err();
        assert!(format!("{err:#}").contains("bad dst"), "{err:#}");
    }

    #[test]
    fn trailing_tokens_are_rejected() {
        let err = parse_edge_line("0 1 2", 5).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 5") && msg.contains("trailing"), "{msg}");
    }

    #[test]
    fn raw_line_reader_caps_hostile_lines() {
        use std::io::Cursor;
        // Normal lines round-trip with \r\n stripped.
        let mut r = Cursor::new(b"ab\r\ncd\nef".to_vec());
        let mut buf = Vec::new();
        assert_eq!(read_raw_line(&mut r, &mut buf).unwrap(), Some(true));
        assert_eq!(buf, b"ab");
        assert_eq!(read_raw_line(&mut r, &mut buf).unwrap(), Some(true));
        assert_eq!(buf, b"cd");
        // Final partial line (no trailing newline) still counts.
        assert_eq!(read_raw_line(&mut r, &mut buf).unwrap(), Some(true));
        assert_eq!(buf, b"ef");
        assert_eq!(read_raw_line(&mut r, &mut buf).unwrap(), None);

        // A line past the cap is truncated at MAX_LINE_BYTES, reported
        // as oversized, and fully drained so the next line still parses.
        let mut hostile = vec![b'x'; MAX_LINE_BYTES + 4096];
        hostile.push(b'\n');
        hostile.extend_from_slice(b"7 9\n");
        let mut r = Cursor::new(hostile);
        assert_eq!(read_raw_line(&mut r, &mut buf).unwrap(), Some(false));
        assert_eq!(buf.len(), MAX_LINE_BYTES);
        assert_eq!(read_raw_line(&mut r, &mut buf).unwrap(), Some(true));
        assert_eq!(buf, b"7 9");
    }

    #[test]
    fn snippets_are_bounded_and_lossy() {
        assert_eq!(snippet(b"0 1"), "0 1");
        let long = vec![b'a'; 200];
        let s = snippet(&long);
        assert!(s.starts_with("aaaa") && s.ends_with('…'));
        assert_eq!(s.chars().count(), 65);
        // Invalid UTF-8 renders as replacement chars, never panics.
        let s = snippet(&[0xff, 0xfe, b'z']);
        assert!(s.contains('z'));
        // The uniform diagnostic carries path, line and snippet.
        let err = line_err("edges.txt", 12, "bad src", b"x 1");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("edges.txt") && msg.contains("line 12") && msg.contains("x 1"),
            "{msg}"
        );
    }

    #[test]
    fn densify_first_appearance_order() {
        let mut ids = HashMap::new();
        assert_eq!(densify(1000, &mut ids), 0);
        assert_eq!(densify(5, &mut ids), 1);
        assert_eq!(densify(1000, &mut ids), 0, "repeat id keeps its dense id");
        assert_eq!(densify(42, &mut ids), 2);
        assert_eq!(ids.len(), 3);
    }
}
