//! Shared edge-line parsing and id densification — the two text-format
//! primitives every reader of `src<ws>dst` data uses: the edge-list
//! loader ([`super::io::read_edge_list`]), the CSR-free streaming
//! reader ([`crate::stream::FileEdgeStream`]), and the dynamic
//! update-log reader ([`crate::dynamic::read_update_log`]). Keeping
//! them in one module guarantees every path densifies raw ids in the
//! same first-appearance order, so labels produced against one reader
//! line up with a graph loaded by another.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::VertexId;

/// Parse one `src<ws>dst` edge-list line. `Ok(None)` for comment
/// (`#` / `%`) and blank lines.
pub fn parse_edge_line(line: &str, lineno: usize) -> Result<Option<(u64, u64)>> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
        return Ok(None);
    }
    let mut it = t.split_whitespace();
    let (a, b) = match (it.next(), it.next()) {
        (Some(a), Some(b)) => (a, b),
        _ => bail!("line {lineno}: expected `src dst`, got {t:?}"),
    };
    let a: u64 = a.parse().with_context(|| format!("line {lineno}: bad src"))?;
    let b: u64 = b.parse().with_context(|| format!("line {lineno}: bad dst"))?;
    Ok(Some((a, b)))
}

/// Densify an arbitrary raw id to 0..n in first-appearance order.
#[inline]
pub fn densify(raw: u64, ids: &mut HashMap<u64, VertexId>) -> VertexId {
    let next = ids.len() as VertexId;
    *ids.entry(raw).or_insert(next)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pairs_and_skips_comments() {
        assert_eq!(parse_edge_line("3 7", 1).unwrap(), Some((3, 7)));
        assert_eq!(parse_edge_line("3\t7\r\n", 1).unwrap(), Some((3, 7)));
        assert_eq!(parse_edge_line("# comment", 1).unwrap(), None);
        assert_eq!(parse_edge_line("% comment", 1).unwrap(), None);
        assert_eq!(parse_edge_line("   ", 1).unwrap(), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_edge_line("7", 13).unwrap_err();
        assert!(format!("{err:#}").contains("line 13"), "{err:#}");
        let err = parse_edge_line("x 1", 4).unwrap_err();
        assert!(format!("{err:#}").contains("line 4"), "{err:#}");
        let err = parse_edge_line("1 y", 9).unwrap_err();
        assert!(format!("{err:#}").contains("bad dst"), "{err:#}");
    }

    #[test]
    fn densify_first_appearance_order() {
        let mut ids = HashMap::new();
        assert_eq!(densify(1000, &mut ids), 0);
        assert_eq!(densify(5, &mut ids), 1);
        assert_eq!(densify(1000, &mut ids), 0, "repeat id keeps its dense id");
        assert_eq!(densify(42, &mut ids), 2);
        assert_eq!(ids.len(), 3);
    }
}
