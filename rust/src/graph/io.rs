//! Graph I/O: SNAP-style edge-list text, and a fast binary format.
//!
//! * **Edge-list text** — the format SNAP/WebGraph dumps use: one
//!   `src<ws>dst` pair per line, `#` or `%` comment lines ignored.
//!   Vertex ids are arbitrary u64s and are densified to 0..n.
//! * **Binary** — `RVLB` magic + little-endian u64 counts + raw CSR
//!   arrays; ~20x faster to load than text, used to cache generated
//!   surrogate datasets between benchmark runs.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::builder::GraphBuilder;
use super::csr::Graph;
use super::parse::{densify, line_err, parse_edge_line, read_raw_line, snippet};
use crate::config::IngestMode;
use crate::VertexId;

/// Load a whitespace-separated edge-list text file (strict: the first
/// malformed line aborts the load).
///
/// Unknown ids are densified in first-appearance order, so partition
/// labels index into 0..n. Lines starting with `#` or `%` are comments.
pub fn load_edge_list<P: AsRef<Path>>(path: P) -> Result<Graph> {
    load_edge_list_with(path, IngestMode::Strict)
}

/// [`load_edge_list`] with an explicit [`IngestMode`]: `Strict` aborts
/// on the first malformed line, `Lenient` skips-and-counts malformed
/// lines (reported via the `ingest_skipped_lines` counter and a log
/// line) and loads whatever parsed.
pub fn load_edge_list_with<P: AsRef<Path>>(path: P, mode: IngestMode) -> Result<Graph> {
    let label = path.as_ref().display().to_string();
    let f = File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    read_edge_list_named(BufReader::new(f), &label, mode)
}

/// Parse an edge list from any reader, strictly (unit-testable without
/// files; diagnostics use a placeholder source label).
pub fn read_edge_list<R: BufRead>(r: R) -> Result<Graph> {
    read_edge_list_named(r, "<edge list>", IngestMode::Strict)
}

/// The edge-list reader behind every text path: `label` names the
/// source in diagnostics (file path or a placeholder), `mode` picks the
/// strict/lenient malformed-line contract.
///
/// Lines are read as raw bytes into one reusable buffer under the
/// [`crate::graph::parse::MAX_LINE_BYTES`] cap — a hostile unbounded
/// line is truncated and drained, never buffered whole — and parsed in
/// place (the per-line `String` allocation `r.lines()` would make is
/// measurable on multi-million-edge lists). Ids are densified only
/// after a line fully parses, so skipped or failed lines can never
/// mint phantom vertices.
pub fn read_edge_list_named<R: BufRead>(mut r: R, label: &str, mode: IngestMode) -> Result<Graph> {
    let mut ids: std::collections::HashMap<u64, VertexId> = std::collections::HashMap::new();
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut buf = Vec::new();
    let mut lineno = 0usize;
    let mut skipped = 0u64;
    while let Some(fits) = read_raw_line(&mut r, &mut buf)? {
        lineno += 1;
        let parsed = if !fits {
            Err(line_err(label, lineno, "line exceeds the 1 MiB length cap", &buf))
        } else {
            match std::str::from_utf8(&buf) {
                Ok(text) => parse_edge_line(text, lineno).map_err(|e| {
                    e.context(format!("{label}: line {lineno}: {:?}", snippet(&buf)))
                }),
                Err(_) => Err(line_err(label, lineno, "invalid UTF-8", &buf)),
            }
        };
        match (parsed, mode) {
            (Ok(Some((a, b))), _) => {
                let s = densify(a, &mut ids);
                let d = densify(b, &mut ids);
                edges.push((s, d));
            }
            (Ok(None), _) => {}
            (Err(e), IngestMode::Strict) => return Err(e),
            (Err(e), IngestMode::Lenient) => {
                skipped += 1;
                crate::obs::counter_add("ingest_skipped_lines", 1);
                if skipped <= 8 {
                    crate::obs::log::debug(&format!("ingest: skipping {e:#}"));
                }
            }
        }
    }
    if skipped > 0 {
        crate::obs::log::info(&format!(
            "ingest: {label}: skipped {skipped} malformed line(s) (lenient mode)"
        ));
    }
    if ids.is_empty() {
        bail!("edge list contains no edges");
    }
    let mut builder = GraphBuilder::with_capacity(ids.len(), edges.len());
    for (s, d) in edges {
        builder.edge(s, d);
    }
    Ok(builder.build())
}

/// Both on-disk formats carry only (src, dst) pairs; silently
/// flattening a weighted multilevel contraction would reload as a
/// structurally different graph (eq.-(4) weights, out-degree mass), so
/// the savers refuse weighted inputs outright.
fn ensure_plain(g: &Graph) -> Result<()> {
    anyhow::ensure!(
        !g.is_weighted() && !g.has_vertex_weights(),
        "cannot serialize a weighted graph: edge/vertex weights have no \
         on-disk representation (save the finest-level graph instead)"
    );
    Ok(())
}

/// Write a graph back out as an edge-list text file.
pub fn save_edge_list<P: AsRef<Path>>(g: &Graph, path: P) -> Result<()> {
    ensure_plain(g)?;
    let f = File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# revolver edge list |V|={} |E|={}", g.num_vertices(), g.num_edges())?;
    for (s, d) in g.edges() {
        writeln!(w, "{s}\t{d}")?;
    }
    Ok(())
}

const MAGIC: &[u8; 4] = b"RVLB";
const VERSION: u32 = 1;

/// Save in the fast binary format.
pub fn save_binary<P: AsRef<Path>>(g: &Graph, path: P) -> Result<()> {
    ensure_plain(g)?;
    let f = File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    for (s, d) in g.edges() {
        w.write_all(&s.to_le_bytes())?;
        w.write_all(&d.to_le_bytes())?;
    }
    Ok(())
}

/// Load the fast binary format.
///
/// Header counts are untrusted: `m` is validated against the actual
/// file size and `n` against the `u32` vertex-id space *before* any
/// count-sized allocation, so a corrupted or hostile header (e.g.
/// `m = u64::MAX`) fails with a structured error instead of an OOM.
pub fn load_binary<P: AsRef<Path>>(path: P) -> Result<Graph> {
    let f = File::open(path.as_ref())?;
    let file_len = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a revolver binary graph (bad magic)");
    }
    let mut u32buf = [0u8; 4];
    r.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != VERSION {
        bail!("unsupported binary graph version {version}");
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n64 = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u64buf)?;
    let m64 = u64::from_le_bytes(u64buf);

    // Header: magic (4) + version (4) + n (8) + m (8).
    const HEADER: u64 = 24;
    anyhow::ensure!(
        n64 <= u64::from(u32::MAX),
        "corrupt binary graph: vertex count {n64} exceeds the u32 id space"
    );
    let payload = m64.checked_mul(8).filter(|p| HEADER.checked_add(*p) == Some(file_len));
    anyhow::ensure!(
        payload.is_some(),
        "corrupt binary graph: edge count {m64} does not match file size {file_len}"
    );
    let (n, m) = (n64 as usize, m64 as usize);

    let mut builder = GraphBuilder::with_capacity(n, m);
    let mut buf = vec![0u8; 8 * 4096];
    let mut need = m;
    while need > 0 {
        let take = need.min(4096);
        let bytes = take * 8;
        r.read_exact(&mut buf[..bytes])?;
        for i in 0..take {
            let s = u32::from_le_bytes(buf[i * 8..i * 8 + 4].try_into().unwrap());
            let d = u32::from_le_bytes(buf[i * 8 + 4..i * 8 + 8].try_into().unwrap());
            anyhow::ensure!(
                u64::from(s) < n64 && u64::from(d) < n64,
                "corrupt binary graph: edge ({s}, {d}) references a vertex >= {n64}"
            );
            builder.edge(s, d);
        }
        need -= take;
    }
    Ok(builder.build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn weighted_graphs_refuse_to_serialize() {
        let mut b = crate::graph::WeightedGraphBuilder::new(2);
        b.edge(0, 1, 3.5);
        let g = b.build();
        let dir = std::env::temp_dir().join("revolver_io_weighted");
        std::fs::create_dir_all(&dir).unwrap();
        let err = save_edge_list(&g, dir.join("w.txt")).unwrap_err();
        assert!(err.to_string().contains("weighted"), "{err}");
        let err = save_binary(&g, dir.join("w.bin")).unwrap_err();
        assert!(err.to_string().contains("weighted"), "{err}");
    }

    #[test]
    fn parse_simple() {
        let txt = "# comment\n0 1\n1 2\n% another\n2 0\n";
        let g = read_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn densifies_sparse_ids() {
        let txt = "1000000 5\n5 42\n";
        let g = read_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn tabs_and_spaces() {
        let txt = "0\t1\n1  2\n";
        let g = read_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn bad_line_is_error() {
        assert!(read_edge_list(Cursor::new("0\n")).is_err());
        assert!(read_edge_list(Cursor::new("a b\n")).is_err());
        assert!(read_edge_list(Cursor::new("")).is_err());
    }

    #[test]
    fn malformed_line_reports_line_number() {
        // Line 1 comment, line 2 valid, line 3 truncated.
        let err = read_edge_list(Cursor::new("# c\n0 1\n7\n")).unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "{err:#}");
        // Bad src on line 2 (comments still count toward line numbers).
        let err = read_edge_list(Cursor::new("% c\nx 1\n")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2") && msg.contains("bad src"), "{msg}");
        // Bad dst.
        let err = read_edge_list(Cursor::new("0 y\n")).unwrap_err();
        assert!(format!("{err:#}").contains("bad dst"), "{err:#}");
    }

    #[test]
    fn lenient_mode_skips_and_counts_malformed_lines() {
        // Garbage lines of every flavour between two good edges: bad
        // ints, missing tokens, trailing tokens, invalid UTF-8 — all
        // skipped, never densified into phantom vertices.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"0 1\n");
        bytes.extend_from_slice(b"x 1\n7\n1 2 3\n");
        bytes.extend_from_slice(&[0xff, 0xfe, b' ', b'5', b'\n']);
        bytes.extend_from_slice(b"1 2\n");
        let g =
            read_edge_list_named(Cursor::new(&bytes), "t.txt", IngestMode::Lenient).unwrap();
        assert_eq!(g.num_vertices(), 3, "skipped lines must not mint vertices");
        assert_eq!(g.num_edges(), 2);
        // Strict mode aborts on the first malformed line, naming the
        // source.
        let err = read_edge_list_named(Cursor::new(&bytes), "t.txt", IngestMode::Strict)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("t.txt") && msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn oversized_line_is_capped_not_buffered() {
        use crate::graph::parse::MAX_LINE_BYTES;
        let mut bytes = b"0 1\n".to_vec();
        bytes.extend(std::iter::repeat(b'9').take(MAX_LINE_BYTES + 100));
        bytes.extend_from_slice(b"\n1 2\n");
        // Strict: structured error naming the cap.
        let err =
            read_edge_list_named(Cursor::new(&bytes), "big.txt", IngestMode::Strict).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("1 MiB") && msg.contains("line 2"), "{msg}");
        // Lenient: the capped line is skipped, the rest loads.
        let g =
            read_edge_list_named(Cursor::new(&bytes), "big.txt", IngestMode::Lenient).unwrap();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn comments_blank_lines_and_crlf() {
        let txt = "# header\n\n   \n0 1\r\n% mid comment\n1 2\r\n\n2 0\n";
        let g = read_edge_list(Cursor::new(txt)).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn binary_roundtrip_property() {
        // Property-style: across seeds and sizes (including isolated
        // vertices and duplicate raw edges), save→load preserves the
        // exact edge set and vertex count.
        use crate::util::rng::Rng;
        let dir = std::env::temp_dir().join("revolver_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        for seed in [1u64, 7, 1234] {
            for n in [2usize, 17, 301] {
                let mut rng = Rng::new(seed);
                // n+3 vertices but edges only among the first n: the
                // last 3 stay isolated.
                let mut b = crate::graph::GraphBuilder::new(n + 3);
                for _ in 0..(n * 8) {
                    b.edge(rng.below(n as u64) as u32, rng.below(n as u64) as u32);
                }
                let g = b.build();
                let p = dir.join(format!("prop_{seed}_{n}.bin"));
                save_binary(&g, &p).unwrap();
                let g2 = load_binary(&p).unwrap();
                assert_eq!(g2.num_vertices(), g.num_vertices(), "seed={seed} n={n}");
                assert_eq!(
                    g.edges().collect::<Vec<_>>(),
                    g2.edges().collect::<Vec<_>>(),
                    "seed={seed} n={n}"
                );
                g2.validate().unwrap();
            }
        }
    }

    #[test]
    fn binary_rejects_wrong_version() {
        let dir = std::env::temp_dir().join("revolver_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("badver.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = load_binary(&p).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn text_roundtrip() {
        let g = crate::graph::GraphBuilder::new(4)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)])
            .build();
        let dir = std::env::temp_dir().join("revolver_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        save_edge_list(&g, &p).unwrap();
        let g2 = load_edge_list(&p).unwrap();
        assert_eq!(g2.num_vertices(), g.num_vertices());
        assert_eq!(g2.num_edges(), g.num_edges());
    }

    #[test]
    fn binary_roundtrip() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let mut b = crate::graph::GraphBuilder::new(200);
        for _ in 0..2000 {
            b.edge(rng.below(200) as u32, rng.below(200) as u32);
        }
        let g = b.build();
        let dir = std::env::temp_dir().join("revolver_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        save_binary(&g, &p).unwrap();
        let g2 = load_binary(&p).unwrap();
        assert_eq!(g2.num_vertices(), g.num_vertices());
        assert_eq!(g2.num_edges(), g.num_edges());
        // Edge sets identical.
        let e1: Vec<_> = g.edges().collect();
        let e2: Vec<_> = g2.edges().collect();
        assert_eq!(e1, e2);
    }

    #[test]
    fn binary_rejects_hostile_counts_without_allocating() {
        let dir = std::env::temp_dir().join("revolver_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        // A 24-byte header claiming u64::MAX edges: must error on the
        // size mismatch, not attempt a count-sized allocation.
        let p = dir.join("hostile_m.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&4u64.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = load_binary(&p).unwrap_err();
        assert!(format!("{err:#}").contains("edge count"), "{err:#}");
        // A vertex count past the u32 id space is equally structural.
        let p = dir.join("hostile_n.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(u64::from(u32::MAX) + 2).to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = load_binary(&p).unwrap_err();
        assert!(format!("{err:#}").contains("vertex count"), "{err:#}");
        // An edge referencing a vertex past n is rejected, not pushed
        // into the builder.
        let p = dir.join("hostile_edge.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&9u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = load_binary(&p).unwrap_err();
        assert!(format!("{err:#}").contains("references"), "{err:#}");
    }

    #[test]
    fn binary_rejects_garbage() {
        let dir = std::env::temp_dir().join("revolver_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("garbage.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(load_binary(&p).is_err());
    }
}
