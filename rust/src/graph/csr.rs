//! Compressed-sparse-row graph storage.

use crate::VertexId;

/// A directed graph in CSR form, with a precomputed *undirected* weighted
/// adjacency for label propagation.
///
/// Invariants (established by [`super::builder::GraphBuilder`] /
/// [`super::builder::WeightedGraphBuilder`], relied on throughout the
/// hot paths):
/// * `fwd_offsets.len() == n + 1`, `fwd_offsets[n] == fwd_targets.len()`
/// * `und_offsets.len() == n + 1`, `und_offsets[n] == und_targets.len()`
/// * neighbour lists are sorted and deduplicated,
/// * for plain graphs `und_weights[i]` is eq. (4)'s ŵ: 2.0 if both
///   directions exist, 1.0 otherwise; for *weighted* graphs (multilevel
///   contractions) it is the accumulated positive edge weight,
/// * no self-loops.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Number of vertices.
    n: usize,
    /// Forward (out-edge) CSR offsets, length n+1.
    fwd_offsets: Vec<u64>,
    /// Forward CSR targets, length = |E| (directed edges).
    fwd_targets: Vec<VertexId>,
    /// Undirected CSR offsets, length n+1.
    und_offsets: Vec<u64>,
    /// Undirected CSR targets.
    und_targets: Vec<VertexId>,
    /// Eq. (4) weights (plain) or accumulated contraction weights
    /// (weighted), parallel to `und_targets`.
    und_weights: Vec<f32>,
    /// Per-vertex balance weights. `None` for the paper's graphs (every
    /// vertex weighs its out-degree in the load accounting, §II);
    /// `Some` for multilevel coarse graphs, where a vertex stands for a
    /// cluster of fine vertices and balance is enforced in cluster-size
    /// units (see [`Graph::load_mass`]).
    vertex_weights: Option<Vec<u32>>,
    /// General (accumulated) edge weights allowed — relaxes the eq. (4)
    /// 1-or-2 weight check in [`Graph::validate`].
    weighted: bool,
}

impl Graph {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        n: usize,
        fwd_offsets: Vec<u64>,
        fwd_targets: Vec<VertexId>,
        und_offsets: Vec<u64>,
        und_targets: Vec<VertexId>,
        und_weights: Vec<f32>,
        vertex_weights: Option<Vec<u32>>,
        weighted: bool,
    ) -> Self {
        debug_assert_eq!(fwd_offsets.len(), n + 1);
        debug_assert_eq!(und_offsets.len(), n + 1);
        debug_assert_eq!(*fwd_offsets.last().unwrap() as usize, fwd_targets.len());
        debug_assert_eq!(*und_offsets.last().unwrap() as usize, und_targets.len());
        debug_assert_eq!(und_targets.len(), und_weights.len());
        if let Some(vw) = &vertex_weights {
            debug_assert_eq!(vw.len(), n);
        }
        Graph {
            n,
            fwd_offsets,
            fwd_targets,
            und_offsets,
            und_targets,
            und_weights,
            vertex_weights,
            weighted,
        }
    }

    /// Number of vertices |V|.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of directed edges |E|.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.fwd_targets.len()
    }

    /// Out-degree of `v` — the paper's `deg(v)` used for load accounting.
    #[inline]
    pub fn out_degree(&self, v: VertexId) -> u32 {
        let v = v as usize;
        (self.fwd_offsets[v + 1] - self.fwd_offsets[v]) as u32
    }

    /// Out-neighbours of `v`.
    #[inline]
    pub fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        let v = v as usize;
        &self.fwd_targets[self.fwd_offsets[v] as usize..self.fwd_offsets[v + 1] as usize]
    }

    /// Undirected neighbourhood N(v), deduplicated.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let v = v as usize;
        &self.und_targets[self.und_offsets[v] as usize..self.und_offsets[v + 1] as usize]
    }

    /// Eq. (4) weights ŵ(u,v) parallel to [`Self::neighbors`].
    #[inline]
    pub fn neighbor_weights(&self, v: VertexId) -> &[f32] {
        let v = v as usize;
        &self.und_weights[self.und_offsets[v] as usize..self.und_offsets[v + 1] as usize]
    }

    /// Undirected degree |N(v)|.
    #[inline]
    pub fn und_degree(&self, v: VertexId) -> u32 {
        let v = v as usize;
        (self.und_offsets[v + 1] - self.und_offsets[v]) as u32
    }

    /// Total undirected adjacency entries Σ_v |N(v)| — twice the number
    /// of distinct undirected edges. Exact capacity bound for code that
    /// re-emits the undirected adjacency (multilevel contraction).
    #[inline]
    pub fn num_und_entries(&self) -> usize {
        self.und_targets.len()
    }

    /// True when edge weights are general accumulated values (multilevel
    /// contractions) rather than eq. (4)'s 1-or-2.
    #[inline]
    pub fn is_weighted(&self) -> bool {
        self.weighted
    }

    /// Balance weight of vertex `v`: 1 unless explicit vertex weights
    /// were attached (coarse graphs, where it is the cluster size).
    #[inline]
    pub fn vertex_weight(&self, v: VertexId) -> u32 {
        match &self.vertex_weights {
            Some(w) => w[v as usize],
            None => 1,
        }
    }

    /// True when explicit per-vertex balance weights are attached.
    #[inline]
    pub fn has_vertex_weights(&self) -> bool {
        self.vertex_weights.is_some()
    }

    /// Σ_v vertex_weight(v) — |V| for plain graphs, the finest-level
    /// vertex count for a multilevel contraction.
    pub fn total_vertex_weight(&self) -> u64 {
        match &self.vertex_weights {
            Some(w) => w.iter().map(|&x| x as u64).sum(),
            None => self.n as u64,
        }
    }

    /// The per-vertex mass the partition-load accounting b(l) charges:
    /// out-degree for the paper's graphs (§II counts partition size in
    /// outgoing edges), the coarse vertex weight when explicit vertex
    /// weights are attached — so multilevel refinement levels balance in
    /// coarse-vertex-weight units and cannot silently overload a
    /// partition that looks small in merged-edge counts.
    #[inline]
    pub fn load_mass(&self, v: VertexId) -> u32 {
        match &self.vertex_weights {
            Some(w) => w[v as usize],
            None => self.out_degree(v),
        }
    }

    /// Σ_v load_mass(v) — |E| for plain graphs.
    pub fn total_load_mass(&self) -> u64 {
        match &self.vertex_weights {
            Some(w) => w.iter().map(|&x| x as u64).sum(),
            None => self.num_edges() as u64,
        }
    }

    /// Σ over all undirected adjacency entries of ŵ — each undirected
    /// edge contributes its weight *twice* (once per endpoint). The
    /// multilevel edge-weight conservation invariant is stated over
    /// half this value.
    pub fn total_neighbor_weight(&self) -> f64 {
        self.und_weights.iter().map(|&w| w as f64).sum()
    }

    /// Iterate all directed edges as (src, dst).
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.n).flat_map(move |v| {
            self.out_neighbors(v as VertexId)
                .iter()
                .map(move |&u| (v as VertexId, u))
        })
    }

    /// Approximate resident bytes (diagnostics / VMEM-style budgeting).
    pub fn memory_bytes(&self) -> usize {
        (self.fwd_offsets.len() + self.und_offsets.len()) * 8
            + self.fwd_targets.len() * 4
            + self.und_targets.len() * 4
            + self.und_weights.len() * 4
            + self.vertex_weights.as_ref().map_or(0, |w| w.len() * 4)
    }

    /// Structural self-check (used by tests and the loader).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.fwd_offsets.len() == self.n + 1, "bad fwd offsets");
        anyhow::ensure!(self.und_offsets.len() == self.n + 1, "bad und offsets");
        for v in 0..self.n {
            anyhow::ensure!(
                self.fwd_offsets[v] <= self.fwd_offsets[v + 1],
                "fwd offsets not monotone at {v}"
            );
            let ns = self.neighbors(v as VertexId);
            for w in ns.windows(2) {
                anyhow::ensure!(w[0] < w[1], "neighbors of {v} not sorted/dedup");
            }
            for &u in self.out_neighbors(v as VertexId) {
                anyhow::ensure!((u as usize) < self.n, "edge target out of range");
                anyhow::ensure!(u as usize != v, "self-loop at {v}");
            }
            for (&u, &w) in ns.iter().zip(self.neighbor_weights(v as VertexId)) {
                anyhow::ensure!((u as usize) < self.n, "und target out of range");
                if self.weighted {
                    anyhow::ensure!(
                        w.is_finite() && w > 0.0,
                        "weighted graph needs finite positive weights, got {w}"
                    );
                } else {
                    anyhow::ensure!(w == 1.0 || w == 2.0, "weight must be 1 or 2, got {w}");
                }
            }
        }
        if let Some(vw) = &self.vertex_weights {
            anyhow::ensure!(vw.len() == self.n, "vertex weights must cover every vertex");
            anyhow::ensure!(
                vw.iter().all(|&w| w >= 1),
                "vertex weights must be >= 1 (a coarse vertex covers >= 1 fine vertex)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::GraphBuilder;

    #[test]
    fn triangle() {
        // 0->1, 1->2, 2->0 : each vertex out-degree 1, N(v) of size 2.
        let g = GraphBuilder::new(3)
            .edges(&[(0, 1), (1, 2), (2, 0)])
            .build();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        for v in 0..3 {
            assert_eq!(g.out_degree(v), 1);
            assert_eq!(g.und_degree(v), 2);
            // No reciprocal pairs -> all weights 1.
            assert!(g.neighbor_weights(v).iter().all(|&w| w == 1.0));
        }
        g.validate().unwrap();
    }

    #[test]
    fn reciprocal_weight_two() {
        let g = GraphBuilder::new(2).edges(&[(0, 1), (1, 0)]).build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbor_weights(0), &[2.0]);
        assert_eq!(g.neighbor_weights(1), &[2.0]);
    }

    #[test]
    fn edges_iterator_count() {
        let g = GraphBuilder::new(4)
            .edges(&[(0, 1), (0, 2), (1, 3), (2, 3)])
            .build();
        assert_eq!(g.edges().count(), 4);
        assert!(g.edges().all(|(s, t)| (s as usize) < 4 && (t as usize) < 4));
    }

    #[test]
    fn isolated_vertex() {
        let g = GraphBuilder::new(3).edges(&[(0, 1)]).build();
        assert_eq!(g.out_degree(2), 0);
        assert_eq!(g.und_degree(2), 0);
        assert!(g.neighbors(2).is_empty());
        g.validate().unwrap();
    }

    #[test]
    fn memory_accounting_positive() {
        let g = GraphBuilder::new(10).edges(&[(0, 1), (1, 2)]).build();
        assert!(g.memory_bytes() > 0);
    }

    #[test]
    fn plain_graph_mass_is_out_degree() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (0, 2), (1, 2)]).build();
        assert!(!g.is_weighted());
        assert!(!g.has_vertex_weights());
        for v in 0..3 {
            assert_eq!(g.vertex_weight(v), 1);
            assert_eq!(g.load_mass(v), g.out_degree(v));
        }
        assert_eq!(g.total_vertex_weight(), 3);
        assert_eq!(g.total_load_mass(), g.num_edges() as u64);
        // 3 one-way edges, each counted at both endpoints with ŵ=1.
        assert_eq!(g.total_neighbor_weight(), 6.0);
    }
}
