//! Contiguous vertex chunking — the paper's |V|/n-per-thread layout.

/// Partition `0..n` into at most `threads` contiguous, near-equal chunks
/// (first `n % threads` chunks get one extra vertex). Never produces an
/// empty chunk: for tiny inputs the chunk count shrinks to `n`.
#[derive(Debug, Clone)]
pub struct Chunks {
    n: usize,
    bounds: Vec<usize>,
}

impl Chunks {
    pub fn new(n: usize, threads: usize) -> Self {
        assert!(n > 0, "cannot chunk an empty vertex set");
        let t = threads.max(1).min(n);
        let base = n / t;
        let extra = n % t;
        let mut bounds = Vec::with_capacity(t + 1);
        let mut pos = 0;
        bounds.push(0);
        for c in 0..t {
            pos += base + usize::from(c < extra);
            bounds.push(pos);
        }
        debug_assert_eq!(*bounds.last().unwrap(), n);
        Chunks { n, bounds }
    }

    /// Number of chunks (== worker threads used).
    pub fn len(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Total vertices.
    pub fn total(&self) -> usize {
        self.n
    }

    /// Vertex range of chunk `c`.
    pub fn range(&self, c: usize) -> std::ops::Range<usize> {
        self.bounds[c]..self.bounds[c + 1]
    }

    /// Which chunk a vertex belongs to (binary search; not hot-path).
    pub fn chunk_of(&self, v: usize) -> usize {
        debug_assert!(v < self.n);
        match self.bounds.binary_search(&v) {
            Ok(i) if i == self.len() => i - 1,
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split() {
        let c = Chunks::new(100, 4);
        assert_eq!(c.len(), 4);
        for i in 0..4 {
            assert_eq!(c.range(i).len(), 25);
        }
    }

    #[test]
    fn uneven_split_front_loaded() {
        let c = Chunks::new(10, 3);
        assert_eq!(c.range(0).len(), 4);
        assert_eq!(c.range(1).len(), 3);
        assert_eq!(c.range(2).len(), 3);
    }

    #[test]
    fn more_threads_than_vertices() {
        let c = Chunks::new(3, 8);
        assert_eq!(c.len(), 3);
        assert!((0..c.len()).all(|i| c.range(i).len() == 1));
    }

    #[test]
    fn ranges_cover_exactly() {
        let c = Chunks::new(1003, 7);
        let mut covered = vec![false; 1003];
        for i in 0..c.len() {
            for v in c.range(i) {
                assert!(!covered[v], "vertex {v} covered twice");
                covered[v] = true;
            }
        }
        assert!(covered.iter().all(|&x| x));
    }

    #[test]
    fn chunk_of_consistent_with_ranges() {
        let c = Chunks::new(97, 5);
        for i in 0..c.len() {
            for v in c.range(i) {
                assert_eq!(c.chunk_of(v), i, "vertex {v}");
            }
        }
    }
}
