//! Contiguous vertex chunking — the worker-thread work assignment.
//!
//! Two modes (selected via [`crate::config::Schedule`]):
//!
//! * **Vertex-balanced** ([`Chunks::new`]) — the paper's |V|/n-per-thread
//!   layout: near-equal vertex counts per chunk.
//! * **Degree-balanced** ([`Chunks::by_weight`]) — near-equal *cumulative
//!   weight* per chunk (the engine passes `1 + out_degree(v)`). On
//!   power-law graphs (BA/RMAT/LJ) the vertex-balanced layout hands one
//!   chunk the hubs, and the whole barrier-synchronized step then waits
//!   on that straggler; weight-balancing splits `0..n` at the weight
//!   prefix-sum quantiles instead (DESIGN.md §Scheduler).

/// Partition `0..n` into at most `threads` contiguous chunks.
/// Never produces an empty chunk: for tiny inputs the chunk count
/// shrinks to `n`.
#[derive(Debug, Clone)]
pub struct Chunks {
    n: usize,
    bounds: Vec<usize>,
}

impl Chunks {
    /// Vertex-balanced: near-equal chunk sizes (first `n % threads`
    /// chunks get one extra vertex).
    pub fn new(n: usize, threads: usize) -> Self {
        assert!(n > 0, "cannot chunk an empty vertex set");
        let t = threads.max(1).min(n);
        let base = n / t;
        let extra = n % t;
        let mut bounds = Vec::with_capacity(t + 1);
        let mut pos = 0;
        bounds.push(0);
        for c in 0..t {
            pos += base + usize::from(c < extra);
            bounds.push(pos);
        }
        debug_assert_eq!(*bounds.last().unwrap(), n);
        Chunks { n, bounds }
    }

    /// Weight-balanced: chunk boundaries sit at the quantiles of the
    /// cumulative `weight` prefix sum, so each chunk carries ~total/t
    /// weight. Weights are clamped to ≥ 1, which both models the fixed
    /// per-vertex cost and guarantees no empty chunk. Chunks stay
    /// contiguous (the CSR-locality property the per-chunk probability
    /// slabs rely on).
    pub fn by_weight<W: Fn(usize) -> u64>(n: usize, threads: usize, weight: W) -> Self {
        assert!(n > 0, "cannot chunk an empty vertex set");
        let t = threads.max(1).min(n);
        let total: u128 = (0..n).map(|v| weight(v).max(1) as u128).sum();
        let mut bounds = Vec::with_capacity(t + 1);
        bounds.push(0);
        let mut acc: u128 = 0;
        let mut v = 0usize;
        for c in 0..t {
            // Cumulative weight target for the end of chunk `c`, while
            // always leaving ≥ 1 vertex for each of the later chunks.
            let target = total * (c as u128 + 1) / t as u128;
            let max_end = n - (t - 1 - c);
            loop {
                acc += weight(v).max(1) as u128;
                v += 1;
                if v >= max_end || acc >= target {
                    break;
                }
            }
            bounds.push(v);
        }
        debug_assert_eq!(*bounds.last().unwrap(), n);
        Chunks { n, bounds }
    }

    /// Weight-balanced chunking of an arbitrary **vertex subset** — the
    /// active-frontier layout: chunk `c` owns the *positions*
    /// `range(c)` of `verts`, so the caller slices `&verts[range(c)]`
    /// to get chunk `c`'s vertices. Same cover-exactly / no-empty-chunk
    /// invariants as [`Chunks::by_weight`], stated over positions
    /// `0..verts.len()`. Unlike the full-graph constructors an **empty**
    /// subset is legal and yields zero chunks (`is_empty()` — the
    /// engine halts on an empty frontier before ever slicing one).
    pub fn by_weight_subset<W: Fn(crate::VertexId) -> u64>(
        verts: &[crate::VertexId],
        threads: usize,
        weight: W,
    ) -> Self {
        if verts.is_empty() {
            return Chunks { n: 0, bounds: vec![0] };
        }
        Chunks::by_weight(verts.len(), threads, |i| weight(verts[i]))
    }

    /// Reuse this layout for a **shorter** vertex/position list of
    /// length `new_total` — the frontier-shrink amortization: rebuilding
    /// [`Chunks::by_weight_subset`] costs O(frontier) per step, but when
    /// the frontier shrank by < 2× the previous quantile boundaries are
    /// still near-balanced, so the coordinator clamps them instead of
    /// recomputing the prefix-sum walk. Every boundary is clamped to
    /// `new_total`; chunk count is preserved, so — unlike the
    /// constructors — trailing chunks **may be empty** (the engine
    /// tolerates empty slices). Cover-exactly over `0..new_total` still
    /// holds.
    pub fn clamped(&self, new_total: usize) -> Self {
        debug_assert!(new_total <= self.n);
        let bounds: Vec<usize> = self.bounds.iter().map(|&b| b.min(new_total)).collect();
        Chunks { n: new_total, bounds }
    }

    /// Number of chunks (== worker threads used).
    pub fn len(&self) -> usize {
        self.bounds.len() - 1
    }

    /// True only for the zero-chunk layout [`Chunks::by_weight_subset`]
    /// builds from an empty frontier; the full-graph constructors assert
    /// `n > 0` and always yield ≥ 1 chunk.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total vertices.
    pub fn total(&self) -> usize {
        self.n
    }

    /// Vertex range of chunk `c`.
    pub fn range(&self, c: usize) -> std::ops::Range<usize> {
        self.bounds[c]..self.bounds[c + 1]
    }

    /// Which chunk a vertex belongs to (binary search; not hot-path).
    pub fn chunk_of(&self, v: usize) -> usize {
        debug_assert!(v < self.n);
        match self.bounds.binary_search(&v) {
            Ok(i) if i == self.len() => i - 1,
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{ba, rmat};
    use crate::graph::Graph;

    #[test]
    fn even_split() {
        let c = Chunks::new(100, 4);
        assert_eq!(c.len(), 4);
        for i in 0..4 {
            assert_eq!(c.range(i).len(), 25);
        }
    }

    #[test]
    fn uneven_split_front_loaded() {
        let c = Chunks::new(10, 3);
        assert_eq!(c.range(0).len(), 4);
        assert_eq!(c.range(1).len(), 3);
        assert_eq!(c.range(2).len(), 3);
    }

    #[test]
    fn more_threads_than_vertices() {
        let c = Chunks::new(3, 8);
        assert_eq!(c.len(), 3);
        assert!((0..c.len()).all(|i| c.range(i).len() == 1));
    }

    #[test]
    fn ranges_cover_exactly() {
        let c = Chunks::new(1003, 7);
        let mut covered = vec![false; 1003];
        for i in 0..c.len() {
            for v in c.range(i) {
                assert!(!covered[v], "vertex {v} covered twice");
                covered[v] = true;
            }
        }
        assert!(covered.iter().all(|&x| x));
    }

    #[test]
    fn chunk_of_consistent_with_ranges() {
        let c = Chunks::new(97, 5);
        for i in 0..c.len() {
            for v in c.range(i) {
                assert_eq!(c.chunk_of(v), i, "vertex {v}");
            }
        }
    }

    #[test]
    fn is_empty_derives_from_len() {
        // Regression: `is_empty` used to return a hard-coded `false`
        // instead of consulting `len()`.
        for (n, t) in [(1, 1), (5, 2), (100, 7), (3, 8)] {
            let c = Chunks::new(n, t);
            assert_eq!(c.is_empty(), c.len() == 0);
            assert!(!c.is_empty(), "n={n} t={t} must yield ≥ 1 chunk");
            let c = Chunks::by_weight(n, t, |v| v as u64);
            assert_eq!(c.is_empty(), c.len() == 0);
            assert!(!c.is_empty());
        }
    }

    /// Cover-exactly + no-empty-chunk + chunk_of consistency for an
    /// arbitrary Chunks instance.
    fn assert_chunk_invariants(c: &Chunks, n: usize) {
        assert_eq!(c.total(), n);
        let mut covered = vec![false; n];
        for i in 0..c.len() {
            let r = c.range(i);
            assert!(!r.is_empty(), "chunk {i} empty ({r:?})");
            for v in r {
                assert!(!covered[v], "vertex {v} covered twice");
                covered[v] = true;
                assert_eq!(c.chunk_of(v), i);
            }
        }
        assert!(covered.iter().all(|&x| x), "not all vertices covered");
    }

    fn out_degrees(g: &Graph) -> Vec<u64> {
        (0..g.num_vertices()).map(|v| g.out_degree(v as u32) as u64).collect()
    }

    #[test]
    fn by_weight_invariants_on_ba_degrees() {
        // Barabási–Albert: heavy right-skew (early vertices are hubs).
        let g = ba::barabasi_albert(2048, 8, 7);
        let deg = out_degrees(&g);
        for t in [1usize, 2, 3, 4, 7, 8, 16] {
            let c = Chunks::by_weight(deg.len(), t, |v| 1 + deg[v]);
            assert_eq!(c.len(), t.min(deg.len()));
            assert_chunk_invariants(&c, deg.len());
        }
    }

    #[test]
    fn by_weight_invariants_on_rmat_degrees() {
        let g = rmat::rmat(2048, 16 * 2048, 0.57, 0.19, 0.19, 11);
        let deg = out_degrees(&g);
        for t in [2usize, 4, 8, 16] {
            let c = Chunks::by_weight(deg.len(), t, |v| 1 + deg[v]);
            assert_chunk_invariants(&c, deg.len());
        }
    }

    #[test]
    fn by_weight_balances_skewed_weights() {
        // A BA hub chunk under vertex-balanced splitting carries far
        // more than total/t weight; by_weight must keep every chunk
        // within one max-weight vertex of the ideal share.
        let g = ba::barabasi_albert(4096, 16, 3);
        let w: Vec<u64> = out_degrees(&g).iter().map(|d| 1 + d).collect();
        let total: u128 = w.iter().map(|&x| x as u128).sum();
        let w_max = *w.iter().max().unwrap() as u128;
        let t = 8usize;
        let c = Chunks::by_weight(w.len(), t, |v| w[v]);
        for i in 0..c.len() {
            let cw: u128 = c.range(i).map(|v| w[v] as u128).sum();
            assert!(
                cw <= total / t as u128 + w_max + 1,
                "chunk {i} weight {cw} exceeds ideal {} + max {w_max}",
                total / t as u128
            );
        }
    }

    #[test]
    fn by_weight_uniform_weights_match_vertex_split_sizes() {
        // With uniform weights the degree-balanced split degenerates to
        // (approximately) the vertex-balanced one.
        let c = Chunks::by_weight(1000, 4, |_| 1);
        for i in 0..4 {
            assert_eq!(c.range(i).len(), 250);
        }
    }

    #[test]
    fn by_weight_zero_weights_are_clamped() {
        // All-zero weights must not produce empty or short coverage.
        let c = Chunks::by_weight(10, 3, |_| 0);
        assert_chunk_invariants(&c, 10);
    }

    #[test]
    fn by_weight_subset_covers_exactly_the_subset() {
        // Every other vertex of a BA graph, skewed degree weights.
        let g = ba::barabasi_albert(1024, 8, 5);
        let deg = out_degrees(&g);
        let verts: Vec<u32> = (0..1024u32).filter(|v| v % 2 == 0).collect();
        for t in [1usize, 2, 3, 4, 8] {
            let c = Chunks::by_weight_subset(&verts, t, |v| 1 + deg[v as usize]);
            assert_eq!(c.len(), t.min(verts.len()));
            assert_chunk_invariants(&c, verts.len());
            // Concatenated position ranges must reproduce the subset in
            // order (the engine slices `&verts[range(c)]`).
            let mut seen = Vec::new();
            for i in 0..c.len() {
                seen.extend_from_slice(&verts[c.range(i)]);
            }
            assert_eq!(seen, verts);
        }
    }

    #[test]
    fn by_weight_subset_empty_frontier_yields_no_chunks() {
        let c = Chunks::by_weight_subset(&[], 4, |_| 1);
        assert!(c.is_empty());
        assert_eq!(c.len(), 0);
        assert_eq!(c.total(), 0);
    }

    #[test]
    fn by_weight_subset_single_vertex() {
        let c = Chunks::by_weight_subset(&[17u32], 8, |_| 1000);
        assert_eq!(c.len(), 1);
        assert_eq!(c.range(0), 0..1);
        assert!(!c.is_empty());
    }

    #[test]
    fn by_weight_subset_hub_heavy_subset_no_empty_chunks() {
        // Subset led by one huge-weight vertex: later chunks must still
        // each get at least one position.
        let verts: Vec<u32> = (0..50u32).collect();
        let c = Chunks::by_weight_subset(&verts, 4, |v| if v == 0 { 1_000_000 } else { 1 });
        assert_chunk_invariants(&c, 50);
        assert_eq!(c.range(0), 0..1, "hub chunk should stop right after the hub");
    }

    #[test]
    fn clamped_covers_exactly_allows_empty_tail() {
        let g = ba::barabasi_albert(1024, 8, 5);
        let deg = out_degrees(&g);
        let verts: Vec<u32> = (0..1024u32).collect();
        let c = Chunks::by_weight_subset(&verts, 4, |v| 1 + deg[v as usize]);
        for new_total in [1024usize, 900, 600, 513, 4, 1, 0] {
            let cc = c.clamped(new_total);
            assert_eq!(cc.len(), c.len(), "chunk count preserved");
            assert_eq!(cc.total(), new_total);
            // Cover-exactly over 0..new_total (empty chunks legal).
            let mut covered = vec![false; new_total];
            for i in 0..cc.len() {
                for v in cc.range(i) {
                    assert!(!covered[v], "position {v} covered twice");
                    covered[v] = true;
                }
            }
            assert!(covered.iter().all(|&x| x), "new_total={new_total}");
            // Ranges stay monotone and in-bounds.
            for i in 0..cc.len() {
                let r = cc.range(i);
                assert!(r.start <= r.end && r.end <= new_total);
            }
        }
    }

    #[test]
    fn clamped_identity_when_total_unchanged() {
        let c = Chunks::by_weight(100, 4, |v| 1 + v as u64);
        let cc = c.clamped(100);
        assert_eq!(cc.len(), c.len());
        for i in 0..c.len() {
            assert_eq!(cc.range(i), c.range(i));
        }
    }

    #[test]
    fn by_weight_single_hub_does_not_starve_tail_chunks() {
        // One vertex carries ~all the weight; the remaining chunks must
        // still each receive at least one vertex.
        let c = Chunks::by_weight(100, 4, |v| if v == 0 { 1_000_000 } else { 1 });
        assert_chunk_invariants(&c, 100);
        assert_eq!(c.range(0), 0..1, "hub chunk should stop right after the hub");
    }
}
