//! Execution coordination: vertex chunking, the barrier-phased worker
//! engine, and convergence detection.
//!
//! The paper's C/C++ implementation "balances the vertices among working
//! threads via allocating each subset of vertices to a separate thread"
//! (§V-C): vertices are split into contiguous chunks of ~|V|/n and each
//! chunk is pinned to one worker. Within a step the asynchronous model
//! lets workers free-run over shared atomics; a lightweight barrier
//! separates the action/demand phase from the migrate/learn phase, and
//! the synchronous (Giraph-style) model additionally freezes label
//! snapshots per step.

pub mod chunks;
pub mod convergence;

pub use chunks::Chunks;
pub use convergence::ConvergenceDetector;

use crossbeam_utils::thread as cb_thread;

/// Run `worker(chunk_index, chunk_range)` on `chunks.len()` scoped
/// threads and wait for all of them. Panics propagate.
///
/// This is the engine the partitioners drive; it is deliberately dumb —
/// all interesting state lives in the shared structures the closures
/// capture (DESIGN.md §6).
pub fn run_chunked<F>(chunks: &Chunks, worker: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    if chunks.len() == 1 {
        // Fast path: no thread spawn for single-threaded runs.
        worker(0, chunks.range(0));
        return;
    }
    cb_thread::scope(|s| {
        for c in 0..chunks.len() {
            let worker = &worker;
            let range = chunks.range(c);
            s.spawn(move |_| worker(c, range));
        }
    })
    .expect("worker thread panicked");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn all_vertices_visited_once() {
        let chunks = Chunks::new(1003, 4);
        let visits: Vec<AtomicUsize> = (0..1003).map(|_| AtomicUsize::new(0)).collect();
        run_chunked(&chunks, |_, range| {
            for v in range {
                visits[v].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(visits.iter().all(|v| v.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_fast_path() {
        let chunks = Chunks::new(10, 1);
        let count = AtomicUsize::new(0);
        run_chunked(&chunks, |c, range| {
            assert_eq!(c, 0);
            count.fetch_add(range.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }
}
