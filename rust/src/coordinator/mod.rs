//! Execution coordination primitives: vertex chunking and convergence
//! detection, plus a one-shot parallel map ([`run_chunked`]) for code
//! that does not need the persistent-worker superstep protocol.
//!
//! The paper's C/C++ implementation "balances the vertices among working
//! threads via allocating each subset of vertices to a separate thread"
//! (§V-C): vertices are split into contiguous chunks and each chunk is
//! pinned to one worker. [`Chunks`] owns that split (vertex- or
//! degree-balanced); the persistent worker pool, barrier protocol and
//! snapshot machinery that drive a full partitioning run live in
//! [`crate::engine`].

pub mod chunks;
pub mod convergence;

pub use chunks::Chunks;
pub use convergence::ConvergenceDetector;

/// Run `worker(chunk_index, chunk_range)` on `chunks.len()` scoped
/// threads and wait for all of them. Panics propagate.
///
/// This is deliberately dumb — all interesting state lives in the shared
/// structures the closures capture. Partitioners do **not** use this:
/// they run on [`crate::engine::run`], which keeps workers alive across
/// steps. No in-crate caller remains; this stays as a small,
/// unit-tested public utility for one-shot parallel sweeps.
pub fn run_chunked<F>(chunks: &Chunks, worker: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    if chunks.len() == 1 {
        // Fast path: no thread spawn for single-threaded runs.
        worker(0, chunks.range(0));
        return;
    }
    std::thread::scope(|s| {
        for c in 0..chunks.len() {
            let worker = &worker;
            let range = chunks.range(c);
            s.spawn(move || worker(c, range));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn all_vertices_visited_once() {
        let chunks = Chunks::new(1003, 4);
        let visits: Vec<AtomicUsize> = (0..1003).map(|_| AtomicUsize::new(0)).collect();
        run_chunked(&chunks, |_, range| {
            for v in range {
                visits[v].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(visits.iter().all(|v| v.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_fast_path() {
        let chunks = Chunks::new(10, 1);
        let count = AtomicUsize::new(0);
        run_chunked(&chunks, |c, range| {
            assert_eq!(c, 0);
            count.fetch_add(range.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }
}
