//! Convergence detection (§IV-D.9): halt when the global score has not
//! improved by at least θ for `window` consecutive steps.
//!
//! Under active-set execution (DESIGN.md §Active-set) the observed
//! score is the mean over *evaluated* vertices, not all of |V|, and an
//! **empty frontier** is a stronger signal than any score window: no
//! vertex can change state, so the run halts immediately
//! ([`ConvergenceDetector::observe_empty_frontier`]).

/// Tracks the global score S^i across steps and fires after `window`
/// consecutive sub-θ improvements.
#[derive(Debug, Clone)]
pub struct ConvergenceDetector {
    theta: f64,
    window: u32,
    last_score: Option<f64>,
    stall: u32,
}

impl ConvergenceDetector {
    pub fn new(theta: f64, window: u32) -> Self {
        assert!(window >= 1);
        ConvergenceDetector { theta, window, last_score: None, stall: 0 }
    }

    /// Feed this step's score; returns `true` when the run should halt.
    pub fn observe(&mut self, score: f64) -> bool {
        let improved = match self.last_score {
            None => true, // first observation never counts as a stall
            Some(prev) => (score - prev) >= self.theta,
        };
        self.last_score = Some(score);
        if improved {
            self.stall = 0;
        } else {
            self.stall += 1;
        }
        let halt = self.stall >= self.window;
        if halt {
            crate::obs::counter_add("engine_halts_converged", 1);
        }
        halt
    }

    /// An empty active frontier: every vertex is settled (labels, λ and
    /// loads can no longer change), which dominates any score-window
    /// evidence — the stall counter saturates and the run halts now.
    /// Always returns `true`; the return mirrors [`Self::observe`] so
    /// the engine's halting sites stay uniform.
    pub fn observe_empty_frontier(&mut self) -> bool {
        self.stall = self.stall.max(self.window);
        crate::obs::counter_add("engine_halts_empty_frontier", 1);
        true
    }

    /// Consecutive stalled steps so far.
    pub fn stalled(&self) -> u32 {
        self.stall
    }

    pub fn reset(&mut self) {
        self.last_score = None;
        self.stall = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halts_after_window_stalls() {
        let mut d = ConvergenceDetector::new(0.001, 3);
        assert!(!d.observe(0.5));
        assert!(!d.observe(0.5)); // stall 1
        assert!(!d.observe(0.5)); // stall 2
        assert!(d.observe(0.5)); // stall 3 -> halt
    }

    #[test]
    fn improvement_resets() {
        let mut d = ConvergenceDetector::new(0.001, 2);
        assert!(!d.observe(0.5));
        assert!(!d.observe(0.5)); // stall 1
        assert!(!d.observe(0.6)); // improvement, reset
        assert!(!d.observe(0.6)); // stall 1
        assert!(d.observe(0.6)); // stall 2 -> halt
    }

    #[test]
    fn sub_theta_improvement_counts_as_stall() {
        let mut d = ConvergenceDetector::new(0.01, 2);
        assert!(!d.observe(0.5));
        assert!(!d.observe(0.505)); // +0.005 < theta => stall
        assert!(d.observe(0.5099));
    }

    #[test]
    fn decreasing_score_stalls() {
        let mut d = ConvergenceDetector::new(0.001, 2);
        assert!(!d.observe(0.5));
        assert!(!d.observe(0.4));
        assert!(d.observe(0.3));
    }

    #[test]
    fn empty_frontier_halts_immediately_and_stays_halted() {
        let mut d = ConvergenceDetector::new(0.001, 5);
        assert!(!d.observe(0.5), "one observation must not halt");
        assert!(d.observe_empty_frontier(), "empty frontier halts now");
        assert!(d.stalled() >= 5, "stall counter saturates to the window");
        // Reset restores normal windowed behaviour.
        d.reset();
        assert!(!d.observe(0.5));
    }

    #[test]
    fn reset_clears_history() {
        let mut d = ConvergenceDetector::new(0.001, 1);
        assert!(!d.observe(0.5));
        assert!(d.observe(0.5));
        d.reset();
        assert!(!d.observe(0.5));
    }
}
