//! Label-propagation scoring functions.
//!
//! * [`normalized`] — the paper's normalized LP (eqs. 10–12): both the
//!   neighbourhood term τ and the penalty term π are normalized to
//!   [0, 1], so neither can dominate (§IV-B) — this is what keeps
//!   Revolver's partitions balanced.
//! * [`spinner`] — Spinner's original scoring (eqs. 3–5), where the
//!   penalty `π̂(l) = b(l)/C` is *unnormalized* against the
//!   neighbourhood term; the baseline whose imbalance the paper
//!   criticises (§V-H.1).
//!
//! Both operate on a caller-provided scratch histogram so the hot loop
//! allocates nothing.

pub mod normalized;
pub mod spinner;

/// Accumulate the neighbour label-weight histogram
/// `hist[l] = Σ_{u∈N(v)} ŵ(u,v)·δ(ψ(u), l)` and the total weight
/// `Σ ŵ(u,v)` for vertex `v`. Shared by both scoring functions.
///
/// `labels_of` maps a neighbour to its current label — the asynchronous
/// engine passes a relaxed atomic read, the synchronous engine a frozen
/// snapshot.
#[inline]
pub fn neighbor_histogram<F>(
    neighbors: &[u32],
    weights: &[f32],
    labels_of: F,
    hist: &mut [f32],
) -> f32
where
    F: Fn(u32) -> u32,
{
    debug_assert_eq!(neighbors.len(), weights.len());
    hist.iter_mut().for_each(|h| *h = 0.0);
    // Fast path: isolated (zero-degree) vertices skip the gather loop
    // entirely — their histogram is all-zero and wsum = 0 (the cleared
    // contract above still holds for callers that reuse `hist`).
    if neighbors.is_empty() {
        return 0.0;
    }
    let mut wsum = 0.0f32;
    for (&u, &w) in neighbors.iter().zip(weights.iter()) {
        let l = labels_of(u) as usize;
        debug_assert!(l < hist.len());
        // SAFETY-equivalent: labels are always < k by construction
        // (PartitionState never stores an out-of-range label); checked
        // in debug builds above.
        hist[l] += w;
        wsum += w;
    }
    wsum
}

/// [`neighbor_histogram`] for callers that reuse one scratch histogram
/// across many vertices: `hist` must be **all-zero on entry**; each
/// label whose entry is first touched is pushed onto `touched`, so the
/// caller restores the all-zero invariant by clearing only those
/// entries — O(deg) instead of O(k) per vertex, which wins when
/// k ≫ average degree (the hot-loop regime of `--parts 32+` on sparse
/// graphs). The accumulation order, and therefore every f32 sum, is
/// identical to the full-clear path (asserted in tests).
#[inline]
pub fn neighbor_histogram_sparse<F>(
    neighbors: &[u32],
    weights: &[f32],
    labels_of: F,
    hist: &mut [f32],
    touched: &mut Vec<u32>,
) -> f32
where
    F: Fn(u32) -> u32,
{
    debug_assert_eq!(neighbors.len(), weights.len());
    debug_assert!(hist.iter().all(|&h| h == 0.0), "hist must be all-zero on entry");
    let mut wsum = 0.0f32;
    for (&u, &w) in neighbors.iter().zip(weights.iter()) {
        let l = labels_of(u) as usize;
        debug_assert!(l < hist.len());
        // Edge weights are strictly positive (Graph::validate), so an
        // entry is zero exactly until its first touch.
        if hist[l] == 0.0 {
            touched.push(l as u32);
        }
        hist[l] += w;
        wsum += w;
    }
    wsum
}

/// Clear exactly the `touched` entries of `hist` (restoring the
/// all-zero invariant [`neighbor_histogram_sparse`] requires) and empty
/// the stack.
#[inline]
pub fn clear_touched(hist: &mut [f32], touched: &mut Vec<u32>) {
    for &l in touched.iter() {
        hist[l as usize] = 0.0;
    }
    touched.clear();
}

/// Integer fast path of [`neighbor_histogram`] for graphs whose edge
/// weights are eq. (4)'s small integers (1 one-directional, 2
/// reciprocated — `Graph::is_weighted() == false`). The f32 histogram
/// then only ever holds integer values, so accumulating in a contiguous
/// `u32` layout streams half the bytes and keeps FP adds out of the
/// gather loop, and converts back losslessly: every partial sum stays
/// far below 2²⁴, where `count as f32` is **bit-identical** to the f32
/// accumulation of the same integers. Returns the integer Σ ŵ(u,v).
#[inline]
pub fn neighbor_histogram_counts<F>(
    neighbors: &[u32],
    weights: &[f32],
    labels_of: F,
    hist: &mut [u32],
) -> u32
where
    F: Fn(u32) -> u32,
{
    debug_assert_eq!(neighbors.len(), weights.len());
    hist.iter_mut().for_each(|h| *h = 0);
    let mut wsum = 0u32;
    for (&u, &w) in neighbors.iter().zip(weights.iter()) {
        let l = labels_of(u) as usize;
        debug_assert!(l < hist.len());
        debug_assert_eq!(w, w as u32 as f32, "counts path needs integer weights");
        let wi = w as u32;
        hist[l] += wi;
        wsum += wi;
    }
    wsum
}

/// Touched-stack variant of [`neighbor_histogram_counts`]; same
/// all-zero-on-entry contract as [`neighbor_histogram_sparse`].
#[inline]
pub fn neighbor_histogram_counts_sparse<F>(
    neighbors: &[u32],
    weights: &[f32],
    labels_of: F,
    hist: &mut [u32],
    touched: &mut Vec<u32>,
) -> u32
where
    F: Fn(u32) -> u32,
{
    debug_assert_eq!(neighbors.len(), weights.len());
    debug_assert!(hist.iter().all(|&h| h == 0), "hist must be all-zero on entry");
    let mut wsum = 0u32;
    for (&u, &w) in neighbors.iter().zip(weights.iter()) {
        let l = labels_of(u) as usize;
        debug_assert!(l < hist.len());
        debug_assert_eq!(w, w as u32 as f32, "counts path needs integer weights");
        if hist[l] == 0 {
            touched.push(l as u32);
        }
        let wi = w as u32;
        hist[l] += wi;
        wsum += wi;
    }
    wsum
}

/// [`clear_touched`] for the u32 count histograms.
#[inline]
pub fn clear_touched_u32(hist: &mut [u32], touched: &mut Vec<u32>) {
    for &l in touched.iter() {
        hist[l as usize] = 0;
    }
    touched.clear();
}

/// Index of the maximum score, first occurrence on ties — the exact
/// semantics of the strict-`>` scan both scoring functions used inline,
/// but written as a fold over the value (max-reduce, then locate) so
/// the reduction loop autovectorizes. `scores` must be non-empty and
/// NaN-free (LP scores are finite by construction).
#[inline]
pub fn argmax(scores: &[f32]) -> usize {
    debug_assert!(!scores.is_empty());
    let mut best = scores[0];
    for &s in &scores[1..] {
        if s > best {
            best = s;
        }
    }
    // First position holding the max — ties resolve to the lowest
    // label, matching the strict-`>` sequential scan.
    scores.iter().position(|&s| s == best).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_accumulates_weights() {
        let neighbors = [0u32, 1, 2, 3];
        let weights = [1.0f32, 2.0, 1.0, 2.0];
        // labels: 0->0, 1->1, 2->0, 3->1
        let mut hist = vec![0.0f32; 2];
        let wsum = neighbor_histogram(&neighbors, &weights, |u| u % 2, &mut hist);
        assert_eq!(wsum, 6.0);
        assert_eq!(hist, vec![2.0, 4.0]);
    }

    #[test]
    fn empty_neighborhood() {
        let mut hist = vec![7.0f32; 3];
        let wsum = neighbor_histogram(&[], &[], |_| 0, &mut hist);
        assert_eq!(wsum, 0.0);
        assert!(hist.iter().all(|&h| h == 0.0), "hist must be cleared");
    }

    #[test]
    fn sparse_histogram_identical_to_full_clear_path() {
        // Satellite acceptance: the touched-stack path must produce the
        // exact same histogram, wsum and (therefore) scores as the
        // full-clear path — same accumulation order, same f32 sums.
        use crate::util::rng::Rng;
        for seed in 0..50u64 {
            let mut rng = Rng::new(seed);
            let k = 2 + rng.below_usize(40);
            let deg = rng.below_usize(12); // k ≫ deg regime included
            let neighbors: Vec<u32> = (0..deg as u32).collect();
            let labels: Vec<u32> = (0..deg).map(|_| rng.below(k as u64) as u32).collect();
            let weights: Vec<f32> = (0..deg).map(|_| 1.0 + rng.next_f32()).collect();

            let mut full = vec![0.0f32; k];
            let w_full =
                neighbor_histogram(&neighbors, &weights, |u| labels[u as usize], &mut full);

            let mut sparse = vec![0.0f32; k];
            let mut touched = Vec::new();
            let w_sparse = neighbor_histogram_sparse(
                &neighbors,
                &weights,
                |u| labels[u as usize],
                &mut sparse,
                &mut touched,
            );
            assert_eq!(w_full, w_sparse, "seed={seed}");
            assert_eq!(full, sparse, "seed={seed}");
            // Touched records exactly the nonzero entries, each once.
            let mut nonzero: Vec<u32> = (0..k as u32)
                .filter(|&l| sparse[l as usize] != 0.0)
                .collect();
            let mut t = touched.clone();
            t.sort_unstable();
            nonzero.sort_unstable();
            assert_eq!(t, nonzero, "seed={seed}");
            // clear_touched restores the all-zero invariant.
            clear_touched(&mut sparse, &mut touched);
            assert!(sparse.iter().all(|&h| h == 0.0), "seed={seed}");
            assert!(touched.is_empty());
        }
    }

    #[test]
    fn count_histograms_bit_exact_vs_f32_unit_weights() {
        // The u32 fast path must reproduce the f32 path exactly on
        // eq.-(4)-weighted graphs (ŵ ∈ {1, 2}): integer-valued f32 sums
        // below 2^24 are exact, so `count as f32` == Σ ŵ in f32.
        use crate::util::rng::Rng;
        for seed in 0..50u64 {
            let mut rng = Rng::new(0xC0 ^ seed);
            let k = 2 + rng.below_usize(40);
            let deg = rng.below_usize(200);
            let neighbors: Vec<u32> = (0..deg as u32).collect();
            let labels: Vec<u32> = (0..deg).map(|_| rng.below(k as u64) as u32).collect();
            let weights: Vec<f32> =
                (0..deg).map(|_| if rng.chance(0.5) { 2.0 } else { 1.0 }).collect();

            let mut hist_f = vec![0.0f32; k];
            let wsum_f =
                neighbor_histogram(&neighbors, &weights, |u| labels[u as usize], &mut hist_f);

            let mut hist_u = vec![0u32; k];
            let wsum_u = neighbor_histogram_counts(
                &neighbors,
                &weights,
                |u| labels[u as usize],
                &mut hist_u,
            );
            assert_eq!(wsum_f, wsum_u as f32, "seed={seed}");
            for l in 0..k {
                assert_eq!(hist_f[l], hist_u[l] as f32, "seed={seed} l={l}");
            }

            let mut hist_s = vec![0u32; k];
            let mut touched = Vec::new();
            let wsum_s = neighbor_histogram_counts_sparse(
                &neighbors,
                &weights,
                |u| labels[u as usize],
                &mut hist_s,
                &mut touched,
            );
            assert_eq!(wsum_u, wsum_s, "seed={seed}");
            assert_eq!(hist_u, hist_s, "seed={seed}");
            let mut t = touched.clone();
            t.sort_unstable();
            let mut nonzero: Vec<u32> =
                (0..k as u32).filter(|&l| hist_s[l as usize] != 0).collect();
            nonzero.sort_unstable();
            assert_eq!(t, nonzero, "seed={seed}");
            clear_touched_u32(&mut hist_s, &mut touched);
            assert!(hist_s.iter().all(|&h| h == 0), "seed={seed}");
            assert!(touched.is_empty());
        }
    }

    #[test]
    fn argmax_matches_strict_gt_scan() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let k = 1 + rng.below_usize(33);
            // Coarse values force frequent ties.
            let xs: Vec<f32> =
                (0..k).map(|_| (rng.below(5) as f32) * 0.25).collect();
            let mut ref_best = 0usize;
            for (i, &x) in xs.iter().enumerate() {
                if x > xs[ref_best] {
                    ref_best = i;
                }
            }
            assert_eq!(argmax(&xs), ref_best, "xs={xs:?}");
        }
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0, 2.0]), 0, "ties go to the first max");
        assert_eq!(argmax(&[-1.0, -0.5, -0.5]), 1);
    }

    #[test]
    fn sparse_histogram_empty_neighborhood_touches_nothing() {
        let mut hist = vec![0.0f32; 4];
        let mut touched = Vec::new();
        let wsum = neighbor_histogram_sparse(&[], &[], |_| 0, &mut hist, &mut touched);
        assert_eq!(wsum, 0.0);
        assert!(touched.is_empty(), "isolated vertex must not touch the histogram");
        assert!(hist.iter().all(|&h| h == 0.0));
    }
}
