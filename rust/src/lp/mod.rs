//! Label-propagation scoring functions.
//!
//! * [`normalized`] — the paper's normalized LP (eqs. 10–12): both the
//!   neighbourhood term τ and the penalty term π are normalized to
//!   [0, 1], so neither can dominate (§IV-B) — this is what keeps
//!   Revolver's partitions balanced.
//! * [`spinner`] — Spinner's original scoring (eqs. 3–5), where the
//!   penalty `π̂(l) = b(l)/C` is *unnormalized* against the
//!   neighbourhood term; the baseline whose imbalance the paper
//!   criticises (§V-H.1).
//!
//! Both operate on a caller-provided scratch histogram so the hot loop
//! allocates nothing.

pub mod normalized;
pub mod spinner;

/// Accumulate the neighbour label-weight histogram
/// `hist[l] = Σ_{u∈N(v)} ŵ(u,v)·δ(ψ(u), l)` and the total weight
/// `Σ ŵ(u,v)` for vertex `v`. Shared by both scoring functions.
///
/// `labels_of` maps a neighbour to its current label — the asynchronous
/// engine passes a relaxed atomic read, the synchronous engine a frozen
/// snapshot.
#[inline]
pub fn neighbor_histogram<F>(
    neighbors: &[u32],
    weights: &[f32],
    labels_of: F,
    hist: &mut [f32],
) -> f32
where
    F: Fn(u32) -> u32,
{
    debug_assert_eq!(neighbors.len(), weights.len());
    hist.iter_mut().for_each(|h| *h = 0.0);
    let mut wsum = 0.0f32;
    for (&u, &w) in neighbors.iter().zip(weights.iter()) {
        let l = labels_of(u) as usize;
        debug_assert!(l < hist.len());
        // SAFETY-equivalent: labels are always < k by construction
        // (PartitionState never stores an out-of-range label); checked
        // in debug builds above.
        hist[l] += w;
        wsum += w;
    }
    wsum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_accumulates_weights() {
        let neighbors = [0u32, 1, 2, 3];
        let weights = [1.0f32, 2.0, 1.0, 2.0];
        // labels: 0->0, 1->1, 2->0, 3->1
        let mut hist = vec![0.0f32; 2];
        let wsum = neighbor_histogram(&neighbors, &weights, |u| u % 2, &mut hist);
        assert_eq!(wsum, 6.0);
        assert_eq!(hist, vec![2.0, 4.0]);
    }

    #[test]
    fn empty_neighborhood() {
        let mut hist = vec![7.0f32; 3];
        let wsum = neighbor_histogram(&[], &[], |_| 0, &mut hist);
        assert_eq!(wsum, 0.0);
        assert!(hist.iter().all(|&h| h == 0.0), "hist must be cleared");
    }
}
