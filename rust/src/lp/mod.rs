//! Label-propagation scoring functions.
//!
//! * [`normalized`] — the paper's normalized LP (eqs. 10–12): both the
//!   neighbourhood term τ and the penalty term π are normalized to
//!   [0, 1], so neither can dominate (§IV-B) — this is what keeps
//!   Revolver's partitions balanced.
//! * [`spinner`] — Spinner's original scoring (eqs. 3–5), where the
//!   penalty `π̂(l) = b(l)/C` is *unnormalized* against the
//!   neighbourhood term; the baseline whose imbalance the paper
//!   criticises (§V-H.1).
//!
//! Both operate on a caller-provided scratch histogram so the hot loop
//! allocates nothing.

pub mod normalized;
pub mod spinner;

/// Accumulate the neighbour label-weight histogram
/// `hist[l] = Σ_{u∈N(v)} ŵ(u,v)·δ(ψ(u), l)` and the total weight
/// `Σ ŵ(u,v)` for vertex `v`. Shared by both scoring functions.
///
/// `labels_of` maps a neighbour to its current label — the asynchronous
/// engine passes a relaxed atomic read, the synchronous engine a frozen
/// snapshot.
#[inline]
pub fn neighbor_histogram<F>(
    neighbors: &[u32],
    weights: &[f32],
    labels_of: F,
    hist: &mut [f32],
) -> f32
where
    F: Fn(u32) -> u32,
{
    debug_assert_eq!(neighbors.len(), weights.len());
    hist.iter_mut().for_each(|h| *h = 0.0);
    // Fast path: isolated (zero-degree) vertices skip the gather loop
    // entirely — their histogram is all-zero and wsum = 0 (the cleared
    // contract above still holds for callers that reuse `hist`).
    if neighbors.is_empty() {
        return 0.0;
    }
    let mut wsum = 0.0f32;
    for (&u, &w) in neighbors.iter().zip(weights.iter()) {
        let l = labels_of(u) as usize;
        debug_assert!(l < hist.len());
        // SAFETY-equivalent: labels are always < k by construction
        // (PartitionState never stores an out-of-range label); checked
        // in debug builds above.
        hist[l] += w;
        wsum += w;
    }
    wsum
}

/// [`neighbor_histogram`] for callers that reuse one scratch histogram
/// across many vertices: `hist` must be **all-zero on entry**; each
/// label whose entry is first touched is pushed onto `touched`, so the
/// caller restores the all-zero invariant by clearing only those
/// entries — O(deg) instead of O(k) per vertex, which wins when
/// k ≫ average degree (the hot-loop regime of `--parts 32+` on sparse
/// graphs). The accumulation order, and therefore every f32 sum, is
/// identical to the full-clear path (asserted in tests).
#[inline]
pub fn neighbor_histogram_sparse<F>(
    neighbors: &[u32],
    weights: &[f32],
    labels_of: F,
    hist: &mut [f32],
    touched: &mut Vec<u32>,
) -> f32
where
    F: Fn(u32) -> u32,
{
    debug_assert_eq!(neighbors.len(), weights.len());
    debug_assert!(hist.iter().all(|&h| h == 0.0), "hist must be all-zero on entry");
    let mut wsum = 0.0f32;
    for (&u, &w) in neighbors.iter().zip(weights.iter()) {
        let l = labels_of(u) as usize;
        debug_assert!(l < hist.len());
        // Edge weights are strictly positive (Graph::validate), so an
        // entry is zero exactly until its first touch.
        if hist[l] == 0.0 {
            touched.push(l as u32);
        }
        hist[l] += w;
        wsum += w;
    }
    wsum
}

/// Clear exactly the `touched` entries of `hist` (restoring the
/// all-zero invariant [`neighbor_histogram_sparse`] requires) and empty
/// the stack.
#[inline]
pub fn clear_touched(hist: &mut [f32], touched: &mut Vec<u32>) {
    for &l in touched.iter() {
        hist[l as usize] = 0.0;
    }
    touched.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_accumulates_weights() {
        let neighbors = [0u32, 1, 2, 3];
        let weights = [1.0f32, 2.0, 1.0, 2.0];
        // labels: 0->0, 1->1, 2->0, 3->1
        let mut hist = vec![0.0f32; 2];
        let wsum = neighbor_histogram(&neighbors, &weights, |u| u % 2, &mut hist);
        assert_eq!(wsum, 6.0);
        assert_eq!(hist, vec![2.0, 4.0]);
    }

    #[test]
    fn empty_neighborhood() {
        let mut hist = vec![7.0f32; 3];
        let wsum = neighbor_histogram(&[], &[], |_| 0, &mut hist);
        assert_eq!(wsum, 0.0);
        assert!(hist.iter().all(|&h| h == 0.0), "hist must be cleared");
    }

    #[test]
    fn sparse_histogram_identical_to_full_clear_path() {
        // Satellite acceptance: the touched-stack path must produce the
        // exact same histogram, wsum and (therefore) scores as the
        // full-clear path — same accumulation order, same f32 sums.
        use crate::util::rng::Rng;
        for seed in 0..50u64 {
            let mut rng = Rng::new(seed);
            let k = 2 + rng.below_usize(40);
            let deg = rng.below_usize(12); // k ≫ deg regime included
            let neighbors: Vec<u32> = (0..deg as u32).collect();
            let labels: Vec<u32> = (0..deg).map(|_| rng.below(k as u64) as u32).collect();
            let weights: Vec<f32> = (0..deg).map(|_| 1.0 + rng.next_f32()).collect();

            let mut full = vec![0.0f32; k];
            let w_full =
                neighbor_histogram(&neighbors, &weights, |u| labels[u as usize], &mut full);

            let mut sparse = vec![0.0f32; k];
            let mut touched = Vec::new();
            let w_sparse = neighbor_histogram_sparse(
                &neighbors,
                &weights,
                |u| labels[u as usize],
                &mut sparse,
                &mut touched,
            );
            assert_eq!(w_full, w_sparse, "seed={seed}");
            assert_eq!(full, sparse, "seed={seed}");
            // Touched records exactly the nonzero entries, each once.
            let mut nonzero: Vec<u32> = (0..k as u32)
                .filter(|&l| sparse[l as usize] != 0.0)
                .collect();
            let mut t = touched.clone();
            t.sort_unstable();
            nonzero.sort_unstable();
            assert_eq!(t, nonzero, "seed={seed}");
            // clear_touched restores the all-zero invariant.
            clear_touched(&mut sparse, &mut touched);
            assert!(sparse.iter().all(|&h| h == 0.0), "seed={seed}");
            assert!(touched.is_empty());
        }
    }

    #[test]
    fn sparse_histogram_empty_neighborhood_touches_nothing() {
        let mut hist = vec![0.0f32; 4];
        let mut touched = Vec::new();
        let wsum = neighbor_histogram_sparse(&[], &[], |_| 0, &mut hist, &mut touched);
        assert_eq!(wsum, 0.0);
        assert!(touched.is_empty(), "isolated vertex must not touch the histogram");
        assert!(hist.iter().all(|&h| h == 0.0));
    }
}
