//! Spinner's original LP scoring (eqs. 3–5) — the state-of-the-art
//! baseline the paper compares against.
//!
//! `ŝcore(v,l) = hist[l]/Σŵ − π̂(l)` with `π̂(l) = b(l)/C`, where the
//! Spinner load `b(l) = Σ_{u∈B(l)} deg(u)` counts **out-degrees** and
//! `C = (1+ε)·|E|/k`.
//!
//! Note on C: the paper's §III-A prints `C = (ε·|E|)/k`, but its own
//! migration rule needs `r(l) = C − b(l) ≥ 0` at the balanced load
//! `b(l) ≈ |E|/k`, and the original Spinner paper (ICDE'17) defines the
//! capacity as `(1+ε)·|E|/k`. We follow the consistent definition and
//! record the discrepancy in DESIGN.md.

/// Spinner's unnormalized penalty vector π̂(l) = b(l)/C (eq. 5).
pub fn penalty_into(loads: &[f32], capacity: f32, out: &mut [f32]) {
    debug_assert_eq!(loads.len(), out.len());
    let inv_c = 1.0 / capacity;
    for (o, &b) in out.iter_mut().zip(loads.iter()) {
        *o = b * inv_c;
    }
}

/// Fill `scores[l] = hist[l]/wsum − π̂[l]` (eq. 3) and return the argmax
/// — Spinner's candidate partition for the vertex.
#[inline]
pub fn score_into(hist: &[f32], wsum: f32, pi_hat: &[f32], scores: &mut [f32]) -> usize {
    debug_assert_eq!(hist.len(), pi_hat.len());
    debug_assert_eq!(hist.len(), scores.len());
    let inv_w = if wsum > 1e-12 { 1.0 / wsum } else { 0.0 };
    // Fill then reduce (autovectorizes; see `normalized::score_into`).
    for l in 0..hist.len() {
        scores[l] = hist[l] * inv_w - pi_hat[l];
    }
    crate::lp::argmax(scores)
}

/// [`score_into`] over a u32 count histogram (unweighted-graph fast
/// path; bit-identical — counts convert to f32 exactly).
#[inline]
pub fn score_counts_into(hist: &[u32], wsum: u32, pi_hat: &[f32], scores: &mut [f32]) -> usize {
    debug_assert_eq!(hist.len(), pi_hat.len());
    debug_assert_eq!(hist.len(), scores.len());
    let inv_w = if wsum > 0 { 1.0 / wsum as f32 } else { 0.0 };
    for l in 0..hist.len() {
        scores[l] = hist[l] as f32 * inv_w - pi_hat[l];
    }
    crate::lp::argmax(scores)
}

/// Migration probability to candidate partition `l` (§III-A): remaining
/// capacity `C − b(l)` over the demanded load `m(l)`, clamped to [0, 1].
#[inline]
pub fn migration_probability(capacity: f32, load: f32, demand: f32) -> f32 {
    if demand <= 0.0 {
        return 1.0;
    }
    let remaining = capacity - load;
    if remaining <= 0.0 {
        return 0.0;
    }
    (remaining / demand).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn penalty_proportional_to_load() {
        let loads = [10.0f32, 40.0];
        let mut pi = vec![0.0f32; 2];
        penalty_into(&loads, 50.0, &mut pi);
        assert!((pi[0] - 0.2).abs() < 1e-6);
        assert!((pi[1] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn score_is_tau_minus_penalty() {
        let hist = [3.0f32, 1.0];
        let pi = [0.5f32, 0.1];
        let mut scores = vec![0.0f32; 2];
        let best = score_into(&hist, 4.0, &pi, &mut scores);
        assert!((scores[0] - 0.25).abs() < 1e-6);
        assert!((scores[1] - 0.15).abs() < 1e-6);
        assert_eq!(best, 0);
    }

    #[test]
    fn unnormalized_penalty_can_dominate() {
        // The paper's §V-H.1 critique: a hot partition's penalty scales
        // with b(l)/C unboundedly, flipping even a 100% neighbour
        // majority — which is exactly what lets Spinner overshoot ε.
        let hist = [4.0f32, 0.0];
        let pi = [1.5f32, 0.0]; // b(0) = 1.5 C
        let mut scores = vec![0.0f32; 2];
        let best = score_into(&hist, 4.0, &pi, &mut scores);
        assert_eq!(best, 1);
    }

    #[test]
    fn score_counts_bit_exact_vs_f32() {
        use crate::util::rng::Rng;
        for seed in 0..40u64 {
            let mut rng = Rng::new(0x59 ^ seed);
            let k = 2 + rng.below_usize(30);
            let counts: Vec<u32> = (0..k).map(|_| rng.below(50) as u32).collect();
            let wsum: u32 = counts.iter().sum();
            let hist_f: Vec<f32> = counts.iter().map(|&c| c as f32).collect();
            let pi: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
            let mut s_f = vec![0.0f32; k];
            let mut s_u = vec![0.0f32; k];
            let best_f = score_into(&hist_f, wsum as f32, &pi, &mut s_f);
            let best_u = score_counts_into(&counts, wsum, &pi, &mut s_u);
            assert_eq!(best_f, best_u, "seed={seed}");
            assert_eq!(s_f, s_u, "seed={seed}");
        }
    }

    #[test]
    fn migration_probability_bounds() {
        assert_eq!(migration_probability(100.0, 120.0, 10.0), 0.0);
        assert_eq!(migration_probability(100.0, 50.0, 0.0), 1.0);
        assert_eq!(migration_probability(100.0, 50.0, 25.0), 1.0);
        let p = migration_probability(100.0, 50.0, 100.0);
        assert!((p - 0.5).abs() < 1e-6);
    }
}
