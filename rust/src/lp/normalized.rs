//! The paper's normalized label propagation (eqs. 10–12).
//!
//! `score(v,l) = (τ(v,l) + π(l)) / 2` with
//! `τ(v,l) = hist[l] / Σŵ` (normalized neighbourhood affinity) and
//! `π(l) = (1 − b(l)/C) / Σᵢ(1 − b(lᵢ)/C)` (normalized remaining
//! capacity), including footnote 1's shift when some partition exceeds
//! its capacity. Numeric semantics mirror `ref.py::score_ref` /
//! `kernels/score.py` exactly so the `--engine xla` path is
//! interchangeable.

/// Compute the normalized penalty vector π (eq. 12 + footnote 1) from
/// the current loads. Computed **once per step** (or per batch) and
/// shared across vertices — π only depends on global loads.
pub fn penalty_into(loads: &[f32], capacity: f32, out: &mut [f32]) {
    debug_assert_eq!(loads.len(), out.len());
    let mut min_pen = f32::INFINITY;
    for (o, &b) in out.iter_mut().zip(loads.iter()) {
        let pen = 1.0 - b / capacity;
        *o = pen;
        if pen < min_pen {
            min_pen = pen;
        }
    }
    // Footnote 1: augment w.r.t. the minimum negative value.
    if min_pen < 0.0 {
        out.iter_mut().for_each(|o| *o -= min_pen);
    }
    let sum: f32 = out.iter().sum();
    let inv = 1.0 / sum.max(1e-12);
    out.iter_mut().for_each(|o| *o *= inv);
}

/// Fill `scores[l] = (hist[l]/wsum + pi[l]) / 2` (eq. 10) and return the
/// argmax — the paper's λ(v) (§IV-D.3).
///
/// `wsum == 0` (isolated vertex) degrades gracefully to τ = 0.
#[inline]
pub fn score_into(hist: &[f32], wsum: f32, pi: &[f32], scores: &mut [f32]) -> usize {
    debug_assert_eq!(hist.len(), pi.len());
    debug_assert_eq!(hist.len(), scores.len());
    let inv_w = if wsum > 1e-12 { 1.0 / wsum } else { 0.0 };
    // Fill then reduce: the plain fill loop and the max-fold both
    // autovectorize, where the fused fill+argmax scan does not. Tie
    // semantics (first max) match the previous strict-`>` scan.
    for l in 0..hist.len() {
        scores[l] = (hist[l] * inv_w + pi[l]) * 0.5;
    }
    crate::lp::argmax(scores)
}

/// [`score_into`] over a u32 count histogram (the unweighted-graph fast
/// path). Counts convert to f32 exactly (degrees ≪ 2²⁴), so this is
/// bit-identical to `score_into(&counts.map(f32), wsum as f32, ..)`.
#[inline]
pub fn score_counts_into(hist: &[u32], wsum: u32, pi: &[f32], scores: &mut [f32]) -> usize {
    debug_assert_eq!(hist.len(), pi.len());
    debug_assert_eq!(hist.len(), scores.len());
    let inv_w = if wsum > 0 { 1.0 / wsum as f32 } else { 0.0 };
    for l in 0..hist.len() {
        scores[l] = (hist[l] as f32 * inv_w + pi[l]) * 0.5;
    }
    crate::lp::argmax(scores)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn penalty_normalized() {
        let loads = [10.0f32, 20.0, 30.0];
        let mut pi = vec![0.0f32; 3];
        penalty_into(&loads, 40.0, &mut pi);
        let sum: f32 = pi.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        // Emptier partitions get higher penalty-term scores.
        assert!(pi[0] > pi[1] && pi[1] > pi[2]);
    }

    #[test]
    fn penalty_overload_footnote1() {
        // b(2) > C => raw penalty negative => shift then normalize.
        let loads = [10.0f32, 20.0, 60.0];
        let mut pi = vec![0.0f32; 3];
        penalty_into(&loads, 40.0, &mut pi);
        assert!(pi.iter().all(|&x| x >= 0.0));
        let sum: f32 = pi.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert_eq!(pi[2], 0.0, "overloaded partition's penalty shifts to zero");
    }

    #[test]
    fn score_prefers_neighbour_majority_when_balanced() {
        let hist = [1.0f32, 5.0, 2.0];
        let pi = [1.0 / 3.0f32; 3];
        let mut scores = vec![0.0f32; 3];
        let best = score_into(&hist, 8.0, &pi, &mut scores);
        assert_eq!(best, 1);
        // All scores in [0, 1].
        assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
    }

    #[test]
    fn score_balances_against_overloaded_majority() {
        // Neighbour majority on partition 0, but 0 is overloaded and 1
        // empty: the normalized penalty must be able to flip the choice
        // when the majority is weak.
        let hist = [1.1f32, 1.0];
        let loads = [99.0f32, 1.0];
        let mut pi = vec![0.0f32; 2];
        penalty_into(&loads, 100.0, &mut pi);
        let mut scores = vec![0.0f32; 2];
        let best = score_into(&hist, 2.1, &pi, &mut scores);
        assert_eq!(best, 1, "scores={scores:?} pi={pi:?}");
    }

    #[test]
    fn isolated_vertex_scores_by_penalty_only() {
        let hist = [0.0f32, 0.0];
        let pi = [0.7f32, 0.3];
        let mut scores = vec![0.0f32; 2];
        let best = score_into(&hist, 0.0, &pi, &mut scores);
        assert_eq!(best, 0);
        assert!((scores[0] - 0.35).abs() < 1e-6);
    }

    #[test]
    fn score_counts_bit_exact_vs_f32() {
        use crate::util::rng::Rng;
        for seed in 0..40u64 {
            let mut rng = Rng::new(0x5C ^ seed);
            let k = 2 + rng.below_usize(30);
            let counts: Vec<u32> = (0..k).map(|_| rng.below(50) as u32).collect();
            let wsum: u32 = counts.iter().sum();
            let hist_f: Vec<f32> = counts.iter().map(|&c| c as f32).collect();
            let mut pi = vec![0.0f32; k];
            let loads: Vec<f32> = (0..k).map(|_| rng.next_f32() * 40.0).collect();
            penalty_into(&loads, 40.0, &mut pi);

            let mut s_f = vec![0.0f32; k];
            let mut s_u = vec![0.0f32; k];
            let best_f = score_into(&hist_f, wsum as f32, &pi, &mut s_f);
            let best_u = score_counts_into(&counts, wsum, &pi, &mut s_u);
            assert_eq!(best_f, best_u, "seed={seed}");
            assert_eq!(s_f, s_u, "seed={seed}");
        }
        // Isolated vertex: wsum = 0 degrades identically.
        let mut s_f = vec![0.0f32; 2];
        let mut s_u = vec![0.0f32; 2];
        let best_f = score_into(&[0.0, 0.0], 0.0, &[0.7, 0.3], &mut s_f);
        let best_u = score_counts_into(&[0, 0], 0, &[0.7, 0.3], &mut s_u);
        assert_eq!(best_f, best_u);
        assert_eq!(s_f, s_u);
    }

    #[test]
    fn matches_python_oracle_values() {
        // Cross-checked against ref.py::score_ref by hand:
        // hist=[3,1], wsum=4, loads=[10,30], C=40
        // tau = [0.75, 0.25]; pen=[0.75,0.25]; pi=[0.75,0.25]
        // score = [(0.75+0.75)/2, (0.25+0.25)/2] = [0.75, 0.25]
        let hist = [3.0f32, 1.0];
        let mut pi = vec![0.0f32; 2];
        penalty_into(&[10.0, 30.0], 40.0, &mut pi);
        let mut scores = vec![0.0f32; 2];
        score_into(&hist, 4.0, &pi, &mut scores);
        assert!((scores[0] - 0.75).abs() < 1e-6, "{scores:?}");
        assert!((scores[1] - 0.25).abs() < 1e-6);
    }
}
