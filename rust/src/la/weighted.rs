//! Weighted learning automaton — the paper's core contribution (§IV-A,
//! eqs. 8–9).
//!
//! Unlike the classic automaton, which reinforces a single action per
//! step, the weighted automaton applies **all m reinforcement signals in
//! one step**, each scaled by a weight; the reward half and the penalty
//! half of the weight vector each sum to 1 (so Σw = 2). The update is a
//! sequential sweep of eq. (8)/(9) over the m signals — the paper's m²
//! formulation — followed by a float-drift renormalization. The penalty
//! redistribution term is weighted per receiving element (`β·w_j/(m−1)`,
//! eq. (9)'s printed `w_j` subscript — see the comment in [`WeightedLa::update`]).
//!
//! This implementation is kept **bit-for-bit semantically identical** to
//! the Python oracle `python/compile/kernels/ref.py::la_update_ref` (and
//! hence the Pallas kernel): same sweep order, same clamps, same f32
//! arithmetic. The `--engine xla` parity tests rely on this.

use super::{roulette, Signal};
use crate::util::rng::Rng;

/// Minimum probability kept after renormalization (matches ref.py).
const P_FLOOR: f32 = 1e-12;

/// A weighted learning automaton over `m` actions.
///
/// The probability vector is stored externally in a flat slab (one slab
/// per coordinator chunk — see DESIGN.md §6) for cache density; this
/// type provides the *operations* over a `&mut [f32]` row.
pub struct WeightedLa;

impl WeightedLa {
    /// Initialize a row to the uniform distribution (§IV-C step 3).
    pub fn init(probs: &mut [f32]) {
        let m = probs.len();
        debug_assert!(m >= 2);
        let u = 1.0 / m as f32;
        probs.iter_mut().for_each(|p| *p = u);
    }

    /// Draw an action via the roulette wheel.
    #[inline]
    pub fn select(probs: &[f32], rng: &mut Rng) -> usize {
        roulette::spin(probs, rng)
    }

    /// Apply the full weighted update: sweep eq. (8)/(9) over all m
    /// signals in index order, then renormalize.
    ///
    /// * `probs` — the automaton's probability row (modified in place).
    /// * `weights` — weight vector W(n); each half should sum to 1
    ///   (see [`super::signal`]). Entries in [0, 1].
    /// * `signals` — reinforcement signal per action.
    /// * `alpha`, `beta` — reward/penalty learning rates.
    pub fn update(
        probs: &mut [f32],
        weights: &[f32],
        signals: &[Signal],
        alpha: f32,
        beta: f32,
    ) {
        let m = probs.len();
        debug_assert_eq!(weights.len(), m);
        debug_assert_eq!(signals.len(), m);
        debug_assert!(m >= 2);
        let pen_spread = beta / (m as f32 - 1.0);

        // Each pass applies one uniform vector operation to the whole
        // row and then patches the diagonal element — branchless inner
        // loops the compiler auto-vectorizes (perf log P1: ~3× over the
        // per-element `if j == i` form, identical arithmetic).
        for i in 0..m {
            let wi = weights[i];
            match signals[i] {
                Signal::Reward => {
                    // eq. (8): p_i += α·w_i·(1-p_i); p_j *= (1-α·w_i).
                    let scale = 1.0 - alpha * wi;
                    let pi_new = probs[i] + alpha * wi * (1.0 - probs[i]);
                    for p in probs.iter_mut() {
                        *p *= scale;
                    }
                    probs[i] = pi_new;
                }
                Signal::Penalty => {
                    // eq. (9): p_i *= (1-β·w_i);
                    //          p_j = p_j·(1-β·w_i) + β·w_j/(m-1).
                    // The additive redistribution is weighted by the
                    // *receiving* element's weight w_j — eq. (9) as
                    // printed subscripts the weight with j, and the
                    // unweighted β/(m-1) variant hands probability mass
                    // back to known-bad actions every pass, freezing the
                    // automaton at a high noise floor (DESIGN.md F4).
                    let scale = 1.0 - beta * wi;
                    let pi_new = probs[i] * scale;
                    for (p, &w) in probs.iter_mut().zip(weights.iter()) {
                        *p = *p * scale + pen_spread * w;
                    }
                    probs[i] = pi_new;
                }
            }
        }

        // Renormalize (identical to ref.py: clamp then divide).
        let mut sum = 0.0f32;
        for p in probs.iter_mut() {
            if *p < P_FLOOR {
                *p = P_FLOOR;
            }
            sum += *p;
        }
        let inv = 1.0 / sum;
        probs.iter_mut().for_each(|p| *p *= inv);
    }

    /// Index of the most probable action.
    pub fn argmax(probs: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_p = probs[0];
        for (i, &p) in probs.iter().enumerate().skip(1) {
            if p > best_p {
                best_p = p;
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::signal::build_signals;

    fn uniform(m: usize) -> Vec<f32> {
        vec![1.0 / m as f32; m]
    }

    #[test]
    fn sum_stays_one() {
        let m = 8;
        let mut p = uniform(m);
        let raw: Vec<f32> = (0..m).map(|i| i as f32 / m as f32).collect();
        let (w, s) = build_signals(&raw);
        WeightedLa::update(&mut p, &w, &s, 1.0, 0.1);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "sum={sum}");
    }

    #[test]
    fn heavily_rewarded_action_rises() {
        let m = 4;
        let mut p = uniform(m);
        // Action 3 gets all the reward weight, others split penalty.
        let w = [1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0, 1.0];
        let s = [Signal::Penalty, Signal::Penalty, Signal::Penalty, Signal::Reward];
        for _ in 0..20 {
            WeightedLa::update(&mut p, &w, &s, 0.5, 0.1);
        }
        assert_eq!(WeightedLa::argmax(&p), 3);
        assert!(p[3] > 0.8, "p={p:?}");
    }

    #[test]
    fn probabilities_stay_positive() {
        let m = 16;
        let mut p = uniform(m);
        let raw: Vec<f32> = (0..m).map(|i| if i == 0 { 1.0 } else { 0.0 }).collect();
        let (w, s) = build_signals(&raw);
        for _ in 0..200 {
            WeightedLa::update(&mut p, &w, &s, 1.0, 0.1);
        }
        assert!(p.iter().all(|&x| x > 0.0));
        assert!(p.iter().all(|&x| x.is_finite()));
    }

    #[test]
    fn zero_rates_identity_up_to_renorm() {
        let m = 6;
        let mut p = vec![0.3, 0.1, 0.2, 0.15, 0.15, 0.1];
        let before = p.clone();
        let raw: Vec<f32> = (0..m).map(|i| i as f32).collect();
        let (w, s) = build_signals(&raw);
        WeightedLa::update(&mut p, &w, &s, 0.0, 0.0);
        for (a, b) in p.iter().zip(before.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn scalability_uniformity_vs_classic() {
        // §V-I: with many actions, the weighted update must not collapse
        // the distribution onto one action after a single mixed step the
        // way classic single-reward updates do with large alpha.
        let m = 256;
        let mut p = uniform(m);
        let raw: Vec<f32> = (0..m).map(|i| (i % 7) as f32).collect();
        let (w, s) = build_signals(&raw);
        WeightedLa::update(&mut p, &w, &s, 1.0, 0.1);
        let max = p.iter().cloned().fold(0.0f32, f32::max);
        assert!(max < 0.5, "weighted update should spread mass, max={max}");
    }

    #[test]
    fn matches_naive_transcription() {
        // Independent naive transcription of eqs. (8)-(9) in f64.
        let m = 5;
        let mut p = vec![0.2f32; m];
        let w = [0.5, 0.5, 0.4, 0.3, 0.3];
        let s = [
            Signal::Reward,
            Signal::Reward,
            Signal::Penalty,
            Signal::Penalty,
            Signal::Penalty,
        ];
        let (alpha, beta) = (1.0f32, 0.1f32);

        let mut q: Vec<f64> = p.iter().map(|&x| x as f64).collect();
        for i in 0..m {
            let wi = w[i] as f64;
            let mut next = q.clone();
            match s[i] {
                Signal::Reward => {
                    for j in 0..m {
                        next[j] = if j == i {
                            q[j] + alpha as f64 * wi * (1.0 - q[j])
                        } else {
                            q[j] * (1.0 - alpha as f64 * wi)
                        };
                    }
                }
                Signal::Penalty => {
                    for j in 0..m {
                        next[j] = if j == i {
                            q[j] * (1.0 - beta as f64 * wi)
                        } else {
                            q[j] * (1.0 - beta as f64 * wi)
                                + beta as f64 * w[j] as f64 / (m as f64 - 1.0)
                        };
                    }
                }
            }
            q = next;
        }
        let qs: f64 = q.iter().sum();
        let q_norm: Vec<f64> = q.iter().map(|x| x / qs).collect();

        WeightedLa::update(&mut p, &w, &s, alpha, beta);
        for (a, b) in p.iter().zip(q_norm.iter()) {
            assert!((*a as f64 - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
