//! Classic variable-structure learning automaton (paper §III-B,
//! eqs. 6–7) — the baseline the weighted automaton improves on (§IV-A,
//! ablated in E5).

use super::{roulette, Signal};
use crate::util::rng::Rng;

/// Textbook L_{R-P} automaton over `m` actions.
#[derive(Debug, Clone)]
pub struct ClassicLa {
    probs: Vec<f32>,
}

impl ClassicLa {
    /// Uniform initial distribution 1/m (§IV-C step 3).
    pub fn new(m: usize) -> Self {
        assert!(m >= 2, "need at least 2 actions");
        ClassicLa { probs: vec![1.0 / m as f32; m] }
    }

    #[inline]
    pub fn num_actions(&self) -> usize {
        self.probs.len()
    }

    #[inline]
    pub fn probabilities(&self) -> &[f32] {
        &self.probs
    }

    /// Draw an action via the roulette wheel.
    #[inline]
    pub fn select(&self, rng: &mut Rng) -> usize {
        roulette::spin(&self.probs, rng)
    }

    /// Apply eq. (6) (reward) or eq. (7) (penalty) for action `i`.
    pub fn update(&mut self, i: usize, signal: Signal, alpha: f32, beta: f32) {
        let m = self.probs.len();
        debug_assert!(i < m);
        match signal {
            Signal::Reward => {
                for j in 0..m {
                    if j == i {
                        self.probs[j] += alpha * (1.0 - self.probs[j]);
                    } else {
                        self.probs[j] *= 1.0 - alpha;
                    }
                }
            }
            Signal::Penalty => {
                let spread = beta / (m as f32 - 1.0);
                for j in 0..m {
                    if j == i {
                        self.probs[j] *= 1.0 - beta;
                    } else {
                        self.probs[j] = self.probs[j] * (1.0 - beta) + spread;
                    }
                }
            }
        }
    }

    /// Index of the current most probable action.
    pub fn argmax(&self) -> usize {
        self.probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum(la: &ClassicLa) -> f32 {
        la.probabilities().iter().sum()
    }

    #[test]
    fn initial_uniform() {
        let la = ClassicLa::new(4);
        assert!(la.probabilities().iter().all(|&p| (p - 0.25).abs() < 1e-6));
    }

    #[test]
    fn reward_conserves_sum() {
        let mut la = ClassicLa::new(5);
        la.update(2, Signal::Reward, 0.3, 0.1);
        assert!((sum(&la) - 1.0).abs() < 1e-5, "sum={}", sum(&la));
        assert!(la.probabilities()[2] > 0.2);
    }

    #[test]
    fn penalty_conserves_sum() {
        let mut la = ClassicLa::new(5);
        la.update(2, Signal::Penalty, 0.3, 0.1);
        assert!((sum(&la) - 1.0).abs() < 1e-5, "sum={}", sum(&la));
        assert!(la.probabilities()[2] < 0.2);
    }

    #[test]
    fn repeated_reward_converges() {
        let mut la = ClassicLa::new(8);
        for _ in 0..100 {
            la.update(3, Signal::Reward, 0.1, 0.05);
        }
        assert!(la.probabilities()[3] > 0.99);
        assert_eq!(la.argmax(), 3);
    }

    #[test]
    fn selection_tracks_probabilities() {
        let mut la = ClassicLa::new(3);
        for _ in 0..50 {
            la.update(1, Signal::Reward, 0.2, 0.1);
        }
        let mut rng = Rng::new(7);
        let hits = (0..1000).filter(|_| la.select(&mut rng) == 1).count();
        assert!(hits > 950, "hits={hits}");
    }

    #[test]
    #[should_panic]
    fn single_action_rejected() {
        ClassicLa::new(1);
    }
}
