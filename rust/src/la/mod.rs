//! Learning automata (the RL substrate, paper §III-B and §IV-A).
//!
//! * [`classic`] — the textbook variable-structure automaton with the
//!   single-action L_{R-P} update (eqs. 6–7); kept as the ablation
//!   baseline for §V-I's scalability claim.
//! * [`weighted`] — the paper's contribution: the *weighted* automaton
//!   whose update distributes reinforcement across the whole action set
//!   via a weight vector with each half (reward/penalty) summing to 1
//!   (eqs. 8–9).
//! * [`signal`] — construction of the weight vector and reinforcement
//!   signals from neighbour feedback (eq. 13 + §IV-D.6 mean split).
//! * [`roulette`] — probability-proportional action sampling.

pub mod classic;
pub mod roulette;
pub mod signal;
pub mod weighted;

/// Reinforcement signal per action: the paper encodes reward as 0 and
/// penalty as 1 (§III-B), which we keep for fidelity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signal {
    Reward,
    Penalty,
}

impl Signal {
    #[inline]
    pub fn is_reward(self) -> bool {
        matches!(self, Signal::Reward)
    }
}
