//! Weight-vector and reinforcement-signal construction (§IV-D.5/6).
//!
//! Per step, vertex `v` accumulates a raw weight per partition from its
//! neighbours' best-score labels (eq. 13): neighbour `u` whose λ(u) = l
//! contributes ŵ(u,v) to `raw[l]` if v's LA chose l (δ(ψ(v), λ(u)) = 1),
//! or 1 if partition l still has positive migration probability.
//!
//! The raw vector is then split at its **mean**: entries above the mean
//! become rewards (r=0), the rest penalties (r=1). Each entry's weight
//! is its **deviation from the mean** |w − mean|, and each half is
//! normalized to sum 1 so Σw = 2 as eqs. (8)-(9) require.
//!
//! Deviation-proportional weights (rather than raw-proportional) are the
//! disambiguation that makes the mean split meaningful: an entry sitting
//! exactly at the mean carries no signal, an entry far above it carries
//! a strong reward — without this, entries hovering near the mean flip
//! between reward and penalty with near-maximal weights and the automata
//! never settle (DESIGN.md §Fidelity-notes F3).
//!
//! Semantics mirror `ref.py::signal_ref` exactly (strict `>` mean
//! comparison, degenerate halves fall back to uniform-over-members).

use super::Signal;

/// Split `raw` at its mean and half-normalize the deviations.
///
/// Returns the normalized weight vector and per-action signals.
pub fn build_signals(raw: &[f32]) -> (Vec<f32>, Vec<Signal>) {
    let mut w = vec![0.0f32; raw.len()];
    let mut s = vec![Signal::Penalty; raw.len()];
    build_signals_into(raw, &mut w, &mut s);
    (w, s)
}

/// Allocation-free variant for the hot path: writes into caller scratch.
pub fn build_signals_into(raw: &[f32], w_out: &mut [f32], s_out: &mut [Signal]) {
    let m = raw.len();
    debug_assert!(m >= 2);
    debug_assert_eq!(w_out.len(), m);
    debug_assert_eq!(s_out.len(), m);

    let mean: f32 = raw.iter().sum::<f32>() / m as f32;

    let mut rew_sum = 0.0f32;
    let mut rew_cnt = 0u32;
    let mut pen_sum = 0.0f32;
    let mut pen_cnt = 0u32;
    for (i, &x) in raw.iter().enumerate() {
        let dev = (x - mean).abs();
        w_out[i] = dev;
        if x > mean {
            s_out[i] = Signal::Reward;
            rew_sum += dev;
            rew_cnt += 1;
        } else {
            s_out[i] = Signal::Penalty;
            pen_sum += dev;
            pen_cnt += 1;
        }
    }

    // Half-normalization with the same degenerate-half fallbacks as
    // ref.py: positive sum -> scale by sum; zero sum -> uniform over the
    // half's members (empty half -> nothing to write).
    for i in 0..m {
        let (sum, cnt) = match s_out[i] {
            Signal::Reward => (rew_sum, rew_cnt),
            Signal::Penalty => (pen_sum, pen_cnt),
        };
        w_out[i] = if sum > 0.0 {
            w_out[i] / sum
        } else if cnt > 0 {
            1.0 / cnt as f32
        } else {
            0.0
        };
    }
}

/// [`build_signals_into`] over the *implicit* raw vector
/// `raw[i] = base[i] + overlay[i]`, without materializing it.
///
/// This is the sparse-seeding fast path for eq. 13: the hot loop keeps
/// the dense `scores` (base) untouched and accumulates the neighbour
/// modulation into a zeroed `overlay` scratch cleared via its touched
/// stack — O(deg) writes instead of an O(k) `copy_from_slice` per
/// vertex. Each pass recomputes `base[i] + overlay[i]`; f32 addition is
/// deterministic, so the result is **bit-identical** to calling
/// [`build_signals_into`] on the precomputed sum (asserted in tests).
pub fn build_signals_overlay_into(
    base: &[f32],
    overlay: &[f32],
    w_out: &mut [f32],
    s_out: &mut [Signal],
) {
    let m = base.len();
    debug_assert!(m >= 2);
    debug_assert_eq!(overlay.len(), m);
    debug_assert_eq!(w_out.len(), m);
    debug_assert_eq!(s_out.len(), m);

    let mut sum = 0.0f32;
    for i in 0..m {
        sum += base[i] + overlay[i];
    }
    let mean: f32 = sum / m as f32;

    let mut rew_sum = 0.0f32;
    let mut rew_cnt = 0u32;
    let mut pen_sum = 0.0f32;
    let mut pen_cnt = 0u32;
    for i in 0..m {
        let x = base[i] + overlay[i];
        let dev = (x - mean).abs();
        w_out[i] = dev;
        if x > mean {
            s_out[i] = Signal::Reward;
            rew_sum += dev;
            rew_cnt += 1;
        } else {
            s_out[i] = Signal::Penalty;
            pen_sum += dev;
            pen_cnt += 1;
        }
    }

    for i in 0..m {
        let (sum, cnt) = match s_out[i] {
            Signal::Reward => (rew_sum, rew_cnt),
            Signal::Penalty => (pen_sum, pen_cnt),
        };
        w_out[i] = if sum > 0.0 {
            w_out[i] / sum
        } else if cnt > 0 {
            1.0 / cnt as f32
        } else {
            0.0
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn half_sums(w: &[f32], s: &[Signal]) -> (f32, f32) {
        let mut rew = 0.0;
        let mut pen = 0.0;
        for (x, sig) in w.iter().zip(s.iter()) {
            match sig {
                Signal::Reward => rew += x,
                Signal::Penalty => pen += x,
            }
        }
        (rew, pen)
    }

    #[test]
    fn halves_sum_to_one() {
        let raw = [0.9f32, 0.1, 0.5, 0.7, 0.05, 0.3];
        let (w, s) = build_signals(&raw);
        let (rew, pen) = half_sums(&w, &s);
        assert!((rew - 1.0).abs() < 1e-5, "rew={rew}");
        assert!((pen - 1.0).abs() < 1e-5, "pen={pen}");
        let total: f32 = w.iter().sum();
        assert!((total - 2.0).abs() < 1e-5);
    }

    #[test]
    fn above_mean_is_reward() {
        let raw = [1.0f32, 0.0, 0.0, 0.0];
        let (_, s) = build_signals(&raw);
        assert_eq!(s[0], Signal::Reward);
        assert!(s[1..].iter().all(|&x| x == Signal::Penalty));
    }

    #[test]
    fn all_equal_all_penalty() {
        // Strict > mean: equal weights mean nothing is rewarded; the
        // empty reward half contributes 0 and the penalty half is
        // normalized over everything.
        let raw = [0.5f32; 4];
        let (w, s) = build_signals(&raw);
        assert!(s.iter().all(|&x| x == Signal::Penalty));
        let total: f32 = w.iter().sum();
        assert!((total - 1.0).abs() < 1e-5, "only penalty half populated");
    }

    #[test]
    fn all_zero_uniform_penalty() {
        let raw = [0.0f32; 5];
        let (w, s) = build_signals(&raw);
        assert!(s.iter().all(|&x| x == Signal::Penalty));
        assert!(w.iter().all(|&x| (x - 0.2).abs() < 1e-6));
    }

    #[test]
    fn zero_sum_reward_half_impossible_but_zero_pen_half_uniform() {
        // Penalty half with all-zero raw values: uniform over members.
        let raw = [1.0f32, 0.0, 0.0];
        let (w, s) = build_signals(&raw);
        assert_eq!(s[0], Signal::Reward);
        assert!((w[0] - 1.0).abs() < 1e-6);
        assert!((w[1] - 0.5).abs() < 1e-6);
        assert!((w[2] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn overlay_variant_bit_identical_to_dense_sum() {
        use crate::util::rng::Rng;
        for seed in 0..60u64 {
            let mut rng = Rng::new(0x0E ^ seed);
            let k = 2 + rng.below_usize(30);
            let base: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
            // Sparse overlay: most entries zero, as the modulation loop
            // produces (only labels of v's neighbours are touched).
            let overlay: Vec<f32> = (0..k)
                .map(|_| if rng.chance(0.3) { rng.next_f32() } else { 0.0 })
                .collect();
            let dense: Vec<f32> =
                base.iter().zip(&overlay).map(|(&b, &o)| b + o).collect();

            let mut w1 = vec![0.0f32; k];
            let mut s1 = vec![Signal::Penalty; k];
            build_signals_into(&dense, &mut w1, &mut s1);

            let mut w2 = vec![0.0f32; k];
            let mut s2 = vec![Signal::Penalty; k];
            build_signals_overlay_into(&base, &overlay, &mut w2, &mut s2);
            assert_eq!(w1, w2, "seed={seed}");
            assert_eq!(s1, s2, "seed={seed}");
        }
    }

    #[test]
    fn into_variant_matches() {
        let raw = [0.3f32, 0.9, 0.2, 0.8, 0.1];
        let (w1, s1) = build_signals(&raw);
        let mut w2 = vec![0.0; 5];
        let mut s2 = vec![Signal::Penalty; 5];
        build_signals_into(&raw, &mut w2, &mut s2);
        assert_eq!(w1, w2);
        assert_eq!(s1, s2);
    }
}
