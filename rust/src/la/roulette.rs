//! Roulette-wheel (fitness-proportional) selection over a probability
//! vector — how an automaton draws its action (§III-B, citing Goldberg's
//! probability matching).

use crate::util::rng::Rng;

/// Draw an index proportionally to `probs` (assumed non-negative; need
/// not be exactly normalized — the draw is scaled by the actual sum).
///
/// Returns the last non-zero-probability index if accumulated rounding
/// leaves the wheel short (guaranteeing a valid index).
#[inline]
pub fn spin(probs: &[f32], rng: &mut Rng) -> usize {
    debug_assert!(!probs.is_empty());
    let total: f32 = probs.iter().sum();
    if total <= 0.0 {
        // Degenerate distribution: fall back to uniform.
        return rng.below_usize(probs.len());
    }
    let mut target = rng.next_f32() * total;
    let mut last_nonzero = 0usize;
    for (i, &p) in probs.iter().enumerate() {
        if p > 0.0 {
            last_nonzero = i;
            if target < p {
                return i;
            }
            target -= p;
        }
    }
    last_nonzero
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_distribution() {
        let probs = [0.1f32, 0.6, 0.3];
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[spin(&probs, &mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!(
                (frac - probs[i] as f64).abs() < 0.01,
                "action {i}: {frac} vs {}",
                probs[i]
            );
        }
    }

    #[test]
    fn zero_probability_never_drawn() {
        let probs = [0.0f32, 1.0, 0.0];
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            assert_eq!(spin(&probs, &mut rng), 1);
        }
    }

    #[test]
    fn unnormalized_ok() {
        let probs = [2.0f32, 6.0, 2.0];
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..50_000 {
            counts[spin(&probs, &mut rng)] += 1;
        }
        let frac1 = counts[1] as f64 / 50_000.0;
        assert!((frac1 - 0.6).abs() < 0.02, "{frac1}");
    }

    #[test]
    fn degenerate_all_zero_uniform() {
        let probs = [0.0f32; 4];
        let mut rng = Rng::new(4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(spin(&probs, &mut rng));
        }
        assert!(seen.len() > 1, "all-zero wheel should fall back to uniform");
    }

    #[test]
    fn single_action() {
        let mut rng = Rng::new(5);
        assert_eq!(spin(&[1.0], &mut rng), 0);
    }
}
