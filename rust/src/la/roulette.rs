//! Roulette-wheel (fitness-proportional) selection over a probability
//! vector — how an automaton draws its action (§III-B, citing Goldberg's
//! probability matching).

use crate::util::rng::Rng;

/// Draw an index proportionally to `probs` (assumed non-negative; need
/// not be exactly normalized — the draw is scaled by the actual sum).
///
/// Returns the last non-zero-probability index if accumulated rounding
/// leaves the wheel short (guaranteeing a valid index).
#[inline]
pub fn spin(probs: &[f32], rng: &mut Rng) -> usize {
    debug_assert!(!probs.is_empty());
    let total: f32 = probs.iter().sum();
    if total <= 0.0 {
        // Degenerate distribution: fall back to uniform.
        return rng.below_usize(probs.len());
    }
    let mut target = rng.next_f32() * total;
    let mut last_nonzero = 0usize;
    for (i, &p) in probs.iter().enumerate() {
        if p > 0.0 {
            last_nonzero = i;
            if target < p {
                return i;
            }
            target -= p;
        }
    }
    last_nonzero
}

/// [`spin`] over a u16 fixed-point row (the quantized `ProbSlab`
/// format, q = round(p·65535)). The wheel spins directly on the
/// integer weights — one u64 draw, no dequantization, no FP in the
/// walk — with the same guarantees as the f32 wheel: zero-weight
/// actions are never drawn, a degenerate all-zero row falls back to
/// uniform, and accumulated shortfall lands on the last non-zero index.
#[inline]
pub fn spin_u16(probs: &[u16], rng: &mut Rng) -> usize {
    debug_assert!(!probs.is_empty());
    let total: u32 = probs.iter().map(|&p| p as u32).sum();
    if total == 0 {
        return rng.below_usize(probs.len());
    }
    let mut target = rng.below(total as u64) as u32;
    let mut last_nonzero = 0usize;
    for (i, &p) in probs.iter().enumerate() {
        let p = p as u32;
        if p > 0 {
            last_nonzero = i;
            if target < p {
                return i;
            }
            target -= p;
        }
    }
    last_nonzero
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_distribution() {
        let probs = [0.1f32, 0.6, 0.3];
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[spin(&probs, &mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!(
                (frac - probs[i] as f64).abs() < 0.01,
                "action {i}: {frac} vs {}",
                probs[i]
            );
        }
    }

    #[test]
    fn zero_probability_never_drawn() {
        let probs = [0.0f32, 1.0, 0.0];
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            assert_eq!(spin(&probs, &mut rng), 1);
        }
    }

    #[test]
    fn unnormalized_ok() {
        let probs = [2.0f32, 6.0, 2.0];
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..50_000 {
            counts[spin(&probs, &mut rng)] += 1;
        }
        let frac1 = counts[1] as f64 / 50_000.0;
        assert!((frac1 - 0.6).abs() < 0.02, "{frac1}");
    }

    #[test]
    fn degenerate_all_zero_uniform() {
        let probs = [0.0f32; 4];
        let mut rng = Rng::new(4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(spin(&probs, &mut rng));
        }
        assert!(seen.len() > 1, "all-zero wheel should fall back to uniform");
    }

    #[test]
    fn single_action() {
        let mut rng = Rng::new(5);
        assert_eq!(spin(&[1.0], &mut rng), 0);
    }

    #[test]
    fn u16_respects_distribution() {
        // q16 encoding of [0.1, 0.6, 0.3].
        let probs = [6554u16, 39321, 19661];
        let expect = [0.1f64, 0.6, 0.3];
        let mut rng = Rng::new(21);
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[spin_u16(&probs, &mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!((frac - expect[i]).abs() < 0.01, "action {i}: {frac}");
        }
    }

    #[test]
    fn u16_zero_weight_never_drawn() {
        let probs = [0u16, 65535, 0];
        let mut rng = Rng::new(22);
        for _ in 0..1000 {
            assert_eq!(spin_u16(&probs, &mut rng), 1);
        }
    }

    #[test]
    fn u16_degenerate_all_zero_uniform() {
        let probs = [0u16; 4];
        let mut rng = Rng::new(23);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(spin_u16(&probs, &mut rng));
        }
        assert!(seen.len() > 1, "all-zero wheel should fall back to uniform");
    }

    #[test]
    fn u16_single_and_shortfall() {
        let mut rng = Rng::new(24);
        assert_eq!(spin_u16(&[7], &mut rng), 0);
        // Trailing zeros: the draw can never land past the last
        // non-zero entry.
        let probs = [1u16, 1, 0, 0];
        for _ in 0..1000 {
            assert!(spin_u16(&probs, &mut rng) < 2);
        }
    }
}
