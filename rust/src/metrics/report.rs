//! Experiment result reporting: pretty tables for the terminal, CSV for
//! plotting, JSON for machine consumption (the bench harness emits all
//! three).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// One experiment row: (graph, algorithm, k) -> metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultRow {
    pub graph: String,
    pub algorithm: String,
    pub parts: u32,
    pub local_edges: f64,
    pub max_normalized_load: f64,
    pub steps: u32,
    pub wall_time_s: f64,
    pub runs: u32,
}

/// Accumulates rows and renders them in the three output formats.
#[derive(Debug, Default)]
pub struct Report {
    rows: Vec<ResultRow>,
}

impl Report {
    pub fn new() -> Self {
        Report::default()
    }

    pub fn push(&mut self, row: ResultRow) {
        self.rows.push(row);
    }

    pub fn rows(&self) -> &[ResultRow] {
        &self.rows
    }

    /// CSV with a fixed header (matches the bench harness' plot scripts).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "graph,algorithm,parts,local_edges,max_normalized_load,steps,wall_time_s,runs\n",
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{},{},{},{:.6},{:.6},{},{:.3},{}\n",
                r.graph,
                r.algorithm,
                r.parts,
                r.local_edges,
                r.max_normalized_load,
                r.steps,
                r.wall_time_s,
                r.runs
            ));
        }
        out
    }

    /// JSON array of row objects.
    pub fn to_json(&self) -> String {
        let arr: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("graph".into(), Json::Str(r.graph.clone()));
                m.insert("algorithm".into(), Json::Str(r.algorithm.clone()));
                m.insert("parts".into(), Json::Num(r.parts as f64));
                m.insert("local_edges".into(), Json::Num(r.local_edges));
                m.insert(
                    "max_normalized_load".into(),
                    Json::Num(r.max_normalized_load),
                );
                m.insert("steps".into(), Json::Num(r.steps as f64));
                m.insert("wall_time_s".into(), Json::Num(r.wall_time_s));
                m.insert("runs".into(), Json::Num(r.runs as f64));
                Json::Obj(m)
            })
            .collect();
        Json::Arr(arr).to_string()
    }

    /// Figure-3-style grouped table: per graph, one row per k with one
    /// column pair (local edges, max-norm load) per algorithm.
    pub fn to_table(&self) -> String {
        let mut algos: Vec<String> = Vec::new();
        for r in &self.rows {
            if !algos.contains(&r.algorithm) {
                algos.push(r.algorithm.clone());
            }
        }
        let mut graphs: Vec<String> = Vec::new();
        for r in &self.rows {
            if !graphs.contains(&r.graph) {
                graphs.push(r.graph.clone());
            }
        }

        let mut by_key: BTreeMap<(String, u32, String), &ResultRow> = BTreeMap::new();
        let mut parts: Vec<u32> = Vec::new();
        for r in &self.rows {
            by_key.insert((r.graph.clone(), r.parts, r.algorithm.clone()), r);
            if !parts.contains(&r.parts) {
                parts.push(r.parts);
            }
        }
        parts.sort_unstable();

        let mut out = String::new();
        for g in &graphs {
            out.push_str(&format!("=== {} — local edges | max normalized load ===\n", g));
            out.push_str(&format!("{:>6}", "k"));
            for a in &algos {
                out.push_str(&format!(" | {:^21}", a));
            }
            out.push('\n');
            for &k in &parts {
                out.push_str(&format!("{:>6}", k));
                for a in &algos {
                    match by_key.get(&(g.clone(), k, a.clone())) {
                        Some(r) => out.push_str(&format!(
                            " | {:>9.4}  {:>9.4}",
                            r.local_edges, r.max_normalized_load
                        )),
                        None => out.push_str(&format!(" | {:^21}", "-")),
                    }
                }
                out.push('\n');
            }
            out.push('\n');
        }
        out
    }

    /// Write CSV + JSON next to each other under `dir` with `stem`.
    pub fn write_files(&self, dir: &std::path::Path, stem: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(dir.join(format!("{stem}.json")), self.to_json())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(g: &str, a: &str, k: u32, le: f64) -> ResultRow {
        ResultRow {
            graph: g.into(),
            algorithm: a.into(),
            parts: k,
            local_edges: le,
            max_normalized_load: 1.02,
            steps: 100,
            wall_time_s: 1.5,
            runs: 10,
        }
    }

    #[test]
    fn csv_format() {
        let mut rep = Report::new();
        rep.push(row("lj", "revolver", 8, 0.75));
        let csv = rep.to_csv();
        assert!(csv.contains("lj,revolver,8,0.750000,1.020000,100,1.500,10"));
    }

    #[test]
    fn json_parses_back() {
        let mut rep = Report::new();
        rep.push(row("lj", "revolver", 8, 0.75));
        rep.push(row("lj", "spinner", 8, 0.7));
        let j = Json::parse(&rep.to_json()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("algorithm").unwrap().as_str(), Some("revolver"));
        assert_eq!(arr[1].get("local_edges").unwrap().as_f64(), Some(0.7));
    }

    #[test]
    fn table_contains_all_cells() {
        let mut rep = Report::new();
        for a in ["revolver", "spinner", "hash"] {
            for k in [2u32, 4] {
                rep.push(row("wiki", a, k, 0.5));
            }
        }
        let t = rep.to_table();
        assert!(t.contains("wiki"));
        assert!(t.contains("revolver"));
        assert!(t.lines().count() >= 4);
    }

    #[test]
    fn write_files_roundtrip() {
        let mut rep = Report::new();
        rep.push(row("usa", "range", 16, 0.9));
        let dir = std::env::temp_dir().join("revolver_report_test");
        rep.write_files(&dir, "t").unwrap();
        let csv = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert!(csv.contains("usa,range"));
        let json = std::fs::read_to_string(dir.join("t.json")).unwrap();
        assert!(Json::parse(&json).is_ok());
    }
}
