//! Partitioning-quality metrics (§V-E) and experiment reporting.
//!
//! * [`quality`] — *local edges* and *max normalized load*, the two
//!   metrics every figure in the paper plots.
//! * [`trace`] — per-step convergence traces (Figure 4).
//! * [`report`] — CSV / JSON / pretty-table emitters for the bench
//!   harness.

pub mod quality;
pub mod report;
pub mod trace;
