//! Per-step convergence traces — the data behind Figure 4.

/// One sampled point of a partitioning run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TracePoint {
    pub step: u32,
    pub local_edges: f64,
    pub max_normalized_load: f64,
    /// Global mean score S^i — the convergence-check signal (§IV-D.9).
    pub mean_score: f64,
    /// Vertices that migrated during this step.
    pub migrations: u64,
    /// Vertices evaluated during this step — |V| per step under legacy
    /// full-sweep execution, the active-frontier size under
    /// [`crate::config::Frontier::On`].
    pub evaluated: u64,
    /// Wall-clock seconds since the run started, sampled when this
    /// point was recorded — the x-axis for convergence-vs-time plots
    /// (the terminal point's value ~equals `wall_time_s`).
    pub elapsed_s: f64,
}

/// A full run trace plus its terminal summary.
#[derive(Debug, Clone, Default)]
pub struct RunTrace {
    pub points: Vec<TracePoint>,
    /// Step at which the convergence criterion fired (None = ran to
    /// max_steps).
    pub converged_at: Option<u32>,
    pub wall_time_s: f64,
    /// Total vertex-evaluations across *every* executed step (not just
    /// the sampled ones) — `steps × |V|` under full-sweep execution,
    /// strictly less when the active frontier shrinks. The
    /// frontier-acceptance tests compare this, not wall clock.
    pub total_evaluated: u64,
    /// Coordinator-side stamp loads spent collecting frontiers — |V| per
    /// dense-scanned step, 0 for worklist-merged and step-0 identity
    /// frontiers. The hot-path bench rows diff this across
    /// `frontier_dense_frac` settings (DESIGN.md §Hot paths).
    pub stamp_reads: u64,
    /// Frontier collections that fell back to the dense O(n) stamp scan.
    pub scan_steps: u32,
    /// Frontier collections served by the merged O(frontier) worklists.
    pub worklist_steps: u32,
    /// Frontier chunk layouts reused via [`Chunks::clamped`] instead of
    /// a fresh `by_weight_subset` prefix-sum walk.
    ///
    /// [`Chunks::clamped`]: crate::coordinator::Chunks::clamped
    pub chunk_reuses: u32,
}

impl RunTrace {
    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn final_point(&self) -> Option<&TracePoint> {
        self.points.last()
    }

    /// Steps actually executed.
    pub fn steps(&self) -> u32 {
        self.points.last().map(|p| p.step + 1).unwrap_or(0)
    }

    /// CSV rows
    /// (`step,local_edges,max_norm_load,mean_score,migrations,evaluated,elapsed_s`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "step,local_edges,max_normalized_load,mean_score,migrations,evaluated,elapsed_s\n",
        );
        for p in &self.points {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{},{},{:.6}\n",
                p.step, p.local_edges, p.max_normalized_load, p.mean_score, p.migrations,
                p.evaluated, p.elapsed_s
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(step: u32, le: f64) -> TracePoint {
        TracePoint {
            step,
            local_edges: le,
            max_normalized_load: 1.0,
            mean_score: le,
            migrations: 5,
            evaluated: 100,
            elapsed_s: 0.5,
        }
    }

    #[test]
    fn push_and_final() {
        let mut t = RunTrace::default();
        assert_eq!(t.steps(), 0);
        assert!(t.final_point().is_none());
        t.push(pt(0, 0.3));
        t.push(pt(1, 0.5));
        assert_eq!(t.steps(), 2);
        assert_eq!(t.final_point().unwrap().local_edges, 0.5);
    }

    #[test]
    fn csv_shape() {
        let mut t = RunTrace::default();
        t.push(pt(0, 0.25));
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("step,"));
        assert!(lines[0].ends_with(",elapsed_s"));
        assert!(lines[1].starts_with("0,0.25"));
        assert!(lines[1].ends_with(",0.500000"));
    }
}
