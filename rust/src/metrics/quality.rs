//! The paper's two quality metrics (§V-E), plus the edge-balance
//! metric of the streaming literature (LDG/Fennel/restreaming balance
//! total incident-edge work, not just out-edge mass).

use crate::graph::Graph;
use crate::Label;

/// *Local edges*: fraction of directed edges with both endpoints in the
/// same partition — `Σ_{(u,v)∈E} δ(ψ(u),ψ(v)) / |E|`. Higher is better.
pub fn local_edges(g: &Graph, labels: &[Label]) -> f64 {
    debug_assert_eq!(labels.len(), g.num_vertices());
    let mut local = 0u64;
    for v in 0..g.num_vertices() {
        let lv = labels[v];
        for &u in g.out_neighbors(v as u32) {
            if labels[u as usize] == lv {
                local += 1;
            }
        }
    }
    local as f64 / g.num_edges().max(1) as f64
}

/// *Edge cuts* = 1 − local edges (§V-E).
pub fn edge_cuts(g: &Graph, labels: &[Label]) -> f64 {
    1.0 - local_edges(g, labels)
}

/// Per-partition loads b(l) in [`Graph::load_mass`] units — outgoing
/// edges on the paper's graphs (§II), cluster sizes on multilevel
/// contractions: the same units [`crate::partition::PartitionState`]'s
/// capacity gate and the V-cycle rebalance enforce, so this metric
/// measures exactly the balance the system promises.
pub fn partition_loads(g: &Graph, labels: &[Label], k: usize) -> Vec<u64> {
    let mut loads = vec![0u64; k];
    for v in 0..g.num_vertices() {
        let l = labels[v] as usize;
        debug_assert!(l < k, "label {l} out of range {k}");
        loads[l] += g.load_mass(v as u32) as u64;
    }
    loads
}

/// *Max normalized load*: `max_l b(l) / (Σ mass / k)` — i.e.
/// `max_l b(l) / (|E|/k)` on plain graphs. 1.0 is perfect balance; the
/// paper's ε=0.05 admits up to 1.05.
pub fn max_normalized_load(g: &Graph, labels: &[Label], k: usize) -> f64 {
    let loads = partition_loads(g, labels, k);
    let max = loads.iter().copied().max().unwrap_or(0) as f64;
    let expected = g.total_load_mass() as f64 / k as f64;
    if expected > 0.0 {
        max / expected
    } else {
        0.0
    }
}

/// Per-partition *incident-edge* loads: Σ_{v∈l} |N(v)| over the
/// undirected adjacency. Unlike [`partition_loads`] (out-edges only,
/// the paper's b(l)), this counts the total edge work a partition
/// hosts — in- and out-edges — which is what the streaming literature
/// balances. An edge whose endpoints sit in different partitions is
/// charged to both.
pub fn partition_edge_loads(g: &Graph, labels: &[Label], k: usize) -> Vec<u64> {
    let mut loads = vec![0u64; k];
    for v in 0..g.num_vertices() {
        let l = labels[v] as usize;
        debug_assert!(l < k, "label {l} out of range {k}");
        loads[l] += g.und_degree(v as u32) as u64;
    }
    loads
}

/// *Max normalized edge load*: max_l of [`partition_edge_loads`] over
/// its balanced share `Σ_v |N(v)| / k`. 1.0 is perfect edge balance.
pub fn max_normalized_edge_load(g: &Graph, labels: &[Label], k: usize) -> f64 {
    let loads = partition_edge_loads(g, labels, k);
    let total: u64 = loads.iter().sum();
    let max = loads.iter().copied().max().unwrap_or(0) as f64;
    let expected = total as f64 / k as f64;
    if expected > 0.0 {
        max / expected
    } else {
        0.0
    }
}

/// *Communication volume*: Σ_v |{ψ(u) : u ∈ N(v)} \ {ψ(v)}| — for every
/// vertex, the number of *distinct remote partitions* its undirected
/// neighbourhood touches. This is the replication-factor-style metric of
/// the distributed-systems literature: each distinct remote partition is
/// one copy of v's state that must be kept in sync per superstep, so
/// unlike [`edge_cuts`] a vertex with 50 cut edges into one partition
/// costs 1, not 50.
pub fn communication_volume(g: &Graph, labels: &[Label], k: usize) -> u64 {
    debug_assert_eq!(labels.len(), g.num_vertices());
    // Stamp array: seen[l] == v means partition l was already counted
    // for vertex v this pass (u32::MAX never equals a valid vertex id
    // because |V| < 2^32).
    let mut seen = vec![u32::MAX; k];
    let mut total = 0u64;
    for v in 0..g.num_vertices() {
        let lv = labels[v];
        for &u in g.neighbors(v as u32) {
            let l = labels[u as usize];
            debug_assert!((l as usize) < k, "label {l} out of range {k}");
            if l != lv && seen[l as usize] != v as u32 {
                seen[l as usize] = v as u32;
                total += 1;
            }
        }
    }
    total
}

/// [`communication_volume`] per vertex — the mean number of remote
/// partition replicas a vertex needs; comparable across graph sizes.
pub fn mean_communication_volume(g: &Graph, labels: &[Label], k: usize) -> f64 {
    communication_volume(g, labels, k) as f64 / g.num_vertices().max(1) as f64
}

/// Per-partition vertex counts — the balance target of classic LDG.
pub fn partition_vertex_counts(labels: &[Label], k: usize) -> Vec<u64> {
    let mut counts = vec![0u64; k];
    for &l in labels {
        debug_assert!((l as usize) < k, "label {l} out of range {k}");
        counts[l as usize] += 1;
    }
    counts
}

/// *Max normalized vertex load*: max partition vertex count over |V|/k.
pub fn max_normalized_vertex_load(labels: &[Label], k: usize) -> f64 {
    let counts = partition_vertex_counts(labels, k);
    let max = counts.iter().copied().max().unwrap_or(0) as f64;
    let expected = labels.len() as f64 / k as f64;
    if expected > 0.0 {
        max / expected
    } else {
        0.0
    }
}

/// All metrics in one pass-friendly bundle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quality {
    pub local_edges: f64,
    pub max_normalized_load: f64,
    /// Incident-edge (in+out) balance — see [`max_normalized_edge_load`].
    pub max_normalized_edge_load: f64,
    /// Mean distinct remote partitions per vertex — see
    /// [`mean_communication_volume`] (the *total* is the free function
    /// [`communication_volume`]; the names differ so the units can't be
    /// confused). 0.0 is a perfect (no-cut) partition.
    pub mean_communication_volume: f64,
}

pub fn evaluate(g: &Graph, labels: &[Label], k: usize) -> Quality {
    Quality {
        local_edges: local_edges(g, labels),
        max_normalized_load: max_normalized_load(g, labels, k),
        max_normalized_edge_load: max_normalized_edge_load(g, labels, k),
        mean_communication_volume: mean_communication_volume(g, labels, k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn two_cliques() -> Graph {
        // Vertices 0-2 fully connected, 3-5 fully connected, one bridge.
        let mut b = GraphBuilder::new(6);
        for &(i, j) in &[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)] {
            b.edge(i, j);
        }
        b.edge(0, 3);
        b.build()
    }

    #[test]
    fn perfect_split() {
        let g = two_cliques();
        let labels = vec![0, 0, 0, 1, 1, 1];
        // 6 of 7 edges internal.
        assert!((local_edges(&g, &labels) - 6.0 / 7.0).abs() < 1e-12);
        assert!((edge_cuts(&g, &labels) - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn single_partition_all_local() {
        let g = two_cliques();
        let labels = vec![0; 6];
        assert_eq!(local_edges(&g, &labels), 1.0);
    }

    #[test]
    fn loads_count_out_degrees() {
        let g = two_cliques();
        let labels = vec![0, 0, 0, 1, 1, 1];
        let loads = partition_loads(&g, &labels, 2);
        // Vertex 0 has out-degree 2 (0->1, 0->3); 1,2 have 1 each.
        assert_eq!(loads[0], 4);
        assert_eq!(loads[1], 3);
        assert_eq!(loads.iter().sum::<u64>(), g.num_edges() as u64);
    }

    #[test]
    fn max_normalized_load_balanced_is_near_one() {
        let g = two_cliques();
        let labels = vec![0, 0, 0, 1, 1, 1];
        // max(4,3) / (7/2) = 4 / 3.5
        let mnl = max_normalized_load(&g, &labels, 2);
        assert!((mnl - 4.0 / 3.5).abs() < 1e-12);
    }

    #[test]
    fn max_normalized_load_degenerate_all_in_one() {
        let g = two_cliques();
        let labels = vec![0; 6];
        // Everything in partition 0 of 2: max = 7, expected = 3.5 => 2.0.
        assert!((max_normalized_load(&g, &labels, 2) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn evaluate_bundles_all() {
        let g = two_cliques();
        let labels = vec![0, 0, 0, 1, 1, 1];
        let q = evaluate(&g, &labels, 2);
        assert_eq!(q.local_edges, local_edges(&g, &labels));
        assert_eq!(q.max_normalized_load, max_normalized_load(&g, &labels, 2));
        assert_eq!(
            q.max_normalized_edge_load,
            max_normalized_edge_load(&g, &labels, 2)
        );
        assert_eq!(q.mean_communication_volume, mean_communication_volume(&g, &labels, 2));
    }

    #[test]
    fn communication_volume_counts_distinct_remote_partitions() {
        let g = two_cliques();
        // Clique split: only the bridge endpoints (0 and 3) see one
        // remote partition each.
        let labels = vec![0, 0, 0, 1, 1, 1];
        assert_eq!(communication_volume(&g, &labels, 2), 2);
        assert!((mean_communication_volume(&g, &labels, 2) - 2.0 / 6.0).abs() < 1e-12);
        // One partition: nothing is remote.
        assert_eq!(communication_volume(&g, &vec![0; 6], 2), 0);
    }

    #[test]
    fn communication_volume_dedups_within_a_partition() {
        // Star centre with 3 spokes all in one remote partition: many
        // cut edges, communication volume 1 for the centre + 1 per spoke.
        let mut b = crate::graph::GraphBuilder::new(4);
        for s in 1..4u32 {
            b.edge(0, s);
        }
        let g = b.build();
        let labels = vec![0, 1, 1, 1];
        assert_eq!(communication_volume(&g, &labels, 2), 4);
        // Spokes spread across distinct partitions: centre now pays 3.
        let spread = vec![0, 1, 2, 3];
        assert_eq!(communication_volume(&g, &spread, 4), 6);
    }

    #[test]
    fn edge_loads_count_incident_edges() {
        let g = two_cliques();
        let labels = vec![0, 0, 0, 1, 1, 1];
        let loads = partition_edge_loads(&g, &labels, 2);
        // Each clique holds 3 internal edges (6 endpoint-incidences) and
        // one end of the bridge: 7 incidences per side, Σ = 2|E| = 14.
        assert_eq!(loads, vec![7, 7]);
        assert!((max_normalized_edge_load(&g, &labels, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn edge_load_degenerate_all_in_one() {
        let g = two_cliques();
        let labels = vec![0; 6];
        // Everything in partition 0 of 2: max = 14, expected = 7 => 2.0.
        assert!((max_normalized_edge_load(&g, &labels, 2) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn vertex_balance() {
        let labels = vec![0, 0, 0, 1, 1, 1];
        assert_eq!(partition_vertex_counts(&labels, 2), vec![3, 3]);
        assert!((max_normalized_vertex_load(&labels, 2) - 1.0).abs() < 1e-12);
        let skew = vec![0, 0, 0, 0, 1, 1];
        // max(4,2) / 3 = 4/3.
        assert!((max_normalized_vertex_load(&skew, 2) - 4.0 / 3.0).abs() < 1e-12);
    }
}
