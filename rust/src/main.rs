//! `revolver` — CLI launcher for the Revolver graph-partitioning system.
//!
//! Subcommands:
//!   partition    run one algorithm on one graph, print quality metrics
//!   sweep        Figure-3 grid: graphs × algorithms × partition counts
//!   convergence  Figure-4 per-step traces (Revolver vs Spinner)
//!   stream       partition an edge-list file without building CSR
//!   dynamic      evolve a graph (churn recipe / update log) with
//!                incremental frontier-localized repartitioning
//!   stats        Table-I statistics for the surrogate datasets
//!   generate     materialize a surrogate dataset to disk
//!   info         toolchain / artifact diagnostics
//!
//! Examples:
//!   revolver partition --graph lj --vertices 16384 --algorithm revolver --parts 8
//!   revolver partition --graph lj --algorithm revolver --init stream:fennel
//!   revolver partition --graph lj --algorithm multilevel --parts 8 --evaluate
//!   revolver sweep --graphs lj,so --algorithms revolver,fennel,ldg --parts 2,4,8
//!   revolver convergence --graph lj --parts 32 --vertices 16384
//!   revolver stream --file edges.txt --algorithm ldg --parts 8 --evaluate
//!   revolver dynamic --graph lj --churn uniform:0.02 --epochs 5 --out dyn.csv
//!   revolver stats --all
//!   revolver partition --graph lj --engine xla --parts 8

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use revolver::config::{ExecutionModel, RevolverConfig, StreamAlgo};
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::graph::{io, stats, Graph};
use revolver::metrics::quality;
use revolver::metrics::report::{Report, ResultRow};
use revolver::partitioners::{by_name, Partitioner};
use revolver::util::args::Args;
use revolver::util::{with_commas, Stopwatch};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    match args.subcommand() {
        Some("partition") => cmd_partition(args),
        Some("sweep") => cmd_sweep(args),
        Some("convergence") => cmd_convergence(args),
        Some("stream") => cmd_stream(args),
        Some("dynamic") => cmd_dynamic(args),
        Some("stats") => cmd_stats(args),
        Some("generate") => cmd_generate(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown subcommand {other:?}\n{}", usage()),
        None => {
            // Help path: consume nothing, print usage.
            let _ = args.get_bool("help");
            println!("{}", usage());
            Ok(())
        }
    }
}

/// Usage text; the algorithm list comes from the partitioner registry,
/// so it can never drift from what `by_name` accepts.
fn usage() -> String {
    format!(
        "{USAGE_BODY}\n  partition:  --algorithm <{}>  (--algo works too)\n{USAGE_TAIL}",
        revolver::partitioners::REGISTRY.join("|")
    )
}

const USAGE_BODY: &str =
    "usage: revolver <partition|sweep|convergence|stream|dynamic|stats|generate|info> [flags]
  common flags:
    --graph <wiki|uk|usa|so|lj|en|ok|hlwd|eu|path/to/edges.txt>
    --vertices N          surrogate scale (default 16384)
    --parts k             number of partitions (default 8)
    --seed S              RNG seed (default 42)
    --threads T           worker threads
    --schedule <vertex|degree>  full-sweep chunk layout (degree balances by
                          out-degree; only takes effect with --frontier off —
                          frontier mode always degree-balances the live set)
    --frontier <on|off>   active-set supersteps: skip settled vertices,
                          halt on an empty frontier (default on; off =
                          bit-exact legacy full sweeps)
    --frontier-dense-frac F  frontier collector switch point: frontiers
                          larger than F·|V| use the dense stamp scan,
                          smaller ones the merged per-worker worklists
                          (default 0.25; 0 = always scan, 1 = always
                          worklists — same runs either way)
    --prob-format <q16|f32>  LA probability-row storage (default q16,
                          half the memory traffic; f32 = the bit-exact
                          reference trajectory)
    --init <random|stream:<ldg|fennel|restream>>  warm-start policy
    --stream-order <natural|shuffled|bfs>  streaming visit order
    --fennel-gamma G      Fennel load exponent (default 1.5)
    --restream-passes N   restreaming passes (default 3)
    --coarsen-until N     multilevel: coarsest-level size target (default 256)
    --refine-steps N      multilevel: per-level refinement superstep budget (default 10)
    --coarse-algo A       multilevel: coarsest-level algorithm (default fennel)
    --repair-steps N      dynamic: per-epoch repair superstep budget (default 10)
    --compact-ratio R     dynamic: delta/base edge ratio triggering compaction (default 0.25)
    --placement <ldg|fennel>  dynamic: arrival placement score (default fennel)
    --verbosity <quiet|info|debug>  stderr progress chatter (default info)
    --obs-log file.jsonl  stream instrumentation events as JSONL
    --profile             print the hierarchical span timing tree after the run
    --metrics-addr H:P    serve live telemetry for the run's lifetime:
                          /metrics /healthz /profile /events?since=N
                          (port 0 picks a free port, echoed on stderr)
    --config file.toml    load RevolverConfig from file";

const USAGE_TAIL: &str =
    "              --engine <native|xla>  [--evaluate  per-partition load table]
  sweep:      --graphs a,b,c --algorithms a,b --parts 2,4,8 --runs R --out dir
  convergence: --parts k --steps N --out dir
  stream:     --file edges.txt --algorithm <ldg|fennel|restream>
              [--evaluate] [--out labels.txt]   (CSR is never built)
  dynamic:    --churn <uniform:F|hub:F|arrivals:NxE> --epochs N
              | --update-log file.log   (batches separated by `commit`)
              [--algorithm <spinner|revolver>] [--out trace.csv]
  stats:      --all | --graph g
  generate:   --graph g --out file [--format txt|bin]";

/// Shared flag parsing: build a RevolverConfig from --config + overrides.
fn config_from(args: &mut Args) -> Result<RevolverConfig> {
    let mut cfg = match args.get("config") {
        Some(path) if !path.is_empty() => RevolverConfig::from_toml_file(path)?,
        _ => RevolverConfig::default(),
    };
    // `--parts` may be a comma list (sweep); the base config takes the
    // first entry, sweep overrides per-k.
    cfg.parts = args.get_list("parts", &[cfg.parts])?[0];
    cfg.epsilon = args.get_or("epsilon", cfg.epsilon)?;
    cfg.max_steps = args.get_or("steps", cfg.max_steps)?;
    cfg.halt_window = args.get_or("halt-window", cfg.halt_window)?;
    cfg.halt_theta = args.get_or("halt-theta", cfg.halt_theta)?;
    cfg.alpha = args.get_or("alpha", cfg.alpha)?;
    cfg.beta = args.get_or("beta", cfg.beta)?;
    cfg.threads = args.get_or("threads", cfg.threads)?;
    cfg.schedule = args.get_or("schedule", cfg.schedule)?;
    cfg.frontier = args.get_or("frontier", cfg.frontier)?;
    cfg.frontier_dense_frac = args.get_or("frontier-dense-frac", cfg.frontier_dense_frac)?;
    cfg.prob_format = args.get_or("prob-format", cfg.prob_format)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    cfg.trace_every = args.get_or("trace-every", cfg.trace_every)?;
    if let Some(init) = args.get("init") {
        cfg.init = init.parse()?;
    }
    cfg.stream_order = args.get_or("stream-order", cfg.stream_order)?;
    cfg.fennel_gamma = args.get_or("fennel-gamma", cfg.fennel_gamma)?;
    cfg.restream_passes = args.get_or("restream-passes", cfg.restream_passes)?;
    cfg.coarsen_until = args.get_or("coarsen-until", cfg.coarsen_until)?;
    cfg.refine_steps = args.get_or("refine-steps", cfg.refine_steps)?;
    if let Some(ca) = args.get("coarse-algo") {
        cfg.coarse_algo = ca;
    }
    cfg.compact_ratio = args.get_or("compact-ratio", cfg.compact_ratio)?;
    cfg.repair_steps = args.get_or("repair-steps", cfg.repair_steps)?;
    cfg.placement = args.get_or("placement", cfg.placement)?;
    if let Some(engine) = args.get("engine") {
        cfg.engine = engine.parse()?;
    }
    if let Some(exec) = args.get("execution") {
        cfg.execution = match exec.as_str() {
            "async" | "asynchronous" => ExecutionModel::Asynchronous,
            "sync" | "synchronous" => ExecutionModel::Synchronous,
            other => bail!("unknown execution model {other:?}"),
        };
    }
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir;
    }
    cfg.classic_la = args.get_bool("classic-la");
    cfg.verbosity = args.get_or("verbosity", cfg.verbosity)?;
    if let Some(p) = args.get("obs-log") {
        cfg.obs_log = p;
    }
    cfg.profile = cfg.profile || args.get_bool("profile");
    if let Some(addr) = args.get("metrics-addr") {
        cfg.metrics_addr = addr;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// A run's observability plumbing: the installed recorder (when any of
/// `--obs-log`/`--profile`/`--metrics-addr` asked for one), the live
/// telemetry server, and whether to print the profile tree at the end.
/// Built by [`obs_setup`], closed out by [`obs_finish`].
struct ObsSession {
    rec: Option<Arc<revolver::obs::RunRecorder>>,
    server: Option<revolver::obs::http::MetricsServer>,
    profile: bool,
}

/// Apply the verbosity knob and, when `--obs-log`/`--profile`/
/// `--metrics-addr` ask for it, build + install the process-global
/// recorder (and start the telemetry server, echoing the bound address
/// on stderr — parseable, so CI can use port 0).
fn obs_setup(cfg: &RevolverConfig) -> Result<ObsSession> {
    use revolver::config::Verbosity;
    use revolver::obs::log::Level;
    revolver::obs::log::set_level(match cfg.verbosity {
        Verbosity::Quiet => Level::Quiet,
        Verbosity::Info => Level::Info,
        Verbosity::Debug => Level::Debug,
    });
    if cfg.obs_log.is_empty() && !cfg.profile && cfg.metrics_addr.is_empty() {
        return Ok(ObsSession { rec: None, server: None, profile: false });
    }
    let rec = if cfg.obs_log.is_empty() {
        revolver::obs::RunRecorder::new()
    } else {
        let f = std::fs::File::create(&cfg.obs_log)
            .with_context(|| format!("create --obs-log {:?}", cfg.obs_log))?;
        revolver::obs::RunRecorder::with_sink(Box::new(std::io::BufWriter::new(f)))
    };
    let rec = Arc::new(rec);
    revolver::obs::install(rec.clone());
    let server = if cfg.metrics_addr.is_empty() {
        None
    } else {
        let srv = revolver::obs::http::MetricsServer::start(&cfg.metrics_addr, rec.clone())
            .with_context(|| format!("bind --metrics-addr {:?}", cfg.metrics_addr))?;
        // Echoed unconditionally (not via log::info): with port 0 this
        // line is the only way to learn the bound port.
        eprintln!("metrics: serving http://{}/metrics", srv.local_addr());
        Some(srv)
    };
    revolver::obs::event("run_start", &[]);
    Ok(ObsSession { rec: Some(rec), server, profile: cfg.profile })
}

/// Close out a recorded run: terminal event (still scrapeable — the
/// server shuts down *after* it, so a final `/metrics` or `/events`
/// poll can observe the complete run), then server shutdown,
/// uninstall, JSONL flush, and the `--profile` tree if asked.
fn obs_finish(session: ObsSession) {
    use revolver::obs::Recorder as _;
    let ObsSession { rec, server, profile } = session;
    let Some(rec) = rec else { return };
    revolver::obs::event("run_end", &[("wall_s", rec.elapsed_s())]);
    drop(server); // graceful shutdown: drains scrapes, wakes long-polls
    revolver::obs::uninstall();
    rec.flush();
    if profile {
        print!("{}", rec.profile_report());
    }
}

/// Load a graph: surrogate dataset name, or a file path (.txt/.bin).
fn load_graph(args: &mut Args) -> Result<(String, Graph)> {
    let name = args.get("graph").unwrap_or_else(|| "lj".to_string());
    let vertices: usize = args.get_or("vertices", 16384)?;
    let seed: u64 = args.get_or("graph-seed", 7)?;
    if let Some(ds) = Dataset::from_name(&name) {
        let g = generate_dataset(ds, vertices, seed)?;
        return Ok((ds.name().to_string(), g));
    }
    let path = std::path::Path::new(&name);
    if !path.exists() {
        bail!(
            "--graph {name:?} is neither a dataset name ({:?}) nor an existing file",
            Dataset::ALL.iter().map(|d| d.name()).collect::<Vec<_>>()
        );
    }
    let g = if name.ends_with(".bin") {
        io::load_binary(path)?
    } else {
        io::load_edge_list(path)?
    };
    let stem = path.file_stem().unwrap_or_default().to_string_lossy().to_string();
    Ok((stem, g))
}

fn cmd_partition(mut args: Args) -> Result<()> {
    // `--algo` is accepted as a short alias of `--algorithm`.
    let algorithm = args
        .get("algorithm")
        .or_else(|| args.get("algo"))
        .unwrap_or_else(|| "revolver".to_string());
    let evaluate = args.get_bool("evaluate");
    let (gname, g) = load_graph(&mut args)?;
    let cfg = config_from(&mut args)?;
    args.finish()?;

    let k = cfg.parts;
    let obs = obs_setup(&cfg)?;
    revolver::obs::log::info(&format!(
        "partitioning {gname} (|V|={}, |E|={}) with {algorithm}, k={k}, engine={:?}",
        with_commas(g.num_vertices() as u64),
        with_commas(g.num_edges() as u64),
        cfg.engine,
    ));
    let p = by_name(&algorithm, cfg)?;
    let sw = Stopwatch::start();
    let out = p.partition(&g);
    obs_finish(obs);
    let q = quality::evaluate(&g, &out.labels, k);
    println!("graph:               {gname}");
    println!("algorithm:           {algorithm}");
    println!("partitions:          {k}");
    println!("steps:               {}", out.trace.steps());
    println!("converged at:        {:?}", out.trace.converged_at);
    println!("vertex evals:        {}", with_commas(out.trace.total_evaluated));
    println!("local edges:         {:.4}", q.local_edges);
    println!("edge cuts:           {:.4}", 1.0 - q.local_edges);
    println!("max normalized load: {:.4}", q.max_normalized_load);
    println!("max norm edge load:  {:.4}", q.max_normalized_edge_load);
    println!("comm volume/vertex:  {:.4}", q.mean_communication_volume);
    println!("wall time:           {:.2}s", sw.elapsed_s());
    if evaluate {
        // Full per-partition load breakdown (out-edge units).
        let loads = quality::partition_loads(&g, &out.labels, k);
        let counts = quality::partition_vertex_counts(&out.labels, k);
        println!("per-partition loads (out-edges / vertices):");
        for l in 0..k {
            println!("  p{l:<3} {:>12} {:>12}", with_commas(loads[l]), with_commas(counts[l]));
        }
    }
    Ok(())
}

/// Partition an edge-list file straight off disk (no CSR): the
/// streaming subsystem's chunked reader feeds one LDG/Fennel pass (or
/// N restreaming passes). `--evaluate` additionally loads the graph
/// afterwards to report cut quality; `--out` writes one label per
/// dense vertex id.
fn cmd_stream(mut args: Args) -> Result<()> {
    let file = args
        .get("file")
        .filter(|f| !f.is_empty())
        .context("stream requires --file <edges.txt>")?;
    let algorithm = args.get("algorithm").unwrap_or_else(|| "fennel".to_string());
    let evaluate = args.get_bool("evaluate");
    let out = args.get("out");
    let cfg = config_from(&mut args)?;
    args.finish()?;
    let algo: StreamAlgo = algorithm.parse()?;

    let obs = obs_setup(&cfg)?;
    let sw = Stopwatch::start();
    let res = revolver::stream::partition_edge_list_file(&file, &cfg, algo)?;
    obs_finish(obs);
    let elapsed = sw.elapsed_s();
    let k = cfg.parts;
    let max_load = res.loads.iter().cloned().fold(0.0f64, f64::max);
    let expected = res.edges as f64 / k as f64;
    println!("file:                {file}");
    println!("algorithm:           {}", algo.name());
    println!("partitions:          {k}");
    println!("vertices:            {}", with_commas(res.vertices as u64));
    println!("edges streamed:      {}", with_commas(res.edges));
    println!(
        "max normalized load: {:.4}",
        if expected > 0.0 { max_load / expected } else { 0.0 }
    );
    println!("wall time:           {elapsed:.2}s");
    println!(
        "throughput:          {:.2}M edges/s",
        res.edges as f64 / elapsed.max(1e-9) / 1e6
    );

    if let Some(out) = out.filter(|o| !o.is_empty()) {
        use std::fmt::Write as _;
        let mut text = String::with_capacity(res.labels.len() * 4);
        for &l in &res.labels {
            let _ = writeln!(text, "{l}");
        }
        std::fs::write(&out, text)?;
        println!("labels:              {out} (one per dense vertex id)");
    }

    if evaluate {
        // The loader densifies ids in the same first-appearance order
        // as the stream, so the labels line up with this CSR.
        let g = io::load_edge_list(&file)?;
        let q = quality::evaluate(&g, &res.labels, k);
        println!("local edges:         {:.4}", q.local_edges);
        println!("edge cuts:           {:.4}", 1.0 - q.local_edges);
        println!("max norm edge load:  {:.4}", q.max_normalized_edge_load);
        println!("comm volume/vertex:  {:.4}", q.mean_communication_volume);
    }
    Ok(())
}

/// Evolve a graph over N epochs — synthetic churn or a recorded update
/// log — maintaining the partition incrementally: greedy arrival
/// placement plus a frontier-seeded repair pass per epoch. Reports
/// per-epoch quality and evaluated vertices; `--out` writes the
/// quality-over-time trace as CSV (step column = epoch).
fn cmd_dynamic(mut args: Args) -> Result<()> {
    use revolver::dynamic::{read_update_log, ChurnRecipe, IncrementalPartitioner, UpdateBatch};
    use revolver::metrics::trace::RunTrace;
    use revolver::multilevel::Refiner;

    let algorithm = args
        .get("algorithm")
        .or_else(|| args.get("algo"))
        .unwrap_or_else(|| "spinner".to_string());
    let churn = args.get("churn");
    let log = args.get("update-log");
    let epochs: u32 = args.get_or("epochs", 5)?;
    let out = args.get("out");
    let (gname, g) = load_graph(&mut args)?;
    let cfg = config_from(&mut args)?;
    args.finish()?;

    let refiner = match algorithm.to_lowercase().as_str() {
        "spinner" => Refiner::Spinner,
        "revolver" => Refiner::Revolver,
        other => bail!("dynamic repairs with spinner|revolver, got {other:?}"),
    };
    let recipe: Option<ChurnRecipe> = match (&churn, &log) {
        (Some(c), None) => Some(c.parse()?),
        (None, Some(_)) => None,
        (Some(_), Some(_)) => bail!("--churn and --update-log are mutually exclusive"),
        (None, None) => bail!("dynamic requires --churn <recipe> or --update-log <file>"),
    };
    let log_batches: Vec<UpdateBatch> = match &log {
        Some(path) => {
            let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
            read_update_log(std::io::BufReader::new(f), g.num_vertices())?
        }
        None => Vec::new(),
    };
    let epochs = if log.is_some() { log_batches.len() as u32 } else { epochs };

    let k = cfg.parts;
    let seed = cfg.seed;
    let obs = obs_setup(&cfg)?;
    revolver::obs::log::info(&format!(
        "dynamic: {gname} (|V|={}, |E|={}) repair={algorithm} k={k} epochs={epochs} {}",
        with_commas(g.num_vertices() as u64),
        with_commas(g.num_edges() as u64),
        churn.as_deref().unwrap_or("update-log"),
    ));
    let sw = Stopwatch::start();
    let mut inc = IncrementalPartitioner::new(g, cfg, refiner);
    let q0 = quality::evaluate(inc.current(), inc.labels(), k);
    println!(
        "epoch {:>3}: local={:.4} mnl={:.4} (cold partition)",
        "-", q0.local_edges, q0.max_normalized_load
    );

    let mut trace = RunTrace::default();
    for e in 0..epochs {
        let batch = match &recipe {
            Some(r) => r.generate(inc.current(), seed ^ (e as u64 + 1)),
            None => log_batches[e as usize].clone(),
        };
        let stats = inc.epoch(&batch);
        inc.record_epoch(&mut trace, e, &stats);
        let p = trace.final_point().expect("record_epoch pushed a point");
        println!(
            "epoch {e:>3}: local={:.4} mnl={:.4} placed={} seeds={} steps={} evaluated={}",
            p.local_edges,
            p.max_normalized_load,
            stats.placed,
            stats.seeds,
            stats.repair_steps,
            with_commas(stats.evaluated),
        );
    }
    println!(
        "totals:    |V|={} |E|={} repair steps={} evaluated={} wall={:.2}s",
        with_commas(inc.current().num_vertices() as u64),
        with_commas(inc.current().num_edges() as u64),
        inc.total_repair_steps(),
        with_commas(inc.total_evaluated()),
        sw.elapsed_s()
    );
    obs_finish(obs);
    if let Some(out) = out.filter(|o| !o.is_empty()) {
        std::fs::write(&out, trace.to_csv())?;
        println!(
            "trace:     {out} (one row per epoch; step=epoch, \
             migrations=rebalance moves, mean_score=repair wall seconds, \
             elapsed_s=cumulative epoch wall)"
        );
    }
    Ok(())
}

fn cmd_sweep(mut args: Args) -> Result<()> {
    let graphs: Vec<String> =
        args.get_list("graphs", &["lj".to_string()])?;
    let algorithms: Vec<String> = args.get_list(
        "algorithms",
        &[
            "revolver".to_string(),
            "spinner".to_string(),
            "hash".to_string(),
            "range".to_string(),
        ],
    )?;
    let parts: Vec<usize> = args.get_list("parts", &[2usize, 4, 8, 16, 32])?;
    let runs: u32 = args.get_or("runs", 1)?;
    let out_dir = args.get("out").unwrap_or_else(|| "results".to_string());
    let vertices: usize = args.get_or("vertices", 16384)?;
    let base_cfg = config_from(&mut args)?;
    args.finish()?;
    let obs = obs_setup(&base_cfg)?;

    let mut report = Report::new();
    for gname in &graphs {
        let ds = Dataset::from_name(gname)
            .with_context(|| format!("unknown dataset {gname:?} in --graphs"))?;
        let g = generate_dataset(ds, vertices, 7)?;
        revolver::obs::log::info(&format!(
            "sweep: {gname} |V|={} |E|={}",
            with_commas(g.num_vertices() as u64),
            with_commas(g.num_edges() as u64)
        ));
        for algo in &algorithms {
            for &k in &parts {
                let mut le_sum = 0.0;
                let mut mnl_sum = 0.0;
                let mut steps_sum = 0u32;
                let sw = Stopwatch::start();
                for run in 0..runs {
                    let mut cfg = base_cfg.clone();
                    cfg.parts = k;
                    cfg.seed = base_cfg.seed + run as u64;
                    let p = by_name(algo, cfg)?;
                    let out = p.partition(&g);
                    let q = quality::evaluate(&g, &out.labels, k);
                    le_sum += q.local_edges;
                    mnl_sum += q.max_normalized_load;
                    steps_sum += out.trace.steps();
                }
                let row = ResultRow {
                    graph: gname.clone(),
                    algorithm: algo.clone(),
                    parts: k as u32,
                    local_edges: le_sum / runs as f64,
                    max_normalized_load: mnl_sum / runs as f64,
                    steps: steps_sum / runs,
                    wall_time_s: sw.elapsed_s() / runs as f64,
                    runs,
                };
                revolver::obs::log::info(&format!(
                    "  {algo:>9} k={k:<4} local={:.4} mnl={:.4}",
                    row.local_edges, row.max_normalized_load
                ));
                report.push(row);
            }
        }
    }
    obs_finish(obs);
    print!("{}", report.to_table());
    report.write_files(std::path::Path::new(&out_dir), "fig3_sweep")?;
    revolver::obs::log::info(&format!("wrote {out_dir}/fig3_sweep.csv and .json"));
    Ok(())
}

fn cmd_convergence(mut args: Args) -> Result<()> {
    let (gname, g) = load_graph(&mut args)?;
    let out_dir = args.get("out").unwrap_or_else(|| "results".to_string());
    let mut cfg = config_from(&mut args)?;
    args.finish()?;
    cfg.trace_every = cfg.trace_every.max(1);
    // Figure 4 runs the full step budget without early halting.
    cfg.halt_window = u32::MAX;

    std::fs::create_dir_all(&out_dir)?;
    let obs = obs_setup(&cfg)?;
    for algo in ["revolver", "spinner"] {
        let p = by_name(algo, cfg.clone())?;
        revolver::obs::log::info(&format!("convergence: {algo} on {gname} k={}", cfg.parts));
        let out = p.partition(&g);
        let path = format!("{out_dir}/fig4_{algo}_{gname}_k{}.csv", cfg.parts);
        std::fs::write(&path, out.trace.to_csv())?;
        let last = out.trace.final_point().unwrap();
        println!(
            "{algo:>9}: final local edges {:.4}, max norm load {:.4} ({} steps) -> {path}",
            last.local_edges,
            last.max_normalized_load,
            out.trace.steps()
        );
    }
    obs_finish(obs);
    Ok(())
}

fn cmd_stats(mut args: Args) -> Result<()> {
    let all = args.get_bool("all");
    let vertices: usize = args.get_or("vertices", 16384)?;
    let seed: u64 = args.get_or("graph-seed", 7)?;
    let datasets: Vec<Dataset> = if all {
        Dataset::ALL.to_vec()
    } else {
        let name = args.get("graph").unwrap_or_else(|| "lj".to_string());
        vec![Dataset::from_name(&name).with_context(|| format!("unknown dataset {name:?}"))?]
    };
    args.finish()?;

    println!(
        "{:<8} {:>10} {:>12} {:>10} {:>8} | paper: {:>9} {:>9} {:>7} {:>6}",
        "graph", "|V|", "|E|", "D(x1e-5)", "skew", "|V|", "|E|", "D", "skew"
    );
    for ds in datasets {
        let g = generate_dataset(ds, vertices, seed)?;
        let s = stats::compute(&g);
        let p = ds.paper_stats();
        println!(
            "{:<8} {:>10} {:>12} {:>10.3} {:>8.3} | {:>9} {:>9} {:>7.2} {:>6.2}",
            ds.name(),
            with_commas(s.vertices as u64),
            with_commas(s.edges as u64),
            s.density * 1e5,
            s.skewness,
            format!("{:.2}M", p.vertices / 1e6),
            format!("{:.2}M", p.edges / 1e6),
            p.density_e5,
            p.skew,
        );
    }
    Ok(())
}

fn cmd_generate(mut args: Args) -> Result<()> {
    let name = args.get("graph").unwrap_or_else(|| "lj".to_string());
    let vertices: usize = args.get_or("vertices", 16384)?;
    let seed: u64 = args.get_or("graph-seed", 7)?;
    let format = args.get("format").unwrap_or_else(|| "bin".to_string());
    let out = args
        .get("out")
        .unwrap_or_else(|| format!("data/{name}_{vertices}.{format}"));
    args.finish()?;

    let ds = Dataset::from_name(&name).with_context(|| format!("unknown dataset {name:?}"))?;
    let g = generate_dataset(ds, vertices, seed)?;
    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    match format.as_str() {
        "bin" => io::save_binary(&g, &out)?,
        "txt" => io::save_edge_list(&g, &out)?,
        other => bail!("unknown format {other:?} (txt|bin)"),
    }
    println!(
        "wrote {out}: |V|={} |E|={}",
        with_commas(g.num_vertices() as u64),
        with_commas(g.num_edges() as u64)
    );
    Ok(())
}

fn cmd_info(mut args: Args) -> Result<()> {
    let artifacts = args.get("artifacts").unwrap_or_else(|| "artifacts".to_string());
    args.finish()?;
    println!("revolver {} ({})", env!("CARGO_PKG_VERSION"), env!("CARGO_PKG_NAME"));
    println!("threads available: {}", std::thread::available_parallelism()?.get());
    match revolver::runtime::Runtime::open(&artifacts) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts ({artifacts}):");
            for e in &rt.manifest().entries {
                println!("  {:<22} batch={} k={} file={}", e.name, e.batch, e.k, e.file);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e:#})"),
    }
    Ok(())
}
