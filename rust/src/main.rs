//! `revolver` — CLI launcher for the Revolver graph-partitioning system.
//!
//! Subcommands:
//!   partition    run one algorithm on one graph, print quality metrics
//!   sweep        Figure-3 grid: graphs × algorithms × partition counts
//!   convergence  Figure-4 per-step traces (Revolver vs Spinner)
//!   stream       partition an edge-list file without building CSR
//!   dynamic      evolve a graph (churn recipe / update log) with
//!                incremental frontier-localized repartitioning
//!   stats        Table-I statistics for the surrogate datasets
//!   generate     materialize a surrogate dataset to disk
//!   info         toolchain / artifact diagnostics
//!   report       render a text report from an --obs-log JSONL file
//!
//! Examples:
//!   revolver partition --graph lj --vertices 16384 --algorithm revolver --parts 8
//!   revolver partition --graph lj --algorithm revolver --init stream:fennel
//!   revolver partition --graph lj --algorithm multilevel --parts 8 --evaluate
//!   revolver sweep --graphs lj,so --algorithms revolver,fennel,ldg --parts 2,4,8
//!   revolver convergence --graph lj --parts 32 --vertices 16384
//!   revolver stream --file edges.txt --algorithm ldg --parts 8 --evaluate
//!   revolver dynamic --graph lj --churn uniform:0.02 --epochs 5 --out dyn.csv
//!   revolver stats --all
//!   revolver partition --graph lj --engine xla --parts 8

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use revolver::config::{ExecutionModel, IngestMode, RevolverConfig, StreamAlgo};
use revolver::engine::EngineError;
use revolver::graph::gen::{generate_dataset, Dataset};
use revolver::graph::{io, stats, Graph};
use revolver::metrics::quality;
use revolver::metrics::report::{Report, ResultRow};
use revolver::partitioners::{by_name, Partitioner};
use revolver::util::args::{ArgError, Args};
use revolver::util::{with_commas, Stopwatch};

/// A CLI failure carrying its process exit code. The code partitions
/// failures the way scripts need to react to them:
///
/// * `2` — usage / config errors (bad flags, unknown subcommand,
///   invalid config values): fix the invocation.
/// * `1` — runtime failures (missing files, IO errors, corrupt
///   inputs): fix the environment.
/// * `3` — a contained worker panic aborted the run
///   ([`EngineError::WorkerPanic`]): a crash that the engine unwound
///   cleanly; retry / resume is reasonable.
struct CliError {
    code: i32,
    err: anyhow::Error,
}

impl CliError {
    fn usage(err: anyhow::Error) -> Self {
        CliError { code: 2, err }
    }

    fn aborted(err: EngineError) -> Self {
        CliError { code: 3, err: anyhow!("{err}") }
    }
}

/// Plain `?` on an anyhow error is a runtime failure (exit 1).
impl From<anyhow::Error> for CliError {
    fn from(err: anyhow::Error) -> Self {
        CliError { code: 1, err }
    }
}

/// Bare IO errors (fs writes, thread queries) are runtime failures.
impl From<std::io::Error> for CliError {
    fn from(err: std::io::Error) -> Self {
        CliError { code: 1, err: err.into() }
    }
}

/// Flag-parse errors are usage errors wherever they surface (exit 2).
impl From<ArgError> for CliError {
    fn from(err: ArgError) -> Self {
        CliError { code: 2, err: err.into() }
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {:#}", e.err);
        std::process::exit(e.code);
    }
}

fn run() -> Result<(), CliError> {
    let mut args = Args::from_env()?;
    match args.subcommand() {
        Some("partition") => cmd_partition(args),
        Some("sweep") => cmd_sweep(args),
        Some("convergence") => cmd_convergence(args),
        Some("stream") => cmd_stream(args),
        Some("dynamic") => cmd_dynamic(args),
        Some("stats") => cmd_stats(args),
        Some("generate") => cmd_generate(args),
        Some("info") => cmd_info(args),
        Some("report") => cmd_report(args),
        Some(other) => {
            Err(CliError::usage(anyhow!("unknown subcommand {other:?}\n{}", usage())))
        }
        None => {
            // Help path: consume nothing, print usage.
            let _ = args.get_bool("help");
            println!("{}", usage());
            Ok(())
        }
    }
}

/// Usage text; the algorithm list comes from the partitioner registry,
/// so it can never drift from what `by_name` accepts.
fn usage() -> String {
    format!(
        "{USAGE_BODY}\n  partition:  --algorithm <{}>  (--algo works too)\n{USAGE_TAIL}",
        revolver::partitioners::REGISTRY.join("|")
    )
}

const USAGE_BODY: &str =
    "usage: revolver <partition|sweep|convergence|stream|dynamic|stats|generate|info|report> [flags]
  common flags:
    --graph <wiki|uk|usa|so|lj|en|ok|hlwd|eu|path/to/edges.txt>
    --vertices N          surrogate scale (default 16384)
    --parts k             number of partitions (default 8)
    --seed S              RNG seed (default 42)
    --threads T           worker threads
    --schedule <vertex|degree>  full-sweep chunk layout (degree balances by
                          out-degree; only takes effect with --frontier off —
                          frontier mode always degree-balances the live set)
    --frontier <on|off>   active-set supersteps: skip settled vertices,
                          halt on an empty frontier (default on; off =
                          bit-exact legacy full sweeps)
    --frontier-dense-frac F  frontier collector switch point: frontiers
                          larger than F·|V| use the dense stamp scan,
                          smaller ones the merged per-worker worklists
                          (default 0.25; 0 = always scan, 1 = always
                          worklists — same runs either way)
    --prob-format <q16|f32>  LA probability-row storage (default q16,
                          half the memory traffic; f32 = the bit-exact
                          reference trajectory)
    --init <random|stream:<ldg|fennel|restream>>  warm-start policy
    --stream-order <natural|shuffled|bfs>  streaming visit order
    --fennel-gamma G      Fennel load exponent (default 1.5)
    --restream-passes N   restreaming passes (default 3)
    --coarsen-until N     multilevel: coarsest-level size target (default 256)
    --refine-steps N      multilevel: per-level refinement superstep budget (default 10)
    --coarse-algo A       multilevel: coarsest-level algorithm (default fennel)
    --repair-steps N      dynamic: per-epoch repair superstep budget (default 10)
    --compact-ratio R     dynamic: delta/base edge ratio triggering compaction (default 0.25)
    --placement <ldg|fennel>  dynamic: arrival placement score (default fennel)
    --verbosity <quiet|info|debug>  stderr progress chatter (default info)
    --obs-log file.jsonl  stream instrumentation events as JSONL
    --profile             print the hierarchical span timing tree after the run
    --metrics-addr H:P    serve live telemetry for the run's lifetime:
                          /metrics /healthz /profile /events?since=N /state
                          (port 0 picks a free port, echoed on stderr)
    --diag                learning-dynamics observatory: migration flow
                          matrix, per-partition gauges, LA decisiveness
                          and oscillation probes (adds flow/partition/
                          diag events; installs a recorder by itself)
    --ingest <strict|lenient>  text-reader strictness: strict aborts on
                          the first malformed line, lenient skips and
                          counts it with a line-numbered diagnostic
                          (default strict)
    --checkpoint dir/     write durable RVCK snapshots into dir
                          (partition: step cadence; dynamic: epoch cadence)
    --checkpoint-every N  snapshot cadence in steps/epochs (default 10)
    --resume              continue from the newest snapshot in the
                          --checkpoint dir (fresh start when empty)
    --faults SPEC         deterministic fault injection, e.g.
                          \"panic@step:7,io@checkpoint:2,truncate@log:40%\"
    --config file.toml    load RevolverConfig from file";

const USAGE_TAIL: &str =
    "              --engine <native|xla>  [--evaluate  per-partition load table]
  sweep:      --graphs a,b,c --algorithms a,b --parts 2,4,8 --runs R --out dir
  convergence: --parts k --steps N --out dir
  stream:     --file edges.txt --algorithm <ldg|fennel|restream>
              [--evaluate] [--out labels.txt]   (CSR is never built)
  dynamic:    --churn <uniform:F|hub:F|arrivals:NxE> --epochs N
              | --update-log file.log   (batches separated by `commit`)
              [--algorithm <spinner|revolver>] [--out trace.csv]
  stats:      --all | --graph g
  generate:   --graph g --out file [--format txt|bin]
  report:     --obs-log run.jsonl [--partial]   (text report: flow
              matrix, partition trajectories, halt attribution)
  exit codes: 0 ok | 1 runtime failure | 2 usage/config error
              | 3 contained worker panic";

/// Shared flag parsing: build a RevolverConfig from --config + overrides.
fn config_from(args: &mut Args) -> Result<RevolverConfig> {
    let mut cfg = match args.get("config") {
        Some(path) if !path.is_empty() => RevolverConfig::from_toml_file(path)?,
        _ => RevolverConfig::default(),
    };
    // `--parts` may be a comma list (sweep); the base config takes the
    // first entry, sweep overrides per-k.
    cfg.parts = args.get_list("parts", &[cfg.parts])?[0];
    cfg.epsilon = args.get_or("epsilon", cfg.epsilon)?;
    cfg.max_steps = args.get_or("steps", cfg.max_steps)?;
    cfg.halt_window = args.get_or("halt-window", cfg.halt_window)?;
    cfg.halt_theta = args.get_or("halt-theta", cfg.halt_theta)?;
    cfg.alpha = args.get_or("alpha", cfg.alpha)?;
    cfg.beta = args.get_or("beta", cfg.beta)?;
    cfg.threads = args.get_or("threads", cfg.threads)?;
    cfg.schedule = args.get_or("schedule", cfg.schedule)?;
    cfg.frontier = args.get_or("frontier", cfg.frontier)?;
    cfg.frontier_dense_frac = args.get_or("frontier-dense-frac", cfg.frontier_dense_frac)?;
    cfg.prob_format = args.get_or("prob-format", cfg.prob_format)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    cfg.trace_every = args.get_or("trace-every", cfg.trace_every)?;
    if let Some(init) = args.get("init") {
        cfg.init = init.parse()?;
    }
    cfg.stream_order = args.get_or("stream-order", cfg.stream_order)?;
    cfg.fennel_gamma = args.get_or("fennel-gamma", cfg.fennel_gamma)?;
    cfg.restream_passes = args.get_or("restream-passes", cfg.restream_passes)?;
    cfg.coarsen_until = args.get_or("coarsen-until", cfg.coarsen_until)?;
    cfg.refine_steps = args.get_or("refine-steps", cfg.refine_steps)?;
    if let Some(ca) = args.get("coarse-algo") {
        cfg.coarse_algo = ca;
    }
    cfg.compact_ratio = args.get_or("compact-ratio", cfg.compact_ratio)?;
    cfg.repair_steps = args.get_or("repair-steps", cfg.repair_steps)?;
    cfg.placement = args.get_or("placement", cfg.placement)?;
    if let Some(engine) = args.get("engine") {
        cfg.engine = engine.parse()?;
    }
    if let Some(exec) = args.get("execution") {
        cfg.execution = match exec.as_str() {
            "async" | "asynchronous" => ExecutionModel::Asynchronous,
            "sync" | "synchronous" => ExecutionModel::Synchronous,
            other => bail!("unknown execution model {other:?}"),
        };
    }
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir;
    }
    cfg.classic_la = args.get_bool("classic-la");
    cfg.verbosity = args.get_or("verbosity", cfg.verbosity)?;
    if let Some(p) = args.get("obs-log") {
        cfg.obs_log = p;
    }
    cfg.profile = cfg.profile || args.get_bool("profile");
    if let Some(addr) = args.get("metrics-addr") {
        cfg.metrics_addr = addr;
    }
    cfg.diag = cfg.diag || args.get_bool("diag");
    cfg.ingest = args.get_or("ingest", cfg.ingest)?;
    if let Some(dir) = args.get("checkpoint") {
        cfg.checkpoint_dir = dir;
    }
    cfg.checkpoint_every = args.get_or("checkpoint-every", cfg.checkpoint_every)?;
    cfg.resume = cfg.resume || args.get_bool("resume");
    if let Some(spec) = args.get("faults") {
        cfg.faults = spec.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// A run's observability plumbing: the installed recorder (when any of
/// `--obs-log`/`--profile`/`--metrics-addr` asked for one), the live
/// telemetry server, and whether to print the profile tree at the end.
/// Built by [`obs_setup`], closed out by [`obs_finish`].
struct ObsSession {
    rec: Option<Arc<revolver::obs::RunRecorder>>,
    server: Option<revolver::obs::http::MetricsServer>,
    profile: bool,
}

/// Apply the verbosity knob and, when `--obs-log`/`--profile`/
/// `--metrics-addr` ask for it, build + install the process-global
/// recorder (and start the telemetry server, echoing the bound address
/// on stderr — parseable, so CI can use port 0).
fn obs_setup(cfg: &RevolverConfig) -> Result<ObsSession> {
    use revolver::config::Verbosity;
    use revolver::obs::log::Level;
    revolver::obs::log::set_level(match cfg.verbosity {
        Verbosity::Quiet => Level::Quiet,
        Verbosity::Info => Level::Info,
        Verbosity::Debug => Level::Debug,
    });
    if cfg.obs_log.is_empty() && !cfg.profile && cfg.metrics_addr.is_empty() && !cfg.diag {
        return Ok(ObsSession { rec: None, server: None, profile: false });
    }
    let rec = if cfg.obs_log.is_empty() {
        revolver::obs::RunRecorder::new()
    } else {
        let f = std::fs::File::create(&cfg.obs_log)
            .with_context(|| format!("create --obs-log {:?}", cfg.obs_log))?;
        revolver::obs::RunRecorder::with_sink(Box::new(std::io::BufWriter::new(f)))
    };
    let rec = Arc::new(rec);
    revolver::obs::install(rec.clone());
    let server = if cfg.metrics_addr.is_empty() {
        None
    } else {
        let srv = revolver::obs::http::MetricsServer::start(&cfg.metrics_addr, rec.clone())
            .with_context(|| format!("bind --metrics-addr {:?}", cfg.metrics_addr))?;
        // Echoed unconditionally (not via log::info): with port 0 this
        // line is the only way to learn the bound port.
        eprintln!("metrics: serving http://{}/metrics", srv.local_addr());
        Some(srv)
    };
    revolver::obs::event("run_start", &[]);
    Ok(ObsSession { rec: Some(rec), server, profile: cfg.profile })
}

/// Close out a recorded run: terminal event (still scrapeable — the
/// server shuts down *after* it, so a final `/metrics` or `/events`
/// poll can observe the complete run), then server shutdown,
/// uninstall, JSONL flush, and the `--profile` tree if asked.
fn obs_finish(session: ObsSession) {
    use revolver::obs::Recorder as _;
    let ObsSession { rec, server, profile } = session;
    let Some(rec) = rec else { return };
    revolver::obs::event("run_end", &[("wall_s", rec.elapsed_s())]);
    drop(server); // graceful shutdown: drains scrapes, wakes long-polls
    revolver::obs::uninstall();
    rec.flush();
    if profile {
        print!("{}", rec.profile_report());
    }
}

/// Load a graph: surrogate dataset name, or a file path (.txt/.bin).
///
/// Reads `--ingest` directly (besides [`config_from`], which runs
/// *after* this in every command): [`Args::get`] marks a flag consumed
/// without removing it, so both reads see the same value.
fn load_graph(args: &mut Args) -> Result<(String, Graph)> {
    let name = args.get("graph").unwrap_or_else(|| "lj".to_string());
    let vertices: usize = args.get_or("vertices", 16384)?;
    let seed: u64 = args.get_or("graph-seed", 7)?;
    let ingest: IngestMode = args.get_or("ingest", IngestMode::default())?;
    if let Some(ds) = Dataset::from_name(&name) {
        let g = generate_dataset(ds, vertices, seed)?;
        return Ok((ds.name().to_string(), g));
    }
    let path = std::path::Path::new(&name);
    if !path.exists() {
        bail!(
            "--graph {name:?} is neither a dataset name ({:?}) nor an existing file",
            Dataset::ALL.iter().map(|d| d.name()).collect::<Vec<_>>()
        );
    }
    let g = if name.ends_with(".bin") {
        io::load_binary(path)?
    } else {
        io::load_edge_list_with(path, ingest)?
    };
    let stem = path.file_stem().unwrap_or_default().to_string_lossy().to_string();
    Ok((stem, g))
}

fn cmd_partition(mut args: Args) -> Result<(), CliError> {
    // `--algo` is accepted as a short alias of `--algorithm`.
    let algorithm = args
        .get("algorithm")
        .or_else(|| args.get("algo"))
        .unwrap_or_else(|| "revolver".to_string());
    let evaluate = args.get_bool("evaluate");
    let (gname, g) = load_graph(&mut args)?;
    let cfg = config_from(&mut args).map_err(CliError::usage)?;
    args.finish()?;

    let k = cfg.parts;
    let obs = obs_setup(&cfg)?;
    revolver::obs::log::info(&format!(
        "partitioning {gname} (|V|={}, |E|={}) with {algorithm}, k={k}, engine={:?}",
        with_commas(g.num_vertices() as u64),
        with_commas(g.num_edges() as u64),
        cfg.engine,
    ));
    let sw = Stopwatch::start();
    let mut resumed_from = None;
    let resume_snap = match cfg.resume {
        true => revolver::fault::load_latest(std::path::Path::new(&cfg.checkpoint_dir))?,
        false => None,
    };
    let out = match resume_snap {
        Some(snap) => {
            // Continue an interrupted iterative run from its last
            // durable superstep: same assignment, same (or warm-start
            // degraded) LA state, and only the remaining step budget.
            // One-shot algorithms never checkpoint, so resume is an
            // iterative-family affair.
            if snap.seed != cfg.seed || snap.k as usize != k {
                return Err(anyhow!(
                    "checkpoint mismatch: snapshot has seed={} k={}, run has seed={} k={k}",
                    snap.seed,
                    snap.k,
                    cfg.seed
                )
                .into());
            }
            if snap.labels.len() != g.num_vertices() {
                return Err(anyhow!(
                    "checkpoint mismatch: snapshot covers {} vertices, graph has {}",
                    snap.labels.len(),
                    g.num_vertices()
                )
                .into());
            }
            resumed_from = Some(snap.step);
            revolver::obs::log::info(&format!(
                "resuming from checkpoint at step {} ({})",
                snap.step,
                if snap.la.is_some() { "exact LA slab" } else { "warm-start LA" },
            ));
            let mut rcfg = cfg.clone();
            rcfg.max_steps = cfg.max_steps.saturating_sub(snap.step).max(1);
            match algorithm.to_lowercase().as_str() {
                "revolver" => {
                    revolver::partitioners::revolver::resume(
                        &g,
                        &rcfg,
                        snap.labels,
                        snap.la.as_ref(),
                    )
                    .map_err(CliError::aborted)?
                }
                "spinner" => revolver::partitioners::spinner::refine(&g, &rcfg, snap.labels)
                    .map_err(CliError::aborted)?,
                other => {
                    return Err(CliError::usage(anyhow!(
                        "--resume supports the iterative algorithms (spinner|revolver), \
                         got {other:?}"
                    )))
                }
            }
        }
        None => {
            if cfg.resume {
                revolver::obs::log::info(&format!(
                    "no checkpoint in {:?}; starting fresh",
                    cfg.checkpoint_dir
                ));
            }
            let p = by_name(&algorithm, cfg.clone()).map_err(CliError::usage)?;
            p.try_partition(&g).map_err(CliError::aborted)?
        }
    };
    obs_finish(obs);
    let q = quality::evaluate(&g, &out.labels, k);
    println!("graph:               {gname}");
    println!("algorithm:           {algorithm}");
    println!("partitions:          {k}");
    println!("steps:               {}", out.trace.steps());
    if let Some(step) = resumed_from {
        println!("resumed from step:   {step}");
    }
    println!("converged at:        {:?}", out.trace.converged_at);
    println!("vertex evals:        {}", with_commas(out.trace.total_evaluated));
    println!("local edges:         {:.4}", q.local_edges);
    println!("edge cuts:           {:.4}", 1.0 - q.local_edges);
    println!("max normalized load: {:.4}", q.max_normalized_load);
    println!("max norm edge load:  {:.4}", q.max_normalized_edge_load);
    println!("comm volume/vertex:  {:.4}", q.mean_communication_volume);
    println!("wall time:           {:.2}s", sw.elapsed_s());
    if evaluate {
        // Full per-partition load breakdown (out-edge units).
        let loads = quality::partition_loads(&g, &out.labels, k);
        let counts = quality::partition_vertex_counts(&out.labels, k);
        println!("per-partition loads (out-edges / vertices):");
        for l in 0..k {
            println!("  p{l:<3} {:>12} {:>12}", with_commas(loads[l]), with_commas(counts[l]));
        }
    }
    Ok(())
}

/// Partition an edge-list file straight off disk (no CSR): the
/// streaming subsystem's chunked reader feeds one LDG/Fennel pass (or
/// N restreaming passes). `--evaluate` additionally loads the graph
/// afterwards to report cut quality; `--out` writes one label per
/// dense vertex id.
fn cmd_stream(mut args: Args) -> Result<(), CliError> {
    let file = args
        .get("file")
        .filter(|f| !f.is_empty())
        .ok_or_else(|| CliError::usage(anyhow!("stream requires --file <edges.txt>")))?;
    let algorithm = args.get("algorithm").unwrap_or_else(|| "fennel".to_string());
    let evaluate = args.get_bool("evaluate");
    let out = args.get("out");
    let cfg = config_from(&mut args).map_err(CliError::usage)?;
    args.finish()?;
    let algo: StreamAlgo = algorithm.parse().map_err(CliError::usage)?;

    let obs = obs_setup(&cfg)?;
    let sw = Stopwatch::start();
    let res = revolver::stream::partition_edge_list_file(&file, &cfg, algo)?;
    obs_finish(obs);
    let elapsed = sw.elapsed_s();
    let k = cfg.parts;
    let max_load = res.loads.iter().cloned().fold(0.0f64, f64::max);
    let expected = res.edges as f64 / k as f64;
    println!("file:                {file}");
    println!("algorithm:           {}", algo.name());
    println!("partitions:          {k}");
    println!("vertices:            {}", with_commas(res.vertices as u64));
    println!("edges streamed:      {}", with_commas(res.edges));
    println!(
        "max normalized load: {:.4}",
        if expected > 0.0 { max_load / expected } else { 0.0 }
    );
    println!("wall time:           {elapsed:.2}s");
    println!(
        "throughput:          {:.2}M edges/s",
        res.edges as f64 / elapsed.max(1e-9) / 1e6
    );

    if let Some(out) = out.filter(|o| !o.is_empty()) {
        use std::fmt::Write as _;
        let mut text = String::with_capacity(res.labels.len() * 4);
        for &l in &res.labels {
            let _ = writeln!(text, "{l}");
        }
        std::fs::write(&out, text)?;
        println!("labels:              {out} (one per dense vertex id)");
    }

    if evaluate {
        // The loader densifies ids in the same first-appearance order
        // as the stream, so the labels line up with this CSR.
        let g = io::load_edge_list_with(&file, cfg.ingest)?;
        let q = quality::evaluate(&g, &res.labels, k);
        println!("local edges:         {:.4}", q.local_edges);
        println!("edge cuts:           {:.4}", 1.0 - q.local_edges);
        println!("max norm edge load:  {:.4}", q.max_normalized_edge_load);
        println!("comm volume/vertex:  {:.4}", q.mean_communication_volume);
    }
    Ok(())
}

/// Evolve a graph over N epochs — synthetic churn or a recorded update
/// log — maintaining the partition incrementally: greedy arrival
/// placement plus a frontier-seeded repair pass per epoch. Reports
/// per-epoch quality and evaluated vertices; `--out` writes the
/// quality-over-time trace as CSV (step column = epoch).
fn cmd_dynamic(mut args: Args) -> Result<(), CliError> {
    use revolver::dynamic::{
        read_update_log_named, ChurnRecipe, DynamicGraph, IncrementalPartitioner, UpdateBatch,
    };
    use revolver::metrics::trace::RunTrace;
    use revolver::multilevel::Refiner;

    let algorithm = args
        .get("algorithm")
        .or_else(|| args.get("algo"))
        .unwrap_or_else(|| "spinner".to_string());
    let churn = args.get("churn");
    let log = args.get("update-log");
    let epochs: u32 = args.get_or("epochs", 5)?;
    let out = args.get("out");
    let (gname, g) = load_graph(&mut args)?;
    let cfg = config_from(&mut args).map_err(CliError::usage)?;
    args.finish()?;

    let refiner = match algorithm.to_lowercase().as_str() {
        "spinner" => Refiner::Spinner,
        "revolver" => Refiner::Revolver,
        other => {
            return Err(CliError::usage(anyhow!(
                "dynamic repairs with spinner|revolver, got {other:?}"
            )))
        }
    };
    let recipe: Option<ChurnRecipe> = match (&churn, &log) {
        (Some(c), None) => Some(c.parse().map_err(CliError::usage)?),
        (None, Some(_)) => None,
        (Some(_), Some(_)) => {
            return Err(CliError::usage(anyhow!(
                "--churn and --update-log are mutually exclusive"
            )))
        }
        (None, None) => {
            return Err(CliError::usage(anyhow!(
                "dynamic requires --churn <recipe> or --update-log <file>"
            )))
        }
    };
    let log_batches: Vec<UpdateBatch> = match &log {
        Some(path) => {
            let bytes = std::fs::read(path).with_context(|| format!("open {path:?}"))?;
            // `truncate@log` fault: keep only the first P% of lines
            // before parsing, simulating a torn write. The lossy UTF-8
            // round-trip only happens on this injected path.
            let bytes = match cfg.faults.truncate_log_pct {
                Some(pct) => {
                    let text = String::from_utf8_lossy(&bytes);
                    let total = text.lines().count();
                    let kept = revolver::fault::truncate_lines(&text, pct);
                    revolver::obs::log::info(&format!(
                        "fault truncate@log: {path} cut to {} of {total} lines ({pct}%)",
                        kept.lines().count(),
                    ));
                    kept.into_bytes()
                }
                None => bytes,
            };
            read_update_log_named(
                std::io::Cursor::new(bytes),
                g.num_vertices(),
                path,
                cfg.ingest,
            )?
        }
        None => Vec::new(),
    };
    let epochs = if log.is_some() { log_batches.len() as u32 } else { epochs };

    let k = cfg.parts;
    let seed = cfg.seed;
    let obs = obs_setup(&cfg)?;
    revolver::obs::log::info(&format!(
        "dynamic: {gname} (|V|={}, |E|={}) repair={algorithm} k={k} epochs={epochs} {}",
        with_commas(g.num_vertices() as u64),
        with_commas(g.num_edges() as u64),
        churn.as_deref().unwrap_or("update-log"),
    ));
    let sw = Stopwatch::start();

    // The dynamic driver owns the checkpoint stream at epoch cadence;
    // the cold-start partitioner and the per-epoch repair passes must
    // not interleave their own step-cadence snapshots into the same
    // directory (resume keys off the newest cursor).
    let mut inner_cfg = cfg.clone();
    inner_cfg.checkpoint_dir.clear();
    inner_cfg.resume = false;

    let resume_snap = match cfg.resume {
        true => revolver::fault::load_latest(std::path::Path::new(&cfg.checkpoint_dir))?,
        false => None,
    };
    let (mut inc, start_epoch) = match resume_snap {
        Some(snap) => {
            if snap.seed != seed || snap.k as usize != k {
                return Err(anyhow!(
                    "checkpoint mismatch: snapshot has seed={} k={}, run has seed={seed} k={k}",
                    snap.seed,
                    snap.k
                )
                .into());
            }
            if snap.epoch > epochs as u64 {
                return Err(anyhow!(
                    "checkpoint mismatch: snapshot is at epoch {}, run has only {epochs}",
                    snap.epoch
                )
                .into());
            }
            let start = snap.epoch as u32;
            // Replay the update stream (not the repairs) up to the
            // snapshot epoch: batches are deterministic — seeded churn
            // over the evolving CSR, or the recorded log — so applying
            // them rebuilds exactly the graph the snapshot labelled.
            let mut dg = DynamicGraph::new(g, cfg.compact_ratio);
            let mut touched = Vec::new();
            for e in 0..start {
                // Epochs always leave the overlay compacted, so churn
                // generation must see the compacted CSR to reproduce
                // the original batches bit-for-bit.
                dg.compact();
                let batch = match &recipe {
                    Some(r) => r.generate(dg.base(), seed ^ (e as u64 + 1)),
                    None => log_batches[e as usize].clone(),
                };
                dg.apply(&batch, &mut touched);
            }
            dg.compact();
            let evolved = dg.to_graph();
            if snap.labels.len() != evolved.num_vertices() {
                return Err(anyhow!(
                    "checkpoint mismatch: snapshot covers {} vertices, epoch-{start} graph \
                     has {} (different churn/log inputs?)",
                    snap.labels.len(),
                    evolved.num_vertices()
                )
                .into());
            }
            revolver::obs::log::info(&format!(
                "resuming from checkpoint at epoch {start} (|V|={})",
                with_commas(evolved.num_vertices() as u64)
            ));
            let inc = IncrementalPartitioner::from_assignment(
                evolved,
                inner_cfg.clone(),
                refiner,
                snap.labels,
            );
            let q0 = quality::evaluate(inc.current(), inc.labels(), k);
            println!(
                "epoch {start:>3}: local={:.4} mnl={:.4} (resumed from checkpoint)",
                q0.local_edges, q0.max_normalized_load
            );
            (inc, start)
        }
        None => {
            if cfg.resume {
                revolver::obs::log::info(&format!(
                    "no checkpoint in {:?}; starting fresh",
                    cfg.checkpoint_dir
                ));
            }
            let inc = IncrementalPartitioner::new(g, inner_cfg.clone(), refiner)
                .map_err(CliError::aborted)?;
            let q0 = quality::evaluate(inc.current(), inc.labels(), k);
            println!(
                "epoch {:>3}: local={:.4} mnl={:.4} (cold partition)",
                "-", q0.local_edges, q0.max_normalized_load
            );
            (inc, 0)
        }
    };

    let mut checkpointer = (!cfg.checkpoint_dir.is_empty())
        .then(|| revolver::fault::Checkpointer::new(cfg.checkpoint_dir.as_str(), &cfg.faults));
    let mut trace = RunTrace::default();
    for e in start_epoch..epochs {
        let batch = match &recipe {
            Some(r) => r.generate(inc.current(), seed ^ (e as u64 + 1)),
            None => log_batches[e as usize].clone(),
        };
        let stats = inc.epoch(&batch).map_err(CliError::aborted)?;
        inc.record_epoch(&mut trace, e, &stats);
        // Epoch-cadence durability: the overlay is compacted and the
        // repair pass has joined, so labels/loads are quiescent. A
        // failed write (including the injected `io@checkpoint` fault)
        // only widens the replay window — log and continue.
        if let Some(ck) = checkpointer.as_mut() {
            if (e + 1) % cfg.checkpoint_every.max(1) == 0 || e + 1 == epochs {
                let labels = inc.labels().to_vec();
                let loads = quality::partition_loads(inc.current(), &labels, k);
                let snap = revolver::fault::Snapshot {
                    seed,
                    step: 0,
                    epoch: (e + 1) as u64,
                    k: k as u32,
                    labels,
                    loads,
                    la: None,
                };
                if let Err(err) = ck.write(&snap) {
                    revolver::obs::log::info(&format!(
                        "checkpoint at epoch {} failed (continuing): {err:#}",
                        e + 1
                    ));
                }
            }
        }
        let p = trace.final_point().expect("record_epoch pushed a point");
        println!(
            "epoch {e:>3}: local={:.4} mnl={:.4} placed={} seeds={} steps={} evaluated={}",
            p.local_edges,
            p.max_normalized_load,
            stats.placed,
            stats.seeds,
            stats.repair_steps,
            with_commas(stats.evaluated),
        );
    }
    println!(
        "totals:    |V|={} |E|={} repair steps={} evaluated={} wall={:.2}s",
        with_commas(inc.current().num_vertices() as u64),
        with_commas(inc.current().num_edges() as u64),
        inc.total_repair_steps(),
        with_commas(inc.total_evaluated()),
        sw.elapsed_s()
    );
    obs_finish(obs);
    if let Some(out) = out.filter(|o| !o.is_empty()) {
        std::fs::write(&out, trace.to_csv())?;
        println!(
            "trace:     {out} (one row per epoch; step=epoch, \
             migrations=rebalance moves, mean_score=repair wall seconds, \
             elapsed_s=cumulative epoch wall)"
        );
    }
    Ok(())
}

fn cmd_sweep(mut args: Args) -> Result<(), CliError> {
    let graphs: Vec<String> =
        args.get_list("graphs", &["lj".to_string()])?;
    let algorithms: Vec<String> = args.get_list(
        "algorithms",
        &[
            "revolver".to_string(),
            "spinner".to_string(),
            "hash".to_string(),
            "range".to_string(),
        ],
    )?;
    let parts: Vec<usize> = args.get_list("parts", &[2usize, 4, 8, 16, 32])?;
    let runs: u32 = args.get_or("runs", 1)?;
    let out_dir = args.get("out").unwrap_or_else(|| "results".to_string());
    let vertices: usize = args.get_or("vertices", 16384)?;
    let base_cfg = config_from(&mut args).map_err(CliError::usage)?;
    args.finish()?;
    let obs = obs_setup(&base_cfg)?;

    let mut report = Report::new();
    for gname in &graphs {
        let ds = Dataset::from_name(gname)
            .with_context(|| format!("unknown dataset {gname:?} in --graphs"))?;
        let g = generate_dataset(ds, vertices, 7)?;
        revolver::obs::log::info(&format!(
            "sweep: {gname} |V|={} |E|={}",
            with_commas(g.num_vertices() as u64),
            with_commas(g.num_edges() as u64)
        ));
        for algo in &algorithms {
            for &k in &parts {
                let mut le_sum = 0.0;
                let mut mnl_sum = 0.0;
                let mut steps_sum = 0u32;
                let sw = Stopwatch::start();
                for run in 0..runs {
                    let mut cfg = base_cfg.clone();
                    cfg.parts = k;
                    cfg.seed = base_cfg.seed + run as u64;
                    let p = by_name(algo, cfg).map_err(CliError::usage)?;
                    let out = p.try_partition(&g).map_err(CliError::aborted)?;
                    let q = quality::evaluate(&g, &out.labels, k);
                    le_sum += q.local_edges;
                    mnl_sum += q.max_normalized_load;
                    steps_sum += out.trace.steps();
                }
                let row = ResultRow {
                    graph: gname.clone(),
                    algorithm: algo.clone(),
                    parts: k as u32,
                    local_edges: le_sum / runs as f64,
                    max_normalized_load: mnl_sum / runs as f64,
                    steps: steps_sum / runs,
                    wall_time_s: sw.elapsed_s() / runs as f64,
                    runs,
                };
                revolver::obs::log::info(&format!(
                    "  {algo:>9} k={k:<4} local={:.4} mnl={:.4}",
                    row.local_edges, row.max_normalized_load
                ));
                report.push(row);
            }
        }
    }
    obs_finish(obs);
    print!("{}", report.to_table());
    report.write_files(std::path::Path::new(&out_dir), "fig3_sweep")?;
    revolver::obs::log::info(&format!("wrote {out_dir}/fig3_sweep.csv and .json"));
    Ok(())
}

fn cmd_convergence(mut args: Args) -> Result<(), CliError> {
    let (gname, g) = load_graph(&mut args)?;
    let out_dir = args.get("out").unwrap_or_else(|| "results".to_string());
    let mut cfg = config_from(&mut args).map_err(CliError::usage)?;
    args.finish()?;
    cfg.trace_every = cfg.trace_every.max(1);
    // Figure 4 runs the full step budget without early halting.
    cfg.halt_window = u32::MAX;

    std::fs::create_dir_all(&out_dir)?;
    let obs = obs_setup(&cfg)?;
    for algo in ["revolver", "spinner"] {
        let p = by_name(algo, cfg.clone()).map_err(CliError::usage)?;
        revolver::obs::log::info(&format!("convergence: {algo} on {gname} k={}", cfg.parts));
        let out = p.try_partition(&g).map_err(CliError::aborted)?;
        let path = format!("{out_dir}/fig4_{algo}_{gname}_k{}.csv", cfg.parts);
        std::fs::write(&path, out.trace.to_csv())?;
        let last = out.trace.final_point().unwrap();
        println!(
            "{algo:>9}: final local edges {:.4}, max norm load {:.4} ({} steps) -> {path}",
            last.local_edges,
            last.max_normalized_load,
            out.trace.steps()
        );
    }
    obs_finish(obs);
    Ok(())
}

fn cmd_stats(mut args: Args) -> Result<(), CliError> {
    let all = args.get_bool("all");
    let vertices: usize = args.get_or("vertices", 16384)?;
    let seed: u64 = args.get_or("graph-seed", 7)?;
    let datasets: Vec<Dataset> = if all {
        Dataset::ALL.to_vec()
    } else {
        let name = args.get("graph").unwrap_or_else(|| "lj".to_string());
        vec![Dataset::from_name(&name).with_context(|| format!("unknown dataset {name:?}"))?]
    };
    args.finish()?;

    println!(
        "{:<8} {:>10} {:>12} {:>10} {:>8} | paper: {:>9} {:>9} {:>7} {:>6}",
        "graph", "|V|", "|E|", "D(x1e-5)", "skew", "|V|", "|E|", "D", "skew"
    );
    for ds in datasets {
        let g = generate_dataset(ds, vertices, seed)?;
        let s = stats::compute(&g);
        let p = ds.paper_stats();
        println!(
            "{:<8} {:>10} {:>12} {:>10.3} {:>8.3} | {:>9} {:>9} {:>7.2} {:>6.2}",
            ds.name(),
            with_commas(s.vertices as u64),
            with_commas(s.edges as u64),
            s.density * 1e5,
            s.skewness,
            format!("{:.2}M", p.vertices / 1e6),
            format!("{:.2}M", p.edges / 1e6),
            p.density_e5,
            p.skew,
        );
    }
    Ok(())
}

fn cmd_generate(mut args: Args) -> Result<(), CliError> {
    let name = args.get("graph").unwrap_or_else(|| "lj".to_string());
    let vertices: usize = args.get_or("vertices", 16384)?;
    let seed: u64 = args.get_or("graph-seed", 7)?;
    let format = args.get("format").unwrap_or_else(|| "bin".to_string());
    let out = args
        .get("out")
        .unwrap_or_else(|| format!("data/{name}_{vertices}.{format}"));
    args.finish()?;

    let ds = Dataset::from_name(&name).with_context(|| format!("unknown dataset {name:?}"))?;
    let g = generate_dataset(ds, vertices, seed)?;
    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    match format.as_str() {
        "bin" => io::save_binary(&g, &out)?,
        "txt" => io::save_edge_list(&g, &out)?,
        other => {
            return Err(CliError::usage(anyhow!("unknown format {other:?} (txt|bin)")))
        }
    }
    println!(
        "wrote {out}: |V|={} |E|={}",
        with_commas(g.num_vertices() as u64),
        with_commas(g.num_edges() as u64)
    );
    Ok(())
}

/// `report`: render a text report from an `--obs-log` JSONL file —
/// flow matrix, per-partition trajectories, convergence attribution.
/// `--partial` accepts the prefix a killed run left behind.
fn cmd_report(mut args: Args) -> Result<(), CliError> {
    let path = args
        .get("obs-log")
        .filter(|p| !p.is_empty())
        .ok_or_else(|| CliError::usage(anyhow!("report requires --obs-log <file.jsonl>")))?;
    let partial = args.get_bool("partial");
    args.finish()?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
    let report =
        revolver::obs::report::render_report(&text, partial).map_err(|e| anyhow!("{path}: {e}"))?;
    print!("{report}");
    Ok(())
}

fn cmd_info(mut args: Args) -> Result<(), CliError> {
    let artifacts = args.get("artifacts").unwrap_or_else(|| "artifacts".to_string());
    args.finish()?;
    println!("revolver {} ({})", env!("CARGO_PKG_VERSION"), env!("CARGO_PKG_NAME"));
    println!("threads available: {}", std::thread::available_parallelism()?.get());
    match revolver::runtime::Runtime::open(&artifacts) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts ({artifacts}):");
            for e in &rt.manifest().entries {
                println!("  {:<22} batch={} k={} file={}", e.name, e.batch, e.k, e.file);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e:#})"),
    }
    Ok(())
}
