//! Shared vertex-program execution engine — the superstep runtime every
//! partitioner plugs into.
//!
//! The paper's core framing is vertex-centric: "each vertex is assigned
//! an autonomous agent" that repeatedly senses its neighbourhood and
//! acts. Spinner (Martella et al., arXiv:1404.3861) shows the same
//! computation expressed as a reusable *vertex program* over a BSP
//! runtime — and, crucially, that late in a run only vertices whose
//! neighbourhood changed need re-evaluation. This module factors both
//! ideas out of the individual partitioners:
//!
//! * [`VertexProgram`] — the algorithm: a phase-A (action/demand) hook,
//!   a phase-B (score/migrate/learn) hook, a per-worker scratch factory,
//!   and two coordinator-side hooks that freeze per-step data.
//! * [`run`] — the runtime: persistent workers, the four-barrier step
//!   protocol, the [`ExecutionModel`]::{Asynchronous, Synchronous}
//!   snapshot machinery, per-step aggregate collection, trace recording
//!   and convergence-driven halting.
//!
//! ## Step protocol
//!
//! Per step, coordinator (`==`) and the `t` workers (`--`) meet at four
//! barriers:
//!
//! ```text
//! == collect active frontier (or halt if empty); publish step plan;
//!    reset demand; freeze snapshots (sync mode); prepare_phase_a
//! W1 ─────────────────────────────────────────────────────────────
//! -- phase_a over own work list (action selection, demand registration)
//! W2 ─────────────────────────────────────────────────────────────
//! == prepare_phase_b (e.g. freeze migration probabilities)
//! W2b ────────────────────────────────────────────────────────────
//! -- phase_b over own work list (score, migrate, learn); send StepStats
//! W3 ─────────────────────────────────────────────────────────────
//! == aggregate stats; trace; convergence check
//! ```
//!
//! Workers stay alive across the whole run: no thread-spawn cost inside
//! the step loop, and per-worker scratch is built *on* the worker
//! thread, so `!Send` resources (PJRT executable handles) can live in
//! it.
//!
//! ## Scheduling & the active set
//!
//! Work arrives at the phase hooks as a **work list** (`&[VertexId]`),
//! not a fixed range. Under [`Frontier::Off`] the list is the identity
//! `0..n` split once by [`crate::config::Schedule`] (the paper's
//! vertex-balanced |V|/n split, or the degree-balanced split that keeps
//! a power-law hub chunk from serializing the step barrier); iteration
//! order and RNG streams are bit-identical to the legacy range-based
//! engine. Under [`Frontier::On`] (the default) the coordinator keeps
//! an **epoch-stamped activation array**: `stamp[v] >= step` means `v`
//! is active this step. Programs report the three wake events through
//! [`StepCtx`] — a migration ([`StepCtx::migrate`]), a published-λ
//! change ([`StepCtx::publish`]), each waking the vertex *and* its
//! undirected (in + out) neighbourhood, and an unsettled vertex that
//! still wants to move ([`StepCtx::wake`], self only). Stamps are
//! monotone (`fetch_max(step + 1)`), so nothing is ever cleared
//! per-step; each superstep the coordinator collects the frontier and
//! rebuilds **degree-balanced chunks over the frontier only**
//! ([`Chunks::by_weight_subset`]), so thread balance tracks live work.
//! An empty frontier halts the run immediately (no vertex can change —
//! see [`ConvergenceDetector::observe_empty_frontier`]), and the
//! convergence score becomes a mean over *evaluated* vertices
//! (DESIGN.md §Active-set).

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{mpsc, Arc, Barrier, Mutex};

use crate::config::{ExecutionModel, Frontier, Init, RevolverConfig, Schedule};
use crate::coordinator::{Chunks, ConvergenceDetector};
use crate::graph::Graph;
use crate::metrics::quality;
use crate::metrics::trace::{RunTrace, TracePoint};
use crate::partition::{DemandTracker, InitialAssignment, PartitionState};
use crate::partitioners::PartitionOutput;
use crate::util::rng::Rng;
use crate::util::Stopwatch;
use crate::VertexId;

/// A run-level failure the engine *contains* and reports instead of
/// letting it deadlock the barrier protocol. Today the only variant is
/// a worker panic: every phase hook runs under `catch_unwind`, the
/// first panic is recorded here, and the run unwinds through the
/// normal stop/barrier shutdown with all threads joined.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// A worker's phase hook (or scratch constructor) panicked. The
    /// run's labels/loads may be mid-migration inconsistent, so no
    /// partial output is returned.
    WorkerPanic {
        /// Worker index in `0..threads`.
        worker: usize,
        /// Superstep the panic surfaced in (0-based).
        step: u32,
        /// `"scratch"`, `"A"`, or `"B"`.
        phase: &'static str,
        /// The panic payload, when it was a string.
        message: String,
    },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::WorkerPanic { worker, step, phase, message } => write!(
                f,
                "worker {worker} panicked in phase {phase} at step {step}: {message}"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

/// Extract a human-readable message from a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Per-worker aggregates reported from the phase hooks and reduced by
/// the coordinator each step (replaces ad-hoc bit-cast atomics).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepStats {
    /// Σ over own vertices of the convergence score contribution.
    pub score_sum: f64,
    /// Vertices of the own work list migrated this step.
    pub migrations: u64,
    /// Vertices evaluated — owned by the engine (set from the work-list
    /// length after the phases run); programs leave it at 0.
    pub evaluated: u64,
}

impl StepStats {
    pub fn merged(self, other: StepStats) -> StepStats {
        StepStats {
            score_sum: self.score_sum + other.score_sum,
            migrations: self.migrations + other.migrations,
            evaluated: self.evaluated + other.evaluated,
        }
    }
}

/// One superstep's work assignment: the vertices to evaluate plus the
/// chunk layout splitting them across workers. Shared immutably via
/// `Arc` — under [`Frontier::Off`] a single identity plan is reused for
/// the whole run; under [`Frontier::On`] the coordinator republishes a
/// fresh plan per step.
struct StepPlan {
    verts: Vec<VertexId>,
    chunks: Chunks,
    /// Workers record first-wake transitions into per-worker worklists
    /// this step (the O(frontier) collection path — set when the current
    /// frontier is below `cfg.frontier_dense_frac · n`, so the *next*
    /// step's frontier can be assembled without an O(n) stamp scan).
    record: bool,
}

impl StepPlan {
    /// Worker `c`'s slice of this step's work (empty when the frontier
    /// produced fewer chunks than there are workers).
    fn slice(&self, c: usize) -> &[VertexId] {
        if c < self.chunks.len() {
            &self.verts[self.chunks.range(c)]
        } else {
            &[]
        }
    }
}

/// Per-step frozen snapshots for the synchronous execution model
/// (empty vectors in asynchronous mode).
#[derive(Default)]
struct StepSnapshots {
    labels: Vec<u32>,
    published: Vec<u32>,
}

/// Read-side view a vertex program gets during a step. Unifies the
/// live-vs-frozen read paths the two execution models need (in
/// asynchronous mode reads hit the shared atomics, in synchronous mode
/// the per-step snapshot) and owns the active-set wake protocol: all
/// state changes a program makes during phase B go through
/// [`StepCtx::publish`] / [`StepCtx::migrate`] / [`StepCtx::wake`], so
/// activation stamps can never drift from the events that require them.
pub struct StepCtx<'a> {
    pub graph: &'a Graph,
    pub state: &'a PartitionState,
    pub demand: &'a DemandTracker,
    /// 0-based step index.
    pub step: u32,
    published: &'a [AtomicU32],
    snap: &'a StepSnapshots,
    sync: bool,
    /// Epoch stamps of the active-set scheduler; `None` = frontier off
    /// (every wake is a no-op and all vertices run every step).
    stamps: Option<&'a [AtomicU32]>,
    /// Per-worker wake worklist (the O(frontier) collection path).
    /// `Some` only when the step plan asked workers to record: a vertex
    /// is pushed exactly when its stamp *transitions* to `step + 1` —
    /// `fetch_max` returns the previous value, and during step `s` every
    /// pre-existing stamp is ≤ `s`, so the first wake of a vertex (and
    /// only the first, across all workers: the atomic max hands the
    /// transition to exactly one caller) observes `prev < s + 1`. The
    /// merged per-worker lists are therefore the exact deduplicated
    /// next-step frontier, with the monotone stamps retained as the
    /// correctness oracle (debug builds re-scan and compare).
    wake_sink: Option<&'a RefCell<Vec<VertexId>>>,
    /// k×k migration flow accumulator (`--diag` only; `None` = diag
    /// off, the default — [`StepCtx::migrate`] stays branch-plus-load
    /// on the disabled path).
    flow: Option<&'a crate::obs::diag::FlowMatrix>,
}

impl StepCtx<'_> {
    /// ψ(u): the partition label of `u` — live (async) or step-frozen
    /// (sync).
    #[inline]
    pub fn label(&self, u: VertexId) -> u32 {
        if self.sync {
            self.snap.labels[u as usize]
        } else {
            self.state.label(u)
        }
    }

    /// The per-vertex published value (λ(u) for Revolver) — live (async)
    /// or step-frozen (sync).
    #[inline]
    pub fn published(&self, u: VertexId) -> u32 {
        if self.sync {
            self.snap.published[u as usize]
        } else {
            self.published[u as usize].load(Ordering::Relaxed)
        }
    }

    /// True when the engine is running frontier-driven supersteps.
    #[inline]
    pub fn frontier_on(&self) -> bool {
        self.stamps.is_some()
    }

    /// Publish `val` for vertex `v`. Writes always hit the live array;
    /// synchronous-mode *readers* keep seeing the frozen value until the
    /// next step. A *changed* value is a wake event: `v` and its whole
    /// undirected neighbourhood re-enter the frontier next step (their
    /// scores depend on λ(v)).
    #[inline]
    pub fn publish(&self, v: VertexId, val: u32) {
        let old = self.published[v as usize].swap(val, Ordering::Relaxed);
        if old != val {
            self.wake_neighborhood(v);
        }
    }

    /// Migrate `v` to `to` with load mass `mass` (see
    /// [`PartitionState::migrate`]). An actual move is a wake event for
    /// `v` and its undirected neighbourhood. Returns the previous label.
    ///
    /// Under `--diag` every call lands in the flow matrix — including
    /// degenerate `from == to` calls — so the matrix's cell total
    /// equals the programs' per-call `migrations` counters exactly
    /// (the row-sum equality `tests/diag.rs` pins).
    #[inline]
    pub fn migrate(&self, v: VertexId, to: u32, mass: u32) -> u32 {
        let from = self.state.migrate(v, to, mass);
        if let Some(fm) = self.flow {
            fm.record(from, to, mass as u64);
        }
        if from != to {
            self.wake_neighborhood(v);
        }
        from
    }

    /// Keep `v` (and only `v`) in the frontier next step — for vertices
    /// that still want to move but were denied (capacity gate, lost coin
    /// flip) or are otherwise unsettled. No-op with the frontier off.
    #[inline]
    pub fn wake(&self, v: VertexId) {
        self.stamp_wake(v);
    }

    /// Wake `v` and every undirected (in or out) neighbour for the next
    /// step. Stamps are monotone maxima, so concurrent wakes from racing
    /// workers merge for free and nothing is ever cleared per-step.
    #[inline]
    fn wake_neighborhood(&self, v: VertexId) {
        if self.stamps.is_some() {
            self.stamp_wake(v);
            for &u in self.graph.neighbors(v) {
                self.stamp_wake(u);
            }
        }
    }

    /// Monotone stamp bump, recording the first-wake transition into the
    /// worker's worklist when the step plan asked for it (see
    /// [`StepCtx::wake_sink`]).
    #[inline]
    fn stamp_wake(&self, v: VertexId) {
        if let Some(stamps) = self.stamps {
            let next = self.step + 1;
            let prev = stamps[v as usize].fetch_max(next, Ordering::Relaxed);
            if prev < next {
                if let Some(sink) = self.wake_sink {
                    sink.borrow_mut().push(v);
                }
            }
        }
    }
}

/// A vertex-centric partitioning algorithm, expressed against the
/// engine's superstep protocol. Implementations hold only configuration
/// and (optionally) vertex-indexed persistent state they own themselves;
/// per-run mutable state lives in the engine (shared) or in
/// [`VertexProgram::Scratch`] (per worker).
///
/// **Work lists.** Both phase hooks receive the worker's work list for
/// the step. The engine guarantees (a) the lists of distinct workers
/// are disjoint within a step, (b) a worker's phase-A and phase-B lists
/// of the same step are identical, and (c) with the frontier off the
/// concatenated lists are exactly `0..n` in order, every step. Programs
/// may therefore keep positional phase-A→B hand-off state in scratch
/// (index `i` of the list), and vertex-indexed state shared across
/// workers needs no locking *within* a step.
pub trait VertexProgram: Sync {
    /// Per-worker mutable scratch. Built on the worker thread itself
    /// ([`VertexProgram::make_scratch`]), so it may hold `!Send`
    /// resources such as PJRT executable handles.
    type Scratch;
    /// Data the coordinator freezes before phase A of each step (e.g.
    /// Spinner's per-step penalty vector). `()` when nothing is frozen.
    type PhaseA: Send + Sync;
    /// Data the coordinator freezes between the phases (e.g. Spinner's
    /// migration probabilities, which depend on complete demand).
    type PhaseB: Send + Sync;

    /// Execution model to run under. Programs may override the config —
    /// Spinner is inherently BSP and always returns `Synchronous`.
    fn execution(&self) -> ExecutionModel;

    /// Salt XORed into `cfg.seed` for this program's RNG streams.
    fn rng_salt(&self) -> u64;

    /// Initial per-vertex published value (λ(v) for Revolver).
    fn init_published(&self, v: VertexId, state: &PartitionState) -> u32;

    /// Build one worker's scratch; called once, on the worker thread.
    fn make_scratch(&self) -> Self::Scratch;

    /// Coordinator hook before phase A (workers are parked at W1).
    fn prepare_phase_a(&self, g: &Graph, state: &PartitionState, step: u32) -> Self::PhaseA;

    /// Coordinator hook between the phases (workers parked at W2b);
    /// sees the step's complete migration demand.
    fn prepare_phase_b(
        &self,
        g: &Graph,
        state: &PartitionState,
        demand: &DemandTracker,
        step: u32,
    ) -> Self::PhaseB;

    /// Phase A over the worker's work list: action selection / candidate
    /// registration / demand accounting (§IV-D.1–2).
    fn phase_a(
        &self,
        ctx: &StepCtx<'_>,
        frozen: &Self::PhaseA,
        scratch: &mut Self::Scratch,
        work: &[VertexId],
        rng: &mut Rng,
    ) -> StepStats;

    /// Phase B over the worker's work list: score / migrate / learn
    /// (§IV-D.3–7).
    fn phase_b(
        &self,
        ctx: &StepCtx<'_>,
        frozen: &Self::PhaseB,
        scratch: &mut Self::Scratch,
        work: &[VertexId],
        rng: &mut Rng,
    ) -> StepStats;

    /// Learning-state snapshot for checkpointing, called on the
    /// coordinator between steps (workers parked at W1, so shared
    /// program state is quiescent). Programs with no state beyond the
    /// assignment return `None` (the default); Revolver dumps its LA
    /// slab so a resumed run keeps its learned action probabilities.
    fn la_checkpoint(&self) -> Option<crate::fault::LaSlab> {
        None
    }

    /// Aggregate decisiveness of the program's learning state over
    /// `verts` (the step's frontier), for the `--diag` observatory.
    /// Called on the coordinator while workers are parked at W1 — the
    /// same quiescence window as [`VertexProgram::la_checkpoint`], so
    /// reading shared learning state needs no extra coordination.
    /// Programs without probability rows return `None` (the default).
    fn la_decisiveness(&self, _verts: &[VertexId]) -> Option<crate::obs::diag::Decisiveness> {
        None
    }
}

/// Build the full-graph chunk layout `cfg` asks for.
pub fn chunks_for(g: &Graph, cfg: &RevolverConfig) -> Chunks {
    let n = g.num_vertices();
    match cfg.schedule {
        Schedule::Vertex => Chunks::new(n, cfg.threads),
        // 1 + deg: fixed per-vertex cost plus the CSR-bound edge work.
        Schedule::Degree => {
            Chunks::by_weight(n, cfg.threads, |v| 1 + g.out_degree(v as VertexId) as u64)
        }
    }
}

/// The initial assignment `cfg` asks for: uniform random (the paper),
/// or labels from a streaming pass (`--init stream:<algo>` — the
/// warm-start bridge into [`crate::stream`]).
pub fn initial_assignment(g: &Graph, cfg: &RevolverConfig) -> InitialAssignment {
    match cfg.init {
        Init::Random => InitialAssignment::Random(cfg.seed),
        Init::Stream(algo) => {
            InitialAssignment::Given(crate::stream::stream_labels(g, algo, cfg))
        }
    }
}

/// The active set a run starts from (step 0's frontier).
///
/// `All` is the classic cold start: every vertex is evaluated at step 0
/// and the frontier shrinks from there — every pre-existing caller uses
/// this and is bit-identical to before the variant existed. `Seeds` is
/// the incremental-repair start ([`crate::dynamic`]): only the given
/// vertices enter step 0, so a run whose initial assignment is already
/// near-converged pays ~|seeds| instead of ~|V| for its first superstep
/// — wake events then grow the frontier organically wherever the repair
/// actually propagates. Out-of-range ids are dropped and duplicates
/// deduplicated. With [`Frontier::Off`] there is no active-set
/// machinery to interpret the seeds, so the engine falls back to legacy
/// full sweeps (documented escape hatch, not an error: the result is
/// still correct, just not frontier-localized).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InitialFrontier {
    /// Every vertex is active at step 0 (the default).
    All,
    /// Only these vertices are active at step 0.
    Seeds(Vec<VertexId>),
}

/// Run `program` over `g` to completion: max_steps, convergence-driven
/// halt (§IV-D.9), or an empty active frontier, whichever first. The
/// initial assignment comes from `cfg.init` (see [`initial_assignment`]).
pub fn run<P: VertexProgram>(
    g: &Graph,
    cfg: &RevolverConfig,
    program: &P,
) -> Result<PartitionOutput, EngineError> {
    let init = initial_assignment(g, cfg);
    run_with_init(g, cfg, program, init)
}

/// [`run`] with an explicit initial assignment — callers that also
/// need the labels themselves (Revolver seeds its LA rows from them)
/// compute the assignment once and pass it through. The multilevel
/// V-cycle ([`crate::multilevel`]) is the other client: each level's
/// refinement enters here with the projected coarse labels and a
/// per-level step budget (`cfg.max_steps = refine_steps`), and on
/// graphs with vertex weights the whole load accounting runs in
/// coarse-vertex-weight units via [`Graph::load_mass`]. Both inherit
/// active-set execution (`cfg.frontier`) — bounded per-level refinement
/// is exactly the "few vertices still moving" regime the frontier
/// exploits.
pub fn run_with_init<P: VertexProgram>(
    g: &Graph,
    cfg: &RevolverConfig,
    program: &P,
    init: InitialAssignment,
) -> Result<PartitionOutput, EngineError> {
    run_with_frontier(g, cfg, program, init, InitialFrontier::All)
}

/// [`run_with_init`] with an explicit step-0 frontier. Under
/// [`InitialFrontier::All`] this *is* `run_with_init` — same stamps,
/// same frontier collection, bit-identical results. Under
/// [`InitialFrontier::Seeds`] only the seed vertices are evaluated at
/// step 0; everything else starts settled and enters the frontier only
/// through the normal wake events. The incremental repair pass
/// ([`crate::dynamic::IncrementalPartitioner`]) enters here with the
/// endpoints of an update batch as seeds.
pub fn run_with_frontier<P: VertexProgram>(
    g: &Graph,
    cfg: &RevolverConfig,
    program: &P,
    init: InitialAssignment,
    initial_frontier: InitialFrontier,
) -> Result<PartitionOutput, EngineError> {
    let sw = Stopwatch::start();
    // Observability: `obs_on` is captured once and gates every clock
    // read below, so the disabled path adds only dead branches (the
    // overhead contract, `obs`). The "engine" guard nests the segment
    // cuts under any caller spans (multilevel refine, dynamic repair).
    let obs_on = crate::obs::enabled();
    let _run_span = crate::obs::span("engine");
    let mut seg = crate::obs::span::Segments::start(obs_on);
    if obs_on {
        crate::obs::progress().set_phase("engine");
    }
    let k = cfg.parts;
    let n = g.num_vertices();
    let sync = program.execution() == ExecutionModel::Synchronous;
    let frontier_on = cfg.frontier == Frontier::On;
    // Learning-dynamics observatory (`--diag`): flow matrix, LA
    // decisiveness, oscillation detection, per-partition samples. All
    // of it hangs off this one captured bool, so the default path
    // (diag off) allocates nothing and emits none of the diag events.
    let diag_on = obs_on && cfg.diag;
    let flow = diag_on.then(|| crate::obs::diag::FlowMatrix::new(k));
    let mut osc = diag_on.then(crate::obs::diag::OscillationDetector::new);
    // Why the run's step loop ended, for the terminal `diag` event:
    // 1 = converged (halting window), 2 = empty frontier,
    // 3 = step budget exhausted, 4 = contained worker panic.
    let mut halt_code = 3u32;
    let mut last_oscillating = 0u64;
    let mut last_part_sample_step: Option<u32> = None;

    let state = PartitionState::new(g, k, cfg.epsilon, init);
    // Worker count: both full-graph chunk constructors produce exactly
    // this many chunks, so the RNG stream indexing is identical whether
    // or not the schedule layout is ever materialized.
    let t = cfg.threads.max(1).min(n);
    let base_rng = Rng::new(cfg.seed ^ program.rng_salt());

    let published: Vec<AtomicU32> = (0..n)
        .map(|v| AtomicU32::new(program.init_published(v as VertexId, &state)))
        .collect();
    let demand = DemandTracker::new(k);

    // Activation stamps: `stamp[v] >= step` ⇔ v is active at `step`.
    // All start at 0, so step 0 evaluates the full graph; wake events
    // push stamps to `step + 1` and nothing is ever cleared (monotone
    // epochs instead of a per-step bitmap — DESIGN.md §Active-set).
    let stamps: Vec<AtomicU32> =
        if frontier_on { (0..n).map(|_| AtomicU32::new(0)).collect() } else { Vec::new() };
    let stamps_ref: Option<&[AtomicU32]> = if frontier_on { Some(&stamps) } else { None };

    // Step-0 frontier override. `None` = every vertex (the stamp scan
    // at step 0 returns all of 0..n, since every stamp starts at 0);
    // `Some(seeds)` evaluates only the seeds at step 0 — later steps
    // come from the stamp scan as usual (never-woken vertices keep
    // stamp 0 < 1 and stay settled). Ignored with the frontier off
    // (no active-set machinery to interpret it — legacy full sweeps).
    let seed_frontier: Option<Vec<VertexId>> = match initial_frontier {
        InitialFrontier::All => None,
        InitialFrontier::Seeds(mut s) => {
            s.retain(|&v| (v as usize) < n);
            s.sort_unstable();
            s.dedup();
            Some(s)
        }
    };

    let barrier = Barrier::new(t + 1);
    let stop = AtomicBool::new(false);
    // ── Panic containment ──
    // A worker whose phase hook panics sets `poisoned` and records the
    // first panic here, then keeps participating in every barrier and
    // the full channel protocol (default stats, empty wake lists) so
    // no recv loop ever blocks. The coordinator checks the flag each
    // step after the reduce and breaks into the normal stop/barrier
    // shutdown — bounded drain of at most the in-flight step, never a
    // barrier hang.
    let poisoned = AtomicBool::new(false);
    let first_panic: Mutex<Option<EngineError>> = Mutex::new(None);
    // Step-cadence durability (`--checkpoint dir/`): written by the
    // coordinator between steps, when workers are parked at W1.
    let mut checkpointer = (!cfg.checkpoint_dir.is_empty())
        .then(|| crate::fault::Checkpointer::new(cfg.checkpoint_dir.as_str(), &cfg.faults));
    // Coordinator → worker hand-off slots. With the frontier off, one
    // identity plan (the `cfg.schedule` layout) serves the whole run;
    // with it on, the coordinator republishes a fresh frontier plan
    // before every W1, so no worker ever slices this placeholder and
    // the O(n) identity list + schedule layout are never built.
    let initial_plan = if frontier_on {
        Arc::new(StepPlan {
            verts: Vec::new(),
            chunks: Chunks::by_weight_subset(&[], t, |_| 1),
            record: false,
        })
    } else {
        let chunks = chunks_for(g, cfg);
        debug_assert_eq!(chunks.len(), t, "worker count must match the chunk layout");
        Arc::new(StepPlan { verts: (0..n as VertexId).collect(), chunks, record: false })
    };
    let plan_slot: Mutex<Arc<StepPlan>> = Mutex::new(initial_plan);
    let snap_slot: Mutex<Arc<StepSnapshots>> = Mutex::new(Arc::new(StepSnapshots::default()));
    let a_slot: Mutex<Option<Arc<P::PhaseA>>> = Mutex::new(None);
    let b_slot: Mutex<Option<Arc<P::PhaseB>>> = Mutex::new(None);
    // Worker → coordinator aggregates (one message per worker per
    // step). The third element is the worker's busy seconds — the raw
    // sample behind the `engine_worker_skew` gauge (0.0 when obs is
    // off: the clocks are never read).
    let (stats_tx, stats_rx) = mpsc::channel::<(usize, StepStats, f64)>();
    // Worker → coordinator wake worklists: exactly one message per
    // worker on recording steps, none otherwise.
    let (wake_tx, wake_rx) = mpsc::channel::<Vec<VertexId>>();

    let mut detector = ConvergenceDetector::new(cfg.halt_theta, cfg.halt_window);
    let mut trace = RunTrace::default();
    let mut executed_steps: u32 = 0;
    let mut total_evaluated: u64 = 0;
    // ── Frontier-collection machinery (tentpole: O(frontier) steps) ──
    // Next step's frontier as merged from the workers' wake worklists
    // (`None` = not recorded last step → fall back to the stamp scan).
    let mut pending: Option<Vec<VertexId>> = None;
    // Whether the *current* step's plan records wakes.
    let mut recording = false;
    // Worklist collection pays off below this frontier size; above it
    // the branch-free dense stamp scan wins (DESIGN.md §Hot paths).
    let dense_limit = cfg.frontier_dense_frac * n as f64;
    // Frontier chunk layout cache: `(layout, frontier size it was built
    // for)`. While the frontier shrinks by < 2×, the old quantile
    // boundaries are clamped instead of recomputed.
    let mut chunk_cache: Option<(Chunks, usize)> = None;
    // Instrumentation for the bench trajectory (BENCH_hotpath.json).
    let mut stamp_reads: u64 = 0;
    let mut scan_steps: u32 = 0;
    let mut worklist_steps: u32 = 0;
    let mut chunk_reuses: u32 = 0;
    let mut chunk_builds: u32 = 0;
    let mut total_migrations: u64 = 0;
    // Last step's aggregates, for a truthful terminal trace point when
    // the sampler did not land on the final step.
    let mut last_mean_score = 0.0f64;
    let mut last_migrations = 0u64;
    let mut last_evaluated = 0u64;

    std::thread::scope(|scope| {
        // ── Workers ──
        for c in 0..t {
            let (state, demand, published) = (&state, &demand, &published);
            let (barrier, stop) = (&barrier, &stop);
            let (plan_slot, snap_slot, a_slot, b_slot) =
                (&plan_slot, &snap_slot, &a_slot, &b_slot);
            let (poisoned, first_panic) = (&poisoned, &first_panic);
            let stats_tx = stats_tx.clone();
            let wake_tx = wake_tx.clone();
            let base_rng = base_rng.clone();
            let flow_ref = flow.as_ref();
            // Deterministic fault injection: `panic@step:N` arms
            // worker 0 to panic inside phase A of superstep N,
            // exercising exactly the containment path a real bug would.
            let inject_at: Option<u32> =
                if c == 0 { cfg.faults.panic_at_step } else { None };
            scope.spawn(move || {
                // Record the first panic and poison the run. The worker
                // then degrades to a barrier/channel ghost: it keeps the
                // protocol alive so nobody blocks, but does no work.
                let report = |step: u32, phase: &'static str, payload: Box<dyn std::any::Any + Send>| {
                    let mut slot = first_panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(EngineError::WorkerPanic {
                            worker: c,
                            step,
                            phase,
                            message: panic_message(payload),
                        });
                    }
                    drop(slot);
                    poisoned.store(true, Ordering::Release);
                };
                use std::panic::{catch_unwind, AssertUnwindSafe};
                let mut scratch: Option<P::Scratch> =
                    match catch_unwind(AssertUnwindSafe(|| program.make_scratch())) {
                        Ok(s) => Some(s),
                        Err(payload) => {
                            report(0, "scratch", payload);
                            None
                        }
                    };
                let mut step: u64 = 0;
                // This worker's wake worklist (drained every recording
                // step; allocation reused via the swap below).
                let wake_buf: RefCell<Vec<VertexId>> = RefCell::new(Vec::new());
                loop {
                    barrier.wait(); // W1: step start (coordinator prepared)
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let plan = plan_slot.lock().unwrap().clone();
                    let work = plan.slice(c);
                    let snap = snap_slot.lock().unwrap().clone();
                    let frozen_a =
                        a_slot.lock().unwrap().clone().expect("phase-A data published");
                    let ctx = StepCtx {
                        graph: g,
                        state,
                        demand,
                        step: step as u32,
                        published,
                        snap: &snap,
                        sync,
                        stamps: stamps_ref,
                        wake_sink: if plan.record { Some(&wake_buf) } else { None },
                        flow: flow_ref,
                    };
                    let mut rng = base_rng.fork(step * 2 * t as u64 + c as u64);
                    let t_a = obs_on.then(Stopwatch::start);
                    let stats_a = match scratch.as_mut() {
                        Some(sc) if !poisoned.load(Ordering::Acquire) => {
                            match catch_unwind(AssertUnwindSafe(|| {
                                if inject_at == Some(step as u32) {
                                    crate::obs::counter_add("faults_injected", 1);
                                    crate::obs::event("fault", &[("step", step as f64)]);
                                    panic!("injected fault: panic@step:{step}");
                                }
                                program.phase_a(&ctx, &frozen_a, sc, work, &mut rng)
                            })) {
                                Ok(s) => s,
                                Err(payload) => {
                                    report(step as u32, "A", payload);
                                    StepStats::default()
                                }
                            }
                        }
                        _ => StepStats::default(),
                    };
                    let busy_a = t_a.map_or(0.0, |w| w.elapsed_s());
                    barrier.wait(); // W2: all demand registered
                    barrier.wait(); // W2b: coordinator froze phase-B data
                    let frozen_b =
                        b_slot.lock().unwrap().clone().expect("phase-B data published");
                    let mut rng = base_rng.fork((step * 2 + 1) * t as u64 + c as u64);
                    let t_b = obs_on.then(Stopwatch::start);
                    let stats_b = match scratch.as_mut() {
                        Some(sc) if !poisoned.load(Ordering::Acquire) => {
                            match catch_unwind(AssertUnwindSafe(|| {
                                program.phase_b(&ctx, &frozen_b, sc, work, &mut rng)
                            })) {
                                Ok(s) => s,
                                Err(payload) => {
                                    report(step as u32, "B", payload);
                                    StepStats::default()
                                }
                            }
                        }
                        _ => StepStats::default(),
                    };
                    let mut stats = stats_a.merged(stats_b);
                    stats.evaluated = work.len() as u64;
                    // Per-worker busy time: the straggler / utilization
                    // signal behind degree-balanced scheduling. 0.0
                    // with obs off (both stopwatches are `None` — no
                    // clock is ever read on the disabled path).
                    let busy_s = busy_a + t_b.map_or(0.0, |w| w.elapsed_s());
                    if obs_on {
                        crate::obs::observe("engine_worker_busy_us", (busy_s * 1e6) as u64);
                    }
                    stats_tx.send((c, stats, busy_s)).expect("coordinator alive");
                    if plan.record {
                        wake_tx
                            .send(std::mem::take(&mut *wake_buf.borrow_mut()))
                            .expect("coordinator alive");
                    }
                    barrier.wait(); // W3: step done; coordinator aggregates
                    step += 1;
                }
            });
        }
        drop(stats_tx); // workers hold their own clones
        drop(wake_tx);
        seg.cut("init"); // state + slots + worker spawn

        // ── Coordinator ──
        for step in 0..cfg.max_steps {
            if frontier_on {
                // Collect the active frontier. Three sources, cheapest
                // first: step 0 is the identity (or the explicit seed
                // list) and needs no stamp reads at all; a recorded
                // worklist from last step costs O(frontier); otherwise
                // fall back to the dense O(n) stamp scan. The worklist
                // path is *bit-identical* to the scan: merged first-wake
                // transitions are exactly the set {v : stamp[v] ≥ step}
                // (see [`StepCtx::wake_sink`]), and sorting restores the
                // scan's ascending vertex order, so chunking and RNG
                // stream assignment cannot diverge between the paths.
                let verts: Vec<VertexId> = match (&seed_frontier, step) {
                    (Some(seeds), 0) => seeds.clone(),
                    (None, 0) => (0..n as VertexId).collect(),
                    _ => match pending.take() {
                        Some(wl) => {
                            worklist_steps += 1;
                            #[cfg(debug_assertions)]
                            {
                                let mut oracle: Vec<VertexId> = Vec::new();
                                for (v, s) in stamps.iter().enumerate() {
                                    if s.load(Ordering::Relaxed) >= step {
                                        oracle.push(v as VertexId);
                                    }
                                }
                                debug_assert_eq!(
                                    wl, oracle,
                                    "worklist frontier diverged from the stamp oracle \
                                     at step {step}"
                                );
                            }
                            wl
                        }
                        None => {
                            let mut scanned: Vec<VertexId> = Vec::new();
                            for (v, s) in stamps.iter().enumerate() {
                                if s.load(Ordering::Relaxed) >= step {
                                    scanned.push(v as VertexId);
                                }
                            }
                            stamp_reads += n as u64;
                            scan_steps += 1;
                            scanned
                        }
                    },
                };
                if verts.is_empty() && detector.observe_empty_frontier() {
                    // No vertex can change state any more: labels, λ and
                    // loads of skipped vertices are valid by
                    // construction, so the run is converged — halt
                    // without executing the step.
                    trace.converged_at = Some(executed_steps.saturating_sub(1));
                    halt_code = 2;
                    break;
                }
                // Record wakes whenever the frontier sits below the
                // density crossover, so the *next* collection is the
                // O(frontier) merge. `frontier_dense_frac = 0` forces
                // scan-always, `1` worklist-always.
                let f = verts.len();
                recording = f as f64 <= dense_limit && f > 0;
                // Chunk-rebuild amortization: a < 2× shrink keeps the
                // cached quantile boundaries near-balanced — clamp them
                // instead of re-walking the degree prefix sums.
                let fchunks = match &chunk_cache {
                    Some((cached, built_for)) if f <= *built_for && 2 * f > *built_for => {
                        chunk_reuses += 1;
                        cached.clamped(f)
                    }
                    _ => {
                        chunk_builds += 1;
                        let fresh = Chunks::by_weight_subset(&verts, t, |v| {
                            1 + g.out_degree(v) as u64
                        });
                        chunk_cache = Some((fresh.clone(), f));
                        fresh
                    }
                };
                *plan_slot.lock().unwrap() =
                    Arc::new(StepPlan { verts, chunks: fchunks, record: recording });
            }
            executed_steps = step + 1;
            demand.reset();
            if sync {
                *snap_slot.lock().unwrap() = Arc::new(StepSnapshots {
                    labels: state.labels_snapshot(),
                    published: published.iter().map(|p| p.load(Ordering::Relaxed)).collect(),
                });
            }
            // LA decisiveness over this step's work list (`--diag`):
            // workers are parked at W1, so the program's shared
            // learning state is quiescent (same argument as
            // `la_checkpoint`). O(|frontier| · k) — proportional to
            // the phase work the step already does.
            let decisiveness = if diag_on {
                let plan = plan_slot.lock().unwrap().clone();
                program.la_decisiveness(&plan.verts)
            } else {
                None
            };
            *a_slot.lock().unwrap() = Some(Arc::new(program.prepare_phase_a(g, &state, step)));
            // Coordinator-clock phase segments: consecutive cuts tile
            // the step exactly, so the profile tree's engine children
            // sum to the engine total (barrier-synchronized, the
            // coordinator crosses W1/W2/W2b/W3 with the workers).
            seg.cut("collect"); // frontier + plan + snapshots + prep A
            barrier.wait(); // W1
            barrier.wait(); // W2
            seg.cut("phase_a");
            *b_slot.lock().unwrap() =
                Some(Arc::new(program.prepare_phase_b(g, &state, &demand, step)));
            barrier.wait(); // W2b
            seg.cut("phase_b_prep");
            barrier.wait(); // W3
            seg.cut("phase_b");

            // Merge the wake worklists (recording steps send exactly one
            // message per worker) into next step's frontier: sorted
            // ascending = the stamp scan's vertex order.
            if recording {
                let mut merged: Vec<VertexId> = Vec::new();
                for _ in 0..t {
                    let wl = wake_rx.recv().expect("worker alive");
                    merged.extend_from_slice(&wl);
                }
                merged.sort_unstable();
                pending = Some(merged);
            }

            // Deterministic reduction: fill per-worker slots, then fold
            // in chunk order (f64 addition order is fixed run-to-run).
            let mut parts = vec![StepStats::default(); t];
            let mut busy = vec![0.0f64; t];
            for _ in 0..t {
                let (c, s, b) = stats_rx.recv().expect("worker alive");
                parts[c] = s;
                busy[c] = b;
            }
            let totals = parts
                .into_iter()
                .fold(StepStats::default(), StepStats::merged);
            // Convergence signal: mean over *evaluated* vertices — with
            // the frontier off, `evaluated == n` every step, so the
            // legacy all-vertices mean is reproduced exactly.
            let mean_score = totals.score_sum / totals.evaluated.max(1) as f64;
            total_evaluated += totals.evaluated;
            total_migrations += totals.migrations;
            last_mean_score = mean_score;
            last_migrations = totals.migrations;
            last_evaluated = totals.evaluated;
            if obs_on {
                crate::obs::progress().set_step(step as u64);
                crate::obs::observe("engine_frontier_size", totals.evaluated);
                crate::obs::gauge_set("engine_mean_score", mean_score);
                crate::obs::gauge_set(
                    "engine_worker_skew",
                    crate::obs::diag::worker_skew(&busy),
                );
                crate::obs::event(
                    "step",
                    &[
                        ("step", step as f64),
                        ("frontier", totals.evaluated as f64),
                        ("evaluated", totals.evaluated as f64),
                        ("migrations", totals.migrations as f64),
                        ("mean_score", mean_score),
                    ],
                );
            }

            if cfg.trace_every > 0 && step % cfg.trace_every == 0 {
                let labels = state.labels_snapshot();
                trace.push(TracePoint {
                    step,
                    local_edges: quality::local_edges(g, &labels),
                    max_normalized_load: quality::max_normalized_load(g, &labels, k),
                    mean_score,
                    migrations: totals.migrations,
                    evaluated: totals.evaluated,
                    elapsed_s: sw.elapsed_s(),
                });
            }
            seg.cut("reduce"); // worklist merge + stats fold + trace

            if diag_on {
                // Post-W3 quiescence: workers are parked ahead of the
                // next W1, so labels/loads are stable — the same window
                // the step-cadence checkpoint below relies on.
                let dlabels = state.labels_snapshot();
                last_oscillating = osc.as_mut().map_or(0, |o| o.observe(&dlabels));
                let mut upd = crate::obs::diag::DiagUpdate {
                    step: step as u64,
                    k,
                    oscillating: Some(last_oscillating),
                    ..Default::default()
                };
                if let Some(fm) = flow.as_ref() {
                    // Swap-to-zero drain: the matrix is empty again
                    // before workers resume, so each step's cells are
                    // disjoint and row sums add up to the run's
                    // migration counters exactly.
                    let (moves, mass) = fm.drain();
                    for from in 0..k {
                        for to in 0..k {
                            let m = moves[from * k + to];
                            if m != 0 {
                                crate::obs::event(
                                    "flow",
                                    &[
                                        ("step", step as f64),
                                        ("from", from as f64),
                                        ("to", to as f64),
                                        ("moves", m as f64),
                                        ("mass", mass[from * k + to] as f64),
                                    ],
                                );
                            }
                        }
                    }
                    upd.flow_moves = Some(moves);
                    upd.flow_mass = Some(mass);
                }
                if cfg.trace_every > 0 && step % cfg.trace_every == 0 {
                    let samples = crate::obs::diag::partition_samples(g, &dlabels, k);
                    for (p, s) in samples.iter().enumerate() {
                        crate::obs::event(
                            "partition",
                            &[
                                ("step", step as f64),
                                ("part", p as f64),
                                ("load", s.load as f64),
                                ("boundary", s.boundary as f64),
                                ("local_frac", s.local_frac),
                            ],
                        );
                    }
                    upd.partitions = Some(samples);
                    last_part_sample_step = Some(step);
                }
                let (maxp_mean, entropy_mean) = decisiveness
                    .map_or((f64::NAN, f64::NAN), |d| (d.maxp_mean(), d.entropy_mean()));
                if maxp_mean.is_finite() {
                    crate::obs::gauge_set("la_maxp_mean", maxp_mean);
                    crate::obs::gauge_set("la_entropy_mean", entropy_mean);
                    upd.maxp_mean = Some(maxp_mean);
                    upd.entropy_mean = Some(entropy_mean);
                }
                crate::obs::gauge_set("la_oscillating_vertices", last_oscillating as f64);
                // Non-finite means are dropped by the event renderer,
                // so an LP program (no probability rows) emits a diag
                // line without them.
                crate::obs::event(
                    "diag",
                    &[
                        ("step", step as f64),
                        ("oscillating", last_oscillating as f64),
                        ("frontier", totals.evaluated as f64),
                        ("maxp_mean", maxp_mean),
                        ("entropy_mean", entropy_mean),
                    ],
                );
                crate::obs::diag_update(&upd);
            }

            // Containment: a poisoned step's aggregates are garbage and
            // its state may be mid-migration — stop the run through the
            // normal shutdown (workers are parked at W1 by the time the
            // barrier below releases them into the stop check).
            if poisoned.load(Ordering::Acquire) {
                halt_code = 4;
                break;
            }

            // Step-cadence checkpoint. Workers are past phase B and
            // about to park at W1, so labels/loads/LA state are
            // quiescent. A failed write (including the injected
            // `io@checkpoint` fault) only widens the replay window —
            // log and continue.
            if let Some(ck) = checkpointer.as_mut() {
                if (step + 1) % cfg.checkpoint_every.max(1) == 0 {
                    let labels = state.labels_snapshot();
                    let loads = quality::partition_loads(g, &labels, k);
                    let snap = crate::fault::Snapshot {
                        seed: cfg.seed,
                        step: step + 1,
                        epoch: 0,
                        k: k as u32,
                        labels,
                        loads,
                        la: program.la_checkpoint(),
                    };
                    if let Err(e) = ck.write(&snap) {
                        crate::obs::log::info(&format!(
                            "checkpoint at step {} failed (continuing): {e:#}",
                            step + 1
                        ));
                    }
                }
            }

            if detector.observe(mean_score) {
                trace.converged_at = Some(step);
                halt_code = 1;
                break;
            }
        }
        stop.store(true, Ordering::Release);
        barrier.wait(); // release workers into the stop check
    });

    // A contained panic invalidates the output: loads may be
    // mid-migration inconsistent, so surface the error before any
    // invariant is asserted over them.
    if let Some(err) = first_panic.into_inner().unwrap() {
        return Err(err);
    }

    let labels = state.labels_snapshot();
    debug_assert!(state.check_load_invariant().is_ok());
    // The trace must always end with the final executed step — callers
    // derive the executed superstep count from it (`RunTrace::steps`,
    // the multilevel budget accounting). With `trace_every >= 2` the
    // loop's last sampled point can sit several steps early, so append
    // the terminal point whenever it is missing, carrying the last
    // step's real aggregates (only the two quality metrics the point
    // needs are computed — not the full `evaluate` bundle).
    let final_step = executed_steps.max(1) - 1;
    if trace.points.last().map(|p| p.step) != Some(final_step) {
        trace.push(TracePoint {
            step: final_step,
            local_edges: quality::local_edges(g, &labels),
            max_normalized_load: quality::max_normalized_load(g, &labels, k),
            mean_score: last_mean_score,
            migrations: last_migrations,
            evaluated: last_evaluated,
            elapsed_s: sw.elapsed_s(),
        });
    }
    trace.total_evaluated = total_evaluated;
    trace.stamp_reads = stamp_reads;
    trace.scan_steps = scan_steps;
    trace.worklist_steps = worklist_steps;
    trace.chunk_reuses = chunk_reuses;
    trace.wall_time_s = sw.elapsed_s();
    seg.cut("finish"); // scope teardown + terminal trace point
    if diag_on {
        // Terminal partition sample (mirrors the terminal trace point:
        // with a sparse cadence the loop's last sample can sit early),
        // then a final diag line carrying the halt attribution.
        if last_part_sample_step != Some(final_step) {
            let samples = crate::obs::diag::partition_samples(g, &labels, k);
            for (p, s) in samples.iter().enumerate() {
                crate::obs::event(
                    "partition",
                    &[
                        ("step", final_step as f64),
                        ("part", p as f64),
                        ("load", s.load as f64),
                        ("boundary", s.boundary as f64),
                        ("local_frac", s.local_frac),
                    ],
                );
            }
            crate::obs::diag_update(&crate::obs::diag::DiagUpdate {
                step: final_step as u64,
                k,
                partitions: Some(samples),
                ..Default::default()
            });
        }
        crate::obs::event(
            "diag",
            &[
                ("step", final_step as f64),
                ("oscillating", last_oscillating as f64),
                ("halt", halt_code as f64),
            ],
        );
    }
    if obs_on {
        crate::obs::counter_add("engine_runs", 1);
        crate::obs::counter_add("engine_steps", executed_steps as u64);
        crate::obs::counter_add("engine_evaluated", total_evaluated);
        crate::obs::counter_add("engine_migrations", total_migrations);
        crate::obs::counter_add("engine_scan_steps", scan_steps as u64);
        crate::obs::counter_add("engine_worklist_steps", worklist_steps as u64);
        crate::obs::counter_add("engine_stamp_reads", stamp_reads);
        crate::obs::counter_add("engine_chunk_builds", chunk_builds as u64);
        crate::obs::counter_add("engine_chunk_reuses", chunk_reuses as u64);
    }
    Ok(PartitionOutput { labels, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use std::sync::atomic::AtomicUsize;

    fn ring_graph(n: usize) -> Graph {
        let mut b = GraphBuilder::new(n);
        for v in 0..n as u32 {
            b.edge(v, (v + 1) % n as u32);
        }
        b.build()
    }

    fn cfg(n_threads: usize, steps: u32) -> RevolverConfig {
        RevolverConfig {
            parts: 4,
            threads: n_threads,
            max_steps: steps,
            halt_window: u32::MAX,
            seed: 5,
            ..Default::default()
        }
    }

    /// Counts phase visits; publishes `step + 1` in phase A (so every
    /// vertex stays in the frontier — λ changes each step) and (in sync
    /// mode) asserts cross-chunk reads still see the frozen value.
    struct ProbeProgram {
        execution: ExecutionModel,
        a_visits: AtomicUsize,
        b_visits: AtomicUsize,
        n: usize,
    }

    impl ProbeProgram {
        fn new(execution: ExecutionModel, n: usize) -> Self {
            ProbeProgram {
                execution,
                a_visits: AtomicUsize::new(0),
                b_visits: AtomicUsize::new(0),
                n,
            }
        }
    }

    impl VertexProgram for ProbeProgram {
        type Scratch = ();
        type PhaseA = u32; // the step, to cross-check ctx.step
        type PhaseB = u32;

        fn execution(&self) -> ExecutionModel {
            self.execution
        }
        fn rng_salt(&self) -> u64 {
            0xBEEF
        }
        fn init_published(&self, _v: VertexId, _state: &PartitionState) -> u32 {
            0
        }
        fn make_scratch(&self) {}
        fn prepare_phase_a(&self, _g: &Graph, _state: &PartitionState, step: u32) -> u32 {
            step
        }
        fn prepare_phase_b(
            &self,
            _g: &Graph,
            _state: &PartitionState,
            _demand: &DemandTracker,
            step: u32,
        ) -> u32 {
            step
        }

        fn phase_a(
            &self,
            ctx: &StepCtx<'_>,
            frozen: &u32,
            _scratch: &mut (),
            work: &[VertexId],
            _rng: &mut Rng,
        ) -> StepStats {
            assert_eq!(*frozen, ctx.step);
            for &v in work {
                self.a_visits.fetch_add(1, Ordering::Relaxed);
                ctx.publish(v, ctx.step + 1);
            }
            StepStats::default()
        }

        fn phase_b(
            &self,
            ctx: &StepCtx<'_>,
            frozen: &u32,
            _scratch: &mut (),
            work: &[VertexId],
            _rng: &mut Rng,
        ) -> StepStats {
            assert_eq!(*frozen, ctx.step);
            let mut visited = 0u64;
            for &v in work {
                self.b_visits.fetch_add(1, Ordering::Relaxed);
                // Reads of vertices *outside* the own work list exercise
                // the snapshot machinery: in sync mode every read must
                // see the value frozen at step start — i.e. last step's
                // publish (`step`), not this step's (`step + 1`).
                let other = (v as usize + work.len()) % self.n;
                if self.execution == ExecutionModel::Synchronous {
                    assert_eq!(
                        ctx.published(other as VertexId),
                        ctx.step,
                        "sync read must be frozen"
                    );
                }
                visited += 1;
            }
            StepStats { score_sum: visited as f64, ..StepStats::default() }
        }
    }

    /// A program that never changes anything: publishes the unchanged
    /// init value, never migrates, never wakes. Under the frontier the
    /// run must halt after one full step (everything settled).
    struct SettledProgram;

    impl VertexProgram for SettledProgram {
        type Scratch = ();
        type PhaseA = ();
        type PhaseB = ();
        fn execution(&self) -> ExecutionModel {
            ExecutionModel::Asynchronous
        }
        fn rng_salt(&self) -> u64 {
            0xD0D0
        }
        fn init_published(&self, _v: VertexId, _state: &PartitionState) -> u32 {
            0
        }
        fn make_scratch(&self) {}
        fn prepare_phase_a(&self, _g: &Graph, _state: &PartitionState, _step: u32) {}
        fn prepare_phase_b(
            &self,
            _g: &Graph,
            _state: &PartitionState,
            _demand: &DemandTracker,
            _step: u32,
        ) {
        }
        fn phase_a(
            &self,
            ctx: &StepCtx<'_>,
            _f: &(),
            _s: &mut (),
            work: &[VertexId],
            _rng: &mut Rng,
        ) -> StepStats {
            for &v in work {
                ctx.publish(v, 0); // unchanged value: not a wake event
            }
            StepStats::default()
        }
        fn phase_b(
            &self,
            _ctx: &StepCtx<'_>,
            _f: &(),
            _s: &mut (),
            _work: &[VertexId],
            _rng: &mut Rng,
        ) -> StepStats {
            StepStats::default()
        }
    }

    /// Publishes a changing value for vertex 0 only — the frontier must
    /// shrink to 0's undirected neighbourhood and stay there.
    struct SingleHotProgram;

    impl VertexProgram for SingleHotProgram {
        type Scratch = ();
        type PhaseA = ();
        type PhaseB = ();
        fn execution(&self) -> ExecutionModel {
            ExecutionModel::Asynchronous
        }
        fn rng_salt(&self) -> u64 {
            0x1407
        }
        fn init_published(&self, _v: VertexId, _state: &PartitionState) -> u32 {
            0
        }
        fn make_scratch(&self) {}
        fn prepare_phase_a(&self, _g: &Graph, _state: &PartitionState, _step: u32) {}
        fn prepare_phase_b(
            &self,
            _g: &Graph,
            _state: &PartitionState,
            _demand: &DemandTracker,
            _step: u32,
        ) {
        }
        fn phase_a(
            &self,
            ctx: &StepCtx<'_>,
            _f: &(),
            _s: &mut (),
            work: &[VertexId],
            _rng: &mut Rng,
        ) -> StepStats {
            for &v in work {
                if v == 0 {
                    ctx.publish(v, ctx.step + 1);
                }
            }
            StepStats::default()
        }
        fn phase_b(
            &self,
            _ctx: &StepCtx<'_>,
            _f: &(),
            _s: &mut (),
            work: &[VertexId],
            _rng: &mut Rng,
        ) -> StepStats {
            StepStats { score_sum: work.len() as f64, ..StepStats::default() }
        }
    }

    #[test]
    fn engine_visits_every_vertex_each_phase() {
        let g = ring_graph(103);
        let p = ProbeProgram::new(ExecutionModel::Asynchronous, 103);
        let out = run(&g, &cfg(3, 4), &p).unwrap();
        assert_eq!(p.a_visits.load(Ordering::Relaxed), 4 * 103);
        assert_eq!(p.b_visits.load(Ordering::Relaxed), 4 * 103);
        assert_eq!(out.labels.len(), 103);
        assert_eq!(out.trace.steps(), 4);
        assert_eq!(out.trace.total_evaluated, 4 * 103);
    }

    #[test]
    fn sync_mode_freezes_published_reads() {
        let g = ring_graph(64);
        let p = ProbeProgram::new(ExecutionModel::Synchronous, 64);
        // The assertions live inside phase_b; 2 workers force real
        // cross-chunk interleavings.
        run(&g, &cfg(2, 5), &p).unwrap();
        assert_eq!(p.b_visits.load(Ordering::Relaxed), 5 * 64);
    }

    #[test]
    fn degree_schedule_visits_every_vertex() {
        let g = ring_graph(97);
        let p = ProbeProgram::new(ExecutionModel::Asynchronous, 97);
        let mut c = cfg(4, 2);
        c.schedule = Schedule::Degree;
        run(&g, &c, &p).unwrap();
        assert_eq!(p.a_visits.load(Ordering::Relaxed), 2 * 97);
        assert_eq!(p.b_visits.load(Ordering::Relaxed), 2 * 97);
    }

    #[test]
    fn stream_init_seeds_labels() {
        use crate::config::{Init, StreamAlgo};
        let g = ring_graph(64);
        let p = ProbeProgram::new(ExecutionModel::Asynchronous, 64);
        let mut c = cfg(2, 2);
        c.init = Init::Stream(StreamAlgo::Fennel);
        let out = run(&g, &c, &p).unwrap();
        // ProbeProgram never migrates, so the output labels are exactly
        // the streaming warm start.
        let expect = crate::stream::stream_labels(&g, StreamAlgo::Fennel, &c);
        assert_eq!(out.labels, expect);
    }

    #[test]
    fn sparse_trace_still_records_final_step() {
        // trace_every = 2 over 6 steps samples steps 0/2/4; the terminal
        // point for step 5 must still be appended so steps() reports the
        // executed superstep count (the multilevel budget accounting
        // reads it).
        let g = ring_graph(32);
        let p = ProbeProgram::new(ExecutionModel::Asynchronous, 32);
        let mut c = cfg(2, 6);
        c.trace_every = 2;
        let out = run(&g, &c, &p).unwrap();
        assert_eq!(out.trace.steps(), 6, "sparse tracing must not hide executed steps");
        assert_eq!(out.trace.points.last().unwrap().step, 5);
    }

    #[test]
    fn single_worker_runs_all_chunks_inline() {
        let g = ring_graph(50);
        let p = ProbeProgram::new(ExecutionModel::Asynchronous, 50);
        let out = run(&g, &cfg(1, 3), &p).unwrap();
        assert_eq!(p.a_visits.load(Ordering::Relaxed), 3 * 50);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn empty_frontier_halts_after_one_settled_step() {
        // Nothing changes during step 0, so the frontier is empty at
        // step 1: the run must halt immediately, regardless of the
        // (disabled) score-window detector.
        let g = ring_graph(40);
        let out = run(&g, &cfg(2, 50), &SettledProgram).unwrap();
        assert_eq!(out.trace.steps(), 1, "one full step, then empty-frontier halt");
        assert_eq!(out.trace.converged_at, Some(0));
        assert_eq!(out.trace.total_evaluated, 40);
    }

    #[test]
    fn frontier_off_runs_every_step_even_when_settled() {
        let g = ring_graph(40);
        let mut c = cfg(2, 7);
        c.frontier = Frontier::Off;
        let out = run(&g, &c, &SettledProgram).unwrap();
        assert_eq!(out.trace.steps(), 7, "escape hatch must keep full sweeps");
        assert_eq!(out.trace.total_evaluated, 7 * 40);
    }

    #[test]
    fn frontier_shrinks_to_woken_neighborhood() {
        // Ring of 103: only vertex 0 keeps publishing changes, so from
        // step 1 on the frontier is exactly {0, 1, 102} (0 plus its
        // undirected neighbours).
        let n = 103usize;
        let g = ring_graph(n);
        let steps = 5u32;
        let out = run(&g, &cfg(3, steps), &SingleHotProgram).unwrap();
        let expect = n as u64 + (steps as u64 - 1) * 3;
        assert_eq!(out.trace.total_evaluated, expect);
        assert_eq!(out.trace.steps(), steps, "hot vertex keeps the run alive");
        // Every sampled/terminal point records its frontier size.
        assert_eq!(out.trace.points.last().unwrap().evaluated, 3);
    }

    #[test]
    fn frontier_single_vertex_work_lists_cover_all_workers() {
        // Frontier smaller than the worker count: surplus workers get
        // empty slices but the protocol still completes every barrier.
        let g = ring_graph(16);
        let out = run(&g, &cfg(8, 4), &SingleHotProgram).unwrap();
        assert_eq!(out.trace.steps(), 4);
        assert_eq!(out.trace.total_evaluated, 16 + 3 * 3);
    }

    #[test]
    fn seeded_frontier_evaluates_only_seeds() {
        // SettledProgram wakes nobody: a Seeds start must evaluate
        // exactly the (deduped, in-range) seeds at step 0 and then halt
        // on the empty frontier. Vertex 99 is out of range for n = 40
        // and one 7 is a duplicate — both must be dropped.
        let g = ring_graph(40);
        let out = run_with_frontier(
            &g,
            &cfg(2, 50),
            &SettledProgram,
            InitialAssignment::Random(5),
            InitialFrontier::Seeds(vec![7, 3, 7, 99]),
        ).unwrap();
        assert_eq!(out.trace.total_evaluated, 2, "only the two valid seeds run");
        assert_eq!(out.trace.steps(), 1, "one seeded step, then empty-frontier halt");
    }

    #[test]
    fn seeded_frontier_grows_through_wakes() {
        // Seeds = {0} and vertex 0 keeps publishing changes: step 0
        // evaluates just the seed, every later step its woken undirected
        // neighbourhood {0, 1, n-1}.
        let n = 103usize;
        let g = ring_graph(n);
        let steps = 5u32;
        let out = run_with_frontier(
            &g,
            &cfg(3, steps),
            &SingleHotProgram,
            InitialAssignment::Random(5),
            InitialFrontier::Seeds(vec![0]),
        ).unwrap();
        assert_eq!(out.trace.total_evaluated, 1 + (steps as u64 - 1) * 3);
        assert_eq!(out.trace.steps(), steps);
    }

    #[test]
    fn worklist_collection_bit_identical_to_scan() {
        // Scan-always (frac 0.0), worklist-always (1.0) and the hybrid
        // default must produce identical runs — same frontier sets, same
        // order, same chunking — differing only in collection-path
        // accounting.
        let g = ring_graph(103);
        let run_frac = |frac: f64| {
            let mut c = cfg(3, 6);
            c.frontier_dense_frac = frac;
            run(&g, &c, &SingleHotProgram).unwrap()
        };
        let scan = run_frac(0.0);
        let wl = run_frac(1.0);
        let hybrid = run_frac(0.25);
        assert_eq!(scan.labels, wl.labels);
        assert_eq!(scan.labels, hybrid.labels);
        assert_eq!(scan.trace.total_evaluated, wl.trace.total_evaluated);
        assert_eq!(scan.trace.total_evaluated, hybrid.trace.total_evaluated);
        assert_eq!(scan.trace.steps(), 6);

        // Scan-always: 5 post-identity collections × 103 stamp loads.
        assert_eq!(scan.trace.scan_steps, 5);
        assert_eq!(scan.trace.worklist_steps, 0);
        assert_eq!(scan.trace.stamp_reads, 5 * 103);
        // Worklist-always: no collection ever reads a stamp.
        assert_eq!(wl.trace.scan_steps, 0);
        assert_eq!(wl.trace.worklist_steps, 5);
        assert_eq!(wl.trace.stamp_reads, 0);
        // Hybrid: the full step-0 frontier is above the 0.25 crossover
        // (one scan), then the 3-vertex frontier rides worklists —
        // 5× fewer coordinator stamp reads than scan-always.
        assert_eq!(hybrid.trace.scan_steps, 1);
        assert_eq!(hybrid.trace.worklist_steps, 4);
        assert_eq!(hybrid.trace.stamp_reads, 103);

        // Chunk-layout amortization fires identically in every mode
        // (steps 2..=5 reuse the f=3 layout built at step 1).
        assert_eq!(scan.trace.chunk_reuses, 4);
        assert_eq!(wl.trace.chunk_reuses, hybrid.trace.chunk_reuses);
        assert_eq!(scan.trace.chunk_reuses, hybrid.trace.chunk_reuses);
    }

    #[test]
    fn worklist_matches_scan_with_probe_churn_multithreaded() {
        // ProbeProgram keeps every vertex publishing changes, so the
        // frontier stays full — the worklist path must still collect the
        // exact identity frontier from concurrent per-worker wake lists.
        for threads in [1usize, 2, 4] {
            let mk = |frac: f64| {
                let p = ProbeProgram::new(ExecutionModel::Asynchronous, 64);
                let g = ring_graph(64);
                let mut c = cfg(threads, 4);
                c.frontier_dense_frac = frac;
                let out = run(&g, &c, &p).unwrap();
                (out, p.a_visits.load(Ordering::Relaxed), p.b_visits.load(Ordering::Relaxed))
            };
            let (scan, sa, sb) = mk(0.0);
            let (wl, wa, wb) = mk(1.0);
            assert_eq!(scan.labels, wl.labels, "threads={threads}");
            assert_eq!(scan.trace.total_evaluated, wl.trace.total_evaluated);
            assert_eq!((sa, sb), (wa, wb), "threads={threads}");
            assert_eq!(wl.trace.stamp_reads, 0);
            assert_eq!(wl.trace.worklist_steps, 3);
        }
    }

    #[test]
    fn seeded_frontier_records_worklists_too() {
        // A small seed frontier immediately crosses under the density
        // threshold, so the follow-up steps ride worklists and the
        // stamp array is never scanned.
        let n = 103usize;
        let g = ring_graph(n);
        let steps = 5u32;
        let out = run_with_frontier(
            &g,
            &cfg(3, steps),
            &SingleHotProgram,
            InitialAssignment::Random(5),
            InitialFrontier::Seeds(vec![0]),
        ).unwrap();
        assert_eq!(out.trace.total_evaluated, 1 + (steps as u64 - 1) * 3);
        assert_eq!(out.trace.stamp_reads, 0);
        assert_eq!(out.trace.scan_steps, 0);
        assert_eq!(out.trace.worklist_steps, steps - 1);
    }

    #[test]
    fn run_with_frontier_all_is_bit_identical_to_run_with_init() {
        let g = ring_graph(64);
        let pa = ProbeProgram::new(ExecutionModel::Asynchronous, 64);
        let a = run_with_init(&g, &cfg(2, 4), &pa, InitialAssignment::Random(9)).unwrap();
        let pb = ProbeProgram::new(ExecutionModel::Asynchronous, 64);
        let b = run_with_frontier(
            &g,
            &cfg(2, 4),
            &pb,
            InitialAssignment::Random(9),
            InitialFrontier::All,
        ).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.trace.total_evaluated, b.trace.total_evaluated);
    }

    #[test]
    fn seeds_with_frontier_off_fall_back_to_full_sweeps() {
        let g = ring_graph(40);
        let mut c = cfg(2, 7);
        c.frontier = Frontier::Off;
        let out = run_with_frontier(
            &g,
            &c,
            &SettledProgram,
            InitialAssignment::Random(5),
            InitialFrontier::Seeds(vec![1]),
        ).unwrap();
        assert_eq!(out.trace.total_evaluated, 7 * 40, "off-mode ignores the seed list");
    }

    #[test]
    fn empty_seed_frontier_halts_without_evaluating() {
        let g = ring_graph(16);
        let out = run_with_frontier(
            &g,
            &cfg(2, 10),
            &SettledProgram,
            InitialAssignment::Random(1),
            InitialFrontier::Seeds(Vec::new()),
        ).unwrap();
        assert_eq!(out.trace.total_evaluated, 0);
        assert_eq!(out.labels.len(), 16, "labels still come from the init");
    }

    // ── Fault containment ──

    /// ProbeProgram wired to panic in the chosen phase at the chosen
    /// step — a *real* program bug, not the injection path.
    struct PanickyProgram {
        panic_step: u32,
        in_phase_b: bool,
    }

    impl VertexProgram for PanickyProgram {
        type Scratch = ();
        type PhaseA = ();
        type PhaseB = ();
        fn execution(&self) -> ExecutionModel {
            ExecutionModel::Asynchronous
        }
        fn rng_salt(&self) -> u64 {
            0xBAD
        }
        fn init_published(&self, _v: VertexId, _state: &PartitionState) -> u32 {
            0
        }
        fn make_scratch(&self) {}
        fn prepare_phase_a(&self, _g: &Graph, _state: &PartitionState, _step: u32) {}
        fn prepare_phase_b(
            &self,
            _g: &Graph,
            _state: &PartitionState,
            _demand: &DemandTracker,
            _step: u32,
        ) {
        }
        fn phase_a(
            &self,
            ctx: &StepCtx<'_>,
            _f: &(),
            _s: &mut (),
            work: &[VertexId],
            _rng: &mut Rng,
        ) -> StepStats {
            if !self.in_phase_b && ctx.step == self.panic_step && !work.is_empty() {
                panic!("probe bug in A");
            }
            for &v in work {
                ctx.publish(v, ctx.step + 1); // keep the frontier full
            }
            StepStats::default()
        }
        fn phase_b(
            &self,
            ctx: &StepCtx<'_>,
            _f: &(),
            _s: &mut (),
            work: &[VertexId],
            _rng: &mut Rng,
        ) -> StepStats {
            if self.in_phase_b && ctx.step == self.panic_step && !work.is_empty() {
                panic!("probe bug in B");
            }
            StepStats::default()
        }
    }

    #[test]
    fn injected_panic_returns_err_with_all_threads_joined() {
        // The acceptance criterion: `panic@step` must surface as an
        // `Err` with every thread joined (thread::scope guarantees the
        // join; the stopwatch guarantees the bounded drain).
        let g = ring_graph(64);
        let mut c = cfg(4, 50);
        c.faults = "panic@step:1".parse().unwrap();
        let sw = Stopwatch::start();
        let err = run(&g, &c, &ProbeProgram::new(ExecutionModel::Asynchronous, 64))
            .unwrap_err();
        assert!(sw.elapsed_s() < 5.0, "drain must be bounded, took {}s", sw.elapsed_s());
        match err {
            EngineError::WorkerPanic { worker, step, phase, ref message } => {
                assert_eq!(worker, 0, "injection arms worker 0");
                assert_eq!(step, 1);
                assert_eq!(phase, "A");
                assert!(message.contains("injected fault"), "{message}");
            }
        }
        let msg = err.to_string();
        assert!(msg.contains("worker 0") && msg.contains("step 1"), "{msg}");
    }

    #[test]
    fn real_phase_panics_are_contained_in_both_phases() {
        let g = ring_graph(64);
        for in_phase_b in [false, true] {
            let p = PanickyProgram { panic_step: 2, in_phase_b };
            let err = run(&g, &cfg(3, 50), &p).unwrap_err();
            let EngineError::WorkerPanic { step, phase, .. } = err;
            assert_eq!(step, 2, "in_phase_b={in_phase_b}");
            assert_eq!(phase, if in_phase_b { "B" } else { "A" });
        }
    }

    #[test]
    fn single_threaded_panic_is_contained_too() {
        let g = ring_graph(32);
        let mut c = cfg(1, 10);
        c.faults = "panic@step:0".parse().unwrap();
        let err = run(&g, &c, &SettledProgram).unwrap_err();
        let EngineError::WorkerPanic { worker, step, .. } = err;
        assert_eq!((worker, step), (0, 0));
    }

    #[test]
    fn scratch_panic_is_contained() {
        struct BadScratch;
        impl VertexProgram for BadScratch {
            type Scratch = ();
            type PhaseA = ();
            type PhaseB = ();
            fn execution(&self) -> ExecutionModel {
                ExecutionModel::Asynchronous
            }
            fn rng_salt(&self) -> u64 {
                1
            }
            fn init_published(&self, _v: VertexId, _state: &PartitionState) -> u32 {
                0
            }
            fn make_scratch(&self) {
                panic!("no scratch for you");
            }
            fn prepare_phase_a(&self, _g: &Graph, _s: &PartitionState, _step: u32) {}
            fn prepare_phase_b(
                &self,
                _g: &Graph,
                _s: &PartitionState,
                _d: &DemandTracker,
                _step: u32,
            ) {
            }
            fn phase_a(
                &self,
                _c: &StepCtx<'_>,
                _f: &(),
                _s: &mut (),
                _w: &[VertexId],
                _r: &mut Rng,
            ) -> StepStats {
                StepStats::default()
            }
            fn phase_b(
                &self,
                _c: &StepCtx<'_>,
                _f: &(),
                _s: &mut (),
                _w: &[VertexId],
                _r: &mut Rng,
            ) -> StepStats {
                StepStats::default()
            }
        }
        let g = ring_graph(16);
        let err = run(&g, &cfg(2, 5), &BadScratch).unwrap_err();
        let EngineError::WorkerPanic { phase, .. } = err;
        assert_eq!(phase, "scratch");
    }

    // ── Step-cadence checkpointing ──

    #[test]
    fn checkpoints_written_at_step_cadence_and_resumable() {
        let dir = std::env::temp_dir().join("revolver_engine_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let g = ring_graph(64);
        let p = ProbeProgram::new(ExecutionModel::Asynchronous, 64);
        let mut c = cfg(2, 5);
        c.checkpoint_dir = dir.to_string_lossy().into_owned();
        c.checkpoint_every = 2;
        let out = run(&g, &c, &p).unwrap();
        // Steps 2 and 4 hit the cadence; the newest snapshot carries
        // the exact final assignment (ProbeProgram never migrates, so
        // intermediate and final labels coincide) and matching loads.
        let snap = crate::fault::load_latest(&dir).unwrap().expect("checkpoint written");
        assert_eq!(snap.step, 4);
        assert_eq!(snap.epoch, 0);
        assert_eq!(snap.seed, c.seed);
        assert_eq!(snap.k as usize, c.parts);
        assert_eq!(snap.labels, out.labels);
        assert_eq!(snap.loads, quality::partition_loads(&g, &out.labels, c.parts));
        assert!(snap.la.is_none(), "ProbeProgram exposes no LA state");
    }

    #[test]
    fn injected_checkpoint_io_fault_does_not_kill_the_run() {
        let dir = std::env::temp_dir().join("revolver_engine_ckpt_iofault");
        let _ = std::fs::remove_dir_all(&dir);
        let g = ring_graph(64);
        let p = ProbeProgram::new(ExecutionModel::Asynchronous, 64);
        let mut c = cfg(2, 6);
        c.checkpoint_dir = dir.to_string_lossy().into_owned();
        c.checkpoint_every = 2;
        c.faults = "io@checkpoint:1".parse().unwrap();
        let out = run(&g, &c, &p).unwrap();
        assert_eq!(out.trace.steps(), 6, "a failed checkpoint must not stop the run");
        // Attempt 1 (step 2) failed; steps 4 and 6 made it to disk.
        let snap = crate::fault::load_latest(&dir).unwrap().expect("later attempts succeed");
        assert_eq!(snap.step, 6);
    }
}
