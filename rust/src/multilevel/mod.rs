//! Multilevel partitioning: heavy-edge coarsening + V-cycle refinement
//! driving Revolver/Spinner.
//!
//! Revolver's LA agents and Spinner's label propagation touch all |V|
//! vertices every superstep, so convergence on large graphs is paid in
//! full-graph passes. The multilevel paradigm (the Metis-class
//! partitioners the paper compares against, and the distributed
//! unconstrained-local-search line of Sanders & Seemaier 2024) fixes
//! exactly that: contract the graph down a hierarchy of matchings,
//! partition the tiny coarsest graph, then walk back up, at each level
//! projecting the labels and running a *bounded* local-search
//! refinement — most supersteps are spent on levels a fraction of the
//! original size, and the finest level starts from a near-good cut
//! instead of random noise (the same observation that motivates the
//! streaming warm start, amplified).
//!
//! Pipeline ([`vcycle::Multilevel`]):
//!
//! ```text
//! fine graph ──match──▶ level 1 ──match──▶ … ──▶ coarsest (≤ coarsen_until)
//!                                                  │  any registered algo
//!                                                  ▼  (default: fennel)
//! labels ◀──refine+project── … ◀──refine+project── coarse labels
//! ```
//!
//! * [`matching`] — randomized heavy-edge matching over the eq.-(4)
//!   undirected weights, with a degree-capped neighbour scan for hubs
//!   and a pair-weight cap that keeps clusters balanced.
//! * [`coarsen`] — contraction of a matching into a [`CoarseGraph`]
//!   (weighted CSR, parallel edges merged, vertex weight = cluster
//!   size) and the [`Hierarchy`] stack of vertex maps.
//! * [`project`] — label projection back down the hierarchy.
//! * [`vcycle`] — the [`Multilevel`] partitioner: coarsest-level init by
//!   any [`crate::partitioners::by_name`] algorithm, per-level bounded
//!   Spinner/Revolver refinement through [`crate::engine::run_with_init`]
//!   (balance in coarse-vertex-weight units via
//!   [`crate::graph::Graph::load_mass`]), and a deterministic
//!   ε-rebalance pass so no level silently overloads a partition.

pub mod coarsen;
pub mod matching;
pub mod project;
pub mod vcycle;

pub use coarsen::{contract, CoarseGraph, Hierarchy};
pub use matching::{heavy_edge_matching, matched_weight, HUB_NEIGHBOR_CAP};
pub use project::{project, project_to_finest};
pub use vcycle::{coarse_projection, hierarchy_for, rebalance, Multilevel, Refiner};
