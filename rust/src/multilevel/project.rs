//! Label projection back down the hierarchy: every fine vertex inherits
//! its cluster's label.

use crate::Label;
use crate::VertexId;

use super::coarsen::Hierarchy;

/// Project labels of level `i+1` onto level `i` through the fine→coarse
/// map of that level.
pub fn project(coarse_labels: &[Label], map: &[VertexId]) -> Vec<Label> {
    map.iter().map(|&c| coarse_labels[c as usize]).collect()
}

/// Unwind a coarsest-level labelling all the way to the finest level —
/// the "no refinement" baseline the V-cycle must beat.
pub fn project_to_finest(h: &Hierarchy, mut labels: Vec<Label>) -> Vec<Label> {
    for map in h.maps.iter().rev() {
        labels = project(&labels, map);
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn project_follows_map() {
        let coarse = vec![7, 9];
        let map = vec![0, 1, 1, 0];
        assert_eq!(project(&coarse, &map), vec![7, 9, 9, 7]);
    }

    #[test]
    fn project_to_finest_composes_all_maps() {
        let mut b = GraphBuilder::new(64);
        for v in 0..64u32 {
            b.edge(v, (v + 1) % 64);
            b.edge((v + 1) % 64, v);
        }
        let g = b.build();
        let h = Hierarchy::build(&g, 8, 3, u64::MAX);
        assert!(h.levels() >= 2, "64-ring must coarsen more than once");
        let coarsest_n = h.coarsest().unwrap().num_vertices();
        let coarse_labels: Vec<u32> = (0..coarsest_n as u32).collect();
        let fine = project_to_finest(&h, coarse_labels);
        assert_eq!(fine.len(), 64);
        // Every fine vertex carries exactly its cluster's id, so the
        // composed map partitions the fine vertex set into clusters of
        // total size 64.
        let mut counts = vec![0u32; coarsest_n];
        for &l in &fine {
            counts[l as usize] += 1;
        }
        assert_eq!(counts.iter().sum::<u32>(), 64);
        assert!(counts.iter().all(|&c| c >= 1));
    }
}
