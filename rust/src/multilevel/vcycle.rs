//! The V-cycle: coarsest-level partition → per-level bounded refinement
//! → deterministic ε-rebalance, walking the hierarchy back to the fine
//! graph.

use crate::config::RevolverConfig;
use crate::graph::Graph;
use crate::lp::neighbor_histogram;
use crate::metrics::quality;
use crate::metrics::trace::{RunTrace, TracePoint};
use crate::partitioners::{by_name, PartitionOutput, Partitioner};
use crate::util::Stopwatch;
use crate::{Label, VertexId};

use super::coarsen::Hierarchy;
use super::project::{project, project_to_finest};

/// Which vertex program refines each level (both run through
/// [`crate::engine::run_with_init`] with the projected labels as the
/// initial assignment).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Refiner {
    /// Spinner LP — the default: LP benefits most from a near-good
    /// seed, and its BSP steps are the cheapest per superstep.
    Spinner,
    /// Revolver — each vertex's LA row starts biased toward its
    /// projected label (the streaming warm-start machinery reused).
    Revolver,
}

/// Build the coarsening stack `cfg` asks for. The target level size is
/// raised to `2·parts` so the coarsest balance problem stays feasible,
/// and the pair-weight cap keeps every cluster under ~1.5× the average
/// coarsest cluster — far below a balanced partition's share.
pub fn hierarchy_for(g: &Graph, cfg: &RevolverConfig) -> Hierarchy {
    let target = cfg.coarsen_until.max(2 * cfg.parts);
    let max_pair = (3 * g.total_vertex_weight() / (2 * target as u64)).max(2);
    Hierarchy::build(g, target, cfg.seed, max_pair)
}

/// The coarsest-level labels projected straight to the finest level with
/// **no** refinement — the baseline every refinement level must improve
/// on (and the ablation knob for measuring what the V-cycle adds).
/// Deterministic and hierarchy-identical to what
/// [`Multilevel::partition`] starts from.
pub fn coarse_projection(g: &Graph, cfg: &RevolverConfig) -> Vec<Label> {
    let h = hierarchy_for(g, cfg);
    let coarsest: &Graph = h.coarsest().map(|c| c.graph()).unwrap_or(g);
    let out = by_name(&cfg.coarse_algo, cfg.clone())
        .expect("coarse_algo is validated against the registry")
        .partition(coarsest);
    project_to_finest(&h, out.labels)
}

/// Bound on full rebalance sweeps; each sweep strictly reduces overload
/// or exits, so this only guards pathological mass distributions.
const MAX_REBALANCE_PASSES: usize = 16;

/// Deterministically drain every partition above C = (1+ε)·(Σ mass)/k by
/// moving the cheapest boundary vertices (smallest locality loss, by the
/// undirected weighted histogram) into the best-connected partition with
/// room. Engine refinement only *gates* inflow at C — a projected or
/// streamed start can exceed it, and the migration gate alone cannot
/// force a drain. Mass is [`Graph::load_mass`]: out-degree on plain
/// graphs, coarse vertex weight on contractions, so intermediate levels
/// rebalance in coarse-vertex-weight units. Returns the number of moves.
pub fn rebalance(g: &Graph, labels: &mut [Label], k: usize, epsilon: f64) -> u64 {
    let n = g.num_vertices();
    debug_assert_eq!(labels.len(), n);
    let cap = (1.0 + epsilon) * g.total_load_mass() as f64 / k as f64;
    // Same load_mass units as the reported max_normalized_load — reuse
    // the metric's accounting so they can never diverge.
    let mut loads = quality::partition_loads(g, labels, k);

    let mut moves = 0u64;
    let mut hist = vec![0.0f32; k];
    for _pass in 0..MAX_REBALANCE_PASSES {
        if !loads.iter().any(|&b| b as f64 > cap) {
            break;
        }
        // Collect one candidate move per vertex of an overloaded
        // partition: its best in-capacity target and the local-edge
        // weight it would give up.
        let mut cands: Vec<(f32, VertexId, Label)> = Vec::new();
        for v in 0..n {
            let cur = labels[v] as usize;
            if loads[cur] as f64 <= cap {
                continue;
            }
            let mass = g.load_mass(v as VertexId) as u64;
            if mass == 0 {
                continue; // moving it changes no load
            }
            let vid = v as VertexId;
            neighbor_histogram(
                g.neighbors(vid),
                g.neighbor_weights(vid),
                |u| labels[u as usize],
                &mut hist,
            );
            let mut best: Option<usize> = None;
            for l in 0..k {
                if l == cur || (loads[l] + mass) as f64 > cap {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => hist[l] > hist[b] || (hist[l] == hist[b] && loads[l] < loads[b]),
                };
                if better {
                    best = Some(l);
                }
            }
            if let Some(t) = best {
                cands.push((hist[cur] - hist[t], vid, t as Label));
            }
        }
        if cands.is_empty() {
            break; // nothing movable (e.g. one vertex heavier than C)
        }
        cands.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        let mut moved_any = false;
        for &(_, v, t) in &cands {
            let cur = labels[v as usize] as usize;
            if loads[cur] as f64 <= cap {
                continue; // source already drained
            }
            let mass = g.load_mass(v) as u64;
            let mut t = t as usize;
            if (loads[t] + mass) as f64 > cap {
                // Preferred target filled earlier this sweep. Fall back
                // to the lightest partition with room so one sweep can
                // drain into arbitrarily many partitions (with tied
                // histograms every candidate prefers the same
                // sweep-start-lightest target; without this fallback a
                // concentrated start fills only one partition per sweep
                // and large k exhausts the pass bound). Balance is the
                // hard constraint — locality was only the tie-break for
                // the lost preferred target.
                match (0..k)
                    .filter(|&l| l != cur && (loads[l] + mass) as f64 <= cap)
                    .min_by_key(|&l| loads[l])
                {
                    Some(l) => t = l,
                    None => continue,
                }
            }
            labels[v as usize] = t as Label;
            loads[cur] -= mass;
            loads[t] += mass;
            moves += 1;
            moved_any = true;
        }
        if !moved_any {
            break;
        }
    }
    moves
}

/// Multilevel partitioner: heavy-edge coarsen, partition the coarsest
/// graph with any registered algorithm (`cfg.coarse_algo`, default
/// `fennel`), then refine + rebalance at every level on the way back
/// down. The output trace carries one point whose `step` encodes the
/// total refinement supersteps spent across all levels, so equal-budget
/// comparisons against flat Spinner/Revolver read it directly.
pub struct Multilevel {
    cfg: RevolverConfig,
    refiner: Refiner,
}

impl Multilevel {
    /// Spinner-refined V-cycle (the `multilevel` / `ml-spinner` names).
    pub fn new(cfg: RevolverConfig) -> Self {
        Self::with_refiner(cfg, Refiner::Spinner)
    }

    /// V-cycle with an explicit refiner (`ml-revolver`).
    pub fn with_refiner(cfg: RevolverConfig, refiner: Refiner) -> Self {
        cfg.validate().expect("invalid config");
        Multilevel { cfg, refiner }
    }

    fn refine_level(
        &self,
        g: &Graph,
        labels: Vec<Label>,
        cfg: &RevolverConfig,
        total_steps: &mut u32,
        total_evaluated: &mut u64,
    ) -> Result<Vec<Label>, crate::engine::EngineError> {
        let out = match self.refiner {
            Refiner::Spinner => crate::partitioners::spinner::refine(g, cfg, labels)?,
            Refiner::Revolver => crate::partitioners::revolver::refine(g, cfg, labels)?,
        };
        *total_steps = total_steps.saturating_add(out.trace.steps());
        *total_evaluated = total_evaluated.saturating_add(out.trace.total_evaluated);
        Ok(out.labels)
    }
}

impl Partitioner for Multilevel {
    fn name(&self) -> &'static str {
        match self.refiner {
            Refiner::Spinner => "multilevel",
            Refiner::Revolver => "ml-revolver",
        }
    }

    fn try_partition(&self, g: &Graph) -> Result<PartitionOutput, crate::engine::EngineError> {
        let sw = Stopwatch::start();
        let _run = crate::obs::span("multilevel");
        let obs_on = crate::obs::enabled();
        let cfg = &self.cfg;
        let k = cfg.parts;

        let h = {
            let _s = crate::obs::span("coarsen");
            if obs_on {
                crate::obs::progress().set_phase("multilevel/coarsen");
            }
            hierarchy_for(g, cfg)
        };
        let coarsest: &Graph = h.coarsest().map(|c| c.graph()).unwrap_or(g);

        // Coarsest level: any registered algorithm (streaming passes
        // contribute no supersteps to the budget — they are one sweep).
        let coarse = {
            let _s = crate::obs::span("coarse_partition");
            if obs_on {
                crate::obs::progress().set_phase("multilevel/coarse_partition");
            }
            by_name(&cfg.coarse_algo, cfg.clone())
                .expect("coarse_algo is validated against the registry")
                .try_partition(coarsest)?
        };
        let mut labels = coarse.labels;
        let mut total_steps = coarse.trace.steps();
        let mut total_evaluated = coarse.trace.total_evaluated;

        // Per-level refinement budget; halting (cfg.halt_window/theta)
        // may finish a level early, which the budget accounting sees —
        // and under `cfg.frontier` each level's refinement also skips
        // settled vertices and halts on an empty frontier (bounded
        // refinement is exactly the few-vertices-still-moving regime).
        let mut refine_cfg = cfg.clone();
        refine_cfg.max_steps = cfg.refine_steps;
        // Per-level refinement passes must never interleave their own
        // snapshots with an outer run's checkpoint stream: resume
        // semantics belong to the top-level run only.
        refine_cfg.checkpoint_dir.clear();

        crate::obs::event(
            "ml_level",
            &[("level", h.levels() as f64), ("vertices", coarsest.num_vertices() as f64)],
        );
        {
            let _s = crate::obs::span("refine");
            labels = self.refine_level(
                coarsest,
                labels,
                &refine_cfg,
                &mut total_steps,
                &mut total_evaluated,
            )?;
        }
        {
            let _s = crate::obs::span("rebalance");
            rebalance(coarsest, &mut labels, k, cfg.epsilon);
        }

        for lev in (0..h.levels()).rev() {
            {
                let _s = crate::obs::span("project");
                labels = project(&labels, &h.maps[lev]);
            }
            let lg: &Graph = if lev == 0 { g } else { h.graphs[lev - 1].graph() };
            crate::obs::event(
                "ml_level",
                &[("level", lev as f64), ("vertices", lg.num_vertices() as f64)],
            );
            {
                let _s = crate::obs::span("refine");
                labels = self.refine_level(
                    lg,
                    labels,
                    &refine_cfg,
                    &mut total_steps,
                    &mut total_evaluated,
                )?;
            }
            {
                let _s = crate::obs::span("rebalance");
                rebalance(lg, &mut labels, k, cfg.epsilon);
            }
        }

        let q = quality::evaluate(g, &labels, k);
        let mut trace = RunTrace::default();
        trace.push(TracePoint {
            step: total_steps.max(1) - 1,
            local_edges: q.local_edges,
            max_normalized_load: q.max_normalized_load,
            mean_score: 0.0,
            migrations: 0,
            evaluated: 0, // summary point; the run total lives below
            elapsed_s: sw.elapsed_s(),
        });
        trace.total_evaluated = total_evaluated;
        trace.wall_time_s = sw.elapsed_s();
        Ok(PartitionOutput { labels, trace })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::rmat;
    use crate::graph::GraphBuilder;

    fn cfg(k: usize) -> RevolverConfig {
        RevolverConfig {
            parts: k,
            threads: 2,
            seed: 9,
            coarsen_until: 32,
            refine_steps: 5,
            ..Default::default()
        }
    }

    #[test]
    fn multilevel_produces_valid_balanced_labels() {
        let g = rmat::rmat(1 << 10, 8 << 10, 0.57, 0.19, 0.19, 3);
        let k = 4;
        let out = Multilevel::new(cfg(k)).partition(&g);
        assert_eq!(out.labels.len(), g.num_vertices());
        assert!(out.labels.iter().all(|&l| l < k as u32));
        let mnl = quality::max_normalized_load(&g, &out.labels, k);
        assert!(mnl <= 1.05 + 1e-9, "rebalance must enforce the ε envelope: {mnl}");
        assert!(out.trace.steps() >= 1, "budget accounting must see refinement steps");
    }

    #[test]
    fn deterministic_single_thread() {
        let g = rmat::rmat(512, 4096, 0.57, 0.19, 0.19, 4);
        let mut c = cfg(4);
        c.threads = 1;
        let a = Multilevel::new(c.clone()).partition(&g);
        let b = Multilevel::new(c).partition(&g);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn revolver_refiner_runs() {
        let g = rmat::rmat(512, 4096, 0.57, 0.19, 0.19, 5);
        let mut c = cfg(4);
        c.refine_steps = 3;
        let out = Multilevel::with_refiner(c, Refiner::Revolver).partition(&g);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn small_graph_without_hierarchy_still_partitions() {
        // |V| at most the coarsening target: the hierarchy is empty and
        // the V-cycle degenerates to coarse-algo + one refinement on
        // the input graph itself (the `unwrap_or(g)` fallback).
        let g = rmat::rmat(64, 512, 0.57, 0.19, 0.19, 6);
        let mut c = cfg(4);
        c.coarsen_until = 64;
        assert_eq!(hierarchy_for(&g, &c).levels(), 0, "must exercise the empty hierarchy");
        let out = Multilevel::new(c).partition(&g);
        assert_eq!(out.labels.len(), 64);
        assert!(out.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn rebalance_drains_overloaded_partition() {
        // Path graph, everything in partition 0 of 2: grossly over C.
        let mut b = GraphBuilder::new(64);
        for v in 0..63u32 {
            b.edge(v, v + 1);
        }
        let g = b.build();
        let mut labels = vec![0u32; 64];
        let moves = rebalance(&g, &mut labels, 2, 0.05);
        assert!(moves > 0);
        let mnl = quality::max_normalized_load(&g, &labels, 2);
        assert!(mnl <= 1.05 + 1e-9, "mnl={mnl}");
    }

    #[test]
    fn rebalance_is_a_noop_when_balanced() {
        let mut b = GraphBuilder::new(9);
        for v in 0..8u32 {
            b.edge(v, v + 1);
        }
        let g = b.build();
        // Alternating labels: loads 4/4 of 8 edges, both under
        // C = 1.05·8/2 = 4.2, so the pass loop's balanced early-exit
        // fires and nothing moves.
        let mut labels: Vec<u32> = (0..9).map(|v| v % 2).collect();
        let before = labels.clone();
        assert_eq!(rebalance(&g, &mut labels, 2, 0.05), 0);
        assert_eq!(labels, before);
    }

    #[test]
    fn rebalance_respects_vertex_weight_units() {
        // Weighted graph: vertex weights 4,1,1,1,1 — partition 0 holds
        // {0,1} = mass 5 of total 8, C = (1.05·8)/2 = 4.2 ⇒ overloaded;
        // only moving a light vertex fits partition 1 (4+... no: moving
        // v0 (mass 4) into partition 1 (mass 3) gives 7 > C, so the
        // rebalance must move v1 instead).
        let mut b = crate::graph::WeightedGraphBuilder::new(5);
        b.edge(0, 1, 1.0).edge(1, 2, 1.0).edge(2, 3, 1.0).edge(3, 4, 1.0);
        let g = b.vertex_weights(vec![4, 1, 1, 1, 1]).build();
        let mut labels = vec![0, 0, 1, 1, 1];
        let moves = rebalance(&g, &mut labels, 2, 0.05);
        assert_eq!(moves, 1);
        assert_eq!(labels[0], 0, "heavy vertex cannot fit the other side");
        assert_eq!(labels[1], 1, "light vertex drains the overload");
    }

    #[test]
    fn coarse_projection_matches_vcycle_hierarchy() {
        let g = rmat::rmat(512, 4096, 0.57, 0.19, 0.19, 7);
        let c = cfg(4);
        let a = coarse_projection(&g, &c);
        let b = coarse_projection(&g, &c);
        assert_eq!(a, b, "projection baseline must be deterministic");
        assert_eq!(a.len(), 512);
        assert!(a.iter().all(|&l| l < 4));
    }
}
