//! Randomized heavy-edge matching (HEM) — the coarsening kernel.
//!
//! Visit vertices in a seeded random order; each unmatched vertex
//! matches its heaviest unmatched neighbour by the eq.-(4) undirected
//! weight ŵ (accumulated contraction weight on coarser levels).
//! Contracting heavy edges first removes the most intra-cluster weight
//! per level, which is what makes the coarse cut a faithful proxy for
//! the fine one.
//!
//! Two guards keep power-law graphs well-behaved:
//! * **hub degree cap** — a hub only *scans* a bounded, evenly-strided
//!   sample of its neighbour list ([`HUB_NEIGHBOR_CAP`]), so one pass
//!   stays O(|E|) with a small constant even when a vertex owns a
//!   percent of all edges (hubs still get matched — by themselves or by
//!   a neighbour whose scan reaches them);
//! * **pair-weight cap** — two vertices whose combined cluster size
//!   exceeds `max_pair_weight` never match, so no coarse vertex grows
//!   past a fraction of a balanced partition and the coarsest-level
//!   balance problem stays feasible.

use crate::graph::Graph;
use crate::util::rng::Rng;
use crate::VertexId;

/// Most neighbours a single vertex scans when looking for its mate.
/// Hubs sample their list with an even stride instead of walking all of
/// it; 64 comfortably covers the heavy head of a weight distribution.
pub const HUB_NEIGHBOR_CAP: usize = 64;

/// Compute a matching of `g`: `mate[v] == u` and `mate[u] == v` for a
/// matched pair, `mate[v] == v` for an unmatched vertex. Pairs are
/// always adjacent, and no pair's combined vertex weight exceeds
/// `max_pair_weight`. Deterministic in (`g`, `seed`).
pub fn heavy_edge_matching(g: &Graph, seed: u64, max_pair_weight: u64) -> Vec<VertexId> {
    let n = g.num_vertices();
    let mut order: Vec<VertexId> = (0..n as VertexId).collect();
    Rng::new(seed ^ 0x4845_4D5F_5243_4C52).shuffle(&mut order);

    let mut mate: Vec<VertexId> = (0..n as VertexId).collect();
    for &v in &order {
        if mate[v as usize] != v {
            continue; // already matched by an earlier vertex
        }
        let nbrs = g.neighbors(v);
        let ws = g.neighbor_weights(v);
        let deg = nbrs.len();
        if deg == 0 {
            continue;
        }
        let wv = g.vertex_weight(v) as u64;

        let mut best_w = 0.0f32;
        let mut best_comb = u64::MAX;
        let mut best_u: Option<VertexId> = None;
        let scans = deg.min(HUB_NEIGHBOR_CAP);
        for j in 0..scans {
            // Even stride over the (sorted) neighbour list when capped;
            // identity when not. Indices are strictly increasing, so no
            // neighbour is scanned twice.
            let i = if deg <= HUB_NEIGHBOR_CAP { j } else { j * deg / scans };
            let u = nbrs[i];
            if mate[u as usize] != u {
                continue; // taken
            }
            let w = ws[i];
            let comb = wv + g.vertex_weight(u) as u64;
            if comb > max_pair_weight {
                continue; // would create an unbalanceable cluster
            }
            // Heaviest edge wins; ties prefer the lighter cluster, then
            // the lower id — fully deterministic.
            let better = match best_u {
                None => true,
                Some(bu) => {
                    w > best_w || (w == best_w && (comb < best_comb || (comb == best_comb && u < bu)))
                }
            };
            if better {
                best_w = w;
                best_comb = comb;
                best_u = Some(u);
            }
        }
        if let Some(u) = best_u {
            mate[v as usize] = u;
            mate[u as usize] = v;
        }
    }
    mate
}

/// Total ŵ of the matched edges — the weight a contraction of `mate`
/// removes from the graph (the edge-conservation invariant: coarse
/// total = fine total − matched total).
pub fn matched_weight(g: &Graph, mate: &[VertexId]) -> f64 {
    let mut total = 0.0f64;
    for v in 0..g.num_vertices() {
        let m = mate[v];
        if (m as usize) <= v {
            continue; // count each pair once (and skip unmatched)
        }
        let nbrs = g.neighbors(v as VertexId);
        let i = nbrs
            .binary_search(&m)
            .expect("matched pairs are always adjacent");
        total += g.neighbor_weights(v as VertexId)[i] as f64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn check_is_matching(g: &Graph, mate: &[VertexId]) {
        assert_eq!(mate.len(), g.num_vertices());
        for v in 0..g.num_vertices() {
            let m = mate[v] as usize;
            assert!(m < g.num_vertices());
            // Involution: v's mate points back — no vertex in two pairs.
            assert_eq!(mate[m] as usize, v, "mate not symmetric at {v}");
            if m != v {
                assert!(
                    g.neighbors(v as VertexId).binary_search(&(m as VertexId)).is_ok(),
                    "matched pair ({v},{m}) must be adjacent"
                );
            }
        }
    }

    #[test]
    fn path_graph_matches_alternately() {
        let mut b = GraphBuilder::new(8);
        for v in 0..7u32 {
            b.edge(v, v + 1);
        }
        let g = b.build();
        let mate = heavy_edge_matching(&g, 1, u64::MAX);
        check_is_matching(&g, &mate);
        // A path admits a matching covering >= half the vertices; HEM is
        // maximal, so at most one unmatched vertex per matched pair.
        let matched = (0..8).filter(|&v| mate[v] != v as u32).count();
        assert!(matched >= 4, "{mate:?}");
    }

    #[test]
    fn prefers_heavy_edges() {
        // Two reciprocal (ŵ=2) pairs joined by a one-way (ŵ=1) bridge:
        // whichever vertex is visited first, every vertex's own heaviest
        // unmatched neighbour is its reciprocal partner, so the matching
        // is {0,1},{2,3} for every seed.
        let g = GraphBuilder::new(4)
            .edges(&[(0, 1), (1, 0), (2, 3), (3, 2), (0, 2)])
            .build();
        for seed in 0..10 {
            let mate = heavy_edge_matching(&g, seed, u64::MAX);
            check_is_matching(&g, &mate);
            assert_eq!(mate[0], 1, "seed {seed}: heavy edge must win");
            assert_eq!(mate[2], 3, "seed {seed}: heavy edge must win");
        }
    }

    #[test]
    fn pair_weight_cap_respected() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        // Every vertex weighs 1; cap 1 forbids all pairs.
        let mate = heavy_edge_matching(&g, 3, 1);
        assert!(mate.iter().enumerate().all(|(v, &m)| m as usize == v), "{mate:?}");
    }

    #[test]
    fn matched_weight_counts_each_pair_once() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 0), (2, 3)]).build();
        let mate = heavy_edge_matching(&g, 7, u64::MAX);
        check_is_matching(&g, &mate);
        // 0-1 (ŵ=2) and 2-3 (ŵ=1) are independent edges: both match.
        assert_eq!(mate[0], 1);
        assert_eq!(mate[2], 3);
        assert!((matched_weight(&g, &mate) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn isolated_vertices_stay_unmatched() {
        let g = GraphBuilder::new(5).edges(&[(0, 1)]).build();
        let mate = heavy_edge_matching(&g, 2, u64::MAX);
        check_is_matching(&g, &mate);
        for v in 2..5 {
            assert_eq!(mate[v] as usize, v);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        use crate::graph::gen::rmat;
        let g = rmat::rmat(256, 2048, 0.57, 0.19, 0.19, 9);
        let a = heavy_edge_matching(&g, 5, u64::MAX);
        let b = heavy_edge_matching(&g, 5, u64::MAX);
        assert_eq!(a, b);
        let c = heavy_edge_matching(&g, 6, u64::MAX);
        check_is_matching(&g, &c);
    }
}
