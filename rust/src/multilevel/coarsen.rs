//! Contraction of a matching into a coarse graph, and the hierarchy
//! stack built by repeated matching.

use crate::graph::{Graph, WeightedGraphBuilder};
use crate::VertexId;

use super::matching::heavy_edge_matching;

/// One level of the coarsening hierarchy: a weighted CSR where each
/// vertex stands for a cluster of fine vertices.
///
/// * vertex weight = cluster size (Σ of the fine vertices' weights);
/// * edge weight = accumulated eq.-(4) mass between the two clusters
///   (parallel fine edges merged by summing);
/// * the edge inside a matched pair vanishes (it became intra-cluster).
///
/// The inner [`Graph`] carries both, so the coarse level is directly
/// engine-runnable — refinement balance works in cluster-size units via
/// [`Graph::load_mass`].
pub struct CoarseGraph {
    graph: Graph,
    total_edge_weight: f64,
}

impl CoarseGraph {
    /// The engine-ready weighted graph of this level.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn num_vertices(&self) -> usize {
        self.graph.num_vertices()
    }

    /// Σ of the accumulated weights over distinct coarse edges (each
    /// counted once). Conservation invariant versus the finer level:
    /// `coarse total = fine total − matched-edge weight`.
    pub fn total_edge_weight(&self) -> f64 {
        self.total_edge_weight
    }
}

/// Contract `mate` (from [`heavy_edge_matching`]) over `g`. Returns the
/// coarse graph and the fine→coarse vertex map. Coarse ids are assigned
/// in ascending order of each cluster's smallest fine id, preserving
/// whatever id locality the fine ordering had.
pub fn contract(g: &Graph, mate: &[VertexId]) -> (CoarseGraph, Vec<VertexId>) {
    let n = g.num_vertices();
    debug_assert_eq!(mate.len(), n);

    let mut map = vec![VertexId::MAX; n];
    let mut cn: VertexId = 0;
    for v in 0..n {
        if map[v] != VertexId::MAX {
            continue; // second half of a pair whose first half assigned it
        }
        map[v] = cn;
        map[mate[v] as usize] = cn;
        cn += 1;
    }
    let cn = cn as usize;

    let mut cw = vec![0u32; cn];
    for v in 0..n {
        let c = map[v] as usize;
        cw[c] = cw[c]
            .checked_add(g.vertex_weight(v as VertexId))
            .expect("coarse cluster weight overflows u32 — the weight-conservation invariant would silently break");
    }

    // Each undirected fine edge once (u > v); matched-pair edges fold
    // away, parallel coarse edges accumulate inside the builder. Emit
    // *both* directions at half weight so the coarse forward CSR is
    // symmetric — out-degrees then mean "distinct coarse neighbours"
    // for every vertex (degree-balanced scheduling, BFS stream order),
    // while the mirrored undirected weights still sum to exactly the
    // accumulated fine weight (w/2 + w/2; halving is exact in binary).
    // Exact emission bound: 2 directed entries per undirected pair
    // (u > v), and pairs = und-entries/2 — so at most `num_und_entries`
    // pushes, whatever mix of one-way/symmetric edges the level has.
    let mut b = WeightedGraphBuilder::with_capacity(cn, g.num_und_entries());
    let mut total = 0.0f64;
    for v in 0..n {
        let nbrs = g.neighbors(v as VertexId);
        let ws = g.neighbor_weights(v as VertexId);
        for (&u, &w) in nbrs.iter().zip(ws) {
            if (u as usize) <= v {
                continue;
            }
            let (cv, cu) = (map[v], map[u as usize]);
            if cv == cu {
                continue;
            }
            b.edge(cv, cu, 0.5 * w);
            b.edge(cu, cv, 0.5 * w);
            total += w as f64;
        }
    }
    let graph = b.vertex_weights(cw).build();
    (CoarseGraph { graph, total_edge_weight: total }, map)
}

/// The full coarsening stack: `maps[i]` sends a level-`i` vertex to its
/// level-`i+1` cluster, `graphs[i]` is the level-`i+1` graph (level 0
/// is the caller's original graph, `graphs.last()` the coarsest).
pub struct Hierarchy {
    pub maps: Vec<Vec<VertexId>>,
    pub graphs: Vec<CoarseGraph>,
}

/// A level must shed at least 5% of its vertices or coarsening stops —
/// heavy matchings stall on star-like remainders, and stacking
/// near-identical levels only burns refinement budget.
const MIN_SHRINK: f64 = 0.05;

impl Hierarchy {
    /// Coarsen `g` by repeated heavy-edge matching until a level has at
    /// most `coarsen_until` vertices or shrinkage stalls. Each level
    /// derives its matching seed from `seed` + its depth, so the whole
    /// stack is deterministic.
    pub fn build(g: &Graph, coarsen_until: usize, seed: u64, max_pair_weight: u64) -> Hierarchy {
        let mut maps: Vec<Vec<VertexId>> = Vec::new();
        let mut graphs: Vec<CoarseGraph> = Vec::new();
        loop {
            let cur: &Graph = match graphs.last() {
                Some(c) => c.graph(),
                None => g,
            };
            let n = cur.num_vertices();
            if n <= coarsen_until {
                break;
            }
            let level = graphs.len() as u64;
            let mate = heavy_edge_matching(cur, seed.wrapping_add(level), max_pair_weight);
            // Coarse size = n − matched pairs: check the stall from the
            // matching alone, before paying for the contraction.
            let pairs = (0..n).filter(|&v| (mate[v] as usize) > v).count();
            if ((n - pairs) as f64) > (1.0 - MIN_SHRINK) * n as f64 {
                break; // stalled
            }
            let (cg, map) = contract(cur, &mate);
            debug_assert_eq!(cg.num_vertices(), n - pairs);
            maps.push(map);
            graphs.push(cg);
        }
        Hierarchy { maps, graphs }
    }

    /// Number of coarse levels (0 = the graph was already small enough).
    pub fn levels(&self) -> usize {
        self.graphs.len()
    }

    /// The coarsest level, if any coarsening happened.
    pub fn coarsest(&self) -> Option<&CoarseGraph> {
        self.graphs.last()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::multilevel::matching::matched_weight;

    fn ring(n: usize) -> Graph {
        let mut b = GraphBuilder::new(n);
        for v in 0..n as u32 {
            b.edge(v, (v + 1) % n as u32);
        }
        b.build()
    }

    #[test]
    fn contract_preserves_vertex_weight_total() {
        let g = ring(32);
        let mate = heavy_edge_matching(&g, 1, u64::MAX);
        let (cg, map) = contract(&g, &mate);
        assert_eq!(map.len(), 32);
        assert!(map.iter().all(|&c| (c as usize) < cg.num_vertices()));
        assert_eq!(cg.graph().total_vertex_weight(), 32);
        cg.graph().validate().unwrap();
    }

    #[test]
    fn contract_conserves_edge_weight() {
        let g = ring(64);
        let mate = heavy_edge_matching(&g, 2, u64::MAX);
        let (cg, _) = contract(&g, &mate);
        let fine_total = g.total_neighbor_weight() / 2.0;
        let removed = matched_weight(&g, &mate);
        assert!(
            (cg.total_edge_weight() - (fine_total - removed)).abs() < 1e-6,
            "coarse {} vs fine {} - matched {}",
            cg.total_edge_weight(),
            fine_total,
            removed
        );
        // The builder's accumulated und weights agree with the running
        // total the contraction kept.
        let und_total = cg.graph().total_neighbor_weight() / 2.0;
        assert!((und_total - cg.total_edge_weight()).abs() < 1e-6);
    }

    #[test]
    fn matched_pairs_map_to_one_coarse_vertex() {
        let g = ring(20);
        let mate = heavy_edge_matching(&g, 3, u64::MAX);
        let (_, map) = contract(&g, &mate);
        for v in 0..20usize {
            assert_eq!(map[v], map[mate[v] as usize], "pair must contract together");
        }
    }

    #[test]
    fn parallel_coarse_edges_merge() {
        // Square 0-1-2-3-0 with 0,1 and 2,3 matched: the two cross edges
        // (1,2) and (3,0) become parallel coarse edges and must merge
        // into one undirected coarse edge of weight 2 (stored as one
        // forward edge per direction — the symmetric CSR).
        let g = ring(4);
        let mate = vec![1, 0, 3, 2];
        let (cg, map) = contract(&g, &mate);
        assert_eq!(cg.num_vertices(), 2);
        assert_eq!(map, vec![0, 0, 1, 1]);
        assert_eq!(cg.graph().num_edges(), 2, "one merged edge per direction");
        assert_eq!(cg.graph().out_degree(0), 1);
        assert_eq!(cg.graph().out_degree(1), 1, "coarse CSR must be symmetric");
        assert_eq!(cg.graph().neighbor_weights(0), &[2.0]);
        assert_eq!(cg.graph().neighbor_weights(1), &[2.0]);
        assert!((cg.total_edge_weight() - 2.0).abs() < 1e-9);
        assert_eq!(cg.graph().vertex_weight(0), 2);
        assert_eq!(cg.graph().vertex_weight(1), 2);
    }

    #[test]
    fn hierarchy_reaches_target_and_is_deterministic() {
        use crate::graph::gen::rmat;
        let g = rmat::rmat(512, 4096, 0.57, 0.19, 0.19, 4);
        let h = Hierarchy::build(&g, 64, 7, u64::MAX);
        assert!(h.levels() >= 1);
        let coarsest = h.coarsest().unwrap();
        assert!(coarsest.num_vertices() <= 512);
        // Monotone shrinkage, weight conservation down the stack.
        let mut prev = g.num_vertices();
        for cg in &h.graphs {
            assert!(cg.num_vertices() < prev);
            prev = cg.num_vertices();
            assert_eq!(cg.graph().total_vertex_weight(), 512);
            cg.graph().validate().unwrap();
        }
        let h2 = Hierarchy::build(&g, 64, 7, u64::MAX);
        assert_eq!(h.levels(), h2.levels());
        for (a, b) in h.maps.iter().zip(&h2.maps) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn small_graph_yields_empty_hierarchy() {
        let g = ring(16);
        let h = Hierarchy::build(&g, 64, 1, u64::MAX);
        assert_eq!(h.levels(), 0);
        assert!(h.coarsest().is_none());
    }
}
